# L2 levelized graph evaluator vs the pure-python oracle.
import numpy as np
import jax.numpy as jnp
import pytest

from compile.model import graph_eval
from compile.kernels.ref import graph_eval_ref
from compile.opcodes import ADD, MUL, SUB, DIV, OPCODES

RNG = np.random.default_rng(7)


def random_dag(n, n_inputs, num_levels, pad_to=None):
    """Build a random levelized DAG in the padded-array encoding."""
    pad_to = pad_to or n
    src0 = np.arange(pad_to, dtype=np.int32)   # self-gather default
    src1 = np.arange(pad_to, dtype=np.int32)
    opcode = np.zeros(pad_to, np.int32)
    level = np.full(pad_to, -1, np.int32)
    level[:n_inputs] = 0
    per_level = max(1, (n - n_inputs) // num_levels)
    idx = n_inputs
    for l in range(1, num_levels + 1):
        # Sources must come from strictly lower levels: nodes at the same
        # level fire with start-of-level values in the jnp model.
        level_start = idx
        for _ in range(per_level):
            if idx >= n:
                break
            lo = int(RNG.integers(0, level_start))
            hi = int(RNG.integers(0, level_start))
            src0[idx], src1[idx] = lo, hi
            opcode[idx] = int(RNG.integers(0, 3))  # ADD/MUL/SUB keep values sane
            level[idx] = l
            idx += 1
    vals0 = np.zeros(pad_to, np.float32)
    vals0[:n_inputs] = RNG.standard_normal(n_inputs).astype(np.float32)
    return vals0, src0, src1, opcode, level, num_levels


def run_both(vals0, src0, src1, opcode, level, lmax, block=64):
    got = np.asarray(graph_eval(
        jnp.asarray(vals0), jnp.asarray(src0), jnp.asarray(src1),
        jnp.asarray(opcode), jnp.asarray(level), lmax=lmax, block=block))
    want = graph_eval_ref(vals0, src0, src1, opcode, level, lmax)
    return got, want


def test_single_add():
    vals0 = np.array([2.0, 3.0, 0.0, 0.0], np.float32)
    src0 = np.array([0, 1, 0, 3], np.int32)
    src1 = np.array([0, 1, 1, 3], np.int32)
    opcode = np.array([0, 0, ADD, 0], np.int32)
    level = np.array([0, 0, 1, -1], np.int32)
    got, want = run_both(vals0, src0, src1, opcode, level, 1, block=4)
    assert got[2] == 5.0
    np.testing.assert_array_equal(got, want)


def test_diamond_dependency():
    #   v0, v1 inputs; a = v0+v1; b = v0*v1; c = a-b
    vals0 = np.zeros(8, np.float32)
    vals0[0], vals0[1] = 3.0, 4.0
    src0 = np.array([0, 1, 0, 0, 2, 5, 6, 7], np.int32)
    src1 = np.array([0, 1, 1, 1, 3, 5, 6, 7], np.int32)
    opcode = np.array([0, 0, ADD, MUL, SUB, 0, 0, 0], np.int32)
    level = np.array([0, 0, 1, 1, 2, -1, -1, -1], np.int32)
    got, want = run_both(vals0, src0, src1, opcode, level, 2, block=8)
    assert got[4] == (3.0 + 4.0) - (3.0 * 4.0)
    np.testing.assert_array_equal(got, want)


def test_deep_chain():
    n = 64
    vals0 = np.zeros(n, np.float32)
    vals0[0] = 1.0
    src0 = np.arange(n, dtype=np.int32)
    src1 = np.arange(n, dtype=np.int32)
    opcode = np.zeros(n, np.int32)
    level = np.full(n, -1, np.int32)
    level[0] = 0
    for i in range(1, 40):
        src0[i] = i - 1
        src1[i] = i - 1
        opcode[i] = ADD  # doubles each step
        level[i] = i
    got, want = run_both(vals0, src0, src1, opcode, level, 40, block=16)
    assert got[39] == 2.0 ** 39
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("seed", range(4))
def test_random_dags_match_oracle(seed):
    global RNG
    RNG = np.random.default_rng(seed)
    args = random_dag(n=192, n_inputs=24, num_levels=12, pad_to=256)
    got, want = run_both(*args)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_padding_slots_untouched():
    args = random_dag(n=40, n_inputs=8, num_levels=4, pad_to=64)
    vals0 = args[0].copy()
    vals0[40:] = 123.5
    got, _ = run_both(vals0, *args[1:5], args[5])
    np.testing.assert_array_equal(got[40:], np.full(24, 123.5, np.float32))


def test_lmax_truncates_deeper_levels():
    args = list(random_dag(n=64, n_inputs=8, num_levels=8, pad_to=64))
    got, _ = run_both(*args[:5], 3)  # only levels 1..3 evaluated
    want = graph_eval_ref(*args[:5], 3)
    np.testing.assert_allclose(got, want, rtol=1e-6)
