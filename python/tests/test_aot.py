# AOT path: every artifact lowers to parseable HLO text with the right
# entry signature, and the manifest captures the geometry.
import json
import subprocess
import sys

import pytest

from compile.aot import (lower_alu, lower_lod, lower_graph_eval, to_hlo_text)


@pytest.fixture(scope="module")
def alu_text():
    return to_hlo_text(lower_alu(512))


def test_alu_hlo_has_entry(alu_text):
    assert "ENTRY" in alu_text
    assert "f32[512]" in alu_text
    assert "s32[512]" in alu_text


def test_alu_hlo_returns_tuple(alu_text):
    # return_tuple=True => root is a tuple of one f32[512]
    assert "(f32[512]" in alu_text


def test_lod_hlo_shapes():
    text = to_hlo_text(lower_lod(64))
    assert "ENTRY" in text
    assert "s32[64]" in text
    assert "s32[1]" in text


def test_graph_eval_hlo_shapes():
    text = to_hlo_text(lower_graph_eval(512, 16))
    assert "ENTRY" in text
    assert "f32[512]" in text
    # fori_loop lowers to a while op
    assert "while" in text


def test_cli_writes_all_artifacts(tmp_path):
    import pathlib
    python_dir = pathlib.Path(__file__).resolve().parents[1]
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--alu-batch", "256", "--lod-words", "16",
         "--graph-n", "256", "--graph-lmax", "8"],
        check=True, cwd=str(python_dir),  # so `compile` is importable
    )
    for name in ("alu_batch", "lod", "graph_eval"):
        assert (out / f"{name}.hlo.txt").exists()
    assert (out / "manifest.json").exists()


def test_manifest_roundtrip(tmp_path):
    import compile.aot as aot
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--alu-batch", "256",
                "--lod-words", "16", "--graph-n", "256", "--graph-lmax", "8"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["format"] == "hlo-text"
    assert man["artifacts"]["alu_batch"]["batch"] == 256
    assert man["artifacts"]["graph_eval"]["n"] == 256
    assert man["opcodes"]["0"]["name"] == "ADD"
    for art in man["artifacts"].values():
        assert (tmp_path / art["file"]).exists()
