# L1 LOD kernel vs the reference priority encoder (paper §II-B semantics).
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lod import lod_pick, NO_READY, WORD_BITS
from compile.kernels.ref import lod_ref


def pick(words_u32):
    w = np.asarray(words_u32, np.uint32).astype(np.int32)  # reinterpret bits
    return int(np.asarray(lod_pick(jnp.asarray(w)))[0])


def test_all_zero_returns_sentinel():
    assert pick(np.zeros(128, np.uint32)) == NO_READY


@pytest.mark.parametrize("node", [0, 1, 31, 32, 33, 255, 4095])
def test_single_bit(node):
    words = np.zeros(128, np.uint32)
    words[node // WORD_BITS] |= np.uint32(1 << (node % WORD_BITS))
    assert pick(words) == node


def test_picks_lowest_node_id():
    words = np.zeros(128, np.uint32)
    for node in (4000, 37, 2048, 38):
        words[node // WORD_BITS] |= np.uint32(1 << (node % WORD_BITS))
    assert pick(words) == 37


def test_msb_of_word_zero_beats_lsb_of_word_one():
    words = np.zeros(8, np.uint32)
    words[0] = np.uint32(1 << 31)  # node 31
    words[1] = np.uint32(1)        # node 32
    assert pick(words) == 31


def test_full_words():
    words = np.full(16, 0xFFFFFFFF, dtype=np.uint32)
    assert pick(words) == 0


def test_sign_bit_word():
    # Word value with bit 31 set only — exercises the int32 reinterpret.
    words = np.zeros(4, np.uint32)
    words[2] = np.uint32(0x80000000)  # node 2*32+31 = 95
    assert pick(words) == 95


@settings(max_examples=60, deadline=None)
@given(
    w=st.sampled_from([1, 4, 16, 128, 256]),
    data=st.data(),
)
def test_matches_reference_on_random_vectors(w, data):
    words = np.array(
        data.draw(st.lists(st.integers(0, 2**32 - 1), min_size=w, max_size=w)),
        dtype=np.uint32)
    assert pick(words) == lod_ref(words)
