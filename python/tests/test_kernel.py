# pytest: L1 Pallas ALU kernel vs pure-jnp ref — the CORE correctness signal.
import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels.alu import alu_batch, vmem_bytes, DEFAULT_BLOCK
from compile.kernels.ref import alu_ref, alu_scalar
from compile.opcodes import ADD, MUL, SUB, DIV, MAX, MIN, NEG, COPY, OPCODES

RNG = np.random.default_rng(0xA10)


def run_both(a, b, op, block=DEFAULT_BLOCK):
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    op = jnp.asarray(op, jnp.int32)
    got = np.asarray(alu_batch(a, b, op, block=block))
    want = np.asarray(alu_ref(a, b, op))
    return got, want


@pytest.mark.parametrize("opcode", sorted(OPCODES))
def test_single_opcode_batches(opcode):
    n = DEFAULT_BLOCK * 2
    a = RNG.standard_normal(n).astype(np.float32) * 10
    b = RNG.standard_normal(n).astype(np.float32) * 10
    got, want = run_both(a, b, np.full(n, opcode, np.int32))
    np.testing.assert_array_equal(got, want)


def test_mixed_opcodes_bitexact():
    n = DEFAULT_BLOCK * 4
    a = RNG.standard_normal(n).astype(np.float32)
    b = RNG.standard_normal(n).astype(np.float32)
    op = RNG.integers(0, len(OPCODES), n).astype(np.int32)
    got, want = run_both(a, b, op)
    np.testing.assert_array_equal(got, want)


def test_against_scalar_oracle():
    n = DEFAULT_BLOCK
    a = RNG.standard_normal(n).astype(np.float32)
    b = (RNG.standard_normal(n).astype(np.float32) + 3.0)  # avoid div-by-0
    op = RNG.integers(0, len(OPCODES), n).astype(np.int32)
    got, _ = run_both(a, b, op)
    want = np.array([alu_scalar(int(o), float(x), float(y))
                     for o, x, y in zip(op, a, b)], np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_div_by_zero_is_ieee_inf():
    n = DEFAULT_BLOCK
    a = np.full(n, 3.0, np.float32)
    b = np.zeros(n, np.float32)
    got, want = run_both(a, b, np.full(n, DIV, np.int32))
    assert np.all(np.isinf(got))
    np.testing.assert_array_equal(got, want)


def test_nan_propagates():
    n = DEFAULT_BLOCK
    a = np.full(n, np.nan, np.float32)
    b = np.ones(n, np.float32)
    got, _ = run_both(a, b, np.full(n, ADD, np.int32))
    assert np.all(np.isnan(got))


def test_unknown_opcode_passes_a_through():
    n = DEFAULT_BLOCK
    a = RNG.standard_normal(n).astype(np.float32)
    b = RNG.standard_normal(n).astype(np.float32)
    got, _ = run_both(a, b, np.full(n, 99, np.int32))
    np.testing.assert_array_equal(got, a)


@pytest.mark.parametrize("block", [8, 64, 256, 512])
def test_block_shape_invariance(block):
    """Result must not depend on the VMEM tile size."""
    n = 1024
    a = RNG.standard_normal(n).astype(np.float32)
    b = RNG.standard_normal(n).astype(np.float32)
    op = RNG.integers(0, len(OPCODES), n).astype(np.int32)
    got, want = run_both(a, b, op, block=block)
    np.testing.assert_array_equal(got, want)


def test_non_multiple_batch_rejected():
    a = jnp.zeros(100, jnp.float32)
    with pytest.raises(AssertionError):
        alu_batch(a, a, jnp.zeros(100, jnp.int32), block=64)


def test_vmem_footprint_under_budget():
    # 4 arrays * block * 4B must sit far below a 16 MiB VMEM.
    assert vmem_bytes(DEFAULT_BLOCK) <= 16 * 1024  # 4 KiB with default tile
    assert vmem_bytes(128 * 1024) < 16 * 1024 * 1024
