# hypothesis sweep: ALU kernel shape/dtype/value space vs the jnp reference.
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.alu import alu_batch
from compile.kernels.ref import alu_ref
from compile.opcodes import OPCODES

finite_f32 = st.floats(
    min_value=-(2.0 ** 96), max_value=2.0 ** 96,
    allow_nan=False, allow_infinity=False,
    width=32, allow_subnormal=False,
).map(lambda x: float(np.float32(x)))


@settings(max_examples=40, deadline=None)
@given(
    data=st.data(),
    blocks=st.integers(min_value=1, max_value=6),
    block=st.sampled_from([8, 32, 128]),
)
def test_alu_matches_ref_on_random_batches(data, blocks, block):
    n = blocks * block
    a = np.array(data.draw(st.lists(finite_f32, min_size=n, max_size=n)),
                 np.float32)
    b = np.array(data.draw(st.lists(finite_f32, min_size=n, max_size=n)),
                 np.float32)
    op = np.array(
        data.draw(st.lists(st.integers(0, len(OPCODES) - 1),
                           min_size=n, max_size=n)), np.int32)
    got = np.asarray(alu_batch(jnp.asarray(a), jnp.asarray(b),
                               jnp.asarray(op), block=block))
    want = np.asarray(alu_ref(a, b, op))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(
    special=st.lists(
        st.sampled_from([0.0, -0.0, np.inf, -np.inf, np.nan, 1e-38, -1e38]),
        min_size=32, max_size=32),
    op=st.integers(0, len(OPCODES) - 1),
)
def test_alu_special_values(special, op):
    a = np.array(special, np.float32)
    b = np.array(special[::-1], np.float32)
    ops = np.full(32, op, np.int32)
    got = np.asarray(alu_batch(jnp.asarray(a), jnp.asarray(b),
                               jnp.asarray(ops), block=32))
    want = np.asarray(alu_ref(a, b, ops))
    np.testing.assert_array_equal(
        np.isnan(got), np.isnan(want))
    mask = ~np.isnan(want)
    np.testing.assert_array_equal(got[mask], want[mask])
