"""L1 Pallas kernel: the TDP's floating-point dataflow ALU, batched.

The paper's PE contains two hardened FP DSP blocks (ADD / MULTIPLY mode,
single-stage pipeline).  On TPU the analogous unit is the VPU: an
elementwise, lane-parallel FP datapath.  One kernel invocation evaluates a
*batch* of fired dataflow nodes: given operand vectors ``a``, ``b`` and an
``opcode`` vector, it produces the result vector with a lane-wise opcode
mux (no divergence penalty — every lane evaluates the select chain, the
mux picks one result, exactly like the FPGA's opcode-steered DSP output
mux).

Hardware adaptation (DESIGN.md §Hardware-Adaptation):
  * batch is tiled into VMEM-resident blocks via BlockSpec — M20K operand
    scratchpad <-> VMEM;
  * MXU is deliberately not used: the workload is elementwise, the VPU is
    the roofline unit;
  * ``interpret=True`` everywhere — the CPU PJRT client cannot execute
    Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..opcodes import ADD, MUL, SUB, DIV, MAX, MIN, NEG, COPY

# Default tile: 8 sublanes x 128 lanes = one float32 VREG tile per operand.
DEFAULT_BLOCK = 256


def _alu_kernel(a_ref, b_ref, op_ref, o_ref):
    """Single-block ALU body: opcode-muxed select chain on the VPU."""
    a = a_ref[...]
    b = b_ref[...]
    op = op_ref[...]

    # Each lane computes all candidate results; the select chain is a
    # balanced mux (cheap on the VPU, mirrors the DSP output mux).
    # DIV guards b == 0 the way the FPGA reciprocal unit saturates:
    # x/0 -> inf with the sign of x (IEEE-754, which jnp already gives us).
    res = jnp.where(op == ADD, a + b,
          jnp.where(op == MUL, a * b,
          jnp.where(op == SUB, a - b,
          jnp.where(op == DIV, a / b,
          jnp.where(op == MAX, jnp.maximum(a, b),
          jnp.where(op == MIN, jnp.minimum(a, b),
          jnp.where(op == NEG, -a,
                    a)))))))  # COPY and any unknown opcode: pass a through
    o_ref[...] = res


@partial(jax.jit, static_argnames=("block",))
def alu_batch(a, b, opcode, *, block: int = DEFAULT_BLOCK):
    """Evaluate a batch of dataflow node operations.

    Args:
      a, b:    float32[B] operand vectors (b ignored for unary opcodes).
      opcode:  int32[B] opcode per lane (see compile.opcodes).
      block:   VMEM tile size; B must be a multiple of it.

    Returns:
      float32[B] results.
    """
    (n,) = a.shape
    assert n % block == 0, f"batch {n} not a multiple of block {block}"
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _alu_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        interpret=True,
    )(a.astype(jnp.float32), b.astype(jnp.float32), opcode.astype(jnp.int32))


def vmem_bytes(block: int = DEFAULT_BLOCK) -> int:
    """Estimated VMEM footprint of one ALU tile (3 inputs + 1 output).

    Used by DESIGN.md §Perf: footprint must stay well under ~16 MiB/core.
    """
    return 4 * block * 4
