"""L1 Pallas kernel: hierarchical leading-one detector (paper §II-B).

The paper's scheduler stores RDY bit-flags packed 32-per-BRAM-word and finds
the next ready node with a two-level priority encoder: an OuterLOD over a
summary vector picks the first non-empty flag word, an InnerLOD picks the
first set bit inside it.  Because graph memory is sorted by decreasing
criticality, "first set bit" == "most critical ready node".

Bit convention (shared with rust/src/lod): node ``w*32 + b`` maps to bit
``b`` (LSB-first) of word ``w``; the leading one is the *lowest* node id
with its flag set, i.e. trailing-zero-count order.  Rust uses
``u64::trailing_zeros`` over the same layout.

On TPU a carry-chain priority encoder has no direct analog; the kernel
computes, per word, ``min(lane index where bit set)`` with an iota + where
reduction on the VPU, then reduces across words — two reduction trees, the
vector analog of the paper's deterministic 2-cycle Outer/Inner pick.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NO_READY = 2**30  # sentinel: no flag set anywhere (fits int32)
WORD_BITS = 32


def _lod_kernel(words_ref, o_ref):
    """words: int32[W] packed flag words -> o: int32[1] leading node id."""
    words = words_ref[...]
    w = words.shape[0]
    # InnerLOD, all words in parallel: position of least-significant set bit.
    lanes = jax.lax.broadcasted_iota(jnp.int32, (w, WORD_BITS), 1)
    bits = (words[:, None] >> lanes) & 1
    inner = jnp.min(jnp.where(bits == 1, lanes, NO_READY), axis=1)
    # OuterLOD: first word with any bit set, combined into a global node id.
    word_idx = jax.lax.broadcasted_iota(jnp.int32, (w,), 0)
    node = jnp.where(inner < NO_READY, word_idx * WORD_BITS + inner, NO_READY)
    o_ref[0] = jnp.min(node)


@partial(jax.jit, static_argnames=())
def lod_pick(words):
    """Return the lowest set-bit node id across packed flag words.

    Args:
      words: int32[W] (bits interpreted as uint32), node w*32+b at bit b.

    Returns:
      int32[1]: leading node id, or NO_READY if all words are zero.
    """
    (w,) = words.shape
    return pl.pallas_call(
        _lod_kernel,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        interpret=True,
    )(words.astype(jnp.int32))
