"""Pure-jnp / pure-python correctness oracles for the L1 kernels and the
L2 levelized graph evaluator.  No Pallas here — these are the definitions
the kernels are tested against (pytest + hypothesis)."""

import jax.numpy as jnp
import numpy as np

from ..opcodes import ADD, MUL, SUB, DIV, MAX, MIN, NEG, COPY


def alu_ref(a, b, opcode):
    """Reference semantics of the dataflow ALU (matches kernels.alu)."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    op = jnp.asarray(opcode, jnp.int32)
    return jnp.where(op == ADD, a + b,
           jnp.where(op == MUL, a * b,
           jnp.where(op == SUB, a - b,
           jnp.where(op == DIV, a / b,
           jnp.where(op == MAX, jnp.maximum(a, b),
           jnp.where(op == MIN, jnp.minimum(a, b),
           jnp.where(op == NEG, -a, a)))))))


def alu_scalar(op: int, a: float, b: float) -> float:
    """Scalar python oracle — used by the graph evaluator reference."""
    if op == ADD:
        return a + b
    if op == MUL:
        return a * b
    if op == SUB:
        return a - b
    if op == DIV:
        return a / b if b != 0 else float(np.float32(a) / np.float32(b))
    if op == MAX:
        return max(a, b)
    if op == MIN:
        return min(a, b)
    if op == NEG:
        return -a
    return a  # COPY


def lod_ref(words) -> int:
    """Reference leading-one: lowest node id w*32+b with bit b of word w set."""
    words = np.asarray(words, dtype=np.uint32)
    for w, word in enumerate(words):
        word = int(word)
        if word:
            return w * 32 + (word & -word).bit_length() - 1
    return 2**30  # NO_READY


def graph_eval_ref(values0, src0, src1, opcode, level, num_levels):
    """Pure-python levelized evaluation oracle.

    Nodes with level 0 are graph inputs (value taken from values0);
    level l>0 nodes read the values of src0/src1 (indices into the value
    array) once all lower levels are done.  Padded slots carry level < 0
    and are left untouched.
    """
    vals = np.array(values0, dtype=np.float32).copy()
    src0 = np.asarray(src0)
    src1 = np.asarray(src1)
    opcode = np.asarray(opcode)
    level = np.asarray(level)
    for l in range(1, num_levels + 1):
        for i in np.nonzero(level == l)[0]:
            a = vals[src0[i]]
            b = vals[src1[i]]
            vals[i] = np.float32(alu_scalar(int(opcode[i]), float(a), float(b)))
    return vals
