# L2: functional golden model of what the overlay computes.
#
# The TDP overlay evaluates a dataflow graph; any scheduler / placement /
# overlay size must produce the same node values.  This module is the
# fixed-shape, levelized JAX formulation of that evaluation: one gather +
# one (masked) writeback per level, iterated with lax.fori_loop, with the
# inner arithmetic performed by the L1 Pallas ALU kernel so the kernel
# lowers into the same HLO artifact.
#
# Rust loads artifacts/graph_eval.hlo.txt and uses it as the numerics
# oracle for simulated executions (coordinator::validate).
import jax
import jax.numpy as jnp

from .kernels.alu import alu_batch

# Default padded artifact geometry (recorded in artifacts/manifest.json).
DEFAULT_N = 2048     # padded node-slot count
DEFAULT_LMAX = 256   # max dataflow depth (sparse-LU DAGs are deep)


def graph_eval(values0, src0, src1, opcode, level, *, lmax: int = DEFAULT_LMAX,
               block: int = 256):
    """Levelized dataflow-graph evaluation.

    Args:
      values0: float32[N] initial values (graph inputs at their node slots;
               anything for interior slots).
      src0:    int32[N] first-operand node index per node (self-index for
               inputs / padding — a harmless gather).
      src1:    int32[N] second-operand node index per node.
      opcode:  int32[N] ALU opcode per node (see compile.opcodes).
      level:   int32[N] dataflow (ASAP) level; 0 = graph input, negative =
               padding.  A node at level l only depends on levels < l.
      lmax:    static loop bound; levels beyond lmax are not evaluated.
      block:   Pallas ALU tile size.

    Returns:
      float32[N] final node values.
    """
    n = values0.shape[0]
    assert n % block == 0

    def body(l, vals):
        a = vals[src0]
        b = vals[src1]
        res = alu_batch(a, b, opcode, block=block)
        fire = level == l
        return jnp.where(fire, res, vals)

    return jax.lax.fori_loop(1, lmax + 1, body, values0.astype(jnp.float32))


def graph_eval_jit(lmax: int = DEFAULT_LMAX, block: int = 256):
    """A jitted graph_eval (tuple-returning) closed over static lmax/block,
    ready to ``.lower()`` for the AOT artifact."""
    def fn(values0, src0, src1, opcode, level):
        return (graph_eval(values0, src0, src1, opcode, level,
                           lmax=lmax, block=block),)
    return jax.jit(fn)
