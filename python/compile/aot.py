# AOT lowering: jax -> HLO *text* artifacts for the rust runtime.
#
# HLO text (NOT lowered.compile()/.serialize()) is the interchange format:
# jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
# crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
# parser reassigns ids and round-trips cleanly.  See
# /opt/xla-example/README.md and gen_hlo.py there.
#
# Emitted artifacts (all float32/int32, fixed padded shapes):
#   alu_batch.hlo.txt   — L1 batched dataflow ALU        (a, b, op) -> (out,)
#   lod.hlo.txt         — L1 hierarchical leading-one    (words,)   -> (idx,)
#   graph_eval.hlo.txt  — L2 levelized graph evaluation  (5 arrays) -> (vals,)
#   manifest.json       — shapes, batch sizes, opcode table (rust asserts
#                         its mirror of the opcode table matches).
#
# `make artifacts` runs this once; python never runs on the request path.
import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.alu import alu_batch, DEFAULT_BLOCK
from .kernels.lod import lod_pick
from .model import graph_eval_jit, DEFAULT_N, DEFAULT_LMAX
from .opcodes import OPCODES

DEFAULT_ALU_BATCH = 4096
DEFAULT_LOD_WORDS = 128


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_alu(batch: int):
    f32 = jax.ShapeDtypeStruct((batch,), jnp.float32)
    i32 = jax.ShapeDtypeStruct((batch,), jnp.int32)
    fn = jax.jit(lambda a, b, op: (alu_batch(a, b, op),))
    return fn.lower(f32, f32, i32)


def lower_lod(words: int):
    i32 = jax.ShapeDtypeStruct((words,), jnp.int32)
    fn = jax.jit(lambda w: (lod_pick(w),))
    return fn.lower(i32)


def lower_graph_eval(n: int, lmax: int):
    f32 = jax.ShapeDtypeStruct((n,), jnp.float32)
    i32 = jax.ShapeDtypeStruct((n,), jnp.int32)
    return graph_eval_jit(lmax=lmax).lower(f32, i32, i32, i32, i32)


def write_artifact(out_dir: str, name: str, lowered) -> dict:
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    print(f"wrote {path} ({len(text)} chars, sha256:{digest})")
    return {"file": f"{name}.hlo.txt", "sha256_16": digest}


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower TDP artifacts")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--alu-batch", type=int, default=DEFAULT_ALU_BATCH)
    ap.add_argument("--lod-words", type=int, default=DEFAULT_LOD_WORDS)
    ap.add_argument("--graph-n", type=int, default=DEFAULT_N)
    ap.add_argument("--graph-lmax", type=int, default=DEFAULT_LMAX)
    # Back-compat with the scaffold Makefile's `--out path` spelling.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "format": "hlo-text",
        "opcodes": {str(k): {"name": v[0], "arity": v[1]}
                    for k, v in OPCODES.items()},
        "artifacts": {},
    }
    m = manifest["artifacts"]
    m["alu_batch"] = write_artifact(out_dir, "alu_batch",
                                    lower_alu(args.alu_batch))
    m["alu_batch"]["batch"] = args.alu_batch
    m["lod"] = write_artifact(out_dir, "lod", lower_lod(args.lod_words))
    m["lod"]["words"] = args.lod_words
    m["graph_eval"] = write_artifact(
        out_dir, "graph_eval", lower_graph_eval(args.graph_n, args.graph_lmax))
    m["graph_eval"]["n"] = args.graph_n
    m["graph_eval"]["lmax"] = args.graph_lmax

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
