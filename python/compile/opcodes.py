"""Dataflow ALU opcode table — the single source of truth.

The Arria 10 TDP in the paper synthesizes two hardened floating-point DSP
blocks per PE (ADD and MULTIPLY mode).  Sparse matrix factorization also
needs SUB and DIV (pivot normalization); the paper's kernels obtain these
from the same DSP blocks (subtract = add with negated operand; divide via
reciprocal).  We expose them as first-class opcodes.

Mirrored in rust/src/graph/op.rs; `make artifacts` writes this table into
artifacts/manifest.json and a rust test asserts the two stay in sync.
"""

# opcode -> (name, arity)
OPCODES = {
    0: ("ADD", 2),
    1: ("MUL", 2),
    2: ("SUB", 2),
    3: ("DIV", 2),
    4: ("MAX", 2),
    5: ("MIN", 2),
    6: ("NEG", 1),
    7: ("COPY", 1),
}

ADD, MUL, SUB, DIV, MAX, MIN, NEG, COPY = range(8)

NAMES = {k: v[0] for k, v in OPCODES.items()}
ARITY = {k: v[1] for k, v in OPCODES.items()}
NUM_OPCODES = len(OPCODES)
