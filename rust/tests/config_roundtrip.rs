//! OverlayConfig serialization round-trips (ISSUE satellite): every
//! field survives save→load through both TOML and JSON, and unknown
//! keys are rejected by both strict loaders.

use tdp::config::OverlayConfig;
use tdp::engine::BackendKind;
use tdp::pe::BramConfig;
use tdp::place::{LocalOrder, PlacementPolicy};
use tdp::sched::SchedulerKind;

/// A config where *every* field differs from its default (and still
/// validates), so a field dropped by either serializer fails the
/// round-trip assertion instead of hiding behind a default.
fn every_field_nondefault() -> OverlayConfig {
    let cfg = OverlayConfig {
        cols: 5,
        rows: 7,
        scheduler: SchedulerKind::InOrder,
        bram: BramConfig {
            brams_per_pe: 4,
            words_per_bram: 256,
            word_bits: 36,
            flag_bits_used: 18,
            fifo_brams: 1.25,
            multipump: 3,
        },
        alu_latency: 9,
        placement: PlacementPolicy::Random,
        local_order: LocalOrder::ByNodeId,
        seed: 123_456_789,
        max_cycles: 77_000,
        enforce_capacity: true,
        opt: true,
        backend: BackendKind::SkipAhead,
        shards: 3,
    };
    let d = OverlayConfig::default();
    assert_ne!(cfg.cols, d.cols);
    assert_ne!(cfg.rows, d.rows);
    assert_ne!(cfg.scheduler, d.scheduler);
    assert_ne!(cfg.bram, d.bram);
    assert_ne!(cfg.alu_latency, d.alu_latency);
    assert_ne!(cfg.placement, d.placement);
    assert_ne!(cfg.local_order, d.local_order);
    assert_ne!(cfg.seed, d.seed);
    assert_ne!(cfg.max_cycles, d.max_cycles);
    assert_ne!(cfg.enforce_capacity, d.enforce_capacity);
    assert_ne!(cfg.opt, d.opt);
    assert_ne!(cfg.backend, d.backend);
    assert_ne!(cfg.shards, d.shards);
    cfg.validate().unwrap();
    cfg
}

#[test]
fn toml_roundtrip_preserves_every_field() {
    let cfg = every_field_nondefault();
    let text = cfg.to_toml();
    let back = OverlayConfig::from_toml(&text).unwrap();
    assert_eq!(back, cfg, "TOML save->load must be the identity:\n{text}");
}

#[test]
fn json_roundtrip_preserves_every_field() {
    let cfg = every_field_nondefault();
    let text = cfg.to_json();
    let back = OverlayConfig::from_json(&text).unwrap();
    assert_eq!(back, cfg, "JSON save->load must be the identity:\n{text}");
}

#[test]
fn formats_agree_on_defaults() {
    let d = OverlayConfig::default();
    assert_eq!(OverlayConfig::from_toml(&d.to_toml()).unwrap(), d);
    assert_eq!(OverlayConfig::from_json(&d.to_json()).unwrap(), d);
    // cross-format: TOML text -> config -> JSON text -> config
    let via_both = OverlayConfig::from_json(
        &OverlayConfig::from_toml(&every_field_nondefault().to_toml()).unwrap().to_json(),
    )
    .unwrap();
    assert_eq!(via_both, every_field_nondefault());
}

/// u64 knobs beyond the formats' exact-integer ranges (i64 for the TOML
/// subset, 2^53 for JSON doubles) must still round-trip — they are
/// written as decimal strings, never silently wrapped or rounded.
#[test]
fn huge_u64_knobs_roundtrip_exactly() {
    let mut cfg = OverlayConfig::default();
    for seed in [u64::MAX, (1 << 53) + 1, i64::MAX as u64 + 1] {
        cfg.seed = seed;
        let t = OverlayConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(t.seed, seed, "TOML roundtrip of seed {seed}");
        let j = OverlayConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(j.seed, seed, "JSON roundtrip of seed {seed}");
    }
    // string encoding is also accepted directly
    assert_eq!(
        OverlayConfig::from_json("{\"seed\": \"18446744073709551615\"}").unwrap().seed,
        u64::MAX
    );
    assert_eq!(
        OverlayConfig::from_toml("seed = \"18446744073709551615\"\n").unwrap().seed,
        u64::MAX
    );
}

#[test]
fn toml_unknown_keys_rejected() {
    for (text, needle) in [
        ("cols = 4\nbogus = 1\n", "bogus"),
        ("collumns = 4\n", "collumns"),
        ("[bram]\ntypo_knob = 8\n", "bram.typo_knob"),
        ("[brams]\nbrams_per_pe = 8\n", "brams"),
    ] {
        let err = OverlayConfig::from_toml(text).unwrap_err();
        assert!(err.contains(needle), "'{text}' -> {err}");
    }
}

#[test]
fn json_unknown_keys_rejected() {
    for (text, needle) in [
        ("{\"cols\": 4, \"bogus\": 1}", "bogus"),
        ("{\"bram\": {\"typo_knob\": 8}}", "bram.typo_knob"),
    ] {
        let err = OverlayConfig::from_json(text).unwrap_err();
        assert!(err.contains(needle), "'{text}' -> {err}");
    }
}

#[test]
fn json_type_and_shape_errors() {
    assert!(OverlayConfig::from_json("[]").is_err());
    assert!(OverlayConfig::from_json("{\"cols\": \"x\"}").is_err());
    assert!(OverlayConfig::from_json("{\"cols\": 2.5}").is_err());
    assert!(OverlayConfig::from_json("{\"seed\": -1}").is_err());
    assert!(OverlayConfig::from_json("{\"enforce_capacity\": 1}").is_err());
    assert!(OverlayConfig::from_json("{\"bram\": 4}").is_err());
    // loaded configs are validated like built ones
    assert!(OverlayConfig::from_json("{\"cols\": 0}").is_err());
    assert!(OverlayConfig::from_json("{\"cols\": 64}").is_err());
}

#[test]
fn partial_documents_keep_defaults() {
    let t = OverlayConfig::from_toml("cols = 4\n").unwrap();
    let j = OverlayConfig::from_json("{\"cols\": 4}").unwrap();
    assert_eq!(t, j);
    assert_eq!(t.rows, OverlayConfig::default().rows);
    assert_eq!(
        OverlayConfig::from_json("{}").unwrap(),
        OverlayConfig::default()
    );
}
