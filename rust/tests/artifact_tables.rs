//! The baked-runtime-tables contract (DESIGN.md §10): the compiled
//! artifact's route table and dense metadata are exactly what the seed
//! hot path derived per packet, the dense↔global permutation is a
//! bijection, and running over baked tables is bit-identical — stats
//! and values — to constructing a simulator directly, across all four
//! schedulers and both engine backends.

use tdp::config::{Overlay, OverlayConfig};
use tdp::engine::{self, BackendKind, LockstepBackend, SimBackend, SkipAheadBackend};
use tdp::graph::{DataflowGraph, Op};
use tdp::place::Placement;
use tdp::program::Program;
use tdp::sched::{LifoSched, RandomSched, Scheduler, SchedulerKind};
use tdp::sim::{SimStats, Simulator};
use tdp::workload::layered_random;

fn diamond() -> DataflowGraph {
    let mut g = DataflowGraph::new();
    let a = g.add_input(3.0);
    let b = g.add_input(4.0);
    let s = g.op(Op::Add, &[a, b]);
    let p = g.op(Op::Mul, &[a, b]);
    g.op(Op::Sub, &[s, p]);
    g
}

/// Golden route-table entries for the diamond compiled on a 2×2 overlay
/// (round-robin placement, criticality-sorted local memory — which for
/// this graph coincides with arrival order): every pre-formed header
/// pinned by hand.
#[test]
fn golden_route_table_on_hand_built_diamond() {
    let g = diamond();
    let overlay = Overlay::builder().dims(2, 2).build().unwrap();
    let program = Program::compile(&g, &overlay).unwrap();
    let t = program.runtime_tables();
    // round-robin: pe_of = [0,1,2,3,0]; criticality [2,2,1,1,0] keeps
    // PE0's layout [n0, n4]
    assert_eq!(t.pe_base, vec![0, 2, 3, 4, 5]);
    assert_eq!(t.global_of, vec![0, 4, 1, 2, 3]);
    assert_eq!(t.pe_xy, vec![(0, 0), (1, 0), (0, 1), (1, 1)]);
    assert_eq!(t.route_base, vec![0, 2, 2, 4, 5, 6]);
    let expect: Vec<(u8, u8, u16, u8)> = vec![
        (0, 1, 0, 0), // n0 → n2 on pe2=(0,1), slot 0
        (1, 1, 0, 0), // n0 → n3 on pe3=(1,1), slot 0
        (0, 1, 0, 1), // n1 → n2, slot 1
        (1, 1, 0, 1), // n1 → n3, slot 1
        (0, 0, 1, 0), // n2 → n4 on pe0 local 1, slot 0
        (0, 0, 1, 1), // n3 → n4, slot 1
    ];
    let got: Vec<(u8, u8, u16, u8)> = t
        .routes
        .iter()
        .map(|p| (p.dest_x, p.dest_y, p.local_idx, p.slot))
        .collect();
    assert_eq!(got, expect);
    assert!(t.routes.iter().all(|p| p.payload == 0.0), "headers carry no payload");
}

/// The dense↔global permutation round-trips and is consistent with the
/// placement, for every placement policy the overlay supports.
#[test]
fn dense_global_permutation_round_trip() {
    use tdp::place::PlacementPolicy;
    let g = layered_random(16, 6, 24, 2, 5);
    for policy in [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::Random,
        PlacementPolicy::BlockContiguous,
        PlacementPolicy::Chunked,
    ] {
        let overlay = Overlay::builder().dims(3, 2).placement(policy).build().unwrap();
        let program = Program::compile(&g, &overlay).unwrap();
        let t = program.runtime_tables();
        let place = program.placement();
        assert_eq!(t.global_of.len(), g.len());
        assert_eq!(t.dense_of.len(), g.len());
        for global in 0..g.len() {
            let dense = t.dense_of[global] as usize;
            assert_eq!(t.global_of[dense] as usize, global, "{policy:?}");
            let pe = place.pe_of[global] as usize;
            assert_eq!(dense as u32, t.pe_base[pe] + place.local_of[global], "{policy:?}");
        }
        // CSR covers all edges exactly once
        assert_eq!(*t.route_base.last().unwrap() as usize, g.num_edges());
    }
}

fn run_backend(mut be: Box<dyn SimBackend + '_>) -> (SimStats, Vec<f32>) {
    let stats = be.run().unwrap();
    let values = be.values().to_vec();
    (stats, values)
}

fn assert_bit_identical(a: &(SimStats, Vec<f32>), b: &(SimStats, Vec<f32>), tag: &str) {
    assert_eq!(a.0, b.0, "{tag}: stats diverge");
    assert_eq!(a.1.len(), b.1.len(), "{tag}");
    for (i, (x, y)) in a.1.iter().zip(&b.1).enumerate() {
        assert!(
            x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()),
            "{tag}: node {i} value diverges: {x} vs {y}"
        );
    }
}

/// `Session::run` over the compiled artifact's baked tables must be
/// bit-identical (stats + values) to the direct `Simulator::new` /
/// `make_backend` construction path, for the two paper schedulers on
/// both engine backends.
#[test]
fn baked_tables_match_direct_path_paper_schedulers() {
    let g = layered_random(14, 6, 22, 2, 9);
    for scheduler in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
        for backend in BackendKind::ALL {
            let cfg = OverlayConfig::default()
                .with_dims(3, 3)
                .with_scheduler(scheduler)
                .with_backend(backend);
            let overlay = Overlay::from_config(cfg).unwrap();
            let program = Program::compile(&g, &overlay).unwrap();
            let baked = run_backend(program.session().backend().unwrap());
            let direct = run_backend(engine::make_backend(&g, cfg).unwrap());
            assert_bit_identical(&baked, &direct, &format!("{scheduler:?}/{backend:?}"));
            assert_eq!(baked.0.completed, g.len());
            // and Session::run returns the same stats object
            assert_eq!(program.session().run().unwrap(), baked.0);
        }
    }
}

/// Same contract for the ablation schedulers (LIFO / seeded random):
/// a simulator over the artifact's tables vs one over a freshly built
/// placement, wrapped in each engine backend.
#[test]
fn baked_tables_match_direct_path_ablation_schedulers() {
    let g = layered_random(12, 5, 18, 2, 3);
    let cfg = OverlayConfig::default().with_dims(2, 2);
    let overlay = Overlay::from_config(cfg).unwrap();
    let program = Program::compile(&g, &overlay).unwrap();
    for which in ["lifo", "random"] {
        let factory = move |_: SchedulerKind, n: usize| match which {
            "lifo" => Scheduler::Lifo(LifoSched::new(n)),
            _ => Scheduler::Random(RandomSched::new(n, 42)),
        };
        for backend in BackendKind::ALL {
            let baked_sim =
                Simulator::with_tables_and_factory(&g, program.runtime_tables(), cfg, factory)
                    .unwrap();
            let place = Placement::build(&g, 4, cfg.placement, cfg.local_order, cfg.seed);
            let direct_sim = Simulator::with_scheduler_factory(&g, place, cfg, factory).unwrap();
            let (baked, direct) = match backend {
                BackendKind::Lockstep => (
                    run_backend(Box::new(LockstepBackend::from_simulator(baked_sim))),
                    run_backend(Box::new(LockstepBackend::from_simulator(direct_sim))),
                ),
                BackendKind::SkipAhead => (
                    run_backend(Box::new(SkipAheadBackend::from_simulator(baked_sim))),
                    run_backend(Box::new(SkipAheadBackend::from_simulator(direct_sim))),
                ),
            };
            assert_bit_identical(&baked, &direct, &format!("{which}/{backend:?}"));
            assert_eq!(baked.0.completed, g.len());
            // ablation orders still compute the reference numerics
            let want = g.evaluate();
            for (i, (a, b)) in baked.1.iter().zip(&want).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()),
                    "{which}: node {i}: sim={a}, ref={b}"
                );
            }
        }
    }
}

/// Tracing over baked tables must not perturb the simulation, and the
/// sampled series must stay sane. (Exactness of the active-only
/// `sample()` against a full-fabric scan is pinned cycle-by-cycle by
/// `sim::tests::sample_active_only_matches_full_fabric_scan`, which has
/// access to the per-PE internals.)
#[test]
fn traced_run_over_tables_matches_untraced_stats() {
    let g = layered_random(10, 4, 16, 2, 7);
    let cfg = OverlayConfig::default().with_dims(4, 4);
    let overlay = Overlay::from_config(cfg).unwrap();
    let program = Program::compile(&g, &overlay).unwrap();
    let plain = program.session().run().unwrap();
    let mut sim = Simulator::with_tables(&g, program.runtime_tables(), cfg).unwrap();
    sim.enable_trace(1);
    let traced = sim.run().unwrap();
    assert_eq!(traced, plain, "tracing must not perturb the simulation");
    let trace = sim.trace().unwrap();
    assert!(!trace.samples.is_empty());
    let final_completed = trace.samples.last().unwrap().completed;
    assert!(final_completed <= g.len());
    // busy_pes can never exceed the fabric, and the first sample (cycle
    // 0, inputs just seeded) sees the seeded ready queues
    assert!(trace.samples.iter().all(|s| s.busy_pes <= 16));
    assert!(trace.samples[0].ready_total > 0);
}
