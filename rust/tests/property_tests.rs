//! Property-based tests over the DESIGN.md §5 invariants.
//!
//! `proptest` is not in the offline crate universe, so properties are
//! checked over large seeded-random sample families (deterministic, no
//! shrinking — failures print the seed for replay).

use tdp::config::OverlayConfig;
use tdp::criticality;
use tdp::graph::{DataflowGraph, Op};
use tdp::lod::{naive_scan, HierLod, NO_READY};
use tdp::noc::{Network, Packet};
use tdp::place::{LocalOrder, Placement, PlacementPolicy};
use tdp::sched::{make_scheduler, OutOfOrderLod, ReadyScheduler, SchedulerKind};
use tdp::sim::Simulator;
use tdp::util::rng::Rng;

/// Random DAG with arbitrary op mix (values kept finite-ish by
/// construction not being required — NaN/inf equality is checked too).
fn random_graph(rng: &mut Rng, max_nodes: usize) -> DataflowGraph {
    let inputs = 1 + rng.gen_range(8);
    let ops = rng.gen_range(max_nodes.max(2));
    let mut g = DataflowGraph::new();
    for _ in 0..inputs {
        g.add_input(rng.gen_f32_in(-100.0, 100.0));
    }
    for _ in 0..ops {
        let op = Op::ALL[rng.gen_range(Op::ALL.len())];
        let n = g.len() as u32;
        let a = rng.gen_range(n as usize) as u32;
        let b = rng.gen_range(n as usize) as u32;
        let srcs: Vec<u32> = if op.arity() == 1 { vec![a] } else { vec![a, b] };
        g.add_op(op, &srcs).unwrap();
    }
    g
}

/// Invariant 1+2: any scheduler × placement × overlay computes exactly
/// the reference values, every node exactly once.
#[test]
fn prop_sim_equals_reference_on_random_graphs() {
    for seed in 0..40u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let g = random_graph(&mut rng, 300);
        let dims = [(1usize, 1usize), (2, 2), (3, 5), (8, 8)];
        let (c, r) = dims[rng.gen_range(dims.len())];
        let kind = if rng.gen_bool(0.5) {
            SchedulerKind::InOrder
        } else {
            SchedulerKind::OutOfOrder
        };
        let policies = [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::Random,
            PlacementPolicy::BlockContiguous,
            PlacementPolicy::Chunked,
        ];
        let mut cfg = OverlayConfig::default().with_dims(c, r).with_scheduler(kind);
        cfg.placement = policies[rng.gen_range(policies.len())];
        cfg.seed = seed;
        let mut sim = Simulator::new(&g, cfg).unwrap();
        let stats = sim.run().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(stats.completed, g.len(), "seed {seed}");
        let want = g.evaluate();
        for (i, (a, b)) in sim.values().iter().zip(&want).enumerate() {
            assert!(
                (a == b) || (a.is_nan() && b.is_nan()),
                "seed {seed} node {i}: {a} != {b}"
            );
        }
    }
}

/// Invariant 3: the OoO scheduler always returns the minimum ready local
/// index (== most critical under the §II-B memory sort).
#[test]
fn prop_ooo_picks_minimum_ready() {
    for seed in 0..60u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xA5A5);
        let n = 1 + rng.gen_range(4096);
        let mut s = OutOfOrderLod::new(n);
        let mut model: Vec<u32> = Vec::new(); // sorted ready set
        for _ in 0..200 {
            if model.is_empty() || rng.gen_bool(0.6) {
                // mark a not-ready, not-pending node
                let idx = rng.gen_range(n) as u32;
                if !s.is_ready(idx) && !s.is_pending(idx) {
                    s.mark_ready(idx);
                    model.push(idx);
                    model.sort_unstable();
                }
            } else {
                let got = s.take();
                let want = if model.is_empty() {
                    None
                } else {
                    Some(model.remove(0))
                };
                assert_eq!(got, want, "seed {seed}");
                if let Some(idx) = got {
                    s.fanout_done(idx);
                }
            }
            assert_eq!(s.len(), model.len(), "seed {seed}");
        }
    }
}

/// Invariant 4: the FIFO preserves arrival order exactly.
#[test]
fn prop_fifo_preserves_order() {
    for seed in 0..40u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x0F1F0);
        let mut s = make_scheduler(SchedulerKind::InOrder, 1 << 13, None);
        let mut model = std::collections::VecDeque::new();
        for _ in 0..300 {
            if model.is_empty() || rng.gen_bool(0.55) {
                let idx = rng.gen_range(1 << 13) as u32;
                s.mark_ready(idx);
                model.push_back(idx);
            } else {
                assert_eq!(s.take(), model.pop_front(), "seed {seed}");
            }
        }
    }
}

/// Invariant 5+6: the Hoplite torus delivers every packet exactly once,
/// to the right PE, under arbitrary random traffic.
#[test]
fn prop_noc_conservation() {
    for seed in 0..25u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x1307);
        let cols = 1 + rng.gen_range(8);
        let rows = 1 + rng.gen_range(8);
        let n = cols * rows;
        let mut net = Network::new(cols, rows);
        let total = 50 + rng.gen_range(400);
        let mut sent: Vec<(usize, u16)> = Vec::new(); // (dest, tag)
        let mut got: Vec<(usize, u16)> = Vec::new();
        let mut tag = 0u16;
        let mut inject: Vec<Option<Packet>> = vec![None; n];
        let mut cycles = 0;
        while got.len() < total {
            for (pe, slot) in inject.iter_mut().enumerate() {
                if slot.is_none() && (tag as usize) < total && pe == tag as usize % n {
                    let dest = rng.gen_range(n);
                    *slot = Some(Packet {
                        dest_x: (dest % cols) as u8,
                        dest_y: (dest / cols) as u8,
                        local_idx: tag % 8192,
                        slot: 0,
                        payload: tag as f32,
                    });
                    sent.push((dest, tag % 8192));
                    tag += 1;
                }
            }
            let res = net.step(&inject);
            for (pe, ok) in res.inject_ok.iter().enumerate() {
                if *ok {
                    inject[pe] = None;
                }
            }
            for (pe, e) in res.ejected.iter().enumerate() {
                if let Some(p) = e {
                    got.push((pe, p.local_idx));
                }
            }
            cycles += 1;
            assert!(cycles < 1_000_000, "seed {seed}: livelock (delivered {}/{total})", got.len());
        }
        let mut a = sent.clone();
        let mut b = got.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "seed {seed}: delivery must be exact (no loss/dup)");
        assert!(net.is_empty());
    }
}

/// Packet wire-format roundtrip over random field values.
#[test]
fn prop_packet_roundtrip() {
    let mut rng = Rng::seed_from_u64(0xFACE);
    for _ in 0..5000 {
        let p = Packet {
            dest_x: rng.gen_range(32) as u8,
            dest_y: rng.gen_range(32) as u8,
            local_idx: rng.gen_range(8192) as u16,
            slot: rng.gen_range(2) as u8,
            payload: f32::from_bits(rng.next_u64() as u32),
        };
        let q = Packet::unpack56(p.pack56());
        assert_eq!(q.dest_x, p.dest_x);
        assert_eq!(q.dest_y, p.dest_y);
        assert_eq!(q.local_idx, p.local_idx);
        assert_eq!(q.slot, p.slot);
        assert_eq!(q.payload.to_bits(), p.payload.to_bits());
    }
}

/// Hierarchical LOD == naive scan on random flag vectors of random width.
#[test]
fn prop_hier_lod_equals_naive() {
    let mut rng = Rng::seed_from_u64(0x10D);
    for _ in 0..400 {
        let w = 1 + rng.gen_range(256);
        let density = [0.0, 0.001, 0.05, 0.5][rng.gen_range(4)];
        let mut words = vec![0u32; w];
        let mut summary = vec![0u64; w.div_ceil(64)];
        for i in 0..w {
            for b in 0..32 {
                if rng.gen_bool(density) {
                    words[i] |= 1 << b;
                }
            }
            if words[i] != 0 {
                summary[i / 64] |= 1 << (i % 64);
            }
        }
        let lod = HierLod::new(w);
        assert_eq!(lod.pick(&summary, &words), naive_scan(&words));
    }
    // empty
    let lod = HierLod::new(4);
    assert_eq!(lod.pick(&[0u64], &[0u32; 4]), NO_READY);
}

/// Criticality invariants: slack ≥ 0; criticality decreases along every
/// edge by ≥ 1; ASAP ≤ ALAP.
#[test]
fn prop_criticality_invariants() {
    for seed in 100..140u64 {
        let mut rng = Rng::seed_from_u64(seed);
        let g = random_graph(&mut rng, 400);
        let crit = criticality::criticality(&g);
        let asap = criticality::asap(&g);
        let alap = criticality::alap(&g);
        for (i, node) in g.nodes().iter().enumerate() {
            assert!(asap[i] <= alap[i], "seed {seed} node {i}");
            for &(dst, _) in &node.fanout {
                assert!(
                    crit[i] >= crit[dst as usize] + 1,
                    "seed {seed}: criticality must dominate children"
                );
            }
        }
        // placement sort respects criticality within every PE
        let p = Placement::build(&g, 7, PlacementPolicy::Random, LocalOrder::ByCriticality, seed);
        for locals in &p.nodes_of {
            for w in locals.windows(2) {
                assert!(crit[w[0] as usize] >= crit[w[1] as usize], "seed {seed}");
            }
        }
    }
}

/// Graph JSON (de)serialization roundtrips arbitrary graphs.
#[test]
fn prop_graph_json_roundtrip() {
    use tdp::graph::{graph_from_json, graph_to_json};
    for seed in 0..30u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x15);
        let g = random_graph(&mut rng, 200);
        let g2 = graph_from_json(&graph_to_json(&g)).unwrap();
        assert_eq!(g.len(), g2.len(), "seed {seed}");
        let a = g.evaluate();
        let b = g2.evaluate();
        for (x, y) in a.iter().zip(&b) {
            assert!(x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()));
        }
    }
}

/// Scheduler memory-overhead model: OoO overhead stays ≈6% of the BRAM
/// budget for any PE occupancy; FIFO overhead equals its capacity.
#[test]
fn prop_overhead_arithmetic() {
    for n in [1usize, 31, 32, 33, 1000, 1920, 4096] {
        let ooo = OutOfOrderLod::new(n);
        assert_eq!(ooo.mem_overhead_words(), 2 * n.div_ceil(32));
        let fifo = make_scheduler(SchedulerKind::InOrder, n, None);
        assert_eq!(fifo.mem_overhead_words(), n.max(1));
    }
}
