//! Integration tests: the full simulator stack (graph → criticality →
//! placement → Hoplite → PEs → schedulers) against the functional
//! reference, across workload families, overlay sizes and schedulers.

use tdp::config::OverlayConfig;
use tdp::graph::{DataflowGraph, Op};
use tdp::place::{LocalOrder, PlacementPolicy};
use tdp::sched::SchedulerKind;
use tdp::sim::Simulator;
use tdp::workload::*;

fn values_match(g: &DataflowGraph, got: &[f32]) {
    let want = g.evaluate();
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!(
            (a == b) || (a.is_nan() && b.is_nan()),
            "node {i}: sim={a} ref={b}"
        );
    }
}

fn run_and_check(g: &DataflowGraph, cfg: OverlayConfig) -> tdp::sim::SimStats {
    let mut sim = Simulator::new(g, cfg).expect("sim builds");
    let stats = sim.run().expect("sim completes");
    values_match(g, sim.values());
    assert!(sim.all_computed());
    stats
}

#[test]
fn every_workload_family_on_every_scheduler() {
    let workloads: Vec<(&str, DataflowGraph)> = vec![
        ("lu_banded", lu_factorization_graph(&SparseMatrix::banded(40, 3, 0.9, 1)).0),
        ("lu_random", lu_factorization_graph(&SparseMatrix::random(24, 0.15, 2)).0),
        ("lu_power_law", lu_factorization_graph(&SparseMatrix::power_law(40, 3, 3)).0),
        ("layered", layered_random(12, 6, 20, 2, 4)),
        ("reduction", reduction_tree(37, Op::Add, 5)),
        ("stencil", stencil_1d(12, 5, 6)),
        ("butterfly", butterfly_graph(32, 7)),
    ];
    for (name, g) in &workloads {
        for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
            let cfg = OverlayConfig::default().with_dims(4, 4).with_scheduler(kind);
            let stats = run_and_check(g, cfg);
            assert_eq!(stats.completed, g.len(), "{name}/{:?}", kind);
            // conservation: every edge is exactly one delivered packet
            assert_eq!(stats.net.delivered as usize, g.num_edges(), "{name}");
            assert_eq!(stats.net.injected, stats.net.delivered, "{name}");
        }
    }
}

#[test]
fn all_overlay_shapes() {
    let g = layered_random(16, 8, 24, 2, 9);
    for (c, r) in [(1, 1), (1, 4), (4, 1), (2, 3), (5, 5), (8, 8), (16, 16), (3, 7)] {
        let cfg = OverlayConfig::default().with_dims(c, r);
        run_and_check(&g, cfg);
    }
}

#[test]
fn all_placement_policies_and_orders() {
    let g = lu_factorization_graph(&SparseMatrix::banded(48, 3, 0.8, 11)).0;
    for policy in [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::Random,
        PlacementPolicy::BlockContiguous,
        PlacementPolicy::Chunked,
    ] {
        for order in [LocalOrder::ByCriticality, LocalOrder::ByNodeId] {
            for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
                let mut cfg = OverlayConfig::default().with_dims(3, 3).with_scheduler(kind);
                cfg.placement = policy;
                cfg.local_order = order;
                run_and_check(&g, cfg);
            }
        }
    }
}

#[test]
fn determinism_same_seed_same_cycles() {
    let g = layered_random(16, 10, 32, 2, 5);
    let cfg = OverlayConfig::default().with_dims(4, 4);
    let s1 = run_and_check(&g, cfg);
    let s2 = run_and_check(&g, cfg);
    assert_eq!(s1.cycles, s2.cycles);
    assert_eq!(s1.net.delivered, s2.net.delivered);
    assert_eq!(s1.net.deflections, s2.net.deflections);
}

#[test]
fn alu_latency_sensitivity() {
    let g = reduction_tree(64, Op::Add, 2);
    let mut last = 0u64;
    for lat in [1u64, 2, 4, 8] {
        let mut cfg = OverlayConfig::default().with_dims(2, 2);
        cfg.alu_latency = lat;
        let stats = run_and_check(&g, cfg);
        assert!(
            stats.cycles > last,
            "cycles must grow with ALU latency ({} !> {last})",
            stats.cycles
        );
        last = stats.cycles;
    }
}

#[test]
fn speedup_regime_ooo_wins_with_chunked_placement() {
    // the Fig.1 regime: locality-preserving placement + skewed DAG
    let g = lu_factorization_graph(&SparseMatrix::power_law(140, 3, 44)).0;
    let mut cfg = OverlayConfig::default();
    cfg.placement = PlacementPolicy::Chunked;
    let mut cycles = [0u64; 2];
    for (i, kind) in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder]
        .into_iter()
        .enumerate()
    {
        cycles[i] = run_and_check(&g, cfg.with_scheduler(kind)).cycles;
    }
    let speedup = cycles[0] as f64 / cycles[1] as f64;
    assert!(
        speedup > 1.05,
        "OoO must beat in-order in the queueing regime, got {speedup:.3}"
    );
}

#[test]
fn single_node_graph() {
    let mut g = DataflowGraph::new();
    g.add_input(42.0);
    let stats = run_and_check(&g, OverlayConfig::paper_1x1());
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.net.delivered, 0);
}

#[test]
fn graph_of_only_inputs() {
    let mut g = DataflowGraph::new();
    for i in 0..50 {
        g.add_input(i as f32);
    }
    run_and_check(&g, OverlayConfig::default().with_dims(3, 3));
}

#[test]
fn wide_fanout_hub() {
    // one input feeding 500 consumers: drains 500 cycles through 1 pkt/cy
    let mut g = DataflowGraph::new();
    let hub = g.add_input(2.0);
    for _ in 0..500 {
        g.op(Op::Neg, &[hub]);
    }
    let stats = run_and_check(&g, OverlayConfig::default().with_dims(4, 4));
    assert!(stats.cycles >= 500, "hub drain is serialized: {}", stats.cycles);
}

#[test]
fn deep_chain_crosses_network() {
    let mut g = DataflowGraph::new();
    let mut prev = g.add_input(1.0);
    for _ in 0..300 {
        prev = g.op(Op::Copy, &[prev]);
    }
    let stats = run_and_check(&g, OverlayConfig::default().with_dims(4, 4));
    // each hop pays network latency; chain must still complete exactly
    assert!(stats.cycles > 300);
}

#[test]
fn fifo_overflow_counted_when_underprovisioned() {
    use tdp::place::Placement;
    // NOTE: exercised through the public scheduler API (sim sizes FIFOs
    // at the deadlock-free worst case, so overflow never happens there).
    use tdp::sched::{make_scheduler, ReadyScheduler};
    let mut s = make_scheduler(SchedulerKind::InOrder, 8, Some(4));
    for i in 0..8 {
        s.mark_ready(i);
    }
    assert!(s.overflows() > 0);
    // placement still bijective under stress
    let g = layered_random(8, 3, 8, 1, 0);
    let p = Placement::build(&g, 4, PlacementPolicy::RoundRobin, LocalOrder::ByCriticality, 0);
    assert_eq!(p.pe_of.len(), g.len());
}
