//! Cross-backend parity: `LockstepBackend` and `SkipAheadBackend` must be
//! indistinguishable — bit-exact node values and identical `SimStats`
//! down to every per-PE counter — across ≥3 workload families
//! (synthetic, sparse LU factorization, Matrix Market) × both
//! schedulers, plus seeded-random property sweeps (DESIGN.md §5/§6).

use tdp::config::OverlayConfig;
use tdp::engine::{check_parity, parity::ParityError, BackendKind, SimBackend, SkipAheadBackend};
use tdp::graph::{DataflowGraph, Op};
use tdp::place::PlacementPolicy;
use tdp::sched::SchedulerKind;
use tdp::sim::SimError;
use tdp::util::rng::Rng;
use tdp::workload::{
    butterfly_graph, layered_random, lu_factorization_graph, parse_matrix_market, reduction_tree,
    stencil_1d, SparseMatrix,
};

fn assert_parity(g: &DataflowGraph, cfg: OverlayConfig, label: &str) -> u64 {
    match check_parity(g, cfg) {
        Ok(rep) => {
            assert_eq!(rep.stats.completed, g.len(), "{label}: incomplete run");
            rep.cycles_skipped
        }
        Err(e) => panic!("{label}: parity violation: {e}"),
    }
}

const SCHEDULERS: [SchedulerKind; 2] = [SchedulerKind::InOrder, SchedulerKind::OutOfOrder];

#[test]
fn synthetic_family_parity() {
    let workloads: Vec<(&str, DataflowGraph)> = vec![
        ("layered", layered_random(12, 6, 20, 2, 4)),
        ("reduction", reduction_tree(64, Op::Add, 5)),
        ("stencil", stencil_1d(12, 5, 6)),
        ("butterfly", butterfly_graph(32, 7)),
    ];
    for (name, g) in &workloads {
        for kind in SCHEDULERS {
            for (c, r) in [(1, 1), (2, 2), (4, 4)] {
                let cfg = OverlayConfig::default().with_dims(c, r).with_scheduler(kind);
                assert_parity(g, cfg, &format!("{name}/{kind:?}/{c}x{r}"));
            }
        }
    }
}

#[test]
fn sparse_lu_family_parity() {
    let workloads: Vec<(&str, DataflowGraph)> = vec![
        ("lu_banded", lu_factorization_graph(&SparseMatrix::banded(40, 3, 0.9, 1)).0),
        ("lu_random", lu_factorization_graph(&SparseMatrix::random(24, 0.15, 2)).0),
        ("lu_power_law", lu_factorization_graph(&SparseMatrix::power_law(40, 3, 3)).0),
    ];
    for (name, g) in &workloads {
        for kind in SCHEDULERS {
            let mut cfg = OverlayConfig::default().with_dims(4, 4).with_scheduler(kind);
            cfg.placement = PlacementPolicy::Chunked;
            assert_parity(g, cfg, &format!("{name}/{kind:?}"));
        }
    }
}

#[test]
fn matrix_market_family_parity() {
    let general = "%%MatrixMarket matrix coordinate real general\n\
                   % tiny circuit-like pattern\n\
                   6 6 10\n\
                   1 1 2.0\n2 2 3.0\n3 3 4.0\n4 4 5.0\n5 5 6.0\n6 6 7.0\n\
                   2 1 -1.0\n4 2 0.5\n5 3 -0.25\n6 1 1.5\n";
    let symmetric = "%%MatrixMarket matrix coordinate pattern symmetric\n\
                     5 5 8\n\
                     1 1\n2 2\n3 3\n4 4\n5 5\n3 1\n4 2\n5 3\n";
    for (name, text) in [("mm_general", general), ("mm_symmetric_pattern", symmetric)] {
        let m = parse_matrix_market(text).unwrap();
        let (g, _) = lu_factorization_graph(&m);
        for kind in SCHEDULERS {
            let cfg = OverlayConfig::default().with_dims(2, 2).with_scheduler(kind);
            assert_parity(&g, cfg, &format!("{name}/{kind:?}"));
        }
    }
}

/// Random DAG with arbitrary op mix (NaN/inf paths included).
fn random_graph(rng: &mut Rng, max_nodes: usize) -> DataflowGraph {
    let inputs = 1 + rng.gen_range(8);
    let ops = rng.gen_range(max_nodes.max(2));
    let mut g = DataflowGraph::new();
    for _ in 0..inputs {
        g.add_input(rng.gen_f32_in(-100.0, 100.0));
    }
    for _ in 0..ops {
        let op = Op::ALL[rng.gen_range(Op::ALL.len())];
        let n = g.len() as u32;
        let a = rng.gen_range(n as usize) as u32;
        let b = rng.gen_range(n as usize) as u32;
        let srcs: Vec<u32> = if op.arity() == 1 { vec![a] } else { vec![a, b] };
        g.add_op(op, &srcs).unwrap();
    }
    g
}

/// Property (ISSUE satellite): for seeded random workloads the two
/// backends produce identical `SimStats` — completion cycle, per-PE busy
/// cycles and every other counter — under both scheduler kinds, across
/// random overlay shapes and placement policies.
#[test]
fn prop_backend_parity_on_random_workloads() {
    let policies = [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::Random,
        PlacementPolicy::BlockContiguous,
        PlacementPolicy::Chunked,
    ];
    for seed in 0..30u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xE9613E);
        let g = random_graph(&mut rng, 250);
        let dims = [(1usize, 1usize), (2, 2), (3, 5), (8, 8)];
        let (c, r) = dims[rng.gen_range(dims.len())];
        for kind in SCHEDULERS {
            let mut cfg = OverlayConfig::default().with_dims(c, r).with_scheduler(kind);
            cfg.placement = policies[rng.gen_range(policies.len())];
            cfg.seed = seed;
            // vary the ALU depth too: larger latencies open wider
            // quiescent windows and stress the jump accounting
            cfg.alu_latency = 1 + rng.gen_range(8) as u64;
            let rep = check_parity(&g, cfg)
                .unwrap_or_else(|e| panic!("seed {seed} {kind:?} {c}x{r}: {e}"));
            assert_eq!(rep.stats.completed, g.len(), "seed {seed}");
        }
    }
}

/// The skip-ahead engine must actually skip on sequential workloads —
/// parity alone would also hold for a backend that never jumps.
#[test]
fn skip_ahead_skips_on_sequential_workloads() {
    let m = SparseMatrix::banded(60, 1, 1.0, 9);
    let (g, _) = lu_factorization_graph(&m);
    let mut cfg = OverlayConfig::default()
        .with_dims(8, 8)
        .with_scheduler(SchedulerKind::OutOfOrder);
    cfg.placement = PlacementPolicy::Chunked;
    cfg.alu_latency = 8;
    let skipped = assert_parity(&g, cfg, "sequential lu chain");
    assert!(skipped > 0, "sequential chain must produce clock jumps");
}

/// Identical cycle-limit failures on both backends.
#[test]
fn cycle_limit_parity() {
    let g = layered_random(8, 4, 8, 1, 0);
    let mut cfg = OverlayConfig::default().with_dims(2, 2);
    cfg.max_cycles = 3;
    match check_parity(&g, cfg) {
        Err(ParityError::Sim(SimError::CycleLimitExceeded { cycle, .. })) => assert_eq!(cycle, 3),
        other => panic!("expected identical cycle-limit errors, got {other:?}"),
    }
}

/// `OverlayConfig::backend` routes the whole stack through the chosen
/// engine (the plumbing the CLI `--backend` flag relies on).
#[test]
fn backend_choice_flows_through_config() {
    let g = layered_random(10, 5, 16, 2, 2);
    let mut all_stats = Vec::new();
    for kind in BackendKind::ALL {
        let cfg = OverlayConfig::default().with_dims(2, 2).with_backend(kind);
        let mut be = tdp::engine::make_backend(&g, cfg).unwrap();
        assert_eq!(be.kind(), kind);
        all_stats.push(be.run().unwrap());
    }
    assert_eq!(all_stats[0], all_stats[1]);
}

/// Direct use of the concrete backend type, including its jump counters.
#[test]
fn skip_ahead_backend_counters_consistent() {
    let mut g = DataflowGraph::new();
    let mut prev = g.add_input(2.0);
    for _ in 0..50 {
        prev = g.op(Op::Copy, &[prev]);
    }
    let mut cfg = OverlayConfig::paper_1x1();
    cfg.alu_latency = 6;
    let mut be = SkipAheadBackend::new(&g, cfg).unwrap();
    let stats = be.run().unwrap();
    assert!(be.jumps() > 0);
    assert!(be.cycles_skipped() < stats.cycles, "cannot skip more than total");
    assert_eq!(be.cycle(), stats.cycles);
    assert_eq!(be.values()[50], 2.0);
}
