//! Program reuse (ISSUE satellite): compile once, run under every
//! scheduler and both engine backends, and assert the stats are
//! identical to the fresh-compile path — no state leaks across
//! `Session` runs, and the deprecated one-shot shims stay bit-identical.

use tdp::config::{Overlay, OverlayConfig};
use tdp::engine::BackendKind;
use tdp::program::{run_batch, Program, RunVariant};
use tdp::sched::{LifoSched, RandomSched, Scheduler, SchedulerKind};
use tdp::sim::Simulator;
use tdp::workload::{layered_random, lu_factorization_graph, SparseMatrix};

const SCHEDULERS: [SchedulerKind; 2] = [SchedulerKind::InOrder, SchedulerKind::OutOfOrder];

#[test]
fn one_program_all_variants_matches_fresh_compile() {
    let m = SparseMatrix::banded(40, 3, 0.9, 1);
    let (g, _) = lu_factorization_graph(&m);
    let cfg = OverlayConfig::default().with_dims(4, 4);
    let overlay = Overlay::from_config(cfg).unwrap();
    let shared = Program::compile(&g, &overlay).unwrap();
    for kind in SCHEDULERS {
        for backend in BackendKind::ALL {
            let from_shared = shared
                .session()
                .with_scheduler(kind)
                .with_backend(backend)
                .run()
                .unwrap();
            // fresh compile per variant — the old cost model
            let fresh = Program::compile(&g, &overlay)
                .unwrap()
                .session()
                .with_scheduler(kind)
                .with_backend(backend)
                .run()
                .unwrap();
            assert_eq!(from_shared, fresh, "{kind:?}/{backend:?}");
            // the legacy one-shot simulator agrees bit-for-bit
            let direct_cfg = cfg.with_scheduler(kind).with_backend(backend);
            let mut sim = Simulator::new(&g, direct_cfg).unwrap();
            assert_eq!(sim.run().unwrap(), from_shared, "{kind:?}/{backend:?} vs Simulator");
            // and so does the deprecated shim
            #[allow(deprecated)]
            let shim = tdp::coordinator::run_one(&g, cfg.with_backend(backend), kind).unwrap();
            assert_eq!(shim, from_shared, "{kind:?}/{backend:?} vs run_one shim");
        }
    }
}

#[test]
fn repeated_sessions_leak_no_state() {
    let g = layered_random(16, 8, 24, 2, 3);
    let overlay = Overlay::builder().dims(3, 3).build().unwrap();
    let program = Program::compile(&g, &overlay).unwrap();
    for kind in SCHEDULERS {
        for backend in BackendKind::ALL {
            let session = program.session().with_scheduler(kind).with_backend(backend);
            let first = session.run().unwrap();
            for rep in 0..3 {
                assert_eq!(session.run().unwrap(), first, "{kind:?}/{backend:?} rep {rep}");
            }
        }
    }
}

#[test]
fn session_values_match_reference_evaluation() {
    let g = layered_random(12, 6, 20, 2, 7);
    let want = g.evaluate();
    let overlay = Overlay::builder().dims(2, 2).build().unwrap();
    let program = Program::compile(&g, &overlay).unwrap();
    for kind in SCHEDULERS {
        let mut backend = program.session().with_scheduler(kind).backend().unwrap();
        backend.run().unwrap();
        for (i, (a, b)) in backend.values().iter().zip(&want).enumerate() {
            assert!(
                (a == b) || (a.is_nan() && b.is_nan()),
                "{kind:?} node {i}: sim={a}, ref={b}"
            );
        }
    }
}

/// All four scheduler variants run over one compiled placement: the two
/// paper schedulers through `Session`, the LIFO/random ablations through
/// the scheduler-factory hook on the program's placement — nothing
/// re-places the graph.
#[test]
fn ablation_schedulers_reuse_compiled_placement() {
    let g = layered_random(12, 4, 16, 2, 6);
    let cfg = OverlayConfig::default().with_dims(2, 2);
    let overlay = Overlay::from_config(cfg).unwrap();
    let program = Program::compile(&g, &overlay).unwrap();
    for kind in SCHEDULERS {
        let stats = program.session().with_scheduler(kind).run().unwrap();
        assert_eq!(stats.completed, g.len());
    }
    for which in 0..2 {
        let mut sim = Simulator::with_scheduler_factory_shared(
            &g,
            program.shared_placement(),
            cfg,
            move |_, n| {
                if which == 0 {
                    Scheduler::Lifo(LifoSched::new(n))
                } else {
                    Scheduler::Random(RandomSched::new(n, 42))
                }
            },
        )
        .unwrap();
        let stats = sim.run().unwrap();
        assert_eq!(stats.completed, g.len(), "ablation {which}");
    }
}

#[test]
fn run_batch_matches_serial_sessions() {
    let g = layered_random(14, 6, 20, 2, 9);
    let overlay = Overlay::builder().dims(3, 3).build().unwrap();
    let program = Program::compile(&g, &overlay).unwrap();
    let variants = RunVariant::all();
    let batch = run_batch(&program, &variants, 4);
    assert_eq!(batch.len(), variants.len());
    for (v, r) in variants.iter().zip(batch) {
        let serial = program
            .session()
            .with_scheduler(v.scheduler)
            .with_backend(v.backend)
            .run()
            .unwrap();
        assert_eq!(r.unwrap(), serial, "{v:?}");
    }
}

/// Compile-time capacity errors carry the same fields the runtime check
/// reported before the redesign, and the deprecated shim still surfaces
/// them as `SimError`.
#[test]
fn capacity_error_shapes_agree_across_paths() {
    use tdp::program::CompileError;
    use tdp::sim::SimError;
    let g = layered_random(64, 32, 128, 2, 0); // ~4K nodes on 1 PE
    let mut cfg = OverlayConfig::default().with_dims(1, 1);
    cfg.enforce_capacity = true;
    let overlay = Overlay::from_config(cfg).unwrap();
    let (pe, words_needed, words_available) = match Program::compile(&g, &overlay).unwrap_err() {
        CompileError::CapacityExceeded { pe, words_needed, words_available } => {
            (pe, words_needed, words_available)
        }
        other => panic!("expected CapacityExceeded, got {other}"),
    };
    #[allow(deprecated)]
    let shim_err = tdp::engine::run_with_backend(&g, cfg).unwrap_err();
    assert_eq!(
        shim_err,
        SimError::CapacityExceeded { pe, words_needed, words_available }
    );
    let direct_err = Simulator::new(&g, cfg).err().unwrap();
    assert_eq!(shim_err, direct_err, "shim matches the pre-redesign error");
}
