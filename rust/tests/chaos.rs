//! Chaos determinism (DESIGN.md §15): one fault plan + one job stream
//! must produce bit-identical outcome-code sequences run to run, every
//! submitted job must get exactly one terminal response, and the daemon
//! must survive every injected fault — panics included.

use std::sync::Arc;
use tdp::serve::{client, Daemon, ServeConfig};
use tdp::telemetry::Registry;
use tdp::util::json::Json;
use tdp::FaultPlan;

fn outcome_code(j: &Json) -> String {
    if j.get("result").is_some() {
        "ok".to_string()
    } else {
        j.get("code").and_then(Json::as_str).unwrap_or("?").to_string()
    }
}

/// One chaos round: a fresh single-worker daemon armed with `plan`, the
/// whole stream pipelined over one connection (single worker + single
/// reader = deterministic processing order), outcome codes returned in
/// input order after a clean drain.
fn chaos_round(plan: Arc<FaultPlan>, lines: &[String]) -> Vec<String> {
    let daemon = Daemon::bind(
        "127.0.0.1:0",
        ServeConfig { workers: 1, fault_plan: Some(plan), ..Default::default() },
        Arc::new(Registry::new()),
    )
    .unwrap();
    let addr = daemon.local_addr().to_string();
    let handle = daemon.handle();
    let server = std::thread::spawn(move || daemon.run());
    let responses = client::submit_raw_lines(&addr, lines).unwrap();
    assert_eq!(responses.len(), lines.len(), "exactly one terminal response per job");
    // the daemon survived the whole gauntlet: stats still answers, and
    // it is still serving
    let stats = client::fetch_stats(&addr).unwrap();
    assert_eq!(stats.get("state").and_then(Json::as_str), Some("serving"));
    handle.drain();
    server.join().unwrap().unwrap();
    responses.iter().map(outcome_code).collect()
}

#[test]
fn chaos_runs_are_reproducible_and_never_kill_the_daemon() {
    let plan = FaultPlan {
        seed: 7,
        compile_panics: vec!["chain:48:seed=2".to_string()],
        job_delays: vec![("reduction:32".to_string(), 3)],
        deadline_overruns: vec!["butterfly:16".to_string()],
        barrier_drops: vec![],
    };
    let lines: Vec<String> = [
        // injected compile panic (fires once per engine)
        "{\"workload\": \"chain:48:seed=2\", \"cols\": 2, \"rows\": 2}",
        // delayed a few ms, then fine
        "{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}",
        // forced deadline overrun — typed failure with partial progress
        "{\"workload\": \"butterfly:16\", \"cols\": 2, \"rows\": 2}",
        // resubmit of the panic victim: poison cleared, compiles clean
        "{\"workload\": \"chain:48:seed=2\", \"cols\": 2, \"rows\": 2}",
        // duplicate of the delayed job: cache hit, still delayed, still ok
        "{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();

    // round 1 uses the in-memory plan; round 2 re-reads it through the
    // same JSON round-trip `tdp serve --fault-plan <file>` uses, so the
    // serialized form is proven equivalent
    let reparsed = Arc::new(FaultPlan::parse(&plan.to_json_string()).unwrap());
    let round1 = chaos_round(Arc::new(plan), &lines);
    let round2 = chaos_round(reparsed, &lines);
    assert_eq!(
        round1,
        vec!["panicked", "ok", "deadline_exceeded", "ok", "ok"],
        "one typed outcome per injection site"
    );
    assert_eq!(round1, round2, "same plan + same stream = same outcome codes");
}
