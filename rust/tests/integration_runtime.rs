//! PJRT runtime integration: the AOT artifacts (L1 Pallas kernels + L2
//! graph_eval) against the rust models. Requires `make artifacts`; tests
//! skip (with a loud message) when artifacts are absent so `cargo test`
//! works standalone. `make test` always builds artifacts first.

use std::path::Path;
use tdp::graph::{DataflowGraph, Op};
use tdp::lod::naive_scan;
use tdp::runtime::XlaRuntime;
use tdp::util::rng::Rng;
use tdp::workload::{layered_random, lu_factorization_graph, SparseMatrix};

/// PJRT handles are not Sync (Rc internally), so each test builds its own
/// runtime; loading + compiling the three artifacts takes well under a
/// second on the CPU client.
fn runtime() -> Option<XlaRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match XlaRuntime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIPPING runtime tests: {e}");
            None
        }
    }
}

#[test]
fn opcode_tables_in_sync() {
    let Some(rt) = runtime() else { return };
    rt.manifest.check_opcode_table().unwrap();
}

#[test]
fn alu_artifact_matches_rust_dsp_model() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::seed_from_u64(42);
    for trial in 0..5 {
        let n = [1usize, 7, 256, 1000, 4096][trial];
        let a: Vec<f32> = (0..n).map(|_| rng.gen_f32_in(-50.0, 50.0)).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.gen_f32_in(-50.0, 50.0)).collect();
        let ops: Vec<u32> = (0..n).map(|_| rng.gen_range(8) as u32).collect();
        let got = rt.alu_batch(&a, &b, &ops).unwrap();
        for i in 0..n {
            let want = Op::from_code(ops[i]).unwrap().eval(a[i], b[i]);
            assert!(
                got[i] == want || (got[i].is_nan() && want.is_nan()),
                "lane {i}: {} != {}",
                got[i],
                want
            );
        }
    }
}

#[test]
fn alu_artifact_ieee_edge_cases() {
    let Some(rt) = runtime() else { return };
    let a = [1.0f32, 0.0, f32::NAN, f32::INFINITY];
    let b = [0.0f32, 0.0, 1.0, f32::INFINITY];
    let ops = [Op::Div.code(), Op::Div.code(), Op::Add.code(), Op::Sub.code()];
    let got = rt.alu_batch(&a, &b, &ops).unwrap();
    assert!(got[0].is_infinite());
    assert!(got[1].is_nan());
    assert!(got[2].is_nan());
    assert!(got[3].is_nan()); // inf - inf
}

#[test]
fn lod_artifact_matches_naive_scan() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::seed_from_u64(7);
    for _ in 0..30 {
        let w = 1 + rng.gen_range(128);
        let mut words = vec![0u32; w];
        for word in words.iter_mut() {
            if rng.gen_bool(0.3) {
                *word = rng.next_u64() as u32;
            }
        }
        let got = rt.lod_pick(&words).unwrap();
        assert_eq!(got, naive_scan(&words));
    }
    // all-zero
    assert_eq!(rt.lod_pick(&[0u32; 16]).unwrap(), tdp::lod::NO_READY);
}

#[test]
fn graph_eval_artifact_matches_native_on_random_dags() {
    let Some(rt) = runtime() else { return };
    for seed in 0..5u64 {
        let g = layered_random(16, 10, 40, 2, seed);
        let got = rt.graph_eval(&g).unwrap();
        let want = g.evaluate();
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "seed {seed} node {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn graph_eval_artifact_matches_native_on_lu() {
    let Some(rt) = runtime() else { return };
    let m = SparseMatrix::banded(64, 2, 0.9, 3);
    let (g, _) = lu_factorization_graph(&m);
    assert!(g.len() <= 2048, "fits artifact geometry");
    let got = rt.graph_eval(&g).unwrap();
    let want = g.evaluate();
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        // same op order => bit-exact
        assert_eq!(a.to_bits(), b.to_bits(), "node {i}: {a} vs {b}");
    }
}

#[test]
fn graph_eval_rejects_oversized_graphs() {
    let Some(rt) = runtime() else { return };
    let g = layered_random(64, 40, 128, 2, 0); // > 2048 nodes
    assert!(rt.graph_eval(&g).is_err());
}

#[test]
fn graph_eval_rejects_too_deep_graphs() {
    let Some(rt) = runtime() else { return };
    let mut g = DataflowGraph::new();
    let mut prev = g.add_input(1.0);
    for _ in 0..400 {
        // depth 400 > lmax 256
        prev = g.op(Op::Copy, &[prev]);
    }
    assert!(rt.graph_eval(&g).is_err());
}

#[test]
fn batch_too_large_rejected() {
    let Some(rt) = runtime() else { return };
    let n = rt.manifest.artifacts.alu_batch.batch.unwrap() + 1;
    let v = vec![0f32; n];
    let ops = vec![0u32; n];
    assert!(rt.alu_batch(&v, &v, &ops).is_err());
}
