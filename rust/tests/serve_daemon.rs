//! Daemon-semantics integration tests (DESIGN.md §13): the `tdp serve`
//! contract exercised over real loopback sockets — determinism across
//! concurrent clients, queue-full backpressure as a structured error,
//! and the graceful-drain state machine.

use std::sync::Arc;
use tdp::serve::{client, Daemon, DaemonHandle, ServeConfig};
use tdp::service::{Engine, JobSpec};
use tdp::sim::SimStats;
use tdp::telemetry::Registry;
use tdp::util::json::Json;

type Server = std::thread::JoinHandle<std::io::Result<()>>;

fn start(cfg: ServeConfig) -> (std::net::SocketAddr, DaemonHandle, Server) {
    let daemon = Daemon::bind("127.0.0.1:0", cfg, Arc::new(Registry::new())).unwrap();
    let addr = daemon.local_addr();
    let handle = daemon.handle();
    let server = std::thread::spawn(move || daemon.run());
    (addr, handle, server)
}

fn u(j: Option<&Json>) -> u64 {
    j.and_then(Json::as_u64).unwrap_or(u64::MAX)
}

/// Concurrent clients submit shuffled duplicates of the same job set;
/// every response must be bit-identical (per job) to an in-process
/// [`Engine`] run of the same spec, and the daemon's engine must have
/// compiled exactly once per distinct key — the shared-cache +
/// single-flight guarantee, observed through the socket.
#[test]
fn concurrent_clients_get_bit_identical_results_with_one_compile_per_key() {
    // 3 distinct program keys (scheduler/backend are normalized out of
    // the key, so distinctness must come from graph or overlay shape):
    // same workload on two geometries + a second workload
    let specs = [
        "{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}",
        "{\"workload\": \"reduction:32\", \"cols\": 4, \"rows\": 4}",
        "{\"workload\": \"chain:24:seed=1\", \"cols\": 2, \"rows\": 2}",
    ];
    // in-process ground truth (stats are deterministic; timing is not)
    let oracle = Engine::new();
    let baseline: Vec<SimStats> = specs
        .iter()
        .map(|s| oracle.submit(&JobSpec::from_json(s).unwrap()).unwrap().stats)
        .collect();

    let (addr, handle, server) = start(ServeConfig { workers: 4, ..Default::default() });
    // each client pipelines its own shuffle of duplicated jobs
    let orders: [[usize; 6]; 3] = [[0, 1, 2, 0, 1, 2], [2, 1, 0, 1, 0, 2], [1, 2, 2, 0, 0, 1]];
    std::thread::scope(|scope| {
        let baseline = &baseline;
        for order in &orders {
            scope.spawn(move || {
                let lines: Vec<String> = order.iter().map(|&i| specs[i].to_string()).collect();
                let responses = client::submit_raw_lines(&addr.to_string(), &lines).unwrap();
                for (&i, response) in order.iter().zip(&responses) {
                    let result = response
                        .get("result")
                        .unwrap_or_else(|| panic!("job failed: {response:?}"));
                    let stats =
                        SimStats::from_json_value(result.get("stats").unwrap()).unwrap();
                    assert_eq!(
                        stats, baseline[i],
                        "socket result for {} must be bit-identical to in-process",
                        specs[i]
                    );
                }
            });
        }
    });

    // distinct keys compiled exactly once each, duplicates were hits
    let stats = client::fetch_stats(&addr.to_string()).unwrap();
    let cache = stats.get("engine").unwrap().get("cache").unwrap();
    assert_eq!(u(cache.get("misses")), 3, "one compile per distinct key");
    assert_eq!(u(cache.get("hits")), 15, "every duplicate was a cache hit");
    assert_eq!(u(cache.get("graphs")), 2, "both reduction geometries share one graph");
    let daemon_doc = stats.get("daemon").unwrap();
    assert_eq!(u(daemon_doc.get("accepted")), 18);
    assert_eq!(u(daemon_doc.get("completed")), 18);
    assert_eq!(u(daemon_doc.get("failed")), 0);

    handle.drain();
    server.join().unwrap().unwrap();
}

/// A tiny queue under a pipelined burst: overflow is a structured
/// `queue_full` error on the client's own line — never a disconnect —
/// and accepted + rejected accounts for every job sent.
#[test]
fn queue_full_is_a_structured_error_not_a_disconnect() {
    use std::io::{BufRead, BufReader, Write};
    let (addr, handle, server) =
        start(ServeConfig { workers: 1, queue_capacity: 1, ..Default::default() });
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // burst: the single worker is busy compiling job 1 while the reader
    // admits job 2 and must refuse some of the rest (capacity 1)
    let n = 10usize;
    for _ in 0..n {
        stream
            .write_all(b"{\"workload\": \"lu_banded:60:4:0.9:seed=1\", \"cols\": 2, \"rows\": 2}\n")
            .unwrap();
    }
    stream.flush().unwrap();
    let mut results = 0u64;
    let mut queue_full = 0u64;
    let mut seqs_seen = std::collections::BTreeSet::new();
    for _ in 0..n {
        let mut line = String::new();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "daemon must answer every line, got EOF after {} responses",
            seqs_seen.len()
        );
        let j = tdp::util::json::parse(line.trim()).unwrap();
        seqs_seen.insert(u(j.get("seq")));
        match j.get("result") {
            Some(_) => results += 1,
            None => {
                assert_eq!(j.get("code").and_then(Json::as_str), Some("queue_full"), "{j:?}");
                queue_full += 1;
            }
        }
    }
    assert_eq!(seqs_seen.len(), n, "one response per request line");
    assert!(results >= 1, "the job the worker held must complete");
    assert!(queue_full >= 1, "a 1-slot queue must overflow under a {n}-job burst");
    // the connection survived: a ping still answers
    stream.write_all(b"{\"control\": \"ping\"}\n").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let pong = tdp::util::json::parse(line.trim()).unwrap();
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

    // daemon accounting matches what the wire saw
    let stats = handle.stats_json();
    let d = stats.get("daemon").unwrap();
    assert_eq!(u(d.get("accepted")), results);
    assert_eq!(u(d.get("rejected_full")), queue_full);
    assert_eq!(u(d.get("accepted")) + u(d.get("rejected_full")), n as u64);

    handle.drain();
    server.join().unwrap().unwrap();
}

/// The drain state machine over one connection: jobs admitted before
/// `shutdown` all complete and answer; a job line after the ack gets a
/// structured `draining` refusal; `run()` returns only after the last
/// in-flight response is flushed.
#[test]
fn graceful_drain_finishes_admitted_jobs_and_refuses_new_ones() {
    use std::io::{BufRead, BufReader, Write};
    let (addr, handle, server) = start(ServeConfig { workers: 1, ..Default::default() });
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // 3 jobs + shutdown + 1 more job, pipelined in one write: the reader
    // admits seq 1-3, flips the drain at seq 4, so seq 5 is refused —
    // deterministically, because one reader processes lines in order
    let burst = "\
{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}\n\
{\"workload\": \"chain:24:seed=1\", \"cols\": 2, \"rows\": 2}\n\
{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}\n\
{\"control\": \"shutdown\"}\n\
{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}\n";
    stream.write_all(burst.as_bytes()).unwrap();
    stream.flush().unwrap();
    // responses arrive out of order (worker vs reader); key by seq
    let mut by_seq = std::collections::BTreeMap::new();
    while by_seq.len() < 5 {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "EOF with responses owed: {by_seq:?}");
        let j = tdp::util::json::parse(line.trim()).unwrap();
        by_seq.insert(u(j.get("seq")), j);
    }
    for seq in [1, 2, 3] {
        assert!(
            by_seq[&seq].get("result").is_some(),
            "job admitted before shutdown must complete: {:?}",
            by_seq[&seq]
        );
    }
    assert_eq!(by_seq[&4].get("state").and_then(Json::as_str), Some("draining"));
    assert_eq!(by_seq[&5].get("code").and_then(Json::as_str), Some("draining"));

    // run() returns only after every admitted job answered
    server.join().unwrap().unwrap();
    let stats = handle.stats_json();
    assert_eq!(stats.get("state").and_then(Json::as_str), Some("draining"));
    let d = stats.get("daemon").unwrap();
    assert_eq!(u(d.get("accepted")), 3);
    assert_eq!(u(d.get("completed")), 3);
    assert_eq!(u(d.get("rejected_draining")), 1);
}
