//! `tdp serve` / `tdp batch --connect` / `tdp top` end-to-end as real
//! processes: the daemon's stderr banner is the port-discovery contract
//! for `--listen 127.0.0.1:0`, socket results must be bit-identical
//! (stats-wise) to the in-process batch of the same file, `tdp top
//! --format json` must return a well-formed stats document, and a
//! `shutdown` control line must drain the daemon to a clean exit 0.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use tdp::util::json::{self, Json};

fn tdp() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tdp"))
}

/// Spawn `tdp serve --listen 127.0.0.1:0` and parse the bound address
/// out of the one-line stderr banner.
fn spawn_daemon() -> (Child, String) {
    let mut child = tdp()
        .args(["serve", "--listen", "127.0.0.1:0", "--workers", "2"])
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let stderr = child.stderr.take().unwrap();
    let mut banner = String::new();
    BufReader::new(stderr).read_line(&mut banner).unwrap();
    let addr = banner
        .split("listening on ")
        .nth(1)
        .and_then(|rest| rest.split(' ').next())
        .unwrap_or_else(|| panic!("no address in banner: {banner:?}"))
        .to_string();
    assert!(addr.starts_with("127.0.0.1:"), "banner address: {banner:?}");
    (child, addr)
}

#[test]
fn serve_batch_connect_and_top_roundtrip() {
    let jobs_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("data")
        .join("smoke_jobs.jsonl");
    // ground truth: the same file through an in-process `tdp batch`
    let baseline = tdp().arg("batch").arg(&jobs_path).output().unwrap();
    assert!(baseline.status.success(), "{}", String::from_utf8_lossy(&baseline.stderr));
    let baseline_stats: Vec<Json> = String::from_utf8_lossy(&baseline.stdout)
        .lines()
        .map(|l| json::parse(l).unwrap().get("stats").unwrap().clone())
        .collect();

    let (mut child, addr) = spawn_daemon();
    // guard: kill the daemon if any assertion below panics, so the test
    // process never leaks a listener
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // the same jobs through the daemon
        let out = tdp()
            .arg("batch")
            .arg(&jobs_path)
            .args(["--connect", &addr])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let socket_stats: Vec<Json> = String::from_utf8_lossy(&out.stdout)
            .lines()
            .map(|l| json::parse(l).unwrap().get("stats").unwrap().clone())
            .collect();
        // timing fields differ run to run; the simulation counters are
        // the determinism contract and must match bit for bit
        assert_eq!(socket_stats, baseline_stats, "socket results == in-process results");

        // --workers is a daemon-side knob: connect mode rejects it loudly
        let out = tdp()
            .arg("batch")
            .arg(&jobs_path)
            .args(["--connect", &addr, "--workers", "4"])
            .output()
            .unwrap();
        assert!(!out.status.success(), "--workers must be rejected with --connect");

        // one stats poll through the `tdp top` JSON mode
        let out = tdp()
            .args(["top", &addr, "--format", "json", "--iters", "1"])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let stats = json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
        assert_eq!(stats.get("state").and_then(Json::as_str), Some("serving"));
        let d = stats.get("daemon").unwrap();
        assert_eq!(d.get("completed").and_then(Json::as_u64), Some(4));
        let cache = stats.get("engine").unwrap().get("cache").unwrap();
        // smoke_jobs.jsonl: 4 jobs over 3 distinct program keys
        assert_eq!(cache.get("misses").and_then(Json::as_u64), Some(3));
        assert_eq!(cache.get("hits").and_then(Json::as_u64), Some(1));

        // the text frame renders once without a daemon-side error
        let out = tdp().args(["top", &addr, "--iters", "1"]).output().unwrap();
        assert!(out.status.success());
        assert!(String::from_utf8_lossy(&out.stdout).contains("state: serving"));

        // drain via the control line; the daemon process exits 0
        tdp::serve::client::request_shutdown(&addr).unwrap();
    }));
    if result.is_err() {
        let _ = child.kill();
        let _ = child.wait();
        std::panic::resume_unwind(result.unwrap_err());
    }
    let status = child.wait().unwrap();
    assert!(status.success(), "daemon must exit 0 after a graceful drain");
}

#[test]
fn top_against_no_daemon_fails_fast() {
    // a port nothing listens on: the first poll failing is a hard error
    let out = tdp()
        .args(["top", "127.0.0.1:1", "--format", "json", "--iters", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
