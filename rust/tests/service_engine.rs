//! Service-engine acceptance (ISSUE): N threads submit a shuffled mix
//! of duplicate jobs against one [`Engine`]; results must be
//! bit-identical to sequential runs, and `program::compile_count()`
//! must equal the number of *distinct* (fingerprint, overlay) cache
//! keys — concurrency never double-compiles (the cache is
//! single-flight) and never changes an answer.
//!
//! NOTE: `compile_count` is process-global and `cargo test` runs tests
//! of one binary concurrently, so this file holds exactly ONE `#[test]`
//! (its own process) and measures strictly sequential deltas.

use std::collections::BTreeMap;
use tdp::engine::BackendKind;
use tdp::program::compile_count;
use tdp::sched::SchedulerKind;
use tdp::service::{Engine, JobSpec};
use tdp::util::rng::Rng;

type Key = (String, &'static str, &'static str);

fn key_of(job: &JobSpec) -> Key {
    (
        job.workload.clone(),
        job.scheduler.toml_name(),
        job.backend.toml_name(),
    )
}

#[test]
fn concurrent_duplicate_jobs_compile_once_and_match_sequential() {
    // 3 workloads × 2 schedulers × 2 backends = 12 distinct jobs, but
    // only 3 distinct cache keys: scheduler and backend are session
    // knobs, normalized out of the content address.
    let workloads = ["reduction:48", "chain:24:seed=1", "layered:8:4:16:2:seed=5"];
    let mut jobs: Vec<JobSpec> = Vec::new();
    for w in workloads {
        for sched in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
            for backend in [BackendKind::Lockstep, BackendKind::SkipAhead] {
                let mut job = JobSpec::new(w);
                job.overlay = job.overlay.with_dims(2, 2);
                job.scheduler = sched;
                job.backend = backend;
                jobs.push(job);
            }
        }
    }

    // sequential baseline on its own engine (cold compiles)
    let baseline = Engine::new();
    let mut expect: BTreeMap<Key, tdp::SimStats> = BTreeMap::new();
    for job in &jobs {
        let r = baseline.submit(job).unwrap();
        assert_eq!(r.stats.completed, r.stats.total_nodes, "run completed");
        expect.insert(key_of(job), r.stats);
    }
    assert_eq!(expect.len(), jobs.len(), "12 distinct variants");

    // concurrent phase: 4 threads, each submitting its own shuffled
    // double copy of the job list (duplicates within and across threads)
    const THREADS: u64 = 4;
    let engine = Engine::new();
    let compiles0 = compile_count();
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let engine = &engine;
            let jobs = &jobs;
            let expect = &expect;
            s.spawn(move || {
                let mut order: Vec<usize> =
                    (0..jobs.len()).chain(0..jobs.len()).collect();
                let mut rng = Rng::seed_from_u64(0xBEEF + t);
                for i in (1..order.len()).rev() {
                    order.swap(i, rng.gen_range(i + 1));
                }
                for idx in order {
                    let job = &jobs[idx];
                    let r = engine.submit(job).unwrap();
                    assert_eq!(
                        &r.stats,
                        expect.get(&key_of(job)).unwrap(),
                        "concurrent stats must be bit-identical to the \
                         sequential cold-compile run ({:?})",
                        key_of(job)
                    );
                }
            });
        }
    });

    // exactly one compile per distinct (fingerprint, overlay) key —
    // across every thread, duplicate, scheduler and backend
    let distinct_keys = workloads.len() as u64;
    assert_eq!(
        compile_count() - compiles0,
        distinct_keys,
        "compile count must equal the number of distinct cache keys"
    );
    let stats = engine.cache_stats();
    assert_eq!(stats.misses, distinct_keys);
    assert_eq!(
        stats.hits,
        THREADS * 2 * jobs.len() as u64 - distinct_keys,
        "every other submission is a cache hit"
    );
    assert_eq!(stats.entries, workloads.len());
    assert_eq!(stats.graphs, workloads.len(), "graphs built once per spec");
    assert_eq!(stats.evictions, 0);

    // and a parallel batch over the same engine returns results in job
    // order, all cache hits, still bit-identical
    let batch = engine.submit_batch(&jobs, 3);
    for (job, r) in jobs.iter().zip(&batch) {
        let r = r.as_ref().unwrap();
        assert!(r.cache_hit);
        assert_eq!(r.workload, job.workload, "batch preserves job order");
        assert_eq!(&r.stats, expect.get(&key_of(job)).unwrap());
    }
    assert_eq!(compile_count() - compiles0, distinct_keys, "batch added no compiles");

    // the metrics snapshot must agree exactly with what this scenario
    // pinned down: 4 threads x 2 copies x 12 jobs + the 12-job batch all
    // went through this engine, with one compile per distinct cache key
    let snap = engine.metrics_snapshot();
    let get = |path: &[&str]| -> u64 {
        let mut v = &snap;
        for k in path {
            v = v.get(k).unwrap_or_else(|| panic!("snapshot missing {path:?}"));
        }
        v.as_u64().unwrap_or_else(|| panic!("{path:?} not a u64"))
    };
    let submitted = THREADS * 2 * jobs.len() as u64 + jobs.len() as u64;
    assert_eq!(get(&["version"]), 1);
    assert_eq!(get(&["jobs", "submitted"]), submitted);
    assert_eq!(get(&["jobs", "failed"]), 0);
    assert_eq!(get(&["cache", "misses"]), stats.misses);
    assert_eq!(get(&["cache", "hits"]), submitted - distinct_keys);
    assert_eq!(get(&["cache", "evictions"]), 0);
    assert_eq!(get(&["cache", "entries"]), workloads.len() as u64);
    assert_eq!(get(&["cache", "graphs"]), workloads.len() as u64);
    assert_eq!(
        get(&["latency", "compile_micros", "count"]),
        distinct_keys,
        "compile latency observed once per miss, never on hits"
    );
    assert_eq!(get(&["latency", "run_micros", "count"]), submitted);
    let per = snap.get("workloads").unwrap().as_obj().unwrap();
    assert_eq!(per.len(), workloads.len(), "one latency entry per canonical spec");
    for w in workloads {
        let entry = per.get(w).unwrap_or_else(|| panic!("missing workload key {w}"));
        let jobs_for_key = entry.get("jobs").unwrap().as_u64().unwrap();
        assert_eq!(jobs_for_key, submitted / workloads.len() as u64, "{w}");
        let compiles = entry.get("compile_micros").unwrap().get("count").unwrap();
        assert_eq!(compiles.as_u64(), Some(1), "{w} compiled exactly once");
    }
    // and the textual form round-trips through the strict parser
    let reparsed = tdp::util::json::parse(&engine.metrics_snapshot_json()).unwrap();
    assert_eq!(
        reparsed.get("jobs").unwrap().get("submitted").unwrap().as_u64(),
        Some(submitted)
    );
}
