//! Compile-once acceptance (ISSUE): the Fig.1 sweep and the capacity
//! scan compile each workload exactly once per overlay shape —
//! placement and criticality labeling are never re-run per scheduler or
//! backend variant. Verified with the process-global construction
//! counters (`place::build_count`, `criticality::labeling_count`,
//! `program::compile_count`).
//!
//! NOTE: the counters are process-global and `cargo test` runs tests of
//! one binary concurrently, so this file holds exactly ONE `#[test]`
//! (its own process) and measures strictly sequential deltas.

use tdp::config::Overlay;
use tdp::coordinator::{fig1_config, fig1_sweep};
use tdp::criticality;
use tdp::graph::DataflowGraph;
use tdp::place;
use tdp::program::{compile_count, run_batch, Program, RunVariant};
use tdp::sched::SchedulerKind;
use tdp::workload::layered_random;

#[test]
fn sweeps_and_scans_compile_each_workload_exactly_once() {
    let ws: Vec<(String, DataflowGraph)> = vec![
        ("a".into(), layered_random(12, 6, 24, 2, 1)),
        ("b".into(), layered_random(16, 8, 32, 2, 2)),
        ("c".into(), layered_random(8, 4, 16, 1, 3)),
    ];
    let cfg = fig1_config().with_dims(4, 4);
    let overlay = Overlay::from_config(cfg).unwrap();

    // --- Fig.1 sweep: N workloads x 2 schedulers, N compiles ---
    let places0 = place::build_count();
    let labels0 = criticality::labeling_count();
    let compiles0 = compile_count();
    let rows = fig1_sweep(&ws, cfg, 4).unwrap();
    assert_eq!(rows.len(), ws.len());
    assert_eq!(
        compile_count() - compiles0,
        ws.len() as u64,
        "one Program per workload"
    );
    assert_eq!(
        place::build_count() - places0,
        ws.len() as u64,
        "placement must not be re-run per scheduler"
    );
    assert_eq!(
        criticality::labeling_count() - labels0,
        ws.len() as u64,
        "criticality labeling must not be re-run per scheduler"
    );

    // --- capacity scan: one compile answers both schedulers ---
    let places1 = place::build_count();
    for (_, g) in &ws {
        let program = Program::compile(g, &overlay).unwrap();
        let in_order = program.fits(SchedulerKind::InOrder);
        let ooo = program.fits(SchedulerKind::OutOfOrder);
        assert!(ooo || !in_order, "OoO budget dominates in-order");
    }
    assert_eq!(place::build_count() - places1, ws.len() as u64);

    // --- run_batch: 4 variants, still a single placement ---
    let places2 = place::build_count();
    let labels2 = criticality::labeling_count();
    let program = Program::compile(&ws[0].1, &overlay).unwrap();
    let results = run_batch(&program, &RunVariant::all(), 2);
    assert_eq!(results.len(), 4);
    assert!(results.iter().all(|r| r.is_ok()));
    assert_eq!(place::build_count() - places2, 1, "run_batch shares one placement");
    assert_eq!(criticality::labeling_count() - labels2, 1);
}
