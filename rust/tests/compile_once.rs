//! Compile-once acceptance (ISSUE): the Fig.1 sweep and the capacity
//! scan compile each workload exactly once per overlay shape —
//! placement and criticality labeling are never re-run per scheduler or
//! backend variant. Verified with the process-global construction
//! counters (`place::build_count`, `criticality::labeling_count`,
//! `program::compile_count`).
//!
//! NOTE: the counters are process-global and `cargo test` runs tests of
//! one binary concurrently, so this file holds exactly ONE `#[test]`
//! (its own process) and measures strictly sequential deltas.

use tdp::config::Overlay;
use tdp::coordinator::{fig1_config, fig1_sweep};
use tdp::criticality;
use tdp::graph::DataflowGraph;
use tdp::place;
use tdp::program::{compile_count, run_batch, Program, RunVariant};
use tdp::sched::SchedulerKind;
use tdp::workload::Spec;

#[test]
fn sweeps_and_scans_compile_each_workload_exactly_once() {
    let ws: Vec<(String, Spec)> = vec![
        ("a".into(), "layered:12:6:24:2:seed=1".parse().unwrap()),
        ("b".into(), "layered:16:8:32:2:seed=2".parse().unwrap()),
        ("c".into(), "layered:8:4:16:1:seed=3".parse().unwrap()),
    ];
    let cfg = fig1_config().with_dims(4, 4);
    let overlay = Overlay::from_config(cfg).unwrap();

    // --- Fig.1 sweep (service-layer path): N workloads x 2 schedulers,
    // N compiles — the Engine's content-addressed cache dedups the
    // scheduler variants onto one artifact per workload ---
    let places0 = place::build_count();
    let labels0 = criticality::labeling_count();
    let compiles0 = compile_count();
    let rows = fig1_sweep(&ws, cfg, 4).unwrap();
    assert_eq!(rows.len(), ws.len());
    assert_eq!(
        compile_count() - compiles0,
        ws.len() as u64,
        "one Program per workload"
    );
    assert_eq!(
        place::build_count() - places0,
        ws.len() as u64,
        "placement must not be re-run per scheduler"
    );
    assert_eq!(
        criticality::labeling_count() - labels0,
        ws.len() as u64,
        "criticality labeling must not be re-run per scheduler"
    );

    // --- capacity scan: one compile answers both schedulers ---
    let graphs: Vec<DataflowGraph> =
        ws.iter().map(|(_, spec)| spec.build().unwrap()).collect();
    let places1 = place::build_count();
    for g in &graphs {
        let program = Program::compile(g, &overlay).unwrap();
        let in_order = program.fits(SchedulerKind::InOrder);
        let ooo = program.fits(SchedulerKind::OutOfOrder);
        assert!(ooo || !in_order, "OoO budget dominates in-order");
    }
    assert_eq!(place::build_count() - places1, ws.len() as u64);

    // --- run_batch: 4 variants, still a single placement ---
    let places2 = place::build_count();
    let labels2 = criticality::labeling_count();
    let program = Program::compile(&graphs[0], &overlay).unwrap();
    let results = run_batch(&program, &RunVariant::all(), 2);
    assert_eq!(results.len(), 4);
    assert!(results.iter().all(|r| r.is_ok()));
    assert_eq!(place::build_count() - places2, 1, "run_batch shares one placement");
    assert_eq!(criticality::labeling_count() - labels2, 1);
}
