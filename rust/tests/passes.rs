//! The pass-pipeline contract (DESIGN.md §12), from the outside:
//! golden diagnostics on hand-built bad graphs, bit-parity of the
//! optimizing pipeline (DCE + constant replication) against the plain
//! one across both engine backends and all four schedulers, and
//! determinism / path-parity of traffic-aware placement.

use tdp::config::{Overlay, OverlayConfig};
use tdp::engine::BackendKind;
use tdp::graph::{graph_from_json_raw, DataflowGraph, Op};
use tdp::passes::verify::graph_diagnostics;
use tdp::place::PlacementPolicy;
use tdp::program::{CompileError, Program};
use tdp::sched::{LifoSched, RandomSched, Scheduler, SchedulerKind};
use tdp::sim::Simulator;
use tdp::workload::layered_random;
use tdp::Severity;

fn fixture(name: &str) -> String {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/data").join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

/// The checked-in known-bad fixture (also used by CI's check-smoke job):
/// a forward operand reference is reported as a combinational cycle at
/// the offending node, and the node downstream of it as unreachable —
/// both at error severity, so compilation refuses the graph with the
/// same structured report.
#[test]
fn golden_diagnostics_on_cycle_fixture() {
    let g = graph_from_json_raw(&fixture("bad_cycle.json")).unwrap();
    let diags = graph_diagnostics(&g);
    let codes: Vec<&str> = diags.iter().map(|d| d.code).collect();
    assert_eq!(codes, ["cycle", "unreachable"], "{diags:?}");
    assert_eq!(diags[0].node, Some(1), "cycle pinned to the forward ref");
    assert_eq!(diags[1].node, Some(2), "consumer of the broken node");
    assert!(diags.iter().all(|d| d.severity == Severity::Error));
    let overlay = Overlay::builder().dims(2, 2).build().unwrap();
    match Program::compile(&g, &overlay) {
        Err(CompileError::InvalidGraph { diagnostics }) => {
            let compile_codes: Vec<&str> = diagnostics.iter().map(|d| d.code).collect();
            assert_eq!(compile_codes, codes, "compile surfaces the verifier's report");
        }
        Err(other) => panic!("expected InvalidGraph, got {other}"),
        Ok(_) => panic!("a cyclic graph must not compile"),
    }
}

/// A dangling operand (source id past the end of the graph) is an
/// error on the referencing node; the input left with no consumers is
/// a warning, not an error.
#[test]
fn golden_diagnostics_on_dangling_operand() {
    let g = graph_from_json_raw(r#"{"nodes":[{"in":1.0},{"op":"NEG","src":[9]}]}"#).unwrap();
    let diags = graph_diagnostics(&g);
    let dangling: Vec<_> = diags.iter().filter(|d| d.code == "dangling-operand").collect();
    assert_eq!(dangling.len(), 1, "{diags:?}");
    assert_eq!(dangling[0].node, Some(1));
    assert_eq!(dangling[0].severity, Severity::Error);
    assert!(
        diags.iter().any(|d| d.code == "dead-input" && d.severity == Severity::Warning),
        "unconsumed input is a warning: {diags:?}"
    );
}

/// More nodes on one PE than the 13-bit packet local index can address
/// is a hard compile error naming the PE — capacity enforcement (off by
/// default) cannot wave it through.
#[test]
fn local_index_overflow_is_a_hard_compile_error() {
    let mut g = DataflowGraph::new();
    let mut prev = g.add_input(1.0);
    for _ in 0..8200 {
        prev = g.op(Op::Neg, &[prev]);
    }
    let overlay = Overlay::builder().dims(1, 1).build().unwrap();
    match Program::compile(&g, &overlay) {
        Err(CompileError::LocalIndexOverflow { pe, nodes, max }) => {
            assert_eq!(pe, 0);
            assert_eq!(nodes, 8201);
            assert_eq!(max, 8192);
        }
        Err(other) => panic!("expected LocalIndexOverflow, got {other}"),
        Ok(_) => panic!("8201 nodes on one PE must not compile"),
    }
}

/// A graph that exercises both transform passes: two dead inputs (DCE)
/// and one input with fanout far above the replication threshold.
fn opt_exercising_graph() -> DataflowGraph {
    let mut g = DataflowGraph::new();
    let hot = g.add_input(1.5);
    let _dead1 = g.add_input(9.0);
    let x = g.add_input(-2.0);
    let _dead2 = g.add_input(3.0);
    let mut acc = g.op(Op::Add, &[hot, x]);
    for _ in 0..100 {
        acc = g.op(Op::Add, &[hot, acc]);
    }
    g
}

fn bits_eq(a: f32, b: f32) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

/// ISSUE acceptance (bit-parity): the optimized artifact (`opt = true`:
/// DCE + constant replication, node ids remapped) reports values in
/// original-graph order that are bit-identical to the unoptimized
/// artifact and to the reference evaluation, on every live node, for
/// the two paper schedulers on both engine backends.
#[test]
fn optimized_pipeline_is_bit_identical_on_live_nodes() {
    let g = opt_exercising_graph();
    let want = g.evaluate();
    for backend in BackendKind::ALL {
        for scheduler in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
            let cfg = OverlayConfig::default()
                .with_dims(2, 2)
                .with_scheduler(scheduler)
                .with_backend(backend);
            let mut opt_cfg = cfg;
            opt_cfg.opt = true;
            let plain = Program::compile(&g, &Overlay::from_config(cfg).unwrap()).unwrap();
            let opt = Program::compile(&g, &Overlay::from_config(opt_cfg).unwrap()).unwrap();
            let tag = format!("{scheduler:?}/{backend:?}");
            // the transforms actually fired: 2 dead inputs gone, the
            // hot input split into ceil(101/64) = 2 replicas
            let map = opt.node_map().expect("opt pipeline records a node map");
            assert_eq!(opt.exec_graph().len(), g.len() - 2 + 1, "{tag}");
            assert!(plain.node_map().is_none(), "{tag}: default pipeline is identity");
            let run = |p: &Program| {
                let mut be = p.session().backend().unwrap();
                be.run().unwrap();
                be.values().to_vec()
            };
            let (a, b) = (run(&plain), run(&opt));
            assert_eq!(a.len(), g.len(), "{tag}: plain values in graph order");
            assert_eq!(b.len(), g.len(), "{tag}: remapped values in graph order");
            for i in 0..g.len() as u32 {
                if !map.is_live(i) {
                    continue;
                }
                let (i, x, y, r) = (i as usize, a[i as usize], b[i as usize], want[i as usize]);
                assert!(bits_eq(x, y), "{tag}: node {i}: plain {x} vs opt {y}");
                assert!(bits_eq(y, r), "{tag}: node {i}: opt {y} vs reference {r}");
            }
        }
    }
}

/// Same parity through the ablation schedulers, driven over the
/// optimized artifact's baked tables — `values()` still speaks
/// original-graph ids even though the simulator executes the remapped
/// graph.
#[test]
fn optimized_tables_serve_ablation_schedulers() {
    let g = opt_exercising_graph();
    let want = g.evaluate();
    let mut cfg = OverlayConfig::default().with_dims(2, 2);
    cfg.opt = true;
    let program = Program::compile(&g, &Overlay::from_config(cfg).unwrap()).unwrap();
    let map = program.node_map().unwrap();
    for which in ["lifo", "random"] {
        let factory = move |_: SchedulerKind, n: usize| match which {
            "lifo" => Scheduler::Lifo(LifoSched::new(n)),
            _ => Scheduler::Random(RandomSched::new(n, 42)),
        };
        let mut sim = Simulator::with_tables_and_factory(
            program.exec_graph(),
            program.runtime_tables(),
            cfg,
            factory,
        )
        .unwrap();
        let stats = sim.run().unwrap();
        assert_eq!(stats.completed, program.exec_graph().len(), "{which}");
        let vals = sim.values();
        assert_eq!(vals.len(), g.len(), "{which}: original-graph order");
        for i in 0..g.len() as u32 {
            if map.is_live(i) {
                let (v, r) = (vals[i as usize], want[i as usize]);
                assert!(bits_eq(v, r), "{which}: node {i}: sim {v} vs reference {r}");
            }
        }
    }
}

/// Traffic-aware placement is deterministic under a fixed seed — the
/// annealer's RNG is derived from the config seed, so two compiles of
/// the same graph agree assignment-for-assignment — and the direct
/// `Simulator::new` path (which computes its own criticality labels)
/// lands on the identical placement and stats as the compile pipeline.
#[test]
fn traffic_aware_placement_is_deterministic() {
    let g = layered_random(32, 8, 64, 2, 11);
    let compile = || {
        let overlay = Overlay::builder()
            .dims(4, 4)
            .placement(PlacementPolicy::TrafficAware)
            .build()
            .unwrap();
        Program::compile(&g, &overlay).unwrap()
    };
    let (p1, p2) = (compile(), compile());
    assert_eq!(p1.placement().pe_of, p2.placement().pe_of, "assignment reproduces");
    assert_eq!(p1.placement().local_of, p2.placement().local_of, "layout reproduces");
    let (s1, s2) = (p1.session().run().unwrap(), p2.session().run().unwrap());
    assert_eq!(s1, s2, "runs reproduce");
    let mut cfg = OverlayConfig::default().with_dims(4, 4);
    cfg.placement = PlacementPolicy::TrafficAware;
    let mut sim = Simulator::new(&g, cfg).unwrap();
    assert_eq!(sim.run().unwrap(), s1, "direct path matches the compiled artifact");
    let n_pes = p1.placement().num_pes as u32;
    assert!(p1.placement().pe_of.iter().all(|&pe| pe < n_pes));
}
