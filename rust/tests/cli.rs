//! CLI integration: drive the `tdp` binary end-to-end through its
//! subcommands (workload gen → file → run → validate paths, table
//! rendering, error handling).

use std::process::Command;

fn tdp() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tdp"))
}

fn run_ok(args: &[&str]) -> String {
    let out = tdp().args(args).output().expect("binary runs");
    assert!(
        out.status.success(),
        "tdp {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn help_without_args() {
    let text = run_ok(&[]);
    assert!(text.contains("USAGE"));
    assert!(text.contains("sweep"));
}

#[test]
fn resources_table() {
    let text = run_ok(&["resources", "--points", "16", "--detail"]);
    assert!(text.contains("Table I"));
    assert!(text.contains("306"), "1-PE Fmax row");
    assert!(text.contains("6.25%"), "flag overhead detail");
}

#[test]
fn capacity_claim() {
    let text = run_ok(&["capacity"]);
    assert!(text.contains("5.0"), "ratio ≈5x: {text}");
}

#[test]
fn run_small_workload_both_schedulers() {
    let text = run_ok(&[
        "run",
        "--workload",
        "kind = \"reduction\"\\nwidth = 64",
        "--cols",
        "2",
        "--rows",
        "2",
    ]);
    assert!(text.contains("speedup"));
    assert!(text.contains("in-order"));
}

#[test]
fn gen_then_run_graph_file() {
    let dir = std::env::temp_dir().join(format!("tdp_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.json");
    let text = run_ok(&[
        "gen",
        "--workload",
        "kind = \"stencil\"\\nwidth = 10\\nsteps = 3",
        "--out",
        path.to_str().unwrap(),
    ]);
    assert!(text.contains("wrote"));
    let text = run_ok(&[
        "run",
        "--graph",
        path.to_str().unwrap(),
        "--cols",
        "2",
        "--rows",
        "2",
        "--scheduler",
        "out_of_order",
    ]);
    assert!(text.contains("out-of-order"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn validate_without_pjrt() {
    let text = run_ok(&[
        "validate",
        "--workload",
        "kind = \"butterfly\"\\nwidth = 32",
        "--no-pjrt",
        "--cols",
        "2",
        "--rows",
        "2",
    ]);
    assert!(text.contains("VALIDATION PASSED"));
}

#[test]
fn validate_with_pjrt_if_artifacts_present() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let text = run_ok(&[
        "validate",
        "--workload",
        "kind = \"lu_banded\"\\nn = 40\\nhalf_bw = 2\\nfill = 0.9",
        "--artifacts",
        artifacts.to_str().unwrap(),
    ]);
    assert!(text.contains("PJRT-oracle max |err| = 0"), "{text}");
    assert!(text.contains("VALIDATION PASSED"));
}

#[test]
fn noc_stress_reports_throughput() {
    let text = run_ok(&[
        "noc-stress",
        "--cols",
        "4",
        "--rows",
        "4",
        "--packets",
        "2000",
        "--inject-rate",
        "0.3",
    ]);
    assert!(text.contains("pkts/cycle"));
}

#[test]
fn workload_stats_reports_shape() {
    let text = run_ok(&[
        "workload-stats",
        "--workload",
        "kind = \"layered\"\\ninputs = 8\\nlevels = 5\\nwidth = 16\\nlookback = 1",
        "--pes",
        "4",
    ]);
    assert!(text.contains("parallelism"));
    assert!(text.contains("saturates a 4-PE overlay: YES"));
}

#[test]
fn analyze_traces_both_schedulers() {
    let text = run_ok(&[
        "analyze",
        "--workload",
        "kind = \"reduction\"\\nwidth = 128",
        "--cols",
        "2",
        "--rows",
        "2",
        "--stride",
        "4",
    ]);
    assert!(text.contains("ready queue"));
    assert!(text.contains("=== in-order ==="));
    assert!(text.contains("=== out-of-order ==="));
}

/// `run --format json` emits a machine-readable result whose counters
/// round-trip through the crate's own JSON parser.
#[test]
fn run_format_json_single_scheduler() {
    let text = run_ok(&[
        "run",
        "--workload",
        "kind = \"reduction\"\\nwidth = 64",
        "--cols",
        "2",
        "--rows",
        "2",
        "--scheduler",
        "out_of_order",
        "--format",
        "json",
    ]);
    let stats = tdp::SimStats::from_json(text.trim()).expect("stdout is one stats object");
    assert!(stats.cycles > 0);
    assert_eq!(stats.scheduler, tdp::SchedulerKind::OutOfOrder);
    assert_eq!(stats.completed, stats.total_nodes);
}

#[test]
fn run_format_json_both_schedulers() {
    let text = run_ok(&[
        "run",
        "--workload",
        "kind = \"reduction\"\\nwidth = 64",
        "--cols",
        "2",
        "--rows",
        "2",
        "--format",
        "json",
    ]);
    let j = tdp::util::json::parse(text.trim()).unwrap();
    let speedup = j.get("speedup").unwrap().as_f64().unwrap();
    assert!(speedup > 0.0);
    for kind in ["in_order", "out_of_order"] {
        let stats = tdp::SimStats::from_json_value(j.get(kind).unwrap()).unwrap();
        assert!(stats.cycles > 0, "{kind}");
    }
}

#[test]
fn resources_format_json() {
    let text = run_ok(&["resources", "--points", "16", "--format", "json"]);
    let j = tdp::util::json::parse(text.trim()).unwrap();
    assert!(j.get("title").unwrap().as_str().unwrap().contains("Table I"));
    assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
}

#[test]
fn run_rejects_unknown_format() {
    let out = tdp()
        .args([
            "run",
            "--workload",
            "kind = \"reduction\"\\nwidth = 8",
            "--format",
            "yaml",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

/// Bugfix coverage: the analyze path must propagate failures as typed
/// errors (non-zero exit), never panic — `sim.trace().unwrap()` used to
/// sit on this path.
#[test]
fn analyze_failure_is_a_clean_error_not_a_panic() {
    let out = tdp()
        .args(["analyze", "--graph", "/nonexistent/tdp_graph.json"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(!err.contains("panicked"), "must fail as an error: {err}");
    assert!(err.contains("Error") || err.contains("error"), "{err}");
}

/// A failing simulation must exit non-zero with the typed error on
/// stderr (the `Error` → exit-code propagation of the compile-once API).
#[test]
fn simulation_failure_exits_nonzero() {
    let out = tdp()
        .args([
            "run",
            "--workload",
            "kind = \"reduction\"\\nwidth = 64",
            "--cols",
            "2",
            "--rows",
            "2",
            "--scheduler",
            "out_of_order",
            "--max-cycles",
            "2",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "cycle-limited run must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("cycle limit"), "typed error on stderr: {err}");
}

/// An invalid overlay description fails at validation, not as a panic.
#[test]
fn invalid_overlay_exits_nonzero() {
    let out = tdp()
        .args([
            "run",
            "--workload",
            "kind = \"reduction\"\\nwidth = 8",
            "--cols",
            "64",
            "--rows",
            "1",
            "--scheduler",
            "out_of_order",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("invalid overlay config"), "{err}");
}

/// `tdp perf --quick` emits the BENCH perf-trajectory JSON
/// (perf/README.md, schema version 1): every pinned case reports a
/// positive cycle count and throughput, and `--out` mirrors stdout to
/// disk.
#[test]
fn perf_quick_emits_bench_json() {
    let dir = std::env::temp_dir().join(format!("tdp_perf_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.json");
    let text = run_ok(&["perf", "--quick", "--reps", "1", "--out", path.to_str().unwrap()]);
    let j = tdp::util::json::parse(text.trim()).unwrap();
    assert_eq!(j.get("version").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(j.get("quick"), Some(&tdp::util::json::Json::Bool(true)));
    let cases = j.get("cases").unwrap().as_arr().unwrap();
    assert_eq!(cases.len(), 3, "the quick set is pinned");
    for c in cases {
        let name = c.get("name").unwrap().as_str().unwrap();
        assert!(c.get("sim_cycles").unwrap().as_f64().unwrap() > 0.0, "{name}");
        assert!(c.get("sim_cycles_per_sec").unwrap().as_f64().unwrap() > 0.0, "{name}");
        assert!(c.get("compile_ms").unwrap().as_f64().unwrap() >= 0.0, "{name}");
    }
    assert!(j.get("total_wall_ms").unwrap().as_f64().unwrap() >= 0.0);
    let disk = std::fs::read_to_string(&path).unwrap();
    assert_eq!(disk.trim(), text.trim(), "--out mirrors stdout");
    std::fs::remove_dir_all(&dir).ok();
}

/// ISSUE acceptance: `tdp run --trace-out` on the Fig. 1 `lu_pl`
/// workload produces a valid Chrome trace-event file with compile-stage
/// spans, run-phase spans and per-cycle fabric counters.
#[test]
fn run_trace_out_writes_chrome_trace() {
    use tdp::util::json::{self, Json};
    let dir = std::env::temp_dir().join(format!("tdp_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    run_ok(&[
        "run",
        "--workload",
        "kind = \"lu_power_law\"\\nn = 60\\navg_degree = 3",
        "--cols",
        "4",
        "--rows",
        "4",
        "--seed",
        "42",
        "--trace-out",
        path.to_str().unwrap(),
        "--trace-stride",
        "4",
    ]);
    let j = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert!(j.get("displayTimeUnit").is_some());
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let named = |ph: &str, cat: Option<&str>| -> Vec<&str> {
        events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some(ph))
            .filter(|e| cat.is_none() || e.get("cat").and_then(Json::as_str) == cat)
            .filter_map(|e| e.get("name").and_then(Json::as_str))
            .collect()
    };
    let compile = named("X", Some("compile"));
    for stage in ["criticality", "place", "bram_images", "bake_tables"] {
        assert!(compile.contains(&stage), "missing compile span {stage}: {compile:?}");
    }
    let run = named("X", Some("run"));
    for phase in ["setup", "in-order", "out-of-order"] {
        assert!(run.contains(&phase), "missing run span {phase}: {run:?}");
    }
    let counters = named("C", None);
    for series in ["in_order/busy_pes", "out_of_order/ready_total"] {
        assert!(counters.contains(&series), "missing counter {series}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Telemetry is observation only: a traced run must report bit-identical
/// stats to the plain run of the same job.
#[test]
fn run_trace_out_does_not_perturb_results() {
    let dir = std::env::temp_dir().join(format!("tdp_trace_eq_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let args = [
        "run",
        "--workload",
        "kind = \"reduction\"\\nwidth = 64",
        "--cols",
        "2",
        "--rows",
        "2",
        "--scheduler",
        "out_of_order",
        "--backend",
        "skip-ahead",
        "--format",
        "json",
    ];
    let plain = run_ok(&args);
    let mut traced_args: Vec<&str> = args.to_vec();
    let path = dir.join("t.json");
    traced_args.extend(["--trace-out", path.to_str().unwrap()]);
    let traced = run_ok(&traced_args);
    assert_eq!(plain, traced, "tracing must not change reported stats");
    std::fs::remove_dir_all(&dir).ok();
}

/// `tdp analyze` renders per-PE / per-router activity heatmaps and, with
/// `--json-out`, a machine-readable {stats, activity} document per
/// scheduler.
#[test]
fn analyze_emits_activity_heatmaps_and_json() {
    let dir = std::env::temp_dir().join(format!("tdp_analyze_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("analysis.json");
    let text = run_ok(&[
        "analyze",
        "--workload",
        "kind = \"reduction\"\\nwidth = 128",
        "--cols",
        "2",
        "--rows",
        "2",
        "--stride",
        "4",
        "--json-out",
        path.to_str().unwrap(),
    ]);
    for series in ["pe.firings", "pe.ejects", "router.traffic", "router.deflections"] {
        assert!(text.contains(series), "missing heatmap {series}");
    }
    let j = tdp::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    for kind in ["in_order", "out_of_order"] {
        let entry = j.get(kind).unwrap_or_else(|| panic!("missing {kind}"));
        let stats = tdp::SimStats::from_json_value(entry.get("stats").unwrap()).unwrap();
        assert!(stats.cycles > 0, "{kind}");
        let act = entry.get("activity").unwrap();
        assert_eq!(act.get("cols").unwrap().as_u64(), Some(2));
        let firings = act.get("pe").unwrap().get("firings").unwrap().as_arr().unwrap();
        assert_eq!(firings.len(), 4, "{kind}: one cell per PE");
        let fired: u64 = firings.iter().map(|v| v.as_u64().unwrap()).sum();
        let ops: u64 = stats.pe.iter().map(|p| p.alu_ops).sum();
        assert_eq!(fired, ops, "{kind}: heatmap agrees with stats");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// `tdp perf --trace-out` records compile/run spans but no per-cycle
/// counters — per-cycle tracing would pin the skip-ahead backend to
/// cycle-accurate stepping and distort the measurement.
#[test]
fn perf_trace_out_is_span_only() {
    use tdp::util::json::{self, Json};
    let dir = std::env::temp_dir().join(format!("tdp_perf_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("perf_trace.json");
    run_ok(&["perf", "--quick", "--reps", "1", "--trace-out", path.to_str().unwrap()]);
    let j = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    let ph = |p: &str| {
        events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some(p))
            .count()
    };
    // 3 quick cases x 4 compile stages, plus run spans for every session
    assert!(ph("X") >= 12, "expected compile+run spans, got {} X events", ph("X"));
    assert_eq!(ph("C"), 0, "perf tracing must not record per-cycle counters");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn perf_rejects_unknown_format() {
    let out = tdp().args(["perf", "--quick", "--format", "yaml"]).output().unwrap();
    assert!(!out.status.success());
}

/// `tdp check` on clean workloads: exit 0, diagnostic-free report in
/// both formats (this is exactly what CI's check-smoke job gates on).
#[test]
fn check_clean_workload_exits_zero() {
    let text = run_ok(&["check", "reduction:64"]);
    assert!(text.contains("0 error(s)"), "{text}");
    let text = run_ok(&["check", "lu_pl:60:3:seed=42", "--cols", "4", "--rows", "4", "--format", "json"]);
    let j = tdp::util::json::parse(text.trim()).unwrap();
    assert_eq!(j.get("errors").unwrap().as_f64(), Some(0.0));
    assert!(j.get("nodes").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(j.get("workload").unwrap().as_str(), Some("lu_pl:60:3:seed=42"));
}

/// The checked-in known-bad fixture exits non-zero with the expected
/// structured diagnostics on stdout.
#[test]
fn check_bad_fixture_exits_nonzero_with_cycle_code() {
    let fixture =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/data/bad_cycle.json");
    let out = tdp()
        .args(["check", "--graph", fixture.to_str().unwrap(), "--format", "json"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "error diagnostics must fail the check");
    let j = tdp::util::json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert!(j.get("errors").unwrap().as_f64().unwrap() >= 1.0);
    let codes: Vec<&str> = j
        .get("diagnostics")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|d| d.get("code").unwrap().as_str().unwrap())
        .collect();
    assert!(codes.contains(&"cycle"), "{codes:?}");
    // text mode renders the same diagnostics human-readably
    let out = tdp().args(["check", "--graph", fixture.to_str().unwrap()]).output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("error[cycle]"), "{text}");
}

/// `--dump-passes` prints the per-pass compile table on stderr without
/// touching the stdout payload.
#[test]
fn run_dump_passes_prints_pipeline_table() {
    let out = tdp()
        .args([
            "run",
            "--workload",
            "kind = \"reduction\"\\nwidth = 64",
            "--cols",
            "2",
            "--rows",
            "2",
            "--scheduler",
            "out_of_order",
            "--format",
            "json",
            "--dump-passes",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    for pass in ["verify", "criticality", "place", "bram_images", "bake_tables"] {
        assert!(err.contains(pass), "missing pass '{pass}' in: {err}");
    }
    let stats = tdp::SimStats::from_json(String::from_utf8_lossy(&out.stdout).trim())
        .expect("stdout still carries the stats object");
    assert!(stats.cycles > 0);
}

/// The perf JSON carries the placement-quality section (baseline vs
/// traffic-aware), outside `cases` so the BENCH trajectory stays
/// comparable.
#[test]
fn perf_quick_reports_placement_quality() {
    let text = run_ok(&["perf", "--quick", "--reps", "1"]);
    let j = tdp::util::json::parse(text.trim()).unwrap();
    let pq = j.get("placement_quality").unwrap().as_arr().unwrap();
    assert_eq!(pq.len(), 1, "quick set pins one placement case");
    let row = &pq[0];
    assert!(row.get("baseline_cycles").unwrap().as_f64().unwrap() > 0.0);
    assert!(row.get("traffic_aware_cycles").unwrap().as_f64().unwrap() > 0.0);
    assert!(row.get("traffic_aware_cost").unwrap().as_f64().unwrap() > 0.0);
    assert!(row.get("cycle_ratio").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn unknown_command_fails() {
    let out = tdp().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn unknown_flag_fails() {
    let out = tdp().args(["resources", "--bogus"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn bad_workload_spec_fails() {
    let out = tdp()
        .args(["run", "--workload", "kind = \"nope\""])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

/// `tdp shard` reports a partition: forced N=2 on a small workload, and
/// auto-sizing (`--shards 0`) on a graph that overflows one 2x2 fabric.
#[test]
fn shard_reports_partition_and_runs() {
    let text = run_ok(&[
        "shard", "reduction:64", "--cols", "2", "--rows", "2", "--shards", "2", "--run",
    ]);
    assert!(text.contains("2 shard(s)"), "{text}");
    assert!(text.contains("shard 0:"), "{text}");
    assert!(text.contains("cut:"), "{text}");
    assert!(text.contains("run:"), "{text}");

    let json = run_ok(&[
        "shard",
        "reduction:64:scale=48",
        "--cols",
        "2",
        "--rows",
        "2",
        "--format",
        "json",
        "--run",
    ]);
    let j = tdp::util::json::parse(json.trim()).unwrap();
    assert_eq!(j.get("workload").unwrap().as_str(), Some("reduction:64:scale=48"));
    let n = j.get("num_shards").unwrap().as_usize().unwrap();
    assert!(n >= 2, "oversized workload auto-shards, got {n}");
    assert_eq!(j.get("shards").unwrap().as_arr().unwrap().len(), n);
    assert!(j.get("epoch").unwrap().as_u64().unwrap() > 0);
    let run = j.get("run").unwrap();
    let stats = run.get("stats").unwrap();
    assert_eq!(
        stats.get("completed").unwrap().as_u64(),
        stats.get("total_nodes").unwrap().as_u64(),
        "sharded run completes every node"
    );
}
