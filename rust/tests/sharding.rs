//! Sharded multi-fabric execution end-to-end (DESIGN.md §14): the
//! acceptance invariants of the shard subsystem through public API
//! surfaces only — N=1 is bit-identical to the single-fabric path,
//! N>1 results are deterministic regardless of host thread count and
//! backend, computed values match the reference evaluation, and the
//! engine auto-shards a graph that fails `Program::fits`.

use std::sync::Arc;
use tdp::config::{Overlay, OverlayConfig};
use tdp::engine::BackendKind;
use tdp::graph::DataflowGraph;
use tdp::program::SharedProgram;
use tdp::sched::SchedulerKind;
use tdp::service::{Engine, JobSpec};
use tdp::workload;
use tdp::ShardedProgram;

fn build(spec: &str) -> Arc<DataflowGraph> {
    let s: workload::Spec = spec.parse().unwrap();
    Arc::new(s.build().unwrap())
}

fn overlay(cols: usize, rows: usize) -> Overlay {
    Overlay::from_config(OverlayConfig::default().with_dims(cols, rows)).unwrap()
}

/// f32 equality that treats NaN as equal to NaN — the sim executes the
/// same operation graph as `evaluate`, so results are bit-reproducible
/// even through division blowups.
fn same(a: f32, b: f32) -> bool {
    (a.is_nan() && b.is_nan()) || a == b
}

#[test]
fn n1_matches_single_fabric_for_every_scheduler_and_backend() {
    let g = build("lu_banded:48:4:0.9:seed=2");
    let overlay = overlay(2, 2);
    let single = SharedProgram::compile(Arc::clone(&g), &overlay).unwrap();
    let sharded = ShardedProgram::compile(Arc::clone(&g), &overlay, 1).unwrap();
    for backend in BackendKind::ALL {
        for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
            let reference = single
                .program()
                .session()
                .with_scheduler(kind)
                .with_backend(backend)
                .run()
                .unwrap();
            let run = sharded
                .session()
                .with_scheduler(kind)
                .with_backend(backend)
                .run()
                .unwrap();
            assert_eq!(
                run.stats, reference,
                "N=1 sharded must be bit-identical ({kind:?}/{backend:?})"
            );
            assert_eq!(run.boundary_values, 0, "one shard has no boundary");
        }
    }
}

#[test]
fn multi_shard_values_match_reference_evaluation() {
    let g = build("layered:16:6:24:3:seed=2");
    let overlay = overlay(2, 2);
    let reference = g.evaluate();
    for k in [2, 3, 4] {
        let sharded = ShardedProgram::compile(Arc::clone(&g), &overlay, k).unwrap();
        let run = sharded.session().run().unwrap();
        assert_eq!(run.stats.completed, g.len(), "N={k} completes every node");
        assert_eq!(run.values.len(), reference.len());
        for (i, (&got, &want)) in run.values.iter().zip(&reference).enumerate() {
            assert!(same(got, want), "N={k} node {i}: {got} != {want}");
        }
    }
}

#[test]
fn runs_are_invariant_under_thread_count_and_backend() {
    let g = build("lu_banded:48:4:0.9:seed=7");
    let overlay = overlay(2, 2);
    let sharded = ShardedProgram::compile(Arc::clone(&g), &overlay, 3).unwrap();
    let baseline = sharded.session().with_threads(1).run().unwrap();
    for threads in [2, 3, 8] {
        let run = sharded.session().with_threads(threads).run().unwrap();
        assert_eq!(
            run, baseline,
            "full ShardedRun must not depend on host threads ({threads})"
        );
    }
    // both backends agree on values and merged cycle count
    let skip = sharded
        .session()
        .with_backend(BackendKind::SkipAhead)
        .run()
        .unwrap();
    assert_eq!(skip.stats.cycles, baseline.stats.cycles);
    for (i, (&a, &b)) in skip.values.iter().zip(&baseline.values).enumerate() {
        assert!(same(a, b), "node {i}: backends disagree");
    }
}

/// The acceptance path: a spec that overflows one 2x2 fabric submits
/// through the engine with no shard knob at all, auto-shards, runs to
/// completion, and carries partition provenance in the result.
#[test]
fn engine_auto_shards_an_oversized_spec() {
    let g = build("reduction:64:scale=48");
    let overlay = overlay(2, 2);
    let single = SharedProgram::compile(Arc::clone(&g), &overlay).unwrap();
    assert!(
        !single.program().fits(SchedulerKind::OutOfOrder),
        "fixture must overflow one fabric or this test is vacuous"
    );
    let want = single.program().min_shards(SchedulerKind::OutOfOrder);

    let engine = Engine::new();
    let mut job = JobSpec::new("reduction:64:scale=48");
    job.overlay = job.overlay.with_dims(2, 2);
    let r = engine.submit(&job).unwrap();
    let info = r.shards.as_ref().expect("auto-shard provenance");
    assert_eq!(info.count, want);
    assert_eq!(info.shard_cycles.len(), want);
    assert_eq!(r.stats.completed, r.stats.total_nodes);
    // bit-identical on the cached replay
    let again = engine.submit(&job).unwrap();
    assert!(again.cache_hit);
    assert_eq!(again.stats, r.stats);
    assert_eq!(again.shards, r.shards);
}
