//! `tdp batch` end-to-end (ISSUE acceptance): a 3-workload ×
//! 4-scheduler-spelling × 2-backend job file compiles each workload
//! exactly once (asserted via the `compiles=` counter the binary
//! reports — `program::compile_count()` inside the batch process), and
//! cache-hit jobs return bit-identical `SimStats` to the cold-compile
//! runs of the same variant.

use std::process::Command;
use tdp::util::json::{self, Json};

fn tdp() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tdp"))
}

fn temp_file(name: &str, content: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tdp_batch_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    std::fs::write(&path, content).unwrap();
    path
}

/// Pull `key=value` integers out of the stderr summary line.
fn summary_field(stderr: &str, key: &str) -> u64 {
    let line = stderr
        .lines()
        .find(|l| l.starts_with("batch:"))
        .unwrap_or_else(|| panic!("no batch summary in stderr: {stderr}"));
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(&format!("{key}=")))
        .unwrap_or_else(|| panic!("no {key}= in summary: {line}"))
        .parse()
        .unwrap()
}

#[test]
fn batch_compiles_each_workload_once_with_bit_identical_hits() {
    // 3 workloads × 4 scheduler spellings (2 per kind — aliases must
    // normalize onto the same cache key) × 2 backends = 24 jobs
    let workloads = ["reduction:48", "chain:24:seed=1", "layered:6:4:12:1:seed=2"];
    let schedulers = ["in_order", "fifo", "out_of_order", "ooo"];
    let backends = ["lockstep", "skip_ahead"];
    let mut lines = Vec::new();
    for w in &workloads {
        for s in &schedulers {
            for b in &backends {
                lines.push(format!(
                    "{{\"workload\": \"{w}\", \"scheduler\": \"{s}\", \
                     \"backend\": \"{b}\", \"cols\": 2, \"rows\": 2}}"
                ));
            }
        }
    }
    let path = temp_file("grid.jsonl", &(lines.join("\n") + "\n"));
    let out = tdp().arg("batch").arg(&path).output().expect("binary runs");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "batch failed:\n{stdout}\n{stderr}");

    // one JSON result line per job, in input order
    let results: Vec<Json> = stdout
        .lines()
        .map(|l| json::parse(l).unwrap_or_else(|e| panic!("bad output line '{l}': {e}")))
        .collect();
    assert_eq!(results.len(), 24, "one output line per job");

    // each workload compiled exactly once, in the batch process
    assert_eq!(summary_field(&stderr, "compiles"), 3, "{stderr}");
    assert_eq!(summary_field(&stderr, "cache_misses"), 3);
    assert_eq!(summary_field(&stderr, "cache_hits"), 21);
    assert_eq!(summary_field(&stderr, "failed"), 0);

    // cache-hit jobs return bit-identical stats to the cold-compile run
    // of the same (workload, scheduler, backend) variant: group by the
    // *normalized* variant echo and demand a single stats value, with
    // both hits and at least one cold compile observed overall
    let mut by_variant: std::collections::BTreeMap<(String, String, String), Vec<&Json>> =
        Default::default();
    let mut hits = 0u64;
    for r in &results {
        let get = |k: &str| r.get(k).unwrap().as_str().unwrap().to_string();
        if r.get("cache_hit") == Some(&Json::Bool(true)) {
            hits += 1;
        }
        by_variant
            .entry((get("workload"), get("scheduler"), get("backend")))
            .or_default()
            .push(r.get("stats").unwrap());
    }
    assert_eq!(hits, 21);
    assert_eq!(by_variant.len(), 12, "4 spellings normalize to 2 schedulers");
    for ((w, s, b), stats) in &by_variant {
        assert_eq!(stats.len(), 2, "{w}/{s}/{b}: two spellings per variant");
        assert_eq!(stats[0], stats[1], "{w}/{s}/{b}: hit must equal cold compile");
    }
}

#[test]
fn batch_smoke_file_runs_clean() {
    // the checked-in CI smoke file must stay green
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("data")
        .join("smoke_jobs.jsonl");
    let out = tdp().arg("batch").arg(&path).output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    let results: Vec<Json> = stdout.lines().map(|l| json::parse(l).unwrap()).collect();
    assert_eq!(results.len(), 4);
    for r in &results {
        assert!(r.get("error").is_none(), "{r:?}");
        assert!(r.get("stats").unwrap().get("cycles").unwrap().as_u64().unwrap() > 0);
    }
}

#[test]
fn batch_failed_jobs_exit_nonzero_but_run_the_rest() {
    let content = "\
{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}\n\
{\"workload\": \"nope:1\"}\n\
not json at all\n\
{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2, \"max_cycles\": 2}\n\
{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}\n";
    let path = temp_file("mixed.jsonl", content);
    let out = tdp().arg("batch").arg(&path).output().unwrap();
    assert!(!out.status.success(), "failed jobs must fail the batch");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let results: Vec<Json> = stdout.lines().map(|l| json::parse(l).unwrap()).collect();
    assert_eq!(results.len(), 5, "every line gets an answer");
    // line-addressed errors for the bad spec, the parse failure and the
    // cycle-limited run; healthy jobs still succeed around them
    for (idx, want_err) in [(0, false), (1, true), (2, true), (3, true), (4, false)] {
        let r = &results[idx];
        assert_eq!(r.get("error").is_some(), want_err, "line {}: {r:?}", idx + 1);
        if want_err {
            assert_eq!(r.get("line").unwrap().as_u64().unwrap() as usize, idx + 1);
        }
    }
    // the two healthy duplicates are one compile + one bit-identical hit
    assert_eq!(results[0].get("stats"), results[4].get("stats"));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(summary_field(&stderr, "failed"), 3);
}

/// `--metrics-out` dumps the engine's metrics snapshot: job counts,
/// cache counters and latency histograms that match the batch exactly.
#[test]
fn batch_metrics_out_writes_snapshot() {
    let jobs = "\
{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}\n\
{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2, \"scheduler\": \"ooo\"}\n\
{\"workload\": \"chain:16:seed=1\", \"cols\": 2, \"rows\": 2}\n\
{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}\n";
    let path = temp_file("metered.jsonl", jobs);
    let metrics_path = temp_file("metrics.json", "");
    let out = tdp()
        .arg("batch")
        .arg(&path)
        .arg("--metrics-out")
        .arg(&metrics_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let snap = json::parse(&std::fs::read_to_string(&metrics_path).unwrap()).unwrap();
    let get = |path: &[&str]| -> u64 {
        let mut v = &snap;
        for k in path {
            v = v.get(k).unwrap_or_else(|| panic!("snapshot missing {path:?}"));
        }
        v.as_u64().unwrap()
    };
    assert_eq!(get(&["version"]), 1);
    assert_eq!(get(&["jobs", "submitted"]), 4);
    assert_eq!(get(&["jobs", "failed"]), 0);
    assert_eq!(get(&["cache", "misses"]), 2, "two distinct workloads");
    assert_eq!(get(&["cache", "hits"]), 2);
    assert_eq!(get(&["latency", "compile_micros", "count"]), 2);
    assert_eq!(get(&["latency", "run_micros", "count"]), 4);
    let per = snap.get("workloads").unwrap().as_obj().unwrap();
    assert_eq!(per.len(), 2);
    assert_eq!(
        per.get("reduction:32").unwrap().get("jobs").unwrap().as_u64(),
        Some(3)
    );
}

#[test]
fn batch_without_file_fails() {
    let out = tdp().arg("batch").output().unwrap();
    assert!(!out.status.success());
}

/// `tdp batch -` reads the JSONL from stdin — the shell-pipeline form —
/// and behaves exactly like the file form: ordered output, duplicate
/// jobs bit-identical, same summary counters.
#[test]
fn batch_dash_reads_jobs_from_stdin() {
    use std::io::Write;
    use std::process::Stdio;
    let jobs = "\
{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}\n\
{\"workload\": \"chain:16:seed=1\", \"cols\": 2, \"rows\": 2}\n\
{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}\n";
    let mut child = tdp()
        .arg("batch")
        .arg("-")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    child.stdin.take().unwrap().write_all(jobs.as_bytes()).unwrap();
    let out = child.wait_with_output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "{stdout}\n{stderr}");
    let results: Vec<Json> = stdout.lines().map(|l| json::parse(l).unwrap()).collect();
    assert_eq!(results.len(), 3, "one output line per stdin line");
    assert_eq!(
        results[0].get("workload").unwrap().as_str(),
        Some("reduction:32"),
        "output order follows input order"
    );
    assert_eq!(results[0].get("stats"), results[2].get("stats"), "duplicate is a hit");
    assert_eq!(summary_field(&stderr, "jobs"), 3);
    assert_eq!(summary_field(&stderr, "cache_misses"), 2);
}
