//! Simulator hot-path throughput (PE-cycles simulated per second) — the
//! §Perf headline metric of EXPERIMENTS.md. The Fig. 1 sweep runs
//! millions of overlay cycles; this bench tracks how fast we step them.
//!
//! Two numbers per configuration:
//! * **cold** — `Simulator::new` + run (place + bake tables + run; the
//!   historical row, comparable across snapshots);
//! * **warm** — repeated `Session::run` over one compiled `Program`
//!   (the service steady state: the baked route tables and dense node
//!   metadata are reused, only the run is timed).
//!
//! (`cargo bench --bench sim_hotpath`)

#[path = "harness.rs"]
mod harness;

use tdp::config::{Overlay, OverlayConfig};
use tdp::graph::{DataflowGraph, Op};
use tdp::program::Program;
use tdp::sched::SchedulerKind;
use tdp::sim::Simulator;
use tdp::workload::{lu_factorization_graph, SparseMatrix, Spec};

fn cold_and_warm(g: &DataflowGraph, cfg: OverlayConfig, label: &str, pe_cycles_denom: u64) {
    let mut cycles = 0u64;
    let cold = harness::time_it(1, 5, || {
        let mut sim = Simulator::new(g, cfg).unwrap();
        let stats = sim.run().unwrap();
        cycles = stats.cycles;
        stats.cycles
    });
    let program = Program::compile(g, &Overlay::from_config(cfg).unwrap()).unwrap();
    let warm = harness::time_it(1, 5, || program.session().run().unwrap().cycles);
    let cold_rate = (cycles * pe_cycles_denom) as f64 / cold.median.as_secs_f64();
    let warm_rate = (cycles * pe_cycles_denom) as f64 / warm.median.as_secs_f64();
    harness::report(
        &format!("{label} cold"),
        &cold,
        &format!("{cycles} cyc -> {:.2} M/s", cold_rate / 1e6),
    );
    harness::report(
        &format!("{label} warm"),
        &warm,
        &format!("{cycles} cyc -> {:.2} M/s", warm_rate / 1e6),
    );
}

fn main() {
    harness::section("simulator hot path — PE-cycles/second");
    let m = SparseMatrix::banded(200, 8, 0.9, 3);
    let (g, _) = lu_factorization_graph(&m);
    println!(
        "workload: banded LU 200x200 bw8 -> {} nodes, {} edges",
        g.len(),
        g.num_edges()
    );
    for (cols, rows) in [(2usize, 2usize), (4, 4), (8, 8), (16, 16)] {
        for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
            let cfg = OverlayConfig::default()
                .with_dims(cols, rows)
                .with_scheduler(kind);
            cold_and_warm(&g, cfg, &format!("{cols}x{rows} {}", kind.name()), (cols * rows) as u64);
        }
    }

    // The Fig. 1 power-law LU rung — the workload shape the paper's
    // speedup ladder is built from, on the paper's 16x16 overlay.
    harness::section("Fig. 1 workload — lu_pl:330:3 on 16x16 (fabric-cycles/s)");
    let spec: Spec = "lu_pl:330:3:seed=42".parse().unwrap();
    let lu_pl = spec.build().unwrap();
    println!(
        "workload: {} -> {} nodes, {} edges",
        spec.canonical(),
        lu_pl.len(),
        lu_pl.num_edges()
    );
    for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
        let cfg = OverlayConfig::default().with_dims(16, 16).with_scheduler(kind);
        cold_and_warm(&lu_pl, cfg, &format!("lu_pl 16x16 {}", kind.name()), 1);
    }

    // The active-PE worklist's target regime: a 16x16 overlay (256 PEs)
    // running a strictly sequential dependency chain, so ~1 PE (and ~1
    // router) is busy on any given cycle while the other 255 idle. The
    // pre-worklist simulator paid O(256) per cycle here regardless; with
    // activity-proportional stepping the per-cycle cost is O(active),
    // and with the baked tables each of those active steps is pure
    // indexed loads. Wall clock (not PE-cycles/s) is the honest metric:
    // the denominator is fabric size, which is exactly what idle PEs no
    // longer cost.
    harness::section("sparse activity — 16x16 overlay, 8000-node sequential chain");
    let mut chain = DataflowGraph::new();
    let mut prev = chain.add_input(1.5);
    for _ in 0..8000 {
        prev = chain.op(Op::Neg, &[prev]);
    }
    for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
        let cfg = OverlayConfig::default()
            .with_dims(16, 16)
            .with_scheduler(kind);
        cold_and_warm(&chain, cfg, &format!("16x16 chain {}", kind.name()), 1);
    }
}
