//! Simulator hot-path throughput (PE-cycles simulated per second) — the
//! §Perf headline metric of EXPERIMENTS.md. The Fig. 1 sweep runs
//! millions of overlay cycles; this bench tracks how fast we step them.
//! (`cargo bench --bench sim_hotpath`)

#[path = "harness.rs"]
mod harness;

use tdp::config::OverlayConfig;
use tdp::graph::{DataflowGraph, Op};
use tdp::sched::SchedulerKind;
use tdp::sim::Simulator;
use tdp::workload::{lu_factorization_graph, SparseMatrix};

fn main() {
    harness::section("simulator hot path — PE-cycles/second");
    let m = SparseMatrix::banded(200, 8, 0.9, 3);
    let (g, _) = lu_factorization_graph(&m);
    println!(
        "workload: banded LU 200x200 bw8 -> {} nodes, {} edges",
        g.len(),
        g.num_edges()
    );
    for (cols, rows) in [(2usize, 2usize), (4, 4), (8, 8), (16, 16)] {
        for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
            let cfg = OverlayConfig::default()
                .with_dims(cols, rows)
                .with_scheduler(kind);
            let mut cycles = 0u64;
            let t = harness::time_it(1, 5, || {
                let mut sim = Simulator::new(&g, cfg).unwrap();
                let stats = sim.run().unwrap();
                cycles = stats.cycles;
                stats.cycles
            });
            let pe_cycles = cycles * (cols * rows) as u64;
            let rate = pe_cycles as f64 / t.median.as_secs_f64();
            harness::report(
                &format!("{cols}x{rows} {}", kind.name()),
                &t,
                &format!("{cycles} cyc -> {:.1} M PE-cycles/s", rate / 1e6),
            );
        }
    }

    // The active-PE worklist's target regime: a 16x16 overlay (256 PEs)
    // running a strictly sequential dependency chain, so ~1 PE (and ~1
    // router) is busy on any given cycle while the other 255 idle. The
    // pre-worklist simulator paid O(256) per cycle here regardless; with
    // activity-proportional stepping the per-cycle cost is O(active),
    // which is what the ISSUE's >= 2x acceptance bar measures. Wall
    // clock (not PE-cycles/s) is the honest metric: the denominator is
    // fabric size, which is exactly what idle PEs no longer cost.
    harness::section("sparse activity — 16x16 overlay, 8000-node sequential chain");
    let mut chain = DataflowGraph::new();
    let mut prev = chain.add_input(1.5);
    for _ in 0..8000 {
        prev = chain.op(Op::Neg, &[prev]);
    }
    for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
        let cfg = OverlayConfig::default()
            .with_dims(16, 16)
            .with_scheduler(kind);
        let mut cycles = 0u64;
        let t = harness::time_it(1, 5, || {
            let mut sim = Simulator::new(&chain, cfg).unwrap();
            let stats = sim.run().unwrap();
            cycles = stats.cycles;
            stats.cycles
        });
        let rate = cycles as f64 / t.median.as_secs_f64();
        harness::report(
            &format!("16x16 chain {}", kind.name()),
            &t,
            &format!("{cycles} cyc -> {:.2} M fabric-cycles/s", rate / 1e6),
        );
    }
}
