//! Measures what the compile-once API buys: the wall-clock of a
//! (scheduler × backend) variant sweep with per-cell recompile (the old
//! `run_one` shape — placement + criticality labeling re-run for every
//! cell) vs one shared [`tdp::Program`] per workload. The compile
//! fraction of the sweep should vanish in the shared column.
//! (`cargo bench --bench compile_amortization`)

#[path = "harness.rs"]
mod harness;

use tdp::config::Overlay;
use tdp::coordinator::fig1_config;
use tdp::program::{run_batch, Program, RunVariant};
use tdp::workload::{lu_factorization_graph, SparseMatrix};

fn main() {
    harness::section("compile-once amortization (per-cell recompile vs shared Program)");
    // A compile-heavy regime: a large graph whose placement/labeling
    // cost is material next to its simulation cost.
    let m = SparseMatrix::banded(600, 5, 0.9, 11);
    let (g, _) = lu_factorization_graph(&m);
    let overlay = Overlay::from_config(fig1_config()).unwrap();
    let variants = RunVariant::all();
    println!(
        "workload: banded LU -> {} nodes, {} edges; {} variants/sweep",
        g.len(),
        g.num_edges(),
        variants.len()
    );

    // compile alone: the one-time cost under the microscope
    let t_compile = harness::time_it(1, 5, || Program::compile(&g, &overlay).unwrap());
    harness::report("compile (place + label + images)", &t_compile, "");

    // per-cell recompile: what every sweep paid before the redesign
    let t_percell = harness::time_it(1, 5, || {
        for v in &variants {
            let program = Program::compile(&g, &overlay).unwrap();
            program
                .session()
                .with_scheduler(v.scheduler)
                .with_backend(v.backend)
                .run()
                .unwrap();
        }
    });
    harness::report("sweep, per-cell recompile", &t_percell, "");

    // compile once, share across the same cells
    let t_shared = harness::time_it(1, 5, || {
        let program = Program::compile(&g, &overlay).unwrap();
        for v in &variants {
            program
                .session()
                .with_scheduler(v.scheduler)
                .with_backend(v.backend)
                .run()
                .unwrap();
        }
    });
    harness::report("sweep, shared Program", &t_shared, "");

    // shared + threaded: the run_batch entry point
    let t_batch = harness::time_it(1, 5, || {
        let program = Program::compile(&g, &overlay).unwrap();
        let results = run_batch(&program, &variants, variants.len());
        assert!(results.iter().all(|r| r.is_ok()));
    });
    harness::report("sweep, shared Program + run_batch", &t_batch, "");

    let compile_ns = t_compile.median.as_nanos() as f64;
    let percell_ns = t_percell.median.as_nanos() as f64;
    let shared_ns = t_shared.median.as_nanos() as f64;
    println!(
        "\ncompile fraction: per-cell recompile {:.1}% of sweep -> shared {:.1}%",
        100.0 * (compile_ns * variants.len() as f64) / percell_ns,
        100.0 * compile_ns / shared_ns
    );
    println!(
        "shared-Program speedup over per-cell recompile: {:.3}x \
         ({:.2} ms of redundant compile removed per sweep)",
        percell_ns / shared_ns,
        (variants.len() as f64 - 1.0) * compile_ns / 1e6
    );
}
