//! Scheduler microbenchmarks + the paper's design-choice ablations:
//! * hierarchical LOD pick vs. the naive memory scan (§II-B's motivating
//!   comparison — "in the worst case scan 256 memory locations");
//! * criticality-sorted memory vs. arrival order for the OoO scheduler
//!   (the §II-B heuristic, isolated);
//! * raw mark/take throughput of both schedulers.
//! (`cargo bench --bench sched_micro`)

#[path = "harness.rs"]
mod harness;

use tdp::config::{Overlay, OverlayConfig};
use tdp::lod::{naive_scan, HierLod};
use tdp::place::LocalOrder;
use tdp::program::Program;
use tdp::sched::{make_scheduler, ReadyScheduler, SchedulerKind};
use tdp::util::rng::Rng;
use tdp::workload::{lu_factorization_graph, SparseMatrix};

fn main() {
    harness::section("LOD: hierarchical pick vs naive scan (4096 flags = 128 words)");
    let mut rng = Rng::seed_from_u64(7);
    // sparse ready sets: the realistic regime (few ready among thousands)
    for ready in [1usize, 8, 64, 1024] {
        let mut words = vec![0u32; 128];
        let mut summary = vec![0u64; 2];
        for _ in 0..ready {
            let n = rng.gen_range(4096);
            words[n / 32] |= 1 << (n % 32);
            summary[n / 32 / 64] |= 1 << ((n / 32) % 64);
        }
        let lod = HierLod::new(128);
        let iters = 100_000u64;
        let t_h = harness::time_it(2, 8, || {
            let mut acc = 0u32;
            for _ in 0..iters {
                acc = acc.wrapping_add(std::hint::black_box(lod.pick(&summary, &words)));
            }
            acc
        });
        let t_n = harness::time_it(2, 8, || {
            let mut acc = 0u32;
            for _ in 0..iters {
                acc = acc.wrapping_add(std::hint::black_box(naive_scan(&words)));
            }
            acc
        });
        harness::report(
            &format!("hier pick, {ready} ready"),
            &t_h,
            &format!("{:?}/pick", t_h.per_iter(iters)),
        );
        harness::report(
            &format!("naive scan, {ready} ready"),
            &t_n,
            &format!("{:?}/pick", t_n.per_iter(iters)),
        );
    }

    harness::section("scheduler mark/take throughput (4096-node PE)");
    for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
        let iters = 4096u64;
        let t = harness::time_it(3, 10, || {
            let mut s = make_scheduler(kind, 4096, None);
            for i in 0..4096u32 {
                s.mark_ready(i);
            }
            let mut acc = 0u32;
            while let Some(n) = s.take() {
                acc = acc.wrapping_add(n);
                s.fanout_done(n);
            }
            acc
        });
        harness::report(
            kind.name(),
            &t,
            &format!("{:?}/op", t.per_iter(2 * iters)),
        );
    }

    harness::section("ablation — §II-B criticality sort (OoO, 8x8 overlay)");
    let m = SparseMatrix::power_law(300, 3, 11);
    let (g, _) = lu_factorization_graph(&m);
    println!("workload: power-law LU -> {} nodes", g.len());
    let base = OverlayConfig::default().with_dims(8, 8);
    let mut rows = Vec::new();
    for (label, kind, order) in [
        ("in-order FIFO", SchedulerKind::InOrder, LocalOrder::ByNodeId),
        ("OoO, arrival order (no heuristic)", SchedulerKind::OutOfOrder, LocalOrder::ByNodeId),
        ("OoO, criticality sorted (paper)", SchedulerKind::OutOfOrder, LocalOrder::ByCriticality),
    ] {
        let mut cfg = base.with_scheduler(kind);
        cfg.local_order = order;
        let program = Program::compile(&g, &Overlay::from_config(cfg).unwrap()).unwrap();
        let stats = program.session().run().unwrap();
        rows.push((label.to_string(), stats.cycles));
    }
    // pick-order bounds: LIFO and uniform-random (criticality-blind OoO)
    for (label, which) in [("LIFO pick (stack)", 0u8), ("uniform-random pick", 1)] {
        let mut cfg = base.with_scheduler(SchedulerKind::OutOfOrder);
        cfg.local_order = LocalOrder::ByNodeId;
        let place = tdp::place::Placement::build(
            &g,
            cfg.num_pes(),
            cfg.placement,
            cfg.local_order,
            cfg.seed,
        );
        let mut sim = tdp::sim::Simulator::with_scheduler_factory(
            &g,
            place,
            cfg,
            move |_, num_local| {
                if which == 0 {
                    tdp::sched::Scheduler::Lifo(tdp::sched::LifoSched::new(num_local))
                } else {
                    tdp::sched::Scheduler::Random(tdp::sched::RandomSched::new(num_local, 99))
                }
            },
        )
        .unwrap();
        let stats = sim.run().unwrap();
        rows.push((label.to_string(), stats.cycles));
    }
    let worst = rows[0].1 as f64;
    for (label, cycles) in &rows {
        println!(
            "{label:<36} {cycles:>9} cycles  (speedup vs in-order: {:.3})",
            worst / *cycles as f64
        );
    }
}
