//! Regenerates **Figure 1**: out-of-order speedup over in-order
//! scheduling vs. dataflow graph size, on the paper's 16×16 (256 PE)
//! overlay. (`cargo bench --bench fig1_speedup`)
//!
//! The paper reports speedup ≈ 1 below the parallelism-saturation point
//! and rising (up to ~1.5×) for graphs ≥ 30 K nodes; the bench prints the
//! same series from our cycle-level simulator. `FIG1_FULL=1` runs the
//! full ladder (minutes); the default trims the largest points so
//! `cargo bench` stays fast.

#[path = "harness.rs"]
mod harness;

use tdp::coordinator::{fig1_config, fig1_sweep};
use tdp::workload;

fn main() {
    harness::section("Figure 1 — OoO speedup vs graph size (16x16 overlay)");
    let full = std::env::var("FIG1_FULL").is_ok();
    // specs, not graphs: generation happens inside the service engine
    // the sweep runs on (the ladder is ordered smallest matrix first)
    let mut ws = workload::fig1_specs(42);
    if !full {
        ws.truncate(6);
        eprintln!("(set FIG1_FULL=1 for the full ladder)");
    }
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let cfg = fig1_config(); // 16x16, paper BRAM geometry, chunked placement
    let t0 = std::time::Instant::now();
    let rows = fig1_sweep(&ws, cfg, threads).expect("sweep completes");
    println!(
        "{:<22} {:>12} {:>7} {:>14} {:>12} {:>8}",
        "workload", "nodes+edges", "depth", "in-order cyc", "ooo cyc", "speedup"
    );
    for r in &rows {
        println!(
            "{:<22} {:>12} {:>7} {:>14} {:>12} {:>8.3}",
            r.label, r.nodes_plus_edges, r.depth, r.cycles_inorder, r.cycles_ooo, r.speedup
        );
    }
    // paper-shape checks: speedup should not collapse below ~1 at scale
    let last = rows.last().unwrap();
    let first = rows.first().unwrap();
    println!(
        "\nshape: small-graph speedup {:.3} -> large-graph speedup {:.3} (paper: ~1 -> up to ~1.5)",
        first.speedup, last.speedup
    );
    println!("total wall time: {:?}", t0.elapsed());
}
