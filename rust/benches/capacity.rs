//! Regenerates the **§III capacity claim**: the 256-PE FIFO-based overlay
//! stores ≈100 K nodes+edges; freeing the FIFO BRAMs lets the
//! out-of-order design store ≈5× more. (`cargo bench --bench capacity`)
//!
//! Two views:
//! 1. analytic — BRAM-budget arithmetic at the measured LU edge:node mix;
//! 2. empirical — grow concrete LU workloads until round-robin placement
//!    no longer fits each scheduler's per-PE budget.

#[path = "harness.rs"]
mod harness;

use tdp::config::{Overlay, OverlayConfig};
use tdp::coordinator::capacity_experiment;
use tdp::pe::BramConfig;
use tdp::program::Program;
use tdp::sched::SchedulerKind;
use tdp::workload::{lu_factorization_graph, SparseMatrix};

fn main() {
    harness::section("§III capacity — analytic (BRAM budget arithmetic)");
    println!(
        "{:>6} {:>18} {:>14} {:>7}",
        "PEs", "in-order items", "OoO items", "ratio"
    );
    for pes in [16usize, 64, 256, 300] {
        let row = capacity_experiment(&BramConfig::paper(), pes, 2.0);
        println!(
            "{:>6} {:>18} {:>14} {:>6.2}x",
            row.num_pes, row.max_items_inorder, row.max_items_ooo, row.ratio
        );
    }
    println!("paper at 256 PEs: ≈100K items in-order, ≈5x out-of-order");

    harness::section("§III capacity — empirical (grow LU until placement fails)");
    // one compile per workload answers the fit question for both
    // schedulers (the scan used to re-place per scheduler)
    let overlay = Overlay::from_config(OverlayConfig::default()).unwrap(); // 16x16
    let mut last_fit = [0usize; 2]; // [in-order, ooo] footprints
    for n in (100..=3400).step_by(150) {
        let m = SparseMatrix::banded(n, 6, 0.8, 7);
        let (g, _) = lu_factorization_graph(&m);
        let fp = g.footprint();
        let program = Program::compile(&g, &overlay).unwrap();
        for (i, kind) in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder]
            .into_iter()
            .enumerate()
        {
            if program.fits(kind) {
                last_fit[i] = last_fit[i].max(fp);
            }
        }
    }
    println!("largest fitting LU footprint (nodes+edges), 256 PEs:");
    println!("  in-order:     {:>9}", last_fit[0]);
    println!("  out-of-order: {:>9}", last_fit[1]);
    println!(
        "  empirical ratio: {:.2}x (paper: ≈5x)",
        last_fit[1] as f64 / last_fit[0] as f64
    );

    let t = harness::time_it(1, 5, || {
        let m = SparseMatrix::banded(800, 6, 0.8, 7);
        let (g, _) = lu_factorization_graph(&m);
        let program = Program::compile(&g, &overlay).unwrap();
        program.fits(SchedulerKind::InOrder) | program.fits(SchedulerKind::OutOfOrder)
    });
    harness::report("compile + fit-check (800x800 banded LU)", &t, "");
}
