//! Engine speedup: wall-clock of the skip-ahead event backend vs the
//! lockstep reference (`cargo bench --bench engine_speedup`).
//!
//! Acceptance target (ISSUE 1): ≥ 2× on a sparse-factorization workload
//! with ≥ 64 PEs. The headline row is a banded-LU elimination chain on an
//! 8×8 (64 PE) overlay with chunked (locality-preserving) placement and a
//! deeply pipelined FP datapath (alu_latency 16 — real FPGA FP dividers
//! retire in 10–30 cycles): the regime where the fabric spends most
//! cycles waiting on scheduled events with zero packets in flight, which
//! is exactly what the event horizon skips. Busy, wide workloads
//! (reduction tree, layered DAGs) are reported too — there the fabric is
//! rarely quiescent and skip-ahead degrades gracefully toward 1×.

#[path = "harness.rs"]
mod harness;

use tdp::config::OverlayConfig;
use tdp::engine::{check_parity, make_backend, BackendKind, SimBackend};
use tdp::graph::{DataflowGraph, Op};
use tdp::place::PlacementPolicy;
use tdp::sched::SchedulerKind;
use tdp::workload::{layered_random, lu_factorization_graph, reduction_tree, SparseMatrix};

/// Time both backends on (g, cfg); returns the wall-clock speedup.
fn bench_pair(label: &str, g: &DataflowGraph, cfg: OverlayConfig) -> f64 {
    let mut cycles = 0u64;
    let t_lock = harness::time_it(1, 3, || {
        let mut be = make_backend(g, cfg.with_backend(BackendKind::Lockstep)).unwrap();
        cycles = be.run().unwrap().cycles;
    });
    let mut skip_cycles = 0u64;
    let t_skip = harness::time_it(1, 3, || {
        let mut be = make_backend(g, cfg.with_backend(BackendKind::SkipAhead)).unwrap();
        skip_cycles = be.run().unwrap().cycles;
    });
    assert_eq!(cycles, skip_cycles, "backends must agree on completion cycle");
    let speedup = t_lock.median.as_secs_f64() / t_skip.median.as_secs_f64().max(1e-12);
    harness::report(
        &format!("{label} [lockstep]"),
        &t_lock,
        &format!("{cycles} cyc"),
    );
    harness::report(
        &format!("{label} [skip-ahead]"),
        &t_skip,
        &format!("speedup {speedup:.2}x"),
    );
    speedup
}

fn main() {
    harness::section("engine speedup — skip-ahead vs lockstep wall-clock");

    // parity spot-check before timing anything
    {
        let m = SparseMatrix::banded(48, 2, 0.9, 3);
        let (g, _) = lu_factorization_graph(&m);
        let mut cfg = OverlayConfig::default().with_dims(8, 8);
        cfg.placement = PlacementPolicy::Chunked;
        let rep = check_parity(&g, cfg).expect("backends must be bit-exact");
        println!(
            "parity check: {} cycles, {} jumps, {:.1}% of cycles skipped",
            rep.stats.cycles,
            rep.jumps,
            100.0 * rep.skip_fraction()
        );
    }

    harness::section("sparse factorization (>= 64 PEs)");
    let mut headline = 0.0f64;
    {
        // near-sequential elimination chain: quiescent-dominated
        let m = SparseMatrix::banded(400, 1, 1.0, 7);
        let (g, _) = lu_factorization_graph(&m);
        for (alu_latency, tag) in [(2u64, "alu=2"), (16u64, "alu=16 (deep FP pipe)")] {
            let mut cfg = OverlayConfig::default()
                .with_dims(8, 8)
                .with_scheduler(SchedulerKind::OutOfOrder);
            cfg.placement = PlacementPolicy::Chunked;
            cfg.alu_latency = alu_latency;
            let s = bench_pair(&format!("lu_banded(400,bw1) 8x8 {tag}"), &g, cfg);
            headline = headline.max(s);
        }
        // bushier power-law factorization on 256 PEs
        let m = SparseMatrix::power_law(220, 3, 11);
        let (g, _) = lu_factorization_graph(&m);
        let mut cfg = OverlayConfig::default()
            .with_dims(16, 16)
            .with_scheduler(SchedulerKind::OutOfOrder);
        cfg.placement = PlacementPolicy::Chunked;
        cfg.alu_latency = 16;
        let s = bench_pair("lu_power_law(220) 16x16 alu=16", &g, cfg);
        headline = headline.max(s);
    }

    harness::section("synthetic workloads");
    {
        let g = reduction_tree(4096, Op::Add, 1);
        let cfg = OverlayConfig::default().with_dims(8, 8);
        bench_pair("reduction(4096) 8x8", &g, cfg);

        let g = layered_random(32, 24, 64, 2, 5);
        let mut cfg = OverlayConfig::default().with_dims(8, 8);
        cfg.placement = PlacementPolicy::Chunked;
        cfg.alu_latency = 8;
        bench_pair("layered(32x24x64) 8x8 alu=8", &g, cfg);
    }

    println!(
        "\nacceptance: best sparse-factorization speedup at >= 64 PEs = {headline:.2}x \
         (target >= 2x): {}",
        if headline >= 2.0 { "PASS" } else { "FAIL" }
    );
}
