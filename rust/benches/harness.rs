#![allow(dead_code)] // different bench targets use different helpers
//! Minimal benchmark harness (criterion is not in the offline crate
//! universe). Each bench target is a `harness = false` binary that uses
//! `time_it` / `Bench` to measure and print stable rows; `cargo bench`
//! runs them all. Timing method: warmup + N timed repetitions, report
//! median and spread.

use std::time::{Duration, Instant};

/// Measure `f` over `reps` repetitions after `warmup` runs.
pub fn time_it<T, F: FnMut() -> T>(warmup: usize, reps: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    Timing {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: samples[samples.len() - 1],
        reps,
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub reps: usize,
}

impl Timing {
    pub fn per_iter(&self, iters_per_rep: u64) -> Duration {
        Duration::from_nanos((self.median.as_nanos() as u64) / iters_per_rep.max(1))
    }
}

/// Pretty-print one benchmark row.
pub fn report(name: &str, t: &Timing, extra: &str) {
    println!(
        "{name:<44} median {:>12?} (min {:>12?}, max {:>12?}, n={}) {extra}",
        t.median, t.min, t.max, t.reps
    );
}

/// Section header matching the paper artifact being regenerated.
pub fn section(title: &str) {
    println!("\n==== {title} ====");
}
