//! Regenerates **Table I**: resource utilization and Fmax of the overlay
//! on the Arria 10 10AX115S, from the calibrated analytic model.
//! (`cargo bench --bench table1_resources`)

#[path = "harness.rs"]
mod harness;

use tdp::resource::{self, ARRIA10_10AX115S};

fn main() {
    harness::section("Table I — resource utilization (Arria 10 10AX115S)");
    println!(
        "{:>5} {:>16} {:>16} {:>12} {:>12} {:>10}",
        "PEs", "ALMs", "REGs", "DSPs", "BRAMs", "Fmax(MHz)"
    );
    for r in resource::table1(&[4, 16, 64, 300]) {
        println!(
            "{:>5} {:>9} ({:>4.1}%) {:>9} ({:>4.1}%) {:>5} ({:>4.1}%) {:>5} ({:>4.1}%) {:>10.0}",
            r.pes, r.alms, r.alm_pct, r.regs, r.reg_pct, r.dsps, r.dsp_pct, r.brams, r.bram_pct,
            r.fmax_mhz
        );
    }
    println!("\npaper row 1:   1 PE: 1.4K ALMs (0.3%), 2.2K regs, 2 DSP (0.1%), 8 BRAM (0.3%), 306 MHz");
    println!("paper row 2: 256 PE: 367K ALMs (86%), 559K regs (25%*), 512 DSP (34%), 2K BRAM (75%), 258 MHz");
    println!("(*paper's reg%% uses a different denominator; we report regs/4xALM-FF)");
    println!(
        "max overlay fitting the device: {} PEs (abstract: 'up to 300 processors')",
        resource::max_overlay(&ARRIA10_10AX115S, 1.0)
    );

    // model-evaluation cost is trivial; time it anyway for completeness
    let t = harness::time_it(3, 10, || resource::table1(&[4, 16, 64, 300]));
    harness::report("table1 model evaluation", &t, "");
}
