//! §II-C ablation: what BRAM multipumping buys.
//!
//! The paper multipumps the M20Ks (2×) so the receive path, ALU
//! writeback and packet generation can all touch graph memory in the
//! same fabric cycle. This bench runs the same workload with the port
//! budget of a multipumped PE (4 virtual ports) and an unpumped one
//! (2 physical ports, units contend) and reports the cycle cost.
//! (`cargo bench --bench ports_ablation`)

#[path = "harness.rs"]
mod harness;

use tdp::config::OverlayConfig;
use tdp::sched::SchedulerKind;
use tdp::sim::Simulator;
use tdp::workload::{lu_factorization_graph, SparseMatrix};

fn main() {
    harness::section("§II-C multipump ablation (8x8 overlay, power-law LU)");
    let m = SparseMatrix::power_law(300, 3, 11);
    let (g, _) = lu_factorization_graph(&m);
    println!("workload: {} nodes, {} edges\n", g.len(), g.num_edges());
    println!(
        "{:<26} {:>10} {:>12} {:>14}",
        "config", "cycles", "port stalls", "vs multipumped"
    );
    for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
        let mut base_cycles = 0u64;
        for (label, pump) in [("multipump x2 (paper)", 2usize), ("no multipump", 1)] {
            let mut cfg = OverlayConfig::default().with_dims(8, 8).with_scheduler(kind);
            cfg.bram.multipump = pump;
            let mut sim = Simulator::new(&g, cfg).unwrap();
            let stats = sim.run().unwrap();
            let stalls: u64 = stats.pe.iter().map(|p| p.port_stalls).sum();
            if pump == 2 {
                base_cycles = stats.cycles;
            }
            println!(
                "{:<26} {:>10} {:>12} {:>13.2}x   [{}]",
                label,
                stats.cycles,
                stalls,
                stats.cycles as f64 / base_cycles as f64,
                kind.name()
            );
        }
    }
    println!("\nexpected: the unpumped PE loses packet-gen/writeback slots to the");
    println!("receive path and completes in more cycles — multipumping is what");
    println!("lets the TDP accept one packet AND inject one packet every cycle.");
}
