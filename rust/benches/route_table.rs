//! Baked route-table microbenchmark: what packet construction costs
//! when every header is a pre-formed CSR entry (one indexed load +
//! payload write) versus the seed derivation the simulator used to do
//! per packet — `graph.node(global).fanout[edge]` → `place.pe_of[dst]`
//! → `place.local_of[dst]` → torus div/mod. Also reports the one-time
//! cost of baking the tables, which the compile-once Program amortizes
//! over every run. (`cargo bench --bench route_table`)

#[path = "harness.rs"]
mod harness;

use tdp::config::OverlayConfig;
use tdp::noc::Packet;
use tdp::place::Placement;
use tdp::program::RuntimeTables;
use tdp::workload::Spec;

fn main() {
    harness::section("route table — packet construction paths");
    // the Fig. 1 power-law LU rung on the paper's 16x16 overlay
    let spec: Spec = "lu_pl:330:3:seed=42".parse().unwrap();
    let g = spec.build().unwrap();
    let (cols, rows) = (16usize, 16usize);
    let cfg = OverlayConfig::default().with_dims(cols, rows);
    let place = Placement::build(&g, cols * rows, cfg.placement, cfg.local_order, cfg.seed);
    println!(
        "workload: {} -> {} nodes, {} edges on {cols}x{rows}",
        spec.canonical(),
        g.len(),
        g.num_edges()
    );

    let t_build = harness::time_it(1, 5, || RuntimeTables::build(&g, &place, cols, rows));
    harness::report("bake tables (one-time compile cost)", &t_build, "");
    let tables = RuntimeTables::build(&g, &place, cols, rows);

    // every (node, edge) pair once per rep; checksum defeats dead-code
    // elimination and proves both paths form identical headers
    let sweeps = 200u32;
    let checksum =
        |p: Packet| p.dest_x as u64 + p.dest_y as u64 + p.local_idx as u64 + p.slot as u64;

    let t_graph = harness::time_it(1, 5, || {
        let mut acc = 0u64;
        for _ in 0..sweeps {
            for global in 0..g.len() as u32 {
                let node = g.node(global);
                // the seed hot path, verbatim
                for &(dst, slot) in &node.fanout {
                    let dpe = place.pe_of[dst as usize] as usize;
                    acc += checksum(Packet {
                        dest_x: (dpe % cols) as u8,
                        dest_y: (dpe / cols) as u8,
                        local_idx: place.local_of[dst as usize] as u16,
                        slot,
                        payload: 0.5,
                    });
                }
            }
        }
        acc
    });

    let t_baked = harness::time_it(1, 5, || {
        let mut acc = 0u64;
        for _ in 0..sweeps {
            for dense in 0..tables.len() {
                for edge in 0..tables.route_len(dense) {
                    acc += checksum(tables.packet(dense, edge, 0.5));
                }
            }
        }
        acc
    });

    let packets = sweeps as u64 * g.num_edges() as u64;
    harness::report(
        "graph-chase (seed derivation)",
        &t_graph,
        &format!("{:?}/packet", t_graph.per_iter(packets)),
    );
    harness::report(
        "baked CSR load",
        &t_baked,
        &format!("{:?}/packet", t_baked.per_iter(packets)),
    );
    let speedup = t_graph.median.as_secs_f64() / t_baked.median.as_secs_f64();
    println!("baked-route speedup: {speedup:.2}x over {packets} packets");
}
