//! Hoplite NoC characterization (supports the §I/§II "lightweight,
//! high-bandwidth 56b Hoplite router" claim): delivered throughput,
//! latency and deflection rate under uniform-random traffic across
//! injection rates, plus raw `Network::step` cost (the simulator's
//! second-hottest loop). (`cargo bench --bench noc_throughput`)

#[path = "harness.rs"]
mod harness;

use tdp::noc::{Network, Packet};
use tdp::util::rng::Rng;

fn run_traffic(cols: usize, rows: usize, rate: f64, cycles: u64, seed: u64) -> (f64, f64, f64) {
    let n = cols * rows;
    let mut net = Network::new(cols, rows);
    let mut rng = Rng::seed_from_u64(seed);
    let mut inject: Vec<Option<Packet>> = vec![None; n];
    for _ in 0..cycles {
        for (pe, slot) in inject.iter_mut().enumerate() {
            if slot.is_none() && rng.gen_bool(rate) {
                let dest = rng.gen_range(n);
                *slot = Some(Packet {
                    dest_x: (dest % cols) as u8,
                    dest_y: (dest / cols) as u8,
                    local_idx: (pe % 8192) as u16,
                    slot: 0,
                    payload: 1.0,
                });
            }
        }
        let res = net.step(&inject);
        for (pe, ok) in res.inject_ok.iter().enumerate() {
            if *ok {
                inject[pe] = None;
            }
        }
    }
    let s = net.stats;
    (
        s.delivered as f64 / cycles as f64 / n as f64, // accepted throughput/PE
        s.total_latency as f64 / s.delivered.max(1) as f64,
        s.deflections as f64 / s.delivered.max(1) as f64,
    )
}

fn main() {
    harness::section("Hoplite 16x16 torus — uniform random traffic");
    println!(
        "{:>12} {:>16} {:>12} {:>14}",
        "inject rate", "thpt (pkt/PE/cy)", "avg latency", "deflections/pkt"
    );
    for rate in [0.02, 0.05, 0.1, 0.2, 0.35, 0.5, 0.8, 1.0] {
        let (thpt, lat, defl) = run_traffic(16, 16, rate, 20_000, 1);
        println!("{rate:>12.2} {thpt:>16.4} {lat:>12.1} {defl:>14.3}");
    }

    harness::section("Network::step raw cost (perf target: sim hot loop)");
    for (cols, rows) in [(4usize, 4usize), (8, 8), (16, 16)] {
        let n = cols * rows;
        let mut net = Network::new(cols, rows);
        let mut rng = Rng::seed_from_u64(2);
        let inject: Vec<Option<Packet>> = (0..n)
            .map(|pe| {
                let dest = rng.gen_range(n);
                Some(Packet {
                    dest_x: (dest % cols) as u8,
                    dest_y: (dest / cols) as u8,
                    local_idx: pe as u16,
                    slot: 0,
                    payload: 1.0,
                })
            })
            .collect();
        let iters = 10_000u64;
        let t = harness::time_it(2, 8, || {
            for _ in 0..iters {
                std::hint::black_box(net.step(&inject));
            }
        });
        let per_router = t.median.as_nanos() as f64 / iters as f64 / n as f64;
        harness::report(
            &format!("net.step {cols}x{rows}"),
            &t,
            &format!("= {per_router:.1} ns/router-cycle"),
        );
    }
}
