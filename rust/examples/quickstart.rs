//! Quickstart — the canonical compile-once snippet (DESIGN.md §8).
//!
//! Build a small dataflow graph by hand, validate a 4×4 overlay
//! description, compile the graph for it **once** (placement +
//! criticality labeling), then run cheap sessions under both schedulers
//! and check the computed values against the reference evaluation.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tdp::service::{Engine, JobSpec};
use tdp::{DataflowGraph, Op, Overlay, Program, SchedulerKind};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // f(a, b) = (a + b) * (a - b), replicated over a few token sets, plus
    // a reduction over the results — a toy dataflow kernel.
    let mut g = DataflowGraph::new();
    let mut products = Vec::new();
    for i in 0..8 {
        let a = g.add_input(1.0 + i as f32);
        let b = g.add_input(0.5 * i as f32);
        let s = g.op(Op::Add, &[a, b]);
        let d = g.op(Op::Sub, &[a, b]);
        products.push(g.op(Op::Mul, &[s, d]));
    }
    // reduce: max of all products
    let mut acc = products[0];
    for &p in &products[1..] {
        acc = g.op(Op::Max, &[acc, p]);
    }
    let stats = g.stats();
    println!(
        "graph: {} nodes, {} edges, depth {}",
        stats.nodes, stats.edges, stats.depth
    );

    let reference = g.evaluate();
    println!("reference result (max of (a+b)(a-b)) = {}", reference[acc as usize]);

    // 1. Overlay: the validated hardware description.
    let overlay = Overlay::builder().dims(4, 4).build()?;

    // 2. Program: the one-time compile artifact — placement, criticality
    //    labels, per-PE BRAM images. Never recomputed below.
    let program = Program::compile(&g, &overlay)?;
    println!(
        "compiled: {} PEs, max {} graph words/PE, {} flag words/PE",
        overlay.num_pes(),
        program.max_graph_words(),
        program.flag_layout().words_per_pe
    );

    // 3. Sessions: cheap repeatable runs over the shared program.
    for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
        let mut backend = program.session().with_scheduler(kind).backend()?;
        let stats = backend.run()?;
        let ok = backend.values() == &reference[..];
        println!(
            "{:>12}: {:>5} cycles, {} packets, values {}",
            kind.name(),
            stats.cycles,
            stats.net.delivered,
            if ok { "MATCH" } else { "MISMATCH" }
        );
        assert!(ok, "simulated dataflow must equal reference");
    }
    // 4. Service (DESIGN.md §9): for request streams, let an Engine own
    //    the compile cache — jobs name workloads by spec string, and
    //    duplicates are served from the already-compiled Program.
    let engine = Engine::new();
    let job = JobSpec::from_json(r#"{"workload": "chain:256:seed=7", "cols": 4, "rows": 4}"#)?;
    let cold = engine.submit(&job)?;
    let warm = engine.submit(&job)?;
    assert!(warm.cache_hit && warm.stats == cold.stats);
    println!(
        "service: {} compiled in {}us, replayed from cache in {}us ({} cycles)",
        warm.workload, cold.compile_micros, warm.run_micros, warm.stats.cycles
    );

    println!("quickstart OK");
    Ok(())
}
