//! Quickstart: build a small dataflow graph by hand, run it on a 4×4
//! overlay under both schedulers, and check the computed values against
//! the reference evaluation.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tdp::config::OverlayConfig;
use tdp::graph::{DataflowGraph, Op};
use tdp::sched::SchedulerKind;
use tdp::sim::Simulator;

fn main() {
    // f(a, b) = (a + b) * (a - b), replicated over a few token sets, plus
    // a reduction over the results — a toy dataflow kernel.
    let mut g = DataflowGraph::new();
    let mut products = Vec::new();
    for i in 0..8 {
        let a = g.add_input(1.0 + i as f32);
        let b = g.add_input(0.5 * i as f32);
        let s = g.op(Op::Add, &[a, b]);
        let d = g.op(Op::Sub, &[a, b]);
        products.push(g.op(Op::Mul, &[s, d]));
    }
    // reduce: max of all products
    let mut acc = products[0];
    for &p in &products[1..] {
        acc = g.op(Op::Max, &[acc, p]);
    }
    let stats = g.stats();
    println!(
        "graph: {} nodes, {} edges, depth {}",
        stats.nodes, stats.edges, stats.depth
    );

    let reference = g.evaluate();
    println!("reference result (max of (a+b)(a-b)) = {}", reference[acc as usize]);

    for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
        let cfg = OverlayConfig::default().with_dims(4, 4).with_scheduler(kind);
        let mut sim = Simulator::new(&g, cfg).expect("placement fits");
        let stats = sim.run().expect("graph completes");
        let ok = sim.values() == &reference[..];
        println!(
            "{:>12}: {:>5} cycles, {} packets, values {}",
            kind.name(),
            stats.cycles,
            stats.net.delivered,
            if ok { "MATCH" } else { "MISMATCH" }
        );
        assert!(ok, "simulated dataflow must equal reference");
    }
    println!("quickstart OK");
}
