//! §III capacity scaling: how large a factorization graph can each
//! scheduler's BRAM budget hold as the overlay grows — the "freeing the
//! FIFO BRAMs buys ≈5× graph capacity" claim, plus the ≈6% flag-overhead
//! arithmetic of §II-B.
//!
//! ```sh
//! cargo run --release --example capacity_scaling
//! ```

use tdp::config::{Overlay, OverlayConfig};
use tdp::coordinator::capacity_experiment;
use tdp::pe::BramConfig;
use tdp::program::Program;
use tdp::sched::SchedulerKind;
use tdp::workload::{lu_factorization_graph, SparseMatrix};

fn main() {
    let bram = BramConfig::paper();
    println!("M20K geometry: {} BRAMs/PE x {} words x {} b", bram.brams_per_pe, bram.words_per_bram, bram.word_bits);
    println!(
        "OoO flag overhead: {} words = {:.2}% (paper §II-B: 2*ceil(512/32) = 32 words/BRAM ≈ 6%)",
        bram.flag_words(),
        100.0 * bram.flag_words() as f64 / bram.total_words() as f64
    );
    println!(
        "in-order FIFO reserve: {} words ({} BRAMs)\n",
        bram.fifo_words(),
        bram.fifo_brams
    );

    println!("analytic capacity (items = nodes+edges, LU mix e/n = 2.0):");
    println!("{:>6} {:>16} {:>14} {:>7}", "PEs", "in-order", "out-of-order", "ratio");
    for pes in [1usize, 16, 64, 256, 300] {
        let row = capacity_experiment(&bram, pes, 2.0);
        println!(
            "{:>6} {:>16} {:>14} {:>6.2}x",
            pes, row.max_items_inorder, row.max_items_ooo, row.ratio
        );
    }

    println!("\nempirical: largest banded-LU graph that places on 16x16 (256 PEs):");
    // compile each workload once; one Program answers the capacity
    // question for every scheduler (the per-PE BRAM images are fixed)
    let overlay = Overlay::from_config(OverlayConfig::default()).expect("paper config is valid");
    let mut best = [0usize; 2]; // [in-order, out-of-order]
    let mut n = 100;
    while n <= 3600 {
        let m = SparseMatrix::banded(n, 6, 0.8, 7);
        let (g, _) = lu_factorization_graph(&m);
        let program = Program::compile(&g, &overlay).expect("compile succeeds");
        for (i, kind) in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder]
            .into_iter()
            .enumerate()
        {
            if program.fits(kind) {
                best[i] = g.footprint();
            }
        }
        n += 150;
    }
    for (i, kind) in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder]
        .into_iter()
        .enumerate()
    {
        println!("  {:>13}: {:>8} nodes+edges", kind.name(), best[i]);
    }
    println!("\npaper §III: in-order ≈100K items; out-of-order ≈5x larger");
}
