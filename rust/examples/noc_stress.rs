//! Hoplite NoC characterization: drive the 16×16 torus with uniform
//! random traffic at rising injection rates and plot (textually) the
//! classic bufferless-deflection saturation curve — throughput, latency
//! and deflection rate.
//!
//! ```sh
//! cargo run --release --example noc_stress
//! ```

use tdp::noc::{Network, Packet};
use tdp::util::rng::Rng;

fn run(cols: usize, rows: usize, rate: f64, cycles: u64, seed: u64) -> (f64, f64, f64, f64) {
    let n = cols * rows;
    let mut net = Network::new(cols, rows);
    let mut rng = Rng::seed_from_u64(seed);
    let mut inject: Vec<Option<Packet>> = vec![None; n];
    let mut offered = 0u64;
    for _ in 0..cycles {
        for (pe, slot) in inject.iter_mut().enumerate() {
            if slot.is_none() && rng.gen_bool(rate) {
                let dest = rng.gen_range(n);
                *slot = Some(Packet {
                    dest_x: (dest % cols) as u8,
                    dest_y: (dest / cols) as u8,
                    local_idx: (pe % 8192) as u16,
                    slot: 0,
                    payload: 1.0,
                });
                offered += 1;
            }
        }
        let res = net.step(&inject);
        for (pe, ok) in res.inject_ok.iter().enumerate() {
            if *ok {
                inject[pe] = None;
            }
        }
    }
    let s = net.stats;
    (
        s.delivered as f64 / cycles as f64 / n as f64,
        s.total_latency as f64 / s.delivered.max(1) as f64,
        s.deflections as f64 / s.delivered.max(1) as f64,
        s.injected as f64 / offered.max(1) as f64,
    )
}

fn main() {
    println!("Hoplite 16x16 unidirectional torus, 56b links, uniform random traffic");
    println!(
        "{:>8} {:>16} {:>12} {:>12} {:>12}",
        "offered", "thpt/PE (pkt/cy)", "avg lat", "defl/pkt", "accept rate"
    );
    for rate in [0.01, 0.02, 0.05, 0.1, 0.15, 0.25, 0.4, 0.6, 0.8, 1.0] {
        let (thpt, lat, defl, accept) = run(16, 16, rate, 30_000, 3);
        println!("{rate:>8.2} {thpt:>16.4} {lat:>12.1} {defl:>12.3} {accept:>12.3}");
    }
    println!("\nexpected shape: throughput saturates (bufferless deflection torus),");
    println!("latency and deflections/packet climb sharply past saturation;");
    println!("per the paper/[Hoplite FPL'15] the router itself runs >400 MHz.");
}
