//! **End-to-end driver** (DESIGN.md §4 "End-to-end"): the full
//! three-layer stack on a real small workload.
//!
//! 1. Build the sparse-LU elimination dataflow graph of a 64×64 banded
//!    matrix (the paper's workload class).
//! 2. Simulate it on a 4×4 TDP overlay under both schedulers
//!    (L3 coordinator: placement → criticality sort → Hoplite → PEs).
//! 3. Validate every node value three ways:
//!      * native topological reference,
//!      * the AOT-compiled **L2 JAX graph_eval artifact** via PJRT,
//!      * spot-check the **L1 Pallas ALU kernel** and the **LOD kernel**
//!        against live scheduler state.
//! 4. Report cycles, throughput and the projected wall-clock at the
//!    resource model's Fmax. Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example sparse_factorization
//! ```

use std::path::Path;
use tdp::config::OverlayConfig;
use tdp::coordinator::validate;
use tdp::graph::Op;
use tdp::resource;
use tdp::runtime::XlaRuntime;
use tdp::sched::{OutOfOrderLod, ReadyScheduler, SchedulerKind};
use tdp::workload::{lu_factorization_graph, SparseMatrix};

fn main() {
    // ---- workload: 64x64 banded sparse matrix, LU elimination DAG ----
    let m = SparseMatrix::banded(64, 2, 0.9, 2017);
    let (g, fstats) = lu_factorization_graph(&m);
    println!(
        "LU(64x64, bw=2): {} nodes ({} inputs, {} div, {} mul, {} sub, {} fill-in), {} edges, depth {}",
        g.len(),
        fstats.nnz_in,
        fstats.div_ops,
        fstats.mul_ops,
        fstats.sub_ops,
        fstats.fill_in,
        g.num_edges(),
        g.stats().depth
    );

    // ---- PJRT runtime: the AOT artifacts are the numerics oracle ----
    let rt = match XlaRuntime::load(Path::new("artifacts")) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            rt.manifest.check_opcode_table().expect("opcode tables in sync");
            Some(rt)
        }
        Err(e) => {
            eprintln!("WARNING: artifacts not available ({e}); run `make artifacts`.");
            eprintln!("continuing with native reference only.");
            None
        }
    };

    // ---- L1 spot-checks: ALU kernel + LOD kernel ----
    if let Some(rt) = &rt {
        // ALU: a batch mixing every opcode
        let a = [3.0f32, 3.0, 3.0, 3.0, 3.0, 3.0, 3.0, 3.0];
        let b = [2.0f32, 2.0, 2.0, 2.0, 2.0, 2.0, 9.0, 9.0];
        let ops: Vec<u32> = (0..8).collect();
        let got = rt.alu_batch(&a, &b, &ops).expect("alu artifact executes");
        let want: Vec<f32> = ops
            .iter()
            .map(|&o| Op::from_code(o).unwrap().eval(3.0, if o < 6 { 2.0 } else { 9.0 }))
            .collect();
        assert_eq!(got, want, "L1 Pallas ALU == rust Op::eval");
        println!("L1 ALU kernel: 8/8 opcodes bit-exact vs rust DSP model");

        // LOD: drive a live scheduler and cross-check the kernel's pick
        let mut sched = OutOfOrderLod::new(4096);
        for idx in [3000u32, 1234, 77, 2048] {
            sched.mark_ready(idx);
        }
        let hw_pick = rt.lod_pick(sched.rdy_words()).expect("lod artifact executes");
        assert_eq!(hw_pick, 77, "L1 LOD kernel picks the most-critical ready node");
        println!("L1 LOD kernel: pick({{3000,1234,77,2048}}) = {hw_pick} (lowest address)");
    }

    // ---- L3: simulate + validate both schedulers ----
    let fmax = resource::fmax_mhz(16);
    for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
        let cfg = OverlayConfig::default().with_dims(4, 4).with_scheduler(kind);
        let rep = validate(&g, cfg, rt.as_ref()).expect("simulation completes");
        let s = &rep.stats;
        println!("\n=== {} ===", kind.name());
        println!(
            "  {} cycles  ({:.1} us at {:.0} MHz, 16-PE overlay)",
            s.cycles,
            s.runtime_us(fmax),
            fmax
        );
        println!(
            "  throughput: {:.2} FLOP/cycle, PE utilization {:.1}%",
            s.ops_per_cycle(),
            100.0 * s.avg_pe_utilization
        );
        println!(
            "  network: {} packets, {} deflections, max ready occupancy {}",
            s.net.delivered, s.net.deflections, s.max_ready_occupancy
        );
        println!("  native-ref max |err|: {}", rep.max_abs_err_native);
        match rep.max_abs_err_pjrt {
            Some(e) => println!("  PJRT graph_eval max |err|: {e}"),
            None => println!("  PJRT graph_eval: skipped"),
        }
        assert!(rep.passed(), "all node values must match the oracles");
    }
    println!("\nsparse_factorization end-to-end OK");
}
