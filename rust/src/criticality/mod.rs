//! Static criticality labeling (paper §II-B).
//!
//! Before execution, a one-time software pass labels every node with a
//! *criticality* metric: its height — the length of the longest path from
//! the node to any sink. Nodes on the critical path have the largest
//! height; executing them first shortens overall completion. Each PE's
//! local graph memory is then laid out in **decreasing criticality** order
//! so the hierarchical LOD scheduler (which always picks the ready node at
//! the lowest address) implicitly issues the most critical ready node.

use crate::graph::{DataflowGraph, NodeId, NodeKind};
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of criticality labelings performed (see
/// [`labeling_count`]).
static LABELINGS: AtomicU64 = AtomicU64::new(0);

/// Number of [`criticality`] labeling passes since process start.
///
/// Labeling is part of the one-time compile cost of a
/// [`crate::program::Program`]; compile-once tests snapshot this counter
/// around a sweep to prove labeling is not re-run per scheduler or
/// backend variant. Monotonic and process-global: compare *deltas*, and
/// only from a test that owns the whole process.
pub fn labeling_count() -> u64 {
    LABELINGS.load(Ordering::Relaxed)
}

/// Per-node criticality = longest path (in edges) from the node to a sink.
///
/// Computed in one reverse topological sweep (node ids are topologically
/// ordered by construction).
pub fn criticality(g: &DataflowGraph) -> Vec<u32> {
    LABELINGS.fetch_add(1, Ordering::Relaxed);
    let n = g.len();
    let mut height = vec![0u32; n];
    for i in (0..n).rev() {
        let mut h = 0u32;
        for &(dst, _) in &g.node(i as NodeId).fanout {
            h = h.max(height[dst as usize] + 1);
        }
        height[i] = h;
    }
    height
}

/// ASAP level: earliest cycle-level a node can fire (inputs at 0).
pub fn asap(g: &DataflowGraph) -> Vec<u32> {
    g.asap_levels()
}

/// ALAP level: latest level a node can fire without stretching the
/// schedule beyond the graph depth.
pub fn alap(g: &DataflowGraph) -> Vec<u32> {
    let depth = asap(g).iter().copied().max().unwrap_or(0);
    let crit = criticality(g);
    crit.iter().map(|&h| depth - h).collect()
}

/// Slack = ALAP − ASAP. Zero-slack nodes are on the critical path.
pub fn slack(g: &DataflowGraph) -> Vec<u32> {
    let a = asap(g);
    let l = alap(g);
    a.iter().zip(&l).map(|(&a, &l)| l - a).collect()
}

/// Sort a set of node ids in decreasing criticality (ties broken by node
/// id for determinism) — the memory layout order of §II-B.
pub fn sort_by_criticality(nodes: &mut [NodeId], crit: &[u32]) {
    nodes.sort_by_key(|&n| (std::cmp::Reverse(crit[n as usize]), n));
}

/// Critical-path length of the whole graph (in ALU ops).
pub fn critical_path(g: &DataflowGraph) -> u32 {
    criticality(g)
        .iter()
        .zip(g.nodes())
        .filter(|(_, node)| matches!(node.kind, NodeKind::Input { .. }))
        .map(|(&h, _)| h)
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;

    /// chain: in -> a -> b -> c, plus independent in2 -> d
    fn chain_plus_leaf() -> DataflowGraph {
        let mut g = DataflowGraph::new();
        let i0 = g.add_input(1.0);
        let a = g.op(Op::Copy, &[i0]);
        let b = g.op(Op::Copy, &[a]);
        let _c = g.op(Op::Copy, &[b]);
        let i1 = g.add_input(2.0);
        let _d = g.op(Op::Copy, &[i1]);
        g
    }

    #[test]
    fn criticality_is_height_to_sink() {
        let g = chain_plus_leaf();
        assert_eq!(criticality(&g), vec![3, 2, 1, 0, 1, 0]);
    }

    #[test]
    fn asap_alap_slack() {
        let g = chain_plus_leaf();
        assert_eq!(asap(&g), vec![0, 1, 2, 3, 0, 1]);
        assert_eq!(alap(&g), vec![0, 1, 2, 3, 2, 3]);
        assert_eq!(slack(&g), vec![0, 0, 0, 0, 2, 2]);
    }

    #[test]
    fn critical_path_of_chain() {
        let g = chain_plus_leaf();
        assert_eq!(critical_path(&g), 3);
    }

    #[test]
    fn sort_decreasing_criticality_stable_ties() {
        let g = chain_plus_leaf();
        let crit = criticality(&g);
        let mut ids: Vec<u32> = (0..g.len() as u32).collect();
        sort_by_criticality(&mut ids, &crit);
        assert_eq!(ids, vec![0, 1, 2, 4, 3, 5]);
        // decreasing criticality, ties by id
        let sorted: Vec<u32> = ids.iter().map(|&i| crit[i as usize]).collect();
        assert_eq!(sorted, vec![3, 2, 1, 1, 0, 0]);
    }

    #[test]
    fn diamond_criticality() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(1.0);
        let b = g.add_input(2.0);
        let s = g.op(Op::Add, &[a, b]);
        let p = g.op(Op::Mul, &[a, b]);
        let _r = g.op(Op::Sub, &[s, p]);
        let crit = criticality(&g);
        assert_eq!(crit, vec![2, 2, 1, 1, 0]);
        assert_eq!(critical_path(&g), 2);
    }

    #[test]
    fn single_input_graph() {
        let mut g = DataflowGraph::new();
        g.add_input(5.0);
        assert_eq!(criticality(&g), vec![0]);
        assert_eq!(critical_path(&g), 0);
        assert_eq!(slack(&g), vec![0]);
    }
}
