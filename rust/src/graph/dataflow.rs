//! The dataflow DAG the overlay executes.

use super::Op;
use std::fmt;

/// Index of a node in its [`DataflowGraph`].
pub type NodeId = u32;

/// What a node is: a graph input carrying an initial token value, or an
/// ALU operation over one/two upstream nodes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NodeKind {
    /// Graph input with its initial value (injected at simulation start).
    Input { value: f32 },
    /// Interior operation; `src` holds `op.arity()` operand node ids.
    Operation { op: Op, src: [NodeId; 2] },
}

/// One dataflow actor.
#[derive(Debug, Clone)]
pub struct Node {
    pub kind: NodeKind,
    /// Consumers of this node's value: `(dest node, operand slot)`.
    /// In hardware this is the fanout edge list in graph memory that the
    /// packet generation unit walks, one packet per edge.
    pub fanout: Vec<(NodeId, u8)>,
}

impl Node {
    pub fn arity(&self) -> usize {
        match self.kind {
            NodeKind::Input { .. } => 0,
            NodeKind::Operation { op, .. } => op.arity(),
        }
    }

    pub fn op(&self) -> Option<Op> {
        match self.kind {
            NodeKind::Input { .. } => None,
            NodeKind::Operation { op, .. } => Some(op),
        }
    }
}

/// Errors from graph construction / validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    /// Operand references a node id that does not exist (yet). Builder
    /// order implies acyclicity: operands must precede their consumers.
    ForwardReference { node: NodeId, operand: NodeId },
    /// Graph has no nodes.
    Empty,
}

impl fmt::Display for GraphError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GraphError::ForwardReference { node, operand } => write!(
                f,
                "node {node} references operand {operand} that is not yet defined"
            ),
            GraphError::Empty => write!(f, "graph has no nodes"),
        }
    }
}

impl std::error::Error for GraphError {}

/// Summary statistics (used by reports and capacity checks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphStats {
    pub nodes: usize,
    pub inputs: usize,
    pub edges: usize,
    /// Dataflow depth: number of ASAP levels (inputs are level 0).
    pub depth: usize,
    pub max_fanout: usize,
}

/// A dataflow DAG in construction (topological) order: node `i`'s operands
/// all have ids `< i`, which the builder enforces — so the graph is acyclic
/// by construction and `0..n` is a valid topological order.
#[derive(Debug, Clone, Default)]
pub struct DataflowGraph {
    nodes: Vec<Node>,
}

impl DataflowGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Self {
            nodes: Vec::with_capacity(n),
        }
    }

    /// Construct directly from raw nodes, checking **nothing**: no
    /// topological-order, arity, or fanout-consistency invariants are
    /// enforced (the checked builder path is [`DataflowGraph::add_input`]
    /// / [`DataflowGraph::add_op`]). This exists for two callers that
    /// need to represent graphs the builder cannot: the `tdp check`
    /// loader, which must *load* malformed inputs so the verifier pass
    /// ([`crate::passes::verify`]) can diagnose them, and the transform
    /// passes, which rebuild already-verified node vectors wholesale
    /// with remapped ids. A raw graph must pass
    /// [`crate::passes::verify::graph_diagnostics`] clean before it is
    /// simulated.
    pub fn from_raw_nodes(nodes: Vec<Node>) -> Self {
        Self { nodes }
    }

    /// Add a graph input carrying `value`; returns its id.
    pub fn add_input(&mut self, value: f32) -> NodeId {
        self.nodes.push(Node {
            kind: NodeKind::Input { value },
            fanout: Vec::new(),
        });
        (self.nodes.len() - 1) as NodeId
    }

    /// Add an operation node; operands must already exist.
    pub fn add_op(&mut self, op: Op, srcs: &[NodeId]) -> Result<NodeId, GraphError> {
        assert_eq!(srcs.len(), op.arity(), "operand count != op arity");
        let id = self.nodes.len() as NodeId;
        for &s in srcs {
            if s >= id {
                return Err(GraphError::ForwardReference { node: id, operand: s });
            }
        }
        let src = [srcs[0], *srcs.get(1).unwrap_or(&srcs[0])];
        for (slot, &s) in srcs.iter().enumerate() {
            self.nodes[s as usize].fanout.push((id, slot as u8));
        }
        self.nodes.push(Node {
            kind: NodeKind::Operation { op, src },
            fanout: Vec::new(),
        });
        Ok(id)
    }

    /// Convenience for tests/generators: panics on builder misuse.
    pub fn op(&mut self, op: Op, srcs: &[NodeId]) -> NodeId {
        self.add_op(op, srcs).expect("valid operands")
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    #[inline]
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id as usize]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn num_edges(&self) -> usize {
        self.nodes.iter().map(|n| n.fanout.len()).sum()
    }

    pub fn num_inputs(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Input { .. }))
            .count()
    }

    /// nodes + edges — the paper's graph-memory sizing unit (§III).
    pub fn footprint(&self) -> usize {
        self.len() + self.num_edges()
    }

    /// Functional evaluation in topological order — the native golden
    /// model (cross-checked against the PJRT `graph_eval` artifact).
    pub fn evaluate(&self) -> Vec<f32> {
        let mut vals = vec![0f32; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            vals[i] = match node.kind {
                NodeKind::Input { value } => value,
                NodeKind::Operation { op, src } => {
                    op.eval(vals[src[0] as usize], vals[src[1] as usize])
                }
            };
        }
        vals
    }

    /// ASAP level per node: inputs 0, else 1 + max(level of operands).
    pub fn asap_levels(&self) -> Vec<u32> {
        let mut level = vec![0u32; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            if let NodeKind::Operation { op, src } = node.kind {
                let mut l = level[src[0] as usize];
                if op.arity() == 2 {
                    l = l.max(level[src[1] as usize]);
                }
                level[i] = l + 1;
            }
        }
        level
    }

    pub fn stats(&self) -> GraphStats {
        let depth = self.asap_levels().iter().copied().max().unwrap_or(0) as usize;
        GraphStats {
            nodes: self.len(),
            inputs: self.num_inputs(),
            edges: self.num_edges(),
            depth,
            max_fanout: self.nodes.iter().map(|n| n.fanout.len()).max().unwrap_or(0),
        }
    }

    /// Structural validation (the builder already guarantees most of this;
    /// deserialized graphs go through here).
    pub fn validate(&self) -> Result<(), GraphError> {
        if self.nodes.is_empty() {
            return Err(GraphError::Empty);
        }
        for (i, node) in self.nodes.iter().enumerate() {
            if let NodeKind::Operation { op, src } = node.kind {
                for &s in &src[..op.arity()] {
                    if s as usize >= i {
                        return Err(GraphError::ForwardReference {
                            node: i as NodeId,
                            operand: s,
                        });
                    }
                }
            }
        }
        Ok(())
    }

    /// Stable content fingerprint (FNV-1a 64) over the full executable
    /// identity of the graph: node count, then every node's kind, op,
    /// operand ids and input value bits, in id order.
    ///
    /// Two identical graphs always fingerprint equal, and differing
    /// graphs differ except with the collision probability of a 64-bit
    /// non-cryptographic hash — which is why the service layer's
    /// content-addressed cache key pairs this with the canonical
    /// workload spec (× overlay shape) rather than trusting the hash
    /// alone. Node ids are part
    /// of the identity on purpose: placement walks nodes in id order,
    /// so the *same* structural DAG built in a different insertion
    /// order is a different executable and must not share an artifact.
    /// The hash reads only the `Vec` of nodes (no map iteration), so it
    /// is reproducible across runs, platforms and process restarts.
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        fn eat(h: u64, byte: u8) -> u64 {
            (h ^ byte as u64).wrapping_mul(FNV_PRIME)
        }
        fn eat32(mut h: u64, v: u32) -> u64 {
            for b in v.to_le_bytes() {
                h = eat(h, b);
            }
            h
        }
        let mut h = eat32(FNV_OFFSET, self.nodes.len() as u32);
        for node in &self.nodes {
            match node.kind {
                NodeKind::Input { value } => {
                    h = eat(h, 0x01);
                    h = eat32(h, value.to_bits());
                }
                NodeKind::Operation { op, src } => {
                    h = eat(h, 0x02);
                    h = eat(h, op.code() as u8);
                    h = eat32(h, src[0]);
                    h = eat32(h, src[1]);
                }
            }
        }
        h
    }

    /// Graphviz DOT export (debugging / documentation).
    pub fn to_dot(&self) -> String {
        let mut out = String::from("digraph dataflow {\n  rankdir=TB;\n");
        for (i, node) in self.nodes.iter().enumerate() {
            let label = match node.kind {
                NodeKind::Input { value } => format!("in={value}"),
                NodeKind::Operation { op, .. } => op.name().to_string(),
            };
            out.push_str(&format!("  n{i} [label=\"{i}:{label}\"];\n"));
        }
        for (i, node) in self.nodes.iter().enumerate() {
            for &(dst, slot) in &node.fanout {
                out.push_str(&format!("  n{i} -> n{dst} [label=\"{slot}\"];\n"));
            }
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> DataflowGraph {
        let mut g = DataflowGraph::new();
        let a = g.add_input(3.0);
        let b = g.add_input(4.0);
        let s = g.op(Op::Add, &[a, b]);
        let p = g.op(Op::Mul, &[a, b]);
        g.op(Op::Sub, &[s, p]);
        g
    }

    #[test]
    fn build_and_evaluate_diamond() {
        let g = diamond();
        let vals = g.evaluate();
        assert_eq!(vals, vec![3.0, 4.0, 7.0, 12.0, -5.0]);
    }

    #[test]
    fn fanout_lists_are_consistent() {
        let g = diamond();
        // input a feeds nodes 2 and 3, slot 0
        assert_eq!(g.node(0).fanout, vec![(2, 0), (3, 0)]);
        assert_eq!(g.node(1).fanout, vec![(2, 1), (3, 1)]);
        assert_eq!(g.num_edges(), 6);
        assert_eq!(g.footprint(), 5 + 6);
    }

    #[test]
    fn asap_levels_diamond() {
        let g = diamond();
        assert_eq!(g.asap_levels(), vec![0, 0, 1, 1, 2]);
        assert_eq!(g.stats().depth, 2);
    }

    #[test]
    fn forward_reference_rejected() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(1.0);
        assert!(matches!(
            g.add_op(Op::Add, &[a, 5]),
            Err(GraphError::ForwardReference { .. })
        ));
    }

    #[test]
    fn unary_ops_single_operand() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(2.5);
        let n = g.op(Op::Neg, &[a]);
        let c = g.op(Op::Copy, &[n]);
        let vals = g.evaluate();
        assert_eq!(vals[n as usize], -2.5);
        assert_eq!(vals[c as usize], -2.5);
        assert_eq!(g.node(a).fanout, vec![(n, 0)]);
    }

    #[test]
    fn validate_accepts_builder_output() {
        assert!(diamond().validate().is_ok());
        assert_eq!(DataflowGraph::new().validate(), Err(GraphError::Empty));
    }

    #[test]
    fn dot_export_contains_nodes_and_edges() {
        let dot = diamond().to_dot();
        assert!(dot.contains("n0 -> n2"));
        assert!(dot.contains("ADD"));
    }

    /// Golden fingerprint: pins the hash function itself, so cache keys
    /// are reproducible across runs, platforms and releases. If this
    /// assert fires, the fingerprint algorithm changed and every
    /// persisted cache key is invalidated — bump it knowingly.
    #[test]
    fn fingerprint_golden_value() {
        assert_eq!(diamond().fingerprint(), 0xda70_7bbb_d2f6_ebdc);
        // deterministic: same builder calls, same value
        assert_eq!(diamond().fingerprint(), diamond().fingerprint());
    }

    /// Node-insertion order is part of the executable identity (placement
    /// walks nodes in id order), so the same structural DAG built in a
    /// different order must fingerprint differently.
    #[test]
    fn fingerprint_tracks_insertion_order_and_content() {
        let mut g = DataflowGraph::new();
        let b = g.add_input(4.0);
        let a = g.add_input(3.0);
        let s = g.op(Op::Add, &[b, a]);
        let p = g.op(Op::Mul, &[b, a]);
        g.op(Op::Sub, &[s, p]);
        assert_eq!(g.evaluate()[4], diamond().evaluate()[4], "same math");
        assert_ne!(g.fingerprint(), diamond().fingerprint(), "different layout");
        assert_eq!(g.fingerprint(), 0xc00a_2edc_1bbe_9cfc, "golden (swapped)");
        // a changed input value or opcode changes the fingerprint
        let mut h = DataflowGraph::new();
        let a = h.add_input(3.0);
        let b = h.add_input(4.5);
        let s = h.op(Op::Add, &[a, b]);
        let p = h.op(Op::Mul, &[a, b]);
        h.op(Op::Sub, &[s, p]);
        assert_ne!(h.fingerprint(), diamond().fingerprint());
    }

    #[test]
    #[should_panic(expected = "operand count != op arity")]
    fn wrong_arity_panics() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(1.0);
        let _ = g.add_op(Op::Add, &[a]);
    }
}
