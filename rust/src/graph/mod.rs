//! Dataflow graph IR: the application representation the overlay executes.
//!
//! A graph is a DAG of [`Node`]s. *Input* nodes carry initial token values;
//! interior nodes carry an ALU [`Op`] and one or two operand edges. Fanout
//! adjacency (who consumes my value) is precomputed — in hardware it is the
//! fanout edge list stored in graph memory that the packet-generation unit
//! walks.

mod dataflow;
mod op;
mod ser;

pub use dataflow::{DataflowGraph, GraphError, GraphStats, Node, NodeId, NodeKind};
pub use op::Op;
pub use ser::{graph_from_json, graph_from_json_raw, graph_to_json};
