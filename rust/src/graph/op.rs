//! ALU opcodes — mirrors `python/compile/opcodes.py` (the artifact
//! manifest records the python table; `runtime::manifest` tests assert the
//! two stay in sync).

/// Dataflow ALU operation.
///
/// The paper's PE synthesizes two hardened floating-point DSP blocks (ADD
/// and MULTIPLY mode). Sparse factorization additionally needs SUB and DIV
/// (pivot normalization), obtained from the same blocks; MAX/MIN/NEG/COPY
/// round out the ISA used by the workload generators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Op {
    Add = 0,
    Mul = 1,
    Sub = 2,
    Div = 3,
    Max = 4,
    Min = 5,
    Neg = 6,
    Copy = 7,
}

impl Op {
    pub const ALL: [Op; 8] = [
        Op::Add,
        Op::Mul,
        Op::Sub,
        Op::Div,
        Op::Max,
        Op::Min,
        Op::Neg,
        Op::Copy,
    ];

    /// Opcode encoding shared with the python layer / HLO artifacts.
    #[inline]
    pub fn code(self) -> u32 {
        self as u32
    }

    pub fn from_code(code: u32) -> Option<Op> {
        Op::ALL.get(code as usize).copied()
    }

    /// Sentinel byte marking a graph input (no ALU op) in the compiled
    /// runtime tables' dense opcode array
    /// ([`crate::program::RuntimeTables::op`]). Never a valid [`Op::code8`].
    pub const INPUT_CODE8: u8 = u8::MAX;

    /// Single-byte opcode for the dense runtime tables — same encoding
    /// as [`Op::code`], narrowed to the byte the BRAM image would hold.
    #[inline]
    pub const fn code8(self) -> u8 {
        self as u8
    }

    /// Inverse of [`Op::code8`] ([`Op::INPUT_CODE8`] and any other
    /// non-opcode byte decode to `None`). Delegates to [`Op::from_code`]
    /// so there is exactly one decode table.
    #[inline]
    pub fn from_code8(code: u8) -> Option<Op> {
        Op::from_code(code as u32)
    }

    /// Number of operands the node must receive before it can fire.
    #[inline]
    pub fn arity(self) -> usize {
        match self {
            Op::Neg | Op::Copy => 1,
            _ => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Op::Add => "ADD",
            Op::Mul => "MUL",
            Op::Sub => "SUB",
            Op::Div => "DIV",
            Op::Max => "MAX",
            Op::Min => "MIN",
            Op::Neg => "NEG",
            Op::Copy => "COPY",
        }
    }

    /// Evaluate with f32 semantics — bit-compatible with the Pallas ALU
    /// kernel (`kernels/alu.py`) and the IEEE-754 DSP blocks.
    #[inline]
    pub fn eval(self, a: f32, b: f32) -> f32 {
        match self {
            Op::Add => a + b,
            Op::Mul => a * b,
            Op::Sub => a - b,
            Op::Div => a / b,
            Op::Max => a.max(b),
            Op::Min => a.min(b),
            Op::Neg => -a,
            Op::Copy => a,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_roundtrip() {
        for op in Op::ALL {
            assert_eq!(Op::from_code(op.code()), Some(op));
        }
        assert_eq!(Op::from_code(8), None);
        assert_eq!(Op::from_code(u32::MAX), None);
    }

    #[test]
    fn code8_roundtrip_and_input_sentinel() {
        for op in Op::ALL {
            assert_eq!(op.code8() as u32, op.code(), "same encoding, one byte");
            assert_eq!(Op::from_code8(op.code8()), Some(op));
            assert_ne!(op.code8(), Op::INPUT_CODE8);
        }
        assert_eq!(Op::from_code8(Op::INPUT_CODE8), None);
        assert_eq!(Op::from_code8(8), None);
    }

    #[test]
    fn arity_matches_python_table() {
        // python/compile/opcodes.py: ADD..MIN binary, NEG/COPY unary.
        assert_eq!(Op::Add.arity(), 2);
        assert_eq!(Op::Div.arity(), 2);
        assert_eq!(Op::Neg.arity(), 1);
        assert_eq!(Op::Copy.arity(), 1);
    }

    #[test]
    fn eval_basic() {
        assert_eq!(Op::Add.eval(2.0, 3.0), 5.0);
        assert_eq!(Op::Mul.eval(2.0, 3.0), 6.0);
        assert_eq!(Op::Sub.eval(2.0, 3.0), -1.0);
        assert_eq!(Op::Div.eval(3.0, 2.0), 1.5);
        assert_eq!(Op::Max.eval(2.0, 3.0), 3.0);
        assert_eq!(Op::Min.eval(2.0, 3.0), 2.0);
        assert_eq!(Op::Neg.eval(2.0, 9.0), -2.0);
        assert_eq!(Op::Copy.eval(2.0, 9.0), 2.0);
    }

    #[test]
    fn eval_ieee_edge_cases() {
        assert!(Op::Div.eval(1.0, 0.0).is_infinite());
        assert!(Op::Div.eval(0.0, 0.0).is_nan());
        assert!(Op::Add.eval(f32::NAN, 1.0).is_nan());
        // max/min follow jnp.maximum semantics for signed zero inputs
        assert_eq!(Op::Max.eval(-0.0, 0.0), 0.0);
    }
}
