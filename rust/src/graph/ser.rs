//! Graph (de)serialization — JSON via `util::json`, with post-load
//! validation. Fanout lists are derived state and are rebuilt on load.
//!
//! Format:
//! ```json
//! {"nodes": [ {"in": 1.5},
//!             {"op": "ADD", "src": [0, 1]},
//!             {"op": "NEG", "src": [2]} ]}
//! ```
//!
//! Used by `tdp gen --out g.json` / `tdp run --graph g.json` so workloads
//! can be generated once and replayed across experiments.

use super::{DataflowGraph, Node, NodeKind, Op};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// Serialize a graph to compact JSON.
pub fn graph_to_json(g: &DataflowGraph) -> String {
    let nodes: Vec<Json> = g
        .nodes()
        .iter()
        .map(|n| {
            let mut m = BTreeMap::new();
            match n.kind {
                NodeKind::Input { value } => {
                    m.insert("in".to_string(), Json::Num(value as f64));
                }
                NodeKind::Operation { op, src } => {
                    m.insert("op".to_string(), Json::Str(op.name().to_string()));
                    let srcs = &src[..op.arity()];
                    m.insert(
                        "src".to_string(),
                        Json::Arr(srcs.iter().map(|&s| Json::Num(s as f64)).collect()),
                    );
                }
            }
            Json::Obj(m)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("nodes".to_string(), Json::Arr(nodes));
    json::write(&Json::Obj(root))
}

fn op_by_name(name: &str) -> Option<Op> {
    Op::ALL.into_iter().find(|o| o.name() == name)
}

/// Parse and validate a graph from JSON.
pub fn graph_from_json(s: &str) -> Result<DataflowGraph, String> {
    let doc = json::parse(s).map_err(|e| e.to_string())?;
    let nodes = doc
        .get("nodes")
        .and_then(|n| n.as_arr())
        .ok_or("missing 'nodes' array")?;
    let mut g = DataflowGraph::with_capacity(nodes.len());
    for (i, n) in nodes.iter().enumerate() {
        let obj = n.as_obj().ok_or_else(|| format!("node {i}: not an object"))?;
        if let Some(v) = obj.get("in") {
            let value = v.as_f64().ok_or_else(|| format!("node {i}: bad input value"))? as f32;
            g.add_input(value);
        } else {
            let name = obj
                .get("op")
                .and_then(|o| o.as_str())
                .ok_or_else(|| format!("node {i}: missing op"))?;
            let op = op_by_name(name).ok_or_else(|| format!("node {i}: unknown op {name}"))?;
            let src_json = obj
                .get("src")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| format!("node {i}: missing src"))?;
            let srcs: Vec<u32> = src_json
                .iter()
                .map(|s| s.as_f64().map(|f| f as u32))
                .collect::<Option<Vec<u32>>>()
                .ok_or_else(|| format!("node {i}: bad src ids"))?;
            if srcs.len() != op.arity() {
                return Err(format!(
                    "node {i}: {} expects {} operands, got {}",
                    op.name(),
                    op.arity(),
                    srcs.len()
                ));
            }
            g.add_op(op, &srcs).map_err(|e| format!("node {i}: {e}"))?;
        }
    }
    g.validate().map_err(|e| e.to_string())?;
    Ok(g)
}

/// Parse a graph from JSON *without* structural validation — same
/// format and parse-level checks (op names, arity, value types) as
/// [`graph_from_json`], but forward references, cycles and dangling
/// node ids are loaded as-is instead of rejected. This is the `tdp
/// check` loader: a malformed graph must be *representable* so the
/// verifier pass ([`crate::passes::verify::graph_diagnostics`]) can
/// report every defect with a structured diagnostic, rather than dying
/// on the first one at parse time. Fanout lists are rebuilt for every
/// in-range source id (including forward ones, so cycle edges are
/// visible to the verifier); out-of-range sources simply get no fanout
/// entry and surface as `dangling-operand`.
pub fn graph_from_json_raw(s: &str) -> Result<DataflowGraph, String> {
    let doc = json::parse(s).map_err(|e| e.to_string())?;
    let node_docs = doc
        .get("nodes")
        .and_then(|n| n.as_arr())
        .ok_or("missing 'nodes' array")?;
    let n = node_docs.len();
    let mut nodes: Vec<Node> = Vec::with_capacity(n);
    for (i, nd) in node_docs.iter().enumerate() {
        let obj = nd.as_obj().ok_or_else(|| format!("node {i}: not an object"))?;
        if let Some(v) = obj.get("in") {
            let value = v.as_f64().ok_or_else(|| format!("node {i}: bad input value"))? as f32;
            nodes.push(Node {
                kind: NodeKind::Input { value },
                fanout: Vec::new(),
            });
        } else {
            let name = obj
                .get("op")
                .and_then(|o| o.as_str())
                .ok_or_else(|| format!("node {i}: missing op"))?;
            let op = op_by_name(name).ok_or_else(|| format!("node {i}: unknown op {name}"))?;
            let src_json = obj
                .get("src")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| format!("node {i}: missing src"))?;
            let srcs: Vec<u32> = src_json
                .iter()
                .map(|s| s.as_f64().map(|f| f as u32))
                .collect::<Option<Vec<u32>>>()
                .ok_or_else(|| format!("node {i}: bad src ids"))?;
            if srcs.len() != op.arity() {
                return Err(format!(
                    "node {i}: {} expects {} operands, got {}",
                    op.name(),
                    op.arity(),
                    srcs.len()
                ));
            }
            let src = [srcs[0], *srcs.get(1).unwrap_or(&srcs[0])];
            nodes.push(Node {
                kind: NodeKind::Operation { op, src },
                fanout: Vec::new(),
            });
        }
    }
    // rebuild fanout for every representable edge (second pass, so
    // forward/cyclic sources get their edge too)
    for i in 0..n {
        if let NodeKind::Operation { op, src } = nodes[i].kind {
            for (slot, &s) in src[..op.arity()].iter().enumerate() {
                if (s as usize) < n {
                    nodes[s as usize].fanout.push((i as u32, slot as u8));
                }
            }
        }
    }
    Ok(DataflowGraph::from_raw_nodes(nodes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;

    #[test]
    fn roundtrip() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(1.5);
        let b = g.add_input(-2.0);
        let d = g.op(Op::Div, &[a, b]);
        g.op(Op::Neg, &[d]);
        let json = graph_to_json(&g);
        let g2 = graph_from_json(&json).unwrap();
        assert_eq!(g2.len(), 4);
        assert_eq!(g2.evaluate(), g.evaluate());
        assert_eq!(g2.num_edges(), g.num_edges());
    }

    #[test]
    fn roundtrip_preserves_f32_values() {
        let mut g = DataflowGraph::new();
        g.add_input(0.1); // not exactly representable
        g.add_input(f32::MIN_POSITIVE);
        let g2 = graph_from_json(&graph_to_json(&g)).unwrap();
        assert_eq!(g2.evaluate(), g.evaluate());
    }

    #[test]
    fn raw_loader_represents_malformed_graphs() {
        // forward reference (cycle): rejected by the checked loader,
        // loaded as-is by the raw one — with the cycle edge visible
        let bad = r#"{"nodes":[{"in":1.0},{"op":"ADD","src":[2,0]},{"op":"MUL","src":[1,0]}]}"#;
        assert!(graph_from_json(bad).is_err());
        let g = graph_from_json_raw(bad).unwrap();
        assert_eq!(g.len(), 3);
        assert!(g.node(2).fanout.contains(&(1, 0)), "cycle edge represented");
        // out-of-range source: loaded, no fanout entry
        let dangling = r#"{"nodes":[{"in":1.0},{"op":"NEG","src":[9]}]}"#;
        let g = graph_from_json_raw(dangling).unwrap();
        assert_eq!(g.len(), 2);
        assert!(g.node(0).fanout.is_empty());
        // parse-level defects are still rejected
        assert!(graph_from_json_raw("{not json").is_err());
        assert!(graph_from_json_raw(r#"{"nodes":[{"op":"XOR","src":[0,0]}]}"#).is_err());
        // on a well-formed document the two loaders agree
        let mut good = DataflowGraph::new();
        let a = good.add_input(2.0);
        good.op(Op::Neg, &[a]);
        let json = graph_to_json(&good);
        assert_eq!(
            graph_from_json_raw(&json).unwrap().fingerprint(),
            graph_from_json(&json).unwrap().fingerprint()
        );
    }

    #[test]
    fn corrupt_json_rejected() {
        assert!(graph_from_json("{not json").is_err());
        assert!(graph_from_json("{}").is_err());
        // forward reference
        let bad = r#"{"nodes":[{"op":"ADD","src":[0,1]}]}"#;
        assert!(graph_from_json(bad).is_err());
        // wrong arity
        let bad2 = r#"{"nodes":[{"in":1},{"op":"ADD","src":[0]}]}"#;
        assert!(graph_from_json(bad2).is_err());
        // unknown op
        let bad3 = r#"{"nodes":[{"in":1},{"op":"XOR","src":[0,0]}]}"#;
        assert!(graph_from_json(bad3).is_err());
    }
}
