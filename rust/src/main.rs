//! `tdp` — the overlay coordinator CLI.
//!
//! Subcommands map 1:1 to the paper's experiments (DESIGN.md §4):
//! `sweep` regenerates Figure 1, `resources` Table I, `capacity` the §III
//! claim; `run`/`validate`/`gen`/`noc-stress` are the engineering tools
//! around them.

use anyhow::{anyhow, bail, Result};
use std::path::PathBuf;
use tdp::config::{Overlay, OverlayConfig, WorkloadSpec};
use tdp::coordinator::{
    self, capacity_experiment, fig1_sweep_on, render_csv, render_json, render_markdown, Table,
};
use tdp::engine::BackendKind;
use tdp::graph::{graph_from_json, graph_to_json, DataflowGraph};
use tdp::noc::{Network, Packet};
use tdp::pe::BramConfig;
use tdp::program::{self, Program};
use tdp::resource;
use tdp::runtime::XlaRuntime;
use tdp::sched::SchedulerKind;
use tdp::serve::client as serve_client;
use tdp::serve::{Daemon, ServeConfig};
use tdp::service::{Engine, JobSpec};
use tdp::sim::SimStats;
use tdp::telemetry::{self, Registry};
use tdp::util::cli::Args;
use tdp::util::json::{self, Json};
use tdp::util::rng::Rng;
use tdp::workload;

const USAGE: &str = "\
tdp — out-of-order token dataflow overlay (Siddhartha & Kapre, 2017)

USAGE: tdp <command> [flags]

COMMANDS
  run         simulate one workload          --workload <toml> | --graph <json>
              [--cols 16 --rows 16 --scheduler both|in_order|out_of_order
              --backend lockstep|skip-ahead --max-cycles N --seed 0
              --format text|json --trace-out trace.json --trace-stride 1
              --dump-passes]
              --trace-out writes a Chrome/Perfetto trace-event file:
              compile-stage spans, per-scheduler run spans, and per-cycle
              fabric counters (ready/busy/in-flight/completed) sampled
              every --trace-stride cycles; --dump-passes prints the
              per-pass compile timing/detail table on stderr
  check       lint a workload graph          <spec> | --workload <toml> | --graph <json>
              [--cols 16 --rows 16 --seed 0 --format text|json]
              runs the compile-time verifier (structure lints) plus the
              capacity lints against the chosen overlay geometry, without
              executing anything; --graph uses the *raw* JSON loader so
              broken graphs load far enough to be diagnosed; exit code 1
              iff any error-severity diagnostic fires
  shard       inspect a sharded compile      <spec> [--cols 16 --rows 16 --shards 0
              --run --format text|json]
              partitions the workload across N simulated fabrics
              (--shards 0 sizes N automatically from the BRAM budget,
              like the engine's auto-shard fallback) and reports the
              partition: per-shard members/proxies/fit, boundary
              channels with link counts, cut weight and the epoch
              length; --run also executes the sharded program and
              reports the merged stats plus epoch/stall counters
  batch       serve a job stream             <jobs.jsonl | -> [--workers N (0 = all cores)
              --cache 64 --metrics-out file --connect host:port
              --retries 3 --fault-plan plan.json]
              one JSON job per line in ({\"workload\": \"chain:4096:seed=7\", ...}),
              one JSON result per line out, same order; a job may carry
              \"timeout_ms\": N — past the budget it fails with code
              deadline_exceeded and its partial progress; repeated
              workloads compile once (content-addressed Program cache);
              non-zero exit if any job failed; --metrics-out dumps the
              engine metrics snapshot (cache hits/misses, latency
              percentiles) as JSON; '-' reads the JSONL from stdin
              (shell pipelines); --connect streams the same lines
              through a running 'tdp serve' daemon instead of an
              in-process engine (--workers/--cache/--fault-plan are
              daemon-side knobs then and are rejected here), redialing
              up to --retries times on a lost connection and resubmitting
              only the unanswered lines; --fault-plan arms the in-process
              engine with a deterministic chaos plan (DESIGN.md §15)
  serve       long-lived job daemon          [--listen 127.0.0.1:7411 --workers N (0 = all
              cores) --queue 256 --cache 64 --metrics-out file
              --fault-plan plan.json]
              speaks the batch JobSpec/JobResult JSON as JSONL over TCP
              (seq-tagged responses, pipelining-safe) plus control lines
              {\"control\": \"stats\" | \"ping\" | \"shutdown\"}; one shared
              Engine so compiles amortize across every client; bounded
              admission queue with round-robin per-client fairness
              (queue-full is a structured error, never a disconnect);
              graceful drain on SIGTERM/SIGINT/shutdown finishes all
              admitted jobs before exit; a job that panics is answered
              with code=panicked and the worker survives; queued jobs
              past their timeout_ms are shed with deadline_exceeded
              without occupying a worker; --fault-plan arms the shared
              engine with a deterministic chaos plan (DESIGN.md §15);
              --metrics-out writes the final stats document after the
              drain
  top         live daemon dashboard          <host:port> [--format text|json
              --interval-ms 1000 --iters 0 (0 = forever)]
              polls the stats endpoint into a refreshing terminal view:
              queue depth, per-client in-flight, cache economics
              (hit/miss/eviction), and latency percentiles; --format json
              prints the raw stats documents for scripts/CI
  sweep       regenerate Figure 1            [--cols 16 --rows 16 --seed 42
              --backend lockstep|skip-ahead
              --jobs N (0 = all cores; --threads is a legacy alias)
              --format markdown|csv|json --out file --metrics-out file]
  gen         write a workload graph JSON    --workload <toml> --out <file> [--seed 0]
  validate    check sim numerics vs native + PJRT oracle
              --workload <toml> | --graph <json> [--cols 4 --rows 4
              --backend lockstep|skip-ahead
              --artifacts artifacts --no-pjrt --seed 0]
  resources   regenerate Table I             [--points 16,64 --detail --format ...]
  capacity    regenerate the §III claim      [--pes 256 --edge-per-node 2.0
              --backend lockstep|skip-ahead]
  noc-stress  synthetic NoC traffic          [--cols 16 --rows 16 --packets 100000
              --inject-rate 0.5 --seed 0]
  perf        host-throughput harness        [--quick --reps 5 --budget-ms 0
              --format json|text --out file --trace-out file --dump-passes]
              runs the pinned workload set (compile once, time repeated runs)
              and emits sim cycles/sec + wall ms per run; the JSON is the
              BENCH_*.json perf-trajectory format (perf/README.md).
              --budget-ms N fails (non-zero exit) if total run wall-clock
              exceeds N — CI uses a generous budget as a >2x-regression trap.
              --trace-out writes compile/run spans as a Perfetto trace
              (span-only: per-cycle sampling stays off so skip-ahead
              jumps — the thing being measured — are preserved); the
              output also carries a placement_quality section (baseline
              vs traffic-aware placement: cycles + weighted-hop cost)
              and a sharded section (oversized workload partitioned
              across fabrics: epochs, stalls, compile/run wall), both
              kept out of cases/total_wall_ms so trajectories compare
  analyze     trace a run (queue occupancy / busyness / completion,
              per-PE / per-router activity heatmaps)
              --workload <toml> | --graph <json> [--cols 16 --rows 16
              --stride 0 --csv file --json-out file --seed 0]
  workload-stats  characterize a workload's shape (parallelism, fanout)
              --workload <toml> | --graph <json> [--pes 256 --seed 0]

Workload TOML example: 'kind = \"lu_banded\"\\nn = 100\\nhalf_bw = 4\\nfill = 0.8'
";

fn load_graph(
    workload: Option<String>,
    graph: Option<String>,
    seed: u64,
) -> Result<DataflowGraph> {
    match (workload, graph) {
        (Some(spec), None) => {
            let spec =
                WorkloadSpec::from_toml(&spec.replace("\\n", "\n")).map_err(|e| anyhow!(e))?;
            spec.build(seed).map_err(|e| anyhow!("workload build: {e}"))
        }
        (None, Some(path)) => {
            let text = std::fs::read_to_string(&path)?;
            graph_from_json(&text).map_err(|e| anyhow!("graph load: {e}"))
        }
        _ => bail!("provide exactly one of --workload / --graph"),
    }
}

/// Parse the `--backend` flag shared by run/sweep/validate/capacity.
fn backend_flag(a: &mut Args) -> Result<BackendKind> {
    a.str_or("backend", "lockstep")?
        .parse()
        .map_err(|e: String| anyhow!(e))
}

fn emit(t: &Table, format: &str, out: Option<String>) -> Result<()> {
    let text = match format {
        "markdown" | "md" => render_markdown(t),
        "csv" => render_csv(t),
        "json" => render_json(t),
        other => bail!("unknown format '{other}' (markdown | csv | json)"),
    };
    print!("{text}");
    if let Some(path) = out {
        std::fs::write(&path, &text)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_run(mut a: Args) -> Result<()> {
    let workload = a.str_opt("workload")?;
    let graph = a.str_opt("graph")?;
    let cols = a.usize_or("cols", 16)?;
    let rows = a.usize_or("rows", 16)?;
    let sched = a.str_or("scheduler", "both")?;
    let backend = backend_flag(&mut a)?;
    let max_cycles = a.u64_or("max-cycles", 0)?; // 0 = config default
    let seed = a.u64_or("seed", 0)?;
    let format = a.str_or("format", "text")?;
    let trace_out = a.str_opt("trace-out")?;
    let trace_stride = a.u64_or("trace-stride", 1)?.max(1);
    let dump_passes = a.switch("dump-passes");
    let json_out = match format.as_str() {
        "text" => false,
        "json" => true,
        other => bail!("unknown format '{other}' (text | json)"),
    };
    a.finish()?;
    let g = load_graph(workload, graph, seed)?;
    let s = g.stats();
    if !json_out {
        println!(
            "graph: {} nodes, {} edges, depth {}, max fanout {} (backend: {})",
            s.nodes,
            s.edges,
            s.depth,
            s.max_fanout,
            backend.name()
        );
    }
    let mut cfg = OverlayConfig::default().with_dims(cols, rows).with_backend(backend);
    if max_cycles > 0 {
        cfg.max_cycles = max_cycles;
    }
    // compile once; every scheduler variant is a cheap session over it.
    // With --trace-out a Registry observes the compile stages and each
    // run executes over a per-cycle Trace; everything lands in one
    // Chrome/Perfetto trace-event file.
    let registry = trace_out.as_ref().map(|_| Registry::new());
    let overlay = Overlay::from_config(cfg)?;
    let program = match &registry {
        Some(reg) => Program::compile_with(&g, &overlay, Some(reg))?,
        None => Program::compile(&g, &overlay)?,
    };
    if dump_passes {
        print_pass_table(&program);
    }
    let mut counter_series: Vec<telemetry::CounterSeries> = Vec::new();
    let mut run_kind = |kind: SchedulerKind| -> Result<SimStats> {
        let session = program.session().with_scheduler(kind);
        let Some(reg) = &registry else {
            return Ok(session.run()?);
        };
        let mut backend = {
            let _setup = reg.span("run", "setup");
            session.backend()?
        };
        backend.enable_trace(trace_stride);
        let stats = {
            let _run = reg.span("run", kind.name());
            backend.run()?
        };
        let trace = backend
            .trace()
            .ok_or_else(|| anyhow!("trace buffer missing after enable_trace"))?;
        counter_series.extend(telemetry::trace_counter_series(kind.toml_name(), trace));
        Ok(stats)
    };
    if sched == "both" {
        let stats_in = run_kind(SchedulerKind::InOrder)?;
        let stats_ooo = run_kind(SchedulerKind::OutOfOrder)?;
        let speedup = stats_in.cycles as f64 / stats_ooo.cycles as f64;
        if json_out {
            let mut m = std::collections::BTreeMap::new();
            m.insert("in_order".to_string(), stats_in.to_json_value());
            m.insert("out_of_order".to_string(), stats_ooo.to_json_value());
            m.insert("speedup".to_string(), Json::Num(speedup));
            println!("{}", json::write(&Json::Obj(m)));
        } else {
            for stats in [&stats_in, &stats_ooo] {
                println!(
                    "{:>12}: {} cycles, util {:.1}%, {} deflections",
                    stats.scheduler.name(),
                    stats.cycles,
                    100.0 * stats.avg_pe_utilization,
                    stats.net.deflections
                );
            }
            println!("speedup (in-order / out-of-order): {speedup:.3}");
        }
    } else {
        let kind: SchedulerKind = sched.parse().map_err(|e: String| anyhow!(e))?;
        let stats = run_kind(kind)?;
        if json_out {
            println!("{}", stats.to_json());
        } else {
            println!("{}", stats.one_line());
        }
    }
    if let (Some(reg), Some(path)) = (&registry, &trace_out) {
        std::fs::write(path, telemetry::perfetto_json(reg, &counter_series))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// `--dump-passes` — the per-pass compile table, on stderr so it
/// composes with `--format json` on stdout.
fn print_pass_table(program: &Program) {
    eprintln!("compile passes:");
    for s in program.pass_stats() {
        eprintln!("  {:<18} {:>8} us  {}", s.name, s.micros, s.detail);
    }
}

/// `tdp check` — the compile front-end lints without executing
/// anything: graph verification (structure), then — only when the graph
/// is structurally sound — the capacity lints against the requested
/// overlay geometry. Exit code 1 iff any error-severity diagnostic
/// fires, so CI can gate a workload corpus on a clean report.
fn cmd_check(mut argv: Vec<String>) -> Result<()> {
    use tdp::passes::verify;
    use tdp::place::Placement;
    use tdp::Severity;
    let positional = if argv.first().is_some_and(|s| !s.starts_with("--")) {
        Some(argv.remove(0))
    } else {
        None
    };
    let mut a = Args::parse(argv).map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;
    let workload = a.str_opt("workload")?;
    let graph = a.str_opt("graph")?;
    let cols = a.usize_or("cols", 16)?;
    let rows = a.usize_or("rows", 16)?;
    let seed = a.u64_or("seed", 0)?;
    let format = a.str_or("format", "text")?;
    let json_out = match format.as_str() {
        "text" => false,
        "json" => true,
        other => bail!("unknown format '{other}' (text | json)"),
    };
    a.finish()?;
    // Unlike every other subcommand, `--graph` goes through the *raw*
    // JSON loader: the whole point of check is to report on broken
    // graphs, which the strict loader would reject before we could.
    let (label, g) = match (positional, workload, graph) {
        (Some(spec), None, None) => {
            let s: workload::Spec = spec.parse().map_err(|e: String| anyhow!(e))?;
            let g = s.build().map_err(|e| anyhow!("workload build: {e}"))?;
            (s.canonical(), g)
        }
        (None, Some(spec), None) => {
            let parsed =
                WorkloadSpec::from_toml(&spec.replace("\\n", "\n")).map_err(|e| anyhow!(e))?;
            let g = parsed.build(seed).map_err(|e| anyhow!("workload build: {e}"))?;
            (spec, g)
        }
        (None, None, Some(path)) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow!("cannot read graph '{path}': {e}"))?;
            let g = tdp::graph::graph_from_json_raw(&text).map_err(|e| anyhow!("graph load: {e}"))?;
            (path, g)
        }
        _ => bail!("provide exactly one of <spec> / --workload / --graph"),
    };
    let mut diags = verify::graph_diagnostics(&g);
    let structurally_sound = diags.iter().all(|d| d.severity != Severity::Error);
    if structurally_sound {
        // capacity lints need a placement; build one under the default
        // policy on the requested geometry (criticality only steers the
        // traffic-aware policy, which check does not exercise)
        let cfg = OverlayConfig::default().with_dims(cols, rows);
        Overlay::from_config(cfg)?;
        let place = Placement::build_for_torus(
            &g,
            cols,
            rows,
            cfg.placement,
            cfg.local_order,
            cfg.seed,
            None,
        );
        diags.extend(verify::capacity_diagnostics(&g, &place, &cfg));
    }
    let errors = diags.iter().filter(|d| d.severity == Severity::Error).count();
    let warnings = diags.len() - errors;
    let s = g.stats();
    if json_out {
        let list: Vec<Json> = diags
            .iter()
            .map(|d| {
                let mut dm = std::collections::BTreeMap::new();
                dm.insert("severity".to_string(), Json::Str(d.severity.name().to_string()));
                dm.insert("code".to_string(), Json::Str(d.code.to_string()));
                dm.insert(
                    "node".to_string(),
                    match d.node {
                        Some(n) => Json::Num(f64::from(n)),
                        None => Json::Null,
                    },
                );
                dm.insert("message".to_string(), Json::Str(d.message.clone()));
                Json::Obj(dm)
            })
            .collect();
        let mut m = std::collections::BTreeMap::new();
        m.insert("workload".to_string(), Json::Str(label));
        m.insert("nodes".to_string(), Json::Num(s.nodes as f64));
        m.insert("edges".to_string(), Json::Num(s.edges as f64));
        m.insert("errors".to_string(), Json::Num(errors as f64));
        m.insert("warnings".to_string(), Json::Num(warnings as f64));
        m.insert("diagnostics".to_string(), Json::Arr(list));
        println!("{}", json::write(&Json::Obj(m)));
    } else {
        for d in &diags {
            println!("{d}");
        }
        println!(
            "check: {label}: {} nodes, {} edges — {errors} error(s), {warnings} warning(s)",
            s.nodes, s.edges
        );
    }
    if errors > 0 {
        // stdout is line-buffered; every line above ended in '\n'
        std::process::exit(1);
    }
    Ok(())
}

/// `tdp shard` — inspect the partition a sharded compile produces
/// without going through the engine: per-shard member/proxy counts and
/// fit verdicts, the boundary-channel table, cut cost and epoch length.
/// `--shards 0` (the default) sizes the shard count exactly like the
/// engine's auto-shard fallback (`Program::min_shards` at the
/// out-of-order budget); `--run` also executes the sharded program and
/// reports the merged stats.
fn cmd_shard(mut argv: Vec<String>) -> Result<()> {
    use std::sync::Arc;
    use tdp::program::SharedProgram;
    use tdp::ShardedProgram;
    let positional = if argv.first().is_some_and(|s| !s.starts_with("--")) {
        Some(argv.remove(0))
    } else {
        None
    };
    let mut a = Args::parse(argv).map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;
    let cols = a.usize_or("cols", 16)?;
    let rows = a.usize_or("rows", 16)?;
    let shards = a.usize_or("shards", 0)?;
    let run = a.switch("run");
    let format = a.str_or("format", "text")?;
    let json_out = match format.as_str() {
        "text" => false,
        "json" => true,
        other => bail!("unknown format '{other}' (text | json)"),
    };
    a.finish()?;
    let spec: workload::Spec = positional
        .ok_or_else(|| anyhow!("usage: tdp shard <spec> [flags]\n\n{USAGE}"))?
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let graph = Arc::new(spec.build().map_err(|e| anyhow!("workload build: {e}"))?);
    let cfg = OverlayConfig::default().with_dims(cols, rows);
    let overlay = Overlay::from_config(cfg)?;
    let n = if shards >= 1 {
        shards
    } else {
        let single = SharedProgram::compile(Arc::clone(&graph), &overlay)?;
        single.program().min_shards(cfg.scheduler)
    };
    let sharded = ShardedProgram::compile(graph, &overlay, n)?;
    let part = sharded.partition();
    let outcome = if run { Some(sharded.session().run()?) } else { None };
    if json_out {
        let num = |v: usize| Json::Num(v as f64);
        let units: Vec<Json> = sharded
            .units()
            .iter()
            .map(|u| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("members".to_string(), num(u.members()));
                m.insert("proxies".to_string(), num(u.proxies()));
                m.insert(
                    "fits".to_string(),
                    Json::Bool(u.program.program().fits(cfg.scheduler)),
                );
                Json::Obj(m)
            })
            .collect();
        let channels: Vec<Json> = sharded
            .channels()
            .iter()
            .map(|c| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("src".to_string(), num(c.src_shard as usize));
                m.insert("dst".to_string(), num(c.dst_shard as usize));
                m.insert("links".to_string(), num(c.links.len()));
                Json::Obj(m)
            })
            .collect();
        let mut m = std::collections::BTreeMap::new();
        m.insert("workload".to_string(), Json::Str(spec.canonical()));
        m.insert("nodes".to_string(), num(sharded.graph().len()));
        m.insert("num_shards".to_string(), num(sharded.num_shards()));
        m.insert("epoch".to_string(), Json::Num(sharded.epoch() as f64));
        m.insert("cut_edges".to_string(), num(part.cut_edges.len()));
        m.insert("cut_weight".to_string(), Json::Num(part.cut_weight as f64));
        m.insert("boundary_values".to_string(), num(sharded.boundary_values()));
        m.insert("shards".to_string(), Json::Arr(units));
        m.insert("channels".to_string(), Json::Arr(channels));
        if let Some(r) = &outcome {
            let mut rm = std::collections::BTreeMap::new();
            rm.insert("stats".to_string(), r.stats.to_json_value());
            rm.insert("epochs".to_string(), Json::Num(r.epochs as f64));
            rm.insert(
                "boundary_stalls".to_string(),
                Json::Num(r.boundary_stalls as f64),
            );
            m.insert("run".to_string(), Json::Obj(rm));
        }
        println!("{}", json::write(&Json::Obj(m)));
    } else {
        println!(
            "shard: {}: {} nodes -> {} shard(s) of {cols}x{rows} (epoch {} cycles)",
            spec.canonical(),
            sharded.graph().len(),
            sharded.num_shards(),
            sharded.epoch()
        );
        for (i, u) in sharded.units().iter().enumerate() {
            println!(
                "  shard {i}: {} members + {} proxies, fits {}: {}",
                u.members(),
                u.proxies(),
                cfg.scheduler.name(),
                if u.program.program().fits(cfg.scheduler) { "yes" } else { "NO" }
            );
        }
        println!(
            "  cut: {} edges, weight {}, {} boundary values over {} channel(s)",
            part.cut_edges.len(),
            part.cut_weight,
            sharded.boundary_values(),
            sharded.channels().len()
        );
        for c in sharded.channels() {
            println!("  channel {}->{}: {} links", c.src_shard, c.dst_shard, c.links.len());
        }
        if let Some(r) = &outcome {
            println!(
                "  run: {} cycles over {} epochs, {} boundary stalls",
                r.stats.cycles, r.epochs, r.boundary_stalls
            );
            println!("  {}", r.stats.one_line());
        }
    }
    Ok(())
}

/// `tdp batch <jobs.jsonl | ->` — the service entry point: one JSON job
/// per input line (from a file, or stdin with `-`), one JSON result per
/// output line (same order), all jobs executed over one [`Engine`] so
/// repeated workloads compile exactly once. A malformed line or failed
/// job becomes a `{"line": N, "error": ...}` output line and a non-zero
/// exit at the end; the other jobs still run. Cache counters go to
/// stderr. With `--connect` the same lines stream through a running
/// `tdp serve` daemon instead of an in-process engine.
fn cmd_batch(mut argv: Vec<String>) -> Result<()> {
    let positional = if argv.first().is_some_and(|s| !s.starts_with("--")) {
        Some(argv.remove(0))
    } else {
        None
    };
    let mut a = Args::parse(argv).map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;
    let path = match positional {
        Some(p) => p,
        None => a.str_req("file")?,
    };
    let connect = a.str_opt("connect")?;
    let metrics_out = a.str_opt("metrics-out")?;
    let text = if path == "-" {
        use std::io::Read;
        let mut buf = String::new();
        std::io::stdin()
            .read_to_string(&mut buf)
            .map_err(|e| anyhow!("cannot read jobs from stdin: {e}"))?;
        buf
    } else {
        std::fs::read_to_string(&path)
            .map_err(|e| anyhow!("cannot read job file '{path}': {e}"))?
    };
    if let Some(addr) = connect {
        // --workers/--cache/--fault-plan size and arm the daemon, not
        // this client: finish() rejects them here so they fail loudly
        // instead of silently doing nothing
        let retries = a.usize_or("retries", 3)?;
        a.finish()?;
        return batch_over_socket(&addr, &text, metrics_out, retries);
    }
    let mut workers = a.usize_or("workers", 0)?;
    let cache = a.usize_or("cache", tdp::service::DEFAULT_CACHE_CAPACITY)?;
    let fault_plan = load_fault_plan(&mut a)?;
    a.finish()?;
    if workers == 0 {
        workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    }
    // parse every line up front: line numbers are part of the protocol
    let parsed: Vec<(usize, Result<JobSpec, String>)> = text
        .lines()
        .enumerate()
        .filter(|(_, line)| !line.trim().is_empty())
        .map(|(i, line)| (i + 1, JobSpec::from_json(line)))
        .collect();
    let engine = Engine::with_capacity_and_faults(cache, fault_plan);
    let jobs: Vec<JobSpec> = parsed
        .iter()
        .filter_map(|(_, j)| j.as_ref().ok())
        .cloned()
        .collect();
    let mut outcomes = engine.submit_batch(&jobs, workers).into_iter();
    let mut failed = 0usize;
    for (line_no, job) in &parsed {
        let outcome = match job {
            Ok(_) => outcomes.next().expect("one outcome per parsed job"),
            Err(msg) => Err(tdp::Error::Spec(msg.clone())),
        };
        match outcome {
            Ok(result) => println!("{}", result.to_json()),
            Err(e) => {
                failed += 1;
                let mut m = std::collections::BTreeMap::new();
                m.insert("line".to_string(), Json::Num(*line_no as f64));
                m.insert("error".to_string(), Json::Str(e.to_string()));
                println!("{}", json::write(&Json::Obj(m)));
            }
        }
    }
    let s = engine.cache_stats();
    eprintln!(
        "batch: jobs={} ok={} failed={failed} cache_hits={} cache_misses={} compiles={}",
        parsed.len(),
        parsed.len() - failed,
        s.hits,
        s.misses,
        program::compile_count()
    );
    // metrics land on disk even when the batch had failures: the
    // snapshot (which counts those failures) is most useful exactly then
    if let Some(path) = &metrics_out {
        std::fs::write(path, engine.metrics_snapshot_json())?;
        eprintln!("wrote {path}");
    }
    if failed > 0 {
        bail!("{failed} of {} jobs failed", parsed.len());
    }
    Ok(())
}

/// Parse the shared `--fault-plan <file>` flag into the deterministic
/// chaos plan (DESIGN.md §15) the engine is armed with.
fn load_fault_plan(a: &mut Args) -> Result<Option<std::sync::Arc<tdp::FaultPlan>>> {
    match a.str_opt("fault-plan")? {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow!("cannot read fault plan '{path}': {e}"))?;
            let plan = tdp::FaultPlan::parse(&text).map_err(|e| anyhow!("'{path}': {e}"))?;
            Ok(Some(std::sync::Arc::new(plan)))
        }
        None => Ok(None),
    }
}

/// `tdp batch --connect` — stream the same JSONL through a running
/// `tdp serve` daemon. Output keeps the in-process contract: one line
/// per input line, in input order (`result` objects verbatim, failures
/// as `{"line": N, "code": ..., "error": ...}`), non-zero exit if any
/// job failed. The parsing happens daemon-side; this end only tags
/// lines and reassembles seq-ordered responses, redialing up to
/// `--retries` times on a lost connection (answered jobs are never
/// re-run; resubmits are idempotent via the daemon's Program cache).
fn batch_over_socket(
    addr: &str,
    text: &str,
    metrics_out: Option<String>,
    retries: usize,
) -> Result<()> {
    let lines: Vec<(usize, String)> = text
        .lines()
        .enumerate()
        .filter(|(_, line)| !line.trim().is_empty())
        .map(|(i, line)| (i + 1, line.to_string()))
        .collect();
    let requests: Vec<String> = lines.iter().map(|(_, l)| l.clone()).collect();
    let responses = serve_client::submit_raw_lines_with_retry(addr, &requests, retries)
        .map_err(|e| anyhow!("daemon at {addr}: {e}"))?;
    let mut failed = 0usize;
    for ((line_no, _), response) in lines.iter().zip(&responses) {
        match response.get("result") {
            Some(result) => println!("{}", json::write(result)),
            None => {
                failed += 1;
                let mut m = std::collections::BTreeMap::new();
                m.insert("line".to_string(), Json::Num(*line_no as f64));
                if let Some(code) = response.get("code") {
                    m.insert("code".to_string(), code.clone());
                }
                let err = response
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap_or("daemon returned neither result nor error");
                m.insert("error".to_string(), Json::Str(err.to_string()));
                println!("{}", json::write(&Json::Obj(m)));
            }
        }
    }
    eprintln!(
        "batch: jobs={} ok={} failed={failed} via {addr}",
        lines.len(),
        lines.len() - failed
    );
    // --metrics-out in connect mode captures the *daemon's* stats
    // document — the engine counters live there, not in this process
    if let Some(path) = &metrics_out {
        let stats = serve_client::fetch_stats(addr).map_err(|e| anyhow!("stats from {addr}: {e}"))?;
        std::fs::write(path, json::write(&stats))?;
        eprintln!("wrote {path}");
    }
    if failed > 0 {
        bail!("{failed} of {} jobs failed", lines.len());
    }
    Ok(())
}

/// `tdp serve` — the long-lived daemon over one shared [`Engine`]
/// (DESIGN.md §13). Blocks until drained (SIGTERM/SIGINT or a
/// `shutdown` control line), finishing every admitted job first.
fn cmd_serve(mut a: Args) -> Result<()> {
    use std::sync::atomic::Ordering;
    let listen = a.str_or("listen", "127.0.0.1:7411")?;
    let fault_plan = load_fault_plan(&mut a)?;
    let cfg = ServeConfig {
        workers: a.usize_or("workers", 0)?,
        queue_capacity: a.usize_or("queue", tdp::serve::DEFAULT_QUEUE_CAPACITY)?,
        cache_capacity: a.usize_or("cache", tdp::service::DEFAULT_CACHE_CAPACITY)?,
        fault_plan,
    };
    let cache_capacity = cfg.cache_capacity;
    let faults_armed = cfg.fault_plan.is_some();
    let metrics_out = a.str_opt("metrics-out")?;
    a.finish()?;
    let registry = std::sync::Arc::new(Registry::new());
    let daemon = Daemon::bind(listen.as_str(), cfg, std::sync::Arc::clone(&registry))
        .map_err(|e| anyhow!("cannot listen on {listen}: {e}"))?;
    let handle = daemon.handle();
    let stats = handle.stats_json();
    let d = |k: &str| {
        stats.get("daemon").and_then(|d| d.get(k)).and_then(Json::as_u64).unwrap_or(0)
    };
    // the banner is the port-discovery contract for --listen :0 (tests,
    // scripts): stderr, one line, "listening on <resolved addr>"
    eprintln!(
        "tdp serve: listening on {} (workers={}, queue={}, cache={}{})",
        daemon.local_addr(),
        d("workers"),
        d("queue_capacity"),
        cache_capacity,
        if faults_armed { ", fault plan ARMED" } else { "" },
    );
    // SIGTERM/SIGINT → the same drain path as a shutdown control line
    let flag = tdp::serve::signal::install_shutdown_flag();
    let sig_handle = handle.clone();
    std::thread::spawn(move || loop {
        if flag.load(Ordering::SeqCst) {
            eprintln!("tdp serve: signal received, draining");
            sig_handle.drain();
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    });
    daemon.run()?;
    let stats = handle.stats_json();
    let d = |k: &str| {
        stats.get("daemon").and_then(|d| d.get(k)).and_then(Json::as_u64).unwrap_or(0)
    };
    eprintln!(
        "tdp serve: drained (completed={} failed={} rejected={})",
        d("completed"),
        d("failed"),
        d("rejected"),
    );
    if let Some(path) = &metrics_out {
        std::fs::write(path, json::write(&stats))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// `tdp top <host:port>` — poll the daemon's stats endpoint into a
/// refreshing terminal frame (or raw JSON documents for scripts).
fn cmd_top(mut argv: Vec<String>) -> Result<()> {
    let addr = if argv.first().is_some_and(|s| !s.starts_with("--")) {
        argv.remove(0)
    } else {
        bail!("usage: tdp top <host:port> [--format text|json --interval-ms 1000 --iters 0]");
    };
    let mut a = Args::parse(argv).map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;
    let format = a.str_or("format", "text")?;
    let interval_ms = a.u64_or("interval-ms", 1000)?.max(1);
    let iters = a.u64_or("iters", 0)?; // 0 = until the daemon goes away
    a.finish()?;
    if format != "text" && format != "json" {
        bail!("unknown format '{format}' (text | json)");
    }
    let mut done = 0u64;
    loop {
        let stats = match serve_client::fetch_stats(&addr) {
            Ok(s) => s,
            // first poll failing is an error (wrong address); later ones
            // mean the daemon drained away under us — exit clean
            Err(e) if done == 0 => bail!("no daemon at {addr}: {e}"),
            Err(_) => {
                eprintln!("tdp top: daemon at {addr} is gone");
                return Ok(());
            }
        };
        if format == "json" {
            println!("{}", json::write(&stats));
        } else {
            // clear + home between frames; single-shot output stays
            // pipe-friendly
            if iters != 1 {
                print!("\x1b[2J\x1b[H");
            }
            print!("{}", serve_client::render_top(&addr, &stats));
            use std::io::Write;
            std::io::stdout().flush()?;
        }
        done += 1;
        if iters > 0 && done >= iters {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(interval_ms));
    }
}

fn cmd_sweep(mut a: Args) -> Result<()> {
    let cols = a.usize_or("cols", 16)?;
    let rows = a.usize_or("rows", 16)?;
    let seed = a.u64_or("seed", 42)?;
    let backend = backend_flag(&mut a)?;
    let mut jobs = a.usize_or("jobs", 0)?;
    let threads_legacy = a.usize_or("threads", 0)?; // pre---jobs spelling
    let format = a.str_or("format", "markdown")?;
    let out = a.str_opt("out")?;
    let metrics_out = a.str_opt("metrics-out")?;
    a.finish()?;
    if jobs == 0 {
        jobs = threads_legacy;
    }
    if jobs == 0 {
        jobs = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    }
    let cfg = coordinator::fig1_config().with_dims(cols, rows).with_backend(backend);
    Overlay::from_config(cfg)?; // fail fast, before generating workloads
    let ws = workload::fig1_specs(seed);
    eprintln!(
        "running {} workloads x 2 schedulers on {jobs} jobs ({} backend, \
         each workload compiled once via the service cache)...",
        ws.len(),
        backend.name()
    );
    let engine = Engine::new();
    let rows_out = fig1_sweep_on(&engine, &ws, cfg, jobs)?;
    if let Some(path) = &metrics_out {
        std::fs::write(path, engine.metrics_snapshot_json())?;
        eprintln!("wrote {path}");
    }
    let mut t = Table::new(
        &format!("Figure 1 — OoO speedup vs graph size ({cols}x{rows} overlay)"),
        &["workload", "nodes+edges", "depth", "in-order cyc", "ooo cyc", "speedup"],
    );
    for r in &rows_out {
        t.push(vec![
            r.label.clone(),
            r.nodes_plus_edges.to_string(),
            r.depth.to_string(),
            r.cycles_inorder.to_string(),
            r.cycles_ooo.to_string(),
            format!("{:.3}", r.speedup),
        ]);
    }
    emit(&t, &format, out)
}

fn cmd_gen(mut a: Args) -> Result<()> {
    let workload = a.str_req("workload")?;
    let out = a.str_req("out")?;
    let seed = a.u64_or("seed", 0)?;
    a.finish()?;
    let g = load_graph(Some(workload), None, seed)?;
    std::fs::write(&out, graph_to_json(&g))?;
    let s = g.stats();
    println!(
        "wrote {out} ({} nodes, {} edges, depth {})",
        s.nodes, s.edges, s.depth
    );
    Ok(())
}

fn cmd_validate(mut a: Args) -> Result<()> {
    let workload = a.str_opt("workload")?;
    let graph = a.str_opt("graph")?;
    let cols = a.usize_or("cols", 4)?;
    let rows = a.usize_or("rows", 4)?;
    let artifacts = a.str_or("artifacts", "artifacts")?;
    let no_pjrt = a.switch("no-pjrt");
    let backend = backend_flag(&mut a)?;
    let seed = a.u64_or("seed", 0)?;
    a.finish()?;
    let g = load_graph(workload, graph, seed)?;
    let cfg = OverlayConfig::default().with_dims(cols, rows).with_backend(backend);
    Overlay::from_config(cfg)?;
    let rt = if no_pjrt {
        None
    } else {
        // degrade to native-only validation when the oracle is absent
        // (no artifacts on disk, or a stub build without the xla feature)
        match XlaRuntime::load(&PathBuf::from(artifacts)) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("WARNING: PJRT oracle unavailable ({e}); validating against the native reference only.");
                None
            }
        }
    };
    if let Some(rt) = &rt {
        rt.manifest.check_opcode_table()?;
        println!("PJRT platform: {}", rt.platform());
    }
    let rep = coordinator::validate(&g, cfg, rt.as_ref()).map_err(|e| anyhow!("{e}"))?;
    println!("{}", rep.stats.one_line());
    println!(
        "native-ref max |err| = {} over {} nodes",
        rep.max_abs_err_native, rep.nodes_checked
    );
    match rep.max_abs_err_pjrt {
        Some(e) => println!("PJRT-oracle max |err| = {e}"),
        None => println!("PJRT oracle skipped (graph exceeds artifact geometry or --no-pjrt)"),
    }
    if rep.passed() {
        println!("VALIDATION PASSED");
        Ok(())
    } else {
        bail!("validation failed")
    }
}

fn cmd_resources(mut a: Args) -> Result<()> {
    let points = a.usize_list("points")?;
    let detail = a.switch("detail");
    let format = a.str_or("format", "markdown")?;
    a.finish()?;
    let rows = resource::table1(&points);
    let mut t = Table::new(
        "Table I — resource utilization (Arria 10 10AX115S)",
        &["PEs", "ALMs", "REGs", "DSPs", "BRAMs", "Fmax (MHz)"],
    );
    for r in &rows {
        t.push(vec![
            r.pes.to_string(),
            format!("{} ({:.1}%)", r.alms, r.alm_pct),
            format!("{} ({:.1}%)", r.regs, r.reg_pct),
            format!("{} ({:.1}%)", r.dsps, r.dsp_pct),
            format!("{} ({:.1}%)", r.brams, r.bram_pct),
            format!("{:.0}", r.fmax_mhz),
        ]);
    }
    emit(&t, &format, None)?;
    if detail {
        let b = BramConfig::paper();
        println!("\nBRAM budget per PE (words of 512x40b M20K):");
        println!("  total: {}", b.total_words());
        println!(
            "  OoO flag overhead: {} ({:.2}% — paper: ~6%)",
            b.flag_words(),
            100.0 * b.flag_words() as f64 / b.total_words() as f64
        );
        println!("  in-order FIFO reserve: {}", b.fifo_words());
        println!(
            "  graph words: in-order {}, OoO {}",
            b.graph_words(SchedulerKind::InOrder),
            b.graph_words(SchedulerKind::OutOfOrder)
        );
        println!(
            "  max overlay on device: {} PEs",
            resource::max_overlay(&resource::ARRIA10_10AX115S, 1.0)
        );
    }
    Ok(())
}

/// Squarest (cols, rows) factorization of `pes` that fits the 5 b torus
/// coordinates, if any.
fn torus_dims(pes: usize) -> Option<(usize, usize)> {
    if pes == 0 {
        return None;
    }
    let mut best: Option<(usize, usize)> = None;
    let mut best_score = usize::MAX;
    for rows in 1..=32usize {
        if pes % rows != 0 {
            continue;
        }
        let cols = pes / rows;
        if cols > 32 {
            continue;
        }
        let score = cols.abs_diff(rows);
        if score < best_score {
            best_score = score;
            best = Some((cols, rows));
        }
    }
    best
}

fn cmd_capacity(mut a: Args) -> Result<()> {
    let pes = a.usize_or("pes", 256)?;
    let edge_per_node = a.f64_or("edge-per-node", 2.0)?;
    let backend = backend_flag(&mut a)?;
    a.finish()?;
    let row = capacity_experiment(&BramConfig::paper(), pes, edge_per_node);
    println!(
        "{} PEs, edge/node = {edge_per_node}: in-order ≈{} items, OoO ≈{} items, ratio {:.2}x",
        row.num_pes, row.max_items_inorder, row.max_items_ooo, row.ratio
    );
    println!("paper §III: ≈100K items vs ≈5x at 256 PEs");
    // empirical probe: place a small LU workload with capacity
    // enforcement on and run it on the selected engine backend
    match torus_dims(pes) {
        Some((cols, rows)) => {
            let m = workload::SparseMatrix::banded(120, 4, 0.9, 1);
            let (g, _) = workload::lu_factorization_graph(&m);
            let overlay = Overlay::builder()
                .dims(cols, rows)
                .backend(backend)
                .enforce_capacity(true)
                .build()?;
            // compile once; the capacity check *is* the compile phase
            match Program::compile(&g, &overlay) {
                Ok(program) => match program.session().run() {
                    Ok(stats) => println!(
                        "probe: lu_banded(n=120) placed under enforcement on {cols}x{rows}, \
                         {} backend: {} cycles",
                        backend.name(),
                        stats.cycles
                    ),
                    Err(e) => println!("probe: lu_banded(n=120) on {cols}x{rows}: {e}"),
                },
                Err(e) => println!("probe: lu_banded(n=120) on {cols}x{rows}: {e}"),
            }
        }
        None => println!("probe skipped: {pes} PEs has no torus factorization within 32x32"),
    }
    Ok(())
}

fn cmd_noc_stress(mut a: Args) -> Result<()> {
    let cols = a.usize_or("cols", 16)?;
    let rows = a.usize_or("rows", 16)?;
    let packets = a.usize_or("packets", 100_000)?;
    let inject_rate = a.f64_or("inject-rate", 0.5)?;
    let seed = a.u64_or("seed", 0)?;
    a.finish()?;
    let mut rng = Rng::seed_from_u64(seed);
    let n = cols * rows;
    let mut net = Network::new(cols, rows);
    let mut sent = 0usize;
    let mut cycles = 0u64;
    while net.stats.delivered < packets as u64 {
        let mut inject: Vec<Option<Packet>> = vec![None; n];
        for (pe, slot) in inject.iter_mut().enumerate() {
            if sent < packets && rng.gen_bool(inject_rate) {
                let dest = rng.gen_range(n);
                *slot = Some(Packet {
                    dest_x: (dest % cols) as u8,
                    dest_y: (dest / cols) as u8,
                    local_idx: (pe % 8192) as u16,
                    slot: 0,
                    payload: pe as f32,
                });
            }
        }
        let granted = net.step(&inject).inject_ok.iter().filter(|&&g| g).count();
        sent += granted;
        cycles += 1;
        if cycles > 100_000_000 {
            bail!("NoC stress did not converge");
        }
    }
    let s = net.stats;
    println!(
        "{cols}x{rows} torus: {} pkts in {cycles} cycles = {:.3} pkts/cycle ({:.4}/PE)",
        s.delivered,
        s.delivered as f64 / cycles as f64,
        s.delivered as f64 / cycles as f64 / n as f64
    );
    println!(
        "  deflections: {} ({:.2}%), inject stalls: {}, avg latency {:.1} cyc, max {}",
        s.deflections,
        100.0 * s.deflections as f64 / s.delivered as f64,
        s.inject_stalls,
        s.total_latency as f64 / s.delivered as f64,
        s.max_latency
    );
    Ok(())
}

/// One pinned `tdp perf` case: name, workload spec, overlay dims,
/// scheduler, backend. The set is fixed on purpose — BENCH_*.json
/// snapshots are only comparable if every run measures the same thing.
struct PerfCase {
    name: &'static str,
    spec: &'static str,
    cols: usize,
    rows: usize,
    scheduler: SchedulerKind,
    backend: BackendKind,
}

const fn perf_case(
    name: &'static str,
    spec: &'static str,
    cols: usize,
    rows: usize,
    scheduler: SchedulerKind,
    backend: BackendKind,
) -> PerfCase {
    PerfCase { name, spec, cols, rows, scheduler, backend }
}

/// The pinned workload set. `quick` is the CI smoke variant (seconds,
/// not minutes); the full set is the perf-trajectory unit.
fn perf_cases(quick: bool) -> Vec<PerfCase> {
    use BackendKind::{Lockstep, SkipAhead};
    use SchedulerKind::{InOrder, OutOfOrder};
    let chain = if quick { "chain:2000:seed=1" } else { "chain:8000:seed=1" };
    let lu_pl = if quick { "lu_pl:120:3:seed=42" } else { "lu_pl:330:3:seed=42" };
    let mut set = vec![
        perf_case("sparse_chain_16x16", chain, 16, 16, OutOfOrder, Lockstep),
        perf_case("sparse_chain_16x16_skip", chain, 16, 16, OutOfOrder, SkipAhead),
        perf_case("lu_pl_fig1_16x16_ooo", lu_pl, 16, 16, OutOfOrder, Lockstep),
    ];
    if !quick {
        set.push(perf_case("lu_pl_fig1_16x16_inorder", lu_pl, 16, 16, InOrder, Lockstep));
        set.push(perf_case(
            "lu_banded_8x8_ooo",
            "lu_banded:200:8:0.9:seed=3",
            8,
            8,
            OutOfOrder,
            Lockstep,
        ));
    }
    set
}

/// `tdp perf` — the host-side throughput harness behind the repo's
/// BENCH_*.json perf trajectory (perf/README.md). Each case compiles
/// its Program once, then times `reps` full Session runs (warmup 1);
/// the headline metric is simulated fabric cycles per wall-clock second
/// over the median run.
fn cmd_perf(mut a: Args) -> Result<()> {
    use std::time::Instant;
    let quick = a.switch("quick");
    let reps = a.usize_or("reps", 5)?.max(1);
    let budget_ms = a.u64_or("budget-ms", 0)?;
    let format = a.str_or("format", "json")?;
    let out = a.str_opt("out")?;
    let trace_out = a.str_opt("trace-out")?;
    let dump_passes = a.switch("dump-passes");
    a.finish()?;
    if format != "json" && format != "text" {
        bail!("unknown format '{format}' (json | text)");
    }
    // Span-only telemetry: compile stages and run phases land in the
    // Perfetto export, but no per-cycle Trace is attached — that would
    // pin the skip-ahead backend to cycle-accurate stepping and distort
    // the very numbers this harness exists to track.
    let registry = trace_out.as_ref().map(|_| Registry::new());
    let mut cases_json = Vec::new();
    let mut total_wall_ms = 0f64;
    for case in perf_cases(quick) {
        let spec: workload::Spec = case.spec.parse().map_err(|e: String| anyhow!(e))?;
        let g = spec.build().map_err(|e| anyhow!("workload build: {e}"))?;
        let cfg = OverlayConfig::default()
            .with_dims(case.cols, case.rows)
            .with_scheduler(case.scheduler)
            .with_backend(case.backend);
        let overlay = Overlay::from_config(cfg)?;
        let t0 = Instant::now();
        let program = match &registry {
            Some(reg) => Program::compile_with(&g, &overlay, Some(reg))?,
            None => Program::compile(&g, &overlay)?,
        };
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        if dump_passes {
            eprintln!("[{}]", case.name);
            print_pass_table(&program);
        }
        let session = match &registry {
            Some(reg) => program.session().with_telemetry(reg),
            None => program.session(),
        };
        let mut cycles = session.run()?.cycles; // warmup
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t = Instant::now();
            cycles = session.run()?.cycles;
            samples.push(t.elapsed());
        }
        samples.sort_unstable();
        let median_ms = samples[reps / 2].as_secs_f64() * 1e3;
        let min_ms = samples[0].as_secs_f64() * 1e3;
        let wall_ms: f64 = samples.iter().map(|d| d.as_secs_f64() * 1e3).sum();
        total_wall_ms += wall_ms;
        let rate = cycles as f64 / (median_ms / 1e3);
        if format == "text" {
            println!(
                "{:<28} {} {}x{} {:<12} {:>10} cyc  median {:>9.3} ms (min {:.3})  {:>9.3} M cyc/s",
                case.name,
                case.spec,
                case.cols,
                case.rows,
                case.scheduler.name(),
                cycles,
                median_ms,
                min_ms,
                rate / 1e6
            );
        }
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(case.name.to_string()));
        m.insert("workload".to_string(), Json::Str(spec.canonical()));
        m.insert("cols".to_string(), Json::Num(case.cols as f64));
        m.insert("rows".to_string(), Json::Num(case.rows as f64));
        m.insert(
            "scheduler".to_string(),
            Json::Str(case.scheduler.toml_name().to_string()),
        );
        m.insert("backend".to_string(), Json::Str(case.backend.toml_name().to_string()));
        m.insert("nodes".to_string(), Json::Num(g.len() as f64));
        m.insert("edges".to_string(), Json::Num(g.num_edges() as f64));
        m.insert("sim_cycles".to_string(), Json::Num(cycles as f64));
        m.insert("compile_ms".to_string(), Json::Num(compile_ms));
        m.insert("wall_ms_median".to_string(), Json::Num(median_ms));
        m.insert("wall_ms_min".to_string(), Json::Num(min_ms));
        m.insert("runs".to_string(), Json::Num(reps as f64));
        m.insert("sim_cycles_per_sec".to_string(), Json::Num(rate));
        cases_json.push(Json::Obj(m));
    }
    // Placement-quality section: the same workloads compiled under the
    // default policy and under traffic-aware placement, OoO cycles side
    // by side plus the criticality-weighted hop cost each placement
    // achieves. Deliberately OUTSIDE `cases` and `total_wall_ms`: the
    // BENCH trajectory and the CI budget compare those across commits,
    // and this section measures placement quality, not host throughput.
    let pq_set: &[(&str, &str, usize, usize)] = if quick {
        &[("lu_pl_fig1_16x16", "lu_pl:120:3:seed=42", 16, 16)]
    } else {
        &[
            ("lu_pl_fig1_16x16", "lu_pl:330:3:seed=42", 16, 16),
            ("lu_banded_8x8", "lu_banded:200:8:0.9:seed=3", 8, 8),
        ]
    };
    let mut pq_json = Vec::new();
    for &(name, spec_str, cols, rows) in pq_set {
        use tdp::place::{placement_cost, PlacementPolicy};
        let spec: workload::Spec = spec_str.parse().map_err(|e: String| anyhow!(e))?;
        let g = spec.build().map_err(|e| anyhow!("workload build: {e}"))?;
        let measure = |policy: PlacementPolicy| -> Result<(u64, u64)> {
            let mut cfg = OverlayConfig::default()
                .with_dims(cols, rows)
                .with_scheduler(SchedulerKind::OutOfOrder);
            cfg.placement = policy;
            let overlay = Overlay::from_config(cfg)?;
            let program = Program::compile(&g, &overlay)?;
            let cost = placement_cost(
                program.exec_graph(),
                program.criticality(),
                &program.placement().pe_of,
                cols,
                rows,
            );
            Ok((program.session().run()?.cycles, cost))
        };
        let (base_cycles, base_cost) = measure(OverlayConfig::default().placement)?;
        let (ta_cycles, ta_cost) = measure(PlacementPolicy::TrafficAware)?;
        if format == "text" {
            println!(
                "placement {:<20} baseline {:>9} cyc (cost {:>9})  traffic-aware {:>9} cyc \
                 (cost {:>9})  cycle ratio {:.3}",
                name,
                base_cycles,
                base_cost,
                ta_cycles,
                ta_cost,
                base_cycles as f64 / ta_cycles as f64
            );
        }
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(name.to_string()));
        m.insert("workload".to_string(), Json::Str(spec.canonical()));
        m.insert("cols".to_string(), Json::Num(cols as f64));
        m.insert("rows".to_string(), Json::Num(rows as f64));
        m.insert("baseline_cycles".to_string(), Json::Num(base_cycles as f64));
        m.insert("baseline_cost".to_string(), Json::Num(base_cost as f64));
        m.insert("traffic_aware_cycles".to_string(), Json::Num(ta_cycles as f64));
        m.insert("traffic_aware_cost".to_string(), Json::Num(ta_cost as f64));
        m.insert(
            "cycle_ratio".to_string(),
            Json::Num(base_cycles as f64 / ta_cycles as f64),
        );
        pq_json.push(Json::Obj(m));
    }
    // Sharded-execution section (DESIGN.md §14): an oversized workload
    // partitioned across simulated fabrics, compile + one run timed.
    // Like placement_quality this stays OUTSIDE `cases`/`total_wall_ms`
    // — it tracks the epoch-barrier runtime's cost (stall counters,
    // wall clock), not single-fabric host throughput.
    let sh_set: &[(&str, &str, usize)] = if quick {
        &[("reduction_scale48_2x2_auto", "reduction:64:scale=48", 0)]
    } else {
        &[
            ("reduction_scale48_2x2_auto", "reduction:64:scale=48", 0),
            ("layered_scale8_2x2_n4", "layered:8:4:16:2:scale=8:seed=3", 4),
        ]
    };
    let mut sharded_json = Vec::new();
    for &(name, spec_str, shards) in sh_set {
        use std::sync::Arc;
        use tdp::program::SharedProgram;
        use tdp::ShardedProgram;
        let spec: workload::Spec = spec_str.parse().map_err(|e: String| anyhow!(e))?;
        let g = Arc::new(spec.build().map_err(|e| anyhow!("workload build: {e}"))?);
        let cfg = OverlayConfig::default().with_dims(2, 2);
        let overlay = Overlay::from_config(cfg)?;
        let t0 = Instant::now();
        let n = if shards >= 1 {
            shards
        } else {
            SharedProgram::compile(Arc::clone(&g), &overlay)?
                .program()
                .min_shards(cfg.scheduler)
        };
        let sp = ShardedProgram::compile(g, &overlay, n)?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = Instant::now();
        let r = sp.session().run()?;
        let wall_ms = t1.elapsed().as_secs_f64() * 1e3;
        if format == "text" {
            println!(
                "sharded {:<26} {} shards  {:>9} cyc over {} epochs ({} stalls)  \
                 compile {:>8.3} ms  run {:>8.3} ms",
                name, n, r.stats.cycles, r.epochs, r.boundary_stalls, compile_ms, wall_ms
            );
        }
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(name.to_string()));
        m.insert("workload".to_string(), Json::Str(spec.canonical()));
        m.insert("num_shards".to_string(), Json::Num(n as f64));
        m.insert("epoch".to_string(), Json::Num(sp.epoch() as f64));
        m.insert("epochs".to_string(), Json::Num(r.epochs as f64));
        m.insert("boundary_values".to_string(), Json::Num(r.boundary_values as f64));
        m.insert("boundary_stalls".to_string(), Json::Num(r.boundary_stalls as f64));
        m.insert("sim_cycles".to_string(), Json::Num(r.stats.cycles as f64));
        m.insert("compile_ms".to_string(), Json::Num(compile_ms));
        m.insert("wall_ms".to_string(), Json::Num(wall_ms));
        sharded_json.push(Json::Obj(m));
    }
    let mut root = std::collections::BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("tdp perf".to_string()));
    root.insert("version".to_string(), Json::Num(1.0));
    root.insert("quick".to_string(), Json::Bool(quick));
    root.insert("reps".to_string(), Json::Num(reps as f64));
    root.insert("cases".to_string(), Json::Arr(cases_json));
    root.insert("placement_quality".to_string(), Json::Arr(pq_json));
    root.insert("sharded".to_string(), Json::Arr(sharded_json));
    root.insert("total_wall_ms".to_string(), Json::Num(total_wall_ms));
    let text = json::write(&Json::Obj(root));
    if format == "json" {
        println!("{text}");
    }
    if let Some(path) = &out {
        std::fs::write(path, &text)?;
        eprintln!("wrote {path}");
    }
    if let (Some(reg), Some(path)) = (&registry, &trace_out) {
        std::fs::write(path, telemetry::perfetto_json(reg, &[]))?;
        eprintln!("wrote {path}");
    }
    if format == "text" {
        println!("total timed wall: {total_wall_ms:.1} ms");
    }
    if budget_ms > 0 && total_wall_ms > budget_ms as f64 {
        bail!("perf budget exceeded: {total_wall_ms:.1} ms > {budget_ms} ms (>2x regression trap)");
    }
    Ok(())
}

fn cmd_analyze(mut a: Args) -> Result<()> {
    use tdp::place::PlacementPolicy;
    use tdp::sim::Simulator;
    let workload = a.str_opt("workload")?;
    let graph = a.str_opt("graph")?;
    let cols = a.usize_or("cols", 16)?;
    let rows = a.usize_or("rows", 16)?;
    let stride = a.u64_or("stride", 0)?;
    let csv = a.str_opt("csv")?;
    let json_path = a.str_opt("json-out")?;
    let seed = a.u64_or("seed", 0)?;
    a.finish()?;
    let g = load_graph(workload, graph, seed)?;
    let prof = workload::profile(&g);
    println!("{}\n", prof.report());
    let mut doc = std::collections::BTreeMap::new();
    for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
        let mut cfg = OverlayConfig::default().with_dims(cols, rows).with_scheduler(kind);
        cfg.placement = PlacementPolicy::Chunked;
        let mut sim = Simulator::new(&g, cfg).map_err(|e| anyhow!("{e}"))?;
        // auto-stride: ~400 samples per run
        let est = (g.num_edges() as u64 / (cols * rows) as u64 + prof.depth as u64 * 12).max(400);
        sim.enable_trace(if stride == 0 { est / 400 } else { stride });
        let stats = sim.run().map_err(|e| anyhow!("{e}"))?;
        // propagate instead of panicking: a missing trace is a typed
        // failure exit, like every other error on this path
        let trace = sim
            .trace()
            .ok_or_else(|| anyhow!("trace buffer missing after enable_trace"))?;
        println!("=== {} === ({} cycles)", kind.name(), stats.cycles);
        println!("  ready queue : {}  (peak {})", trace.sparkline(|s| s.ready_total, 48), trace.peak_ready());
        println!("  busy PEs    : {}  (mean {:.1}%)", trace.sparkline(|s| s.busy_pes, 48), 100.0 * trace.mean_busy(cols * rows));
        println!("  in-flight   : {}", trace.sparkline(|s| s.in_flight, 48));
        println!("  completion  : {}", trace.sparkline(|s| s.completed, 48));
        let activity = sim.activity();
        println!("{}", activity.render());
        if json_path.is_some() {
            let mut m = std::collections::BTreeMap::new();
            m.insert("stats".to_string(), stats.to_json_value());
            m.insert("activity".to_string(), activity.to_json_value());
            doc.insert(kind.toml_name().to_string(), Json::Obj(m));
        }
        if let Some(path) = &csv {
            let file = format!("{path}.{}.csv", kind.toml_name());
            std::fs::write(&file, trace.to_csv())?;
            eprintln!("wrote {file}");
        }
    }
    if let Some(path) = &json_path {
        std::fs::write(path, json::write(&Json::Obj(doc)))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_workload_stats(mut a: Args) -> Result<()> {
    let workload = a.str_opt("workload")?;
    let graph = a.str_opt("graph")?;
    let pes = a.usize_or("pes", 256)?;
    let seed = a.u64_or("seed", 0)?;
    a.finish()?;
    let g = load_graph(workload, graph, seed)?;
    let prof = workload::profile(&g);
    println!("{}", prof.report());
    println!(
        "saturates a {pes}-PE overlay: {} (avg parallelism {:.1} vs {} PEs)",
        if prof.saturates(pes) { "YES" } else { "no" },
        prof.avg_width,
        pes
    );
    println!(
        "graph-memory footprint: {} items -> {} BRAM words",
        g.footprint(),
        BramConfig::words_used(g.len(), g.num_edges())
    );
    Ok(())
}

fn main() -> Result<()> {
    let mut argv = std::env::args().skip(1);
    let Some(cmd) = argv.next() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest: Vec<String> = argv.collect();
    // batch takes a positional job-file path; everything else is
    // flags-only
    if cmd == "batch" {
        return cmd_batch(rest);
    }
    // check takes a positional workload spec, like batch's file path
    if cmd == "check" {
        return cmd_check(rest);
    }
    // shard takes a positional workload spec, like check
    if cmd == "shard" {
        return cmd_shard(rest);
    }
    // top takes a positional daemon address
    if cmd == "top" {
        return cmd_top(rest);
    }
    let args = Args::parse(rest).map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;
    match cmd.as_str() {
        "run" => cmd_run(args),
        "serve" => cmd_serve(args),
        "sweep" => cmd_sweep(args),
        "gen" => cmd_gen(args),
        "validate" => cmd_validate(args),
        "resources" => cmd_resources(args),
        "capacity" => cmd_capacity(args),
        "noc-stress" => cmd_noc_stress(args),
        "perf" => cmd_perf(args),
        "analyze" => cmd_analyze(args),
        "workload-stats" => cmd_workload_stats(args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}
