//! `tdp` — the overlay coordinator CLI.
//!
//! Subcommands map 1:1 to the paper's experiments (DESIGN.md §4):
//! `sweep` regenerates Figure 1, `resources` Table I, `capacity` the §III
//! claim; `run`/`validate`/`gen`/`noc-stress` are the engineering tools
//! around them.

use anyhow::{anyhow, bail, Result};
use std::path::PathBuf;
use tdp::config::{Overlay, OverlayConfig, WorkloadSpec};
use tdp::coordinator::{
    self, capacity_experiment, fig1_sweep, render_csv, render_markdown, scheduler_comparison,
    Table,
};
use tdp::engine::BackendKind;
use tdp::graph::{graph_from_json, graph_to_json, DataflowGraph};
use tdp::noc::{Network, Packet};
use tdp::pe::BramConfig;
use tdp::program::Program;
use tdp::resource;
use tdp::runtime::XlaRuntime;
use tdp::sched::SchedulerKind;
use tdp::util::cli::Args;
use tdp::util::rng::Rng;
use tdp::workload;

const USAGE: &str = "\
tdp — out-of-order token dataflow overlay (Siddhartha & Kapre, 2017)

USAGE: tdp <command> [flags]

COMMANDS
  run         simulate one workload          --workload <toml> | --graph <json>
              [--cols 16 --rows 16 --scheduler both|in_order|out_of_order
              --backend lockstep|skip-ahead --max-cycles N --seed 0]
  sweep       regenerate Figure 1            [--cols 16 --rows 16 --seed 42
              --backend lockstep|skip-ahead
              --jobs N (0 = all cores; --threads is a legacy alias)
              --format markdown|csv --out file]
  gen         write a workload graph JSON    --workload <toml> --out <file> [--seed 0]
  validate    check sim numerics vs native + PJRT oracle
              --workload <toml> | --graph <json> [--cols 4 --rows 4
              --backend lockstep|skip-ahead
              --artifacts artifacts --no-pjrt --seed 0]
  resources   regenerate Table I             [--points 16,64 --detail --format ...]
  capacity    regenerate the §III claim      [--pes 256 --edge-per-node 2.0
              --backend lockstep|skip-ahead]
  noc-stress  synthetic NoC traffic          [--cols 16 --rows 16 --packets 100000
              --inject-rate 0.5 --seed 0]
  analyze     trace a run (queue occupancy / busyness / completion)
              --workload <toml> | --graph <json> [--cols 16 --rows 16
              --stride 0 --csv file --seed 0]
  workload-stats  characterize a workload's shape (parallelism, fanout)
              --workload <toml> | --graph <json> [--pes 256 --seed 0]

Workload TOML example: 'kind = \"lu_banded\"\\nn = 100\\nhalf_bw = 4\\nfill = 0.8'
";

fn load_graph(
    workload: Option<String>,
    graph: Option<String>,
    seed: u64,
) -> Result<DataflowGraph> {
    match (workload, graph) {
        (Some(spec), None) => {
            let spec =
                WorkloadSpec::from_toml(&spec.replace("\\n", "\n")).map_err(|e| anyhow!(e))?;
            spec.build(seed).map_err(|e| anyhow!("workload build: {e}"))
        }
        (None, Some(path)) => {
            let text = std::fs::read_to_string(&path)?;
            graph_from_json(&text).map_err(|e| anyhow!("graph load: {e}"))
        }
        _ => bail!("provide exactly one of --workload / --graph"),
    }
}

/// Parse the `--backend` flag shared by run/sweep/validate/capacity.
fn backend_flag(a: &mut Args) -> Result<BackendKind> {
    a.str_or("backend", "lockstep")?
        .parse()
        .map_err(|e: String| anyhow!(e))
}

fn emit(t: &Table, format: &str, out: Option<String>) -> Result<()> {
    let text = match format {
        "markdown" | "md" => render_markdown(t),
        "csv" => render_csv(t),
        other => bail!("unknown format '{other}' (markdown | csv)"),
    };
    print!("{text}");
    if let Some(path) = out {
        std::fs::write(&path, &text)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_run(mut a: Args) -> Result<()> {
    let workload = a.str_opt("workload")?;
    let graph = a.str_opt("graph")?;
    let cols = a.usize_or("cols", 16)?;
    let rows = a.usize_or("rows", 16)?;
    let sched = a.str_or("scheduler", "both")?;
    let backend = backend_flag(&mut a)?;
    let max_cycles = a.u64_or("max-cycles", 0)?; // 0 = config default
    let seed = a.u64_or("seed", 0)?;
    a.finish()?;
    let g = load_graph(workload, graph, seed)?;
    let s = g.stats();
    println!(
        "graph: {} nodes, {} edges, depth {}, max fanout {} (backend: {})",
        s.nodes,
        s.edges,
        s.depth,
        s.max_fanout,
        backend.name()
    );
    let mut cfg = OverlayConfig::default().with_dims(cols, rows).with_backend(backend);
    if max_cycles > 0 {
        cfg.max_cycles = max_cycles;
    }
    if sched == "both" {
        let outs = scheduler_comparison(&g, cfg, "run")?;
        for o in &outs {
            println!(
                "{:>12}: {} cycles, util {:.1}%, {} deflections",
                o.scheduler.name(),
                o.cycles,
                100.0 * o.utilization,
                o.deflections
            );
        }
        println!(
            "speedup (in-order / out-of-order): {:.3}",
            outs[0].cycles as f64 / outs[1].cycles as f64
        );
    } else {
        let kind: SchedulerKind = sched.parse().map_err(|e: String| anyhow!(e))?;
        let overlay = Overlay::from_config(cfg.with_scheduler(kind))?;
        let program = Program::compile(&g, &overlay)?;
        let stats = program.session().run()?;
        println!("{}", stats.one_line());
    }
    Ok(())
}

fn cmd_sweep(mut a: Args) -> Result<()> {
    let cols = a.usize_or("cols", 16)?;
    let rows = a.usize_or("rows", 16)?;
    let seed = a.u64_or("seed", 42)?;
    let backend = backend_flag(&mut a)?;
    let mut jobs = a.usize_or("jobs", 0)?;
    let threads_legacy = a.usize_or("threads", 0)?; // pre---jobs spelling
    let format = a.str_or("format", "markdown")?;
    let out = a.str_opt("out")?;
    a.finish()?;
    if jobs == 0 {
        jobs = threads_legacy;
    }
    if jobs == 0 {
        jobs = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    }
    let cfg = coordinator::fig1_config().with_dims(cols, rows).with_backend(backend);
    Overlay::from_config(cfg)?; // fail fast, before generating workloads
    eprintln!("generating Fig.1 workload ladder (seed {seed})...");
    let ws = workload::fig1_workloads(seed);
    eprintln!(
        "running {} workloads x 2 schedulers on {jobs} jobs ({} backend, \
         each workload compiled once)...",
        ws.len(),
        backend.name()
    );
    let rows_out = fig1_sweep(&ws, cfg, jobs)?;
    let mut t = Table::new(
        &format!("Figure 1 — OoO speedup vs graph size ({cols}x{rows} overlay)"),
        &["workload", "nodes+edges", "depth", "in-order cyc", "ooo cyc", "speedup"],
    );
    for r in &rows_out {
        t.push(vec![
            r.label.clone(),
            r.nodes_plus_edges.to_string(),
            r.depth.to_string(),
            r.cycles_inorder.to_string(),
            r.cycles_ooo.to_string(),
            format!("{:.3}", r.speedup),
        ]);
    }
    emit(&t, &format, out)
}

fn cmd_gen(mut a: Args) -> Result<()> {
    let workload = a.str_req("workload")?;
    let out = a.str_req("out")?;
    let seed = a.u64_or("seed", 0)?;
    a.finish()?;
    let g = load_graph(Some(workload), None, seed)?;
    std::fs::write(&out, graph_to_json(&g))?;
    let s = g.stats();
    println!(
        "wrote {out} ({} nodes, {} edges, depth {})",
        s.nodes, s.edges, s.depth
    );
    Ok(())
}

fn cmd_validate(mut a: Args) -> Result<()> {
    let workload = a.str_opt("workload")?;
    let graph = a.str_opt("graph")?;
    let cols = a.usize_or("cols", 4)?;
    let rows = a.usize_or("rows", 4)?;
    let artifacts = a.str_or("artifacts", "artifacts")?;
    let no_pjrt = a.switch("no-pjrt");
    let backend = backend_flag(&mut a)?;
    let seed = a.u64_or("seed", 0)?;
    a.finish()?;
    let g = load_graph(workload, graph, seed)?;
    let cfg = OverlayConfig::default().with_dims(cols, rows).with_backend(backend);
    Overlay::from_config(cfg)?;
    let rt = if no_pjrt {
        None
    } else {
        // degrade to native-only validation when the oracle is absent
        // (no artifacts on disk, or a stub build without the xla feature)
        match XlaRuntime::load(&PathBuf::from(artifacts)) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("WARNING: PJRT oracle unavailable ({e}); validating against the native reference only.");
                None
            }
        }
    };
    if let Some(rt) = &rt {
        rt.manifest.check_opcode_table()?;
        println!("PJRT platform: {}", rt.platform());
    }
    let rep = coordinator::validate(&g, cfg, rt.as_ref()).map_err(|e| anyhow!("{e}"))?;
    println!("{}", rep.stats.one_line());
    println!(
        "native-ref max |err| = {} over {} nodes",
        rep.max_abs_err_native, rep.nodes_checked
    );
    match rep.max_abs_err_pjrt {
        Some(e) => println!("PJRT-oracle max |err| = {e}"),
        None => println!("PJRT oracle skipped (graph exceeds artifact geometry or --no-pjrt)"),
    }
    if rep.passed() {
        println!("VALIDATION PASSED");
        Ok(())
    } else {
        bail!("validation failed")
    }
}

fn cmd_resources(mut a: Args) -> Result<()> {
    let points = a.usize_list("points")?;
    let detail = a.switch("detail");
    let format = a.str_or("format", "markdown")?;
    a.finish()?;
    let rows = resource::table1(&points);
    let mut t = Table::new(
        "Table I — resource utilization (Arria 10 10AX115S)",
        &["PEs", "ALMs", "REGs", "DSPs", "BRAMs", "Fmax (MHz)"],
    );
    for r in &rows {
        t.push(vec![
            r.pes.to_string(),
            format!("{} ({:.1}%)", r.alms, r.alm_pct),
            format!("{} ({:.1}%)", r.regs, r.reg_pct),
            format!("{} ({:.1}%)", r.dsps, r.dsp_pct),
            format!("{} ({:.1}%)", r.brams, r.bram_pct),
            format!("{:.0}", r.fmax_mhz),
        ]);
    }
    emit(&t, &format, None)?;
    if detail {
        let b = BramConfig::paper();
        println!("\nBRAM budget per PE (words of 512x40b M20K):");
        println!("  total: {}", b.total_words());
        println!(
            "  OoO flag overhead: {} ({:.2}% — paper: ~6%)",
            b.flag_words(),
            100.0 * b.flag_words() as f64 / b.total_words() as f64
        );
        println!("  in-order FIFO reserve: {}", b.fifo_words());
        println!(
            "  graph words: in-order {}, OoO {}",
            b.graph_words(SchedulerKind::InOrder),
            b.graph_words(SchedulerKind::OutOfOrder)
        );
        println!(
            "  max overlay on device: {} PEs",
            resource::max_overlay(&resource::ARRIA10_10AX115S, 1.0)
        );
    }
    Ok(())
}

/// Squarest (cols, rows) factorization of `pes` that fits the 5 b torus
/// coordinates, if any.
fn torus_dims(pes: usize) -> Option<(usize, usize)> {
    if pes == 0 {
        return None;
    }
    let mut best: Option<(usize, usize)> = None;
    let mut best_score = usize::MAX;
    for rows in 1..=32usize {
        if pes % rows != 0 {
            continue;
        }
        let cols = pes / rows;
        if cols > 32 {
            continue;
        }
        let score = cols.abs_diff(rows);
        if score < best_score {
            best_score = score;
            best = Some((cols, rows));
        }
    }
    best
}

fn cmd_capacity(mut a: Args) -> Result<()> {
    let pes = a.usize_or("pes", 256)?;
    let edge_per_node = a.f64_or("edge-per-node", 2.0)?;
    let backend = backend_flag(&mut a)?;
    a.finish()?;
    let row = capacity_experiment(&BramConfig::paper(), pes, edge_per_node);
    println!(
        "{} PEs, edge/node = {edge_per_node}: in-order ≈{} items, OoO ≈{} items, ratio {:.2}x",
        row.num_pes, row.max_items_inorder, row.max_items_ooo, row.ratio
    );
    println!("paper §III: ≈100K items vs ≈5x at 256 PEs");
    // empirical probe: place a small LU workload with capacity
    // enforcement on and run it on the selected engine backend
    match torus_dims(pes) {
        Some((cols, rows)) => {
            let m = workload::SparseMatrix::banded(120, 4, 0.9, 1);
            let (g, _) = workload::lu_factorization_graph(&m);
            let overlay = Overlay::builder()
                .dims(cols, rows)
                .backend(backend)
                .enforce_capacity(true)
                .build()?;
            // compile once; the capacity check *is* the compile phase
            match Program::compile(&g, &overlay) {
                Ok(program) => match program.session().run() {
                    Ok(stats) => println!(
                        "probe: lu_banded(n=120) placed under enforcement on {cols}x{rows}, \
                         {} backend: {} cycles",
                        backend.name(),
                        stats.cycles
                    ),
                    Err(e) => println!("probe: lu_banded(n=120) on {cols}x{rows}: {e}"),
                },
                Err(e) => println!("probe: lu_banded(n=120) on {cols}x{rows}: {e}"),
            }
        }
        None => println!("probe skipped: {pes} PEs has no torus factorization within 32x32"),
    }
    Ok(())
}

fn cmd_noc_stress(mut a: Args) -> Result<()> {
    let cols = a.usize_or("cols", 16)?;
    let rows = a.usize_or("rows", 16)?;
    let packets = a.usize_or("packets", 100_000)?;
    let inject_rate = a.f64_or("inject-rate", 0.5)?;
    let seed = a.u64_or("seed", 0)?;
    a.finish()?;
    let mut rng = Rng::seed_from_u64(seed);
    let n = cols * rows;
    let mut net = Network::new(cols, rows);
    let mut sent = 0usize;
    let mut cycles = 0u64;
    while net.stats.delivered < packets as u64 {
        let mut inject: Vec<Option<Packet>> = vec![None; n];
        for (pe, slot) in inject.iter_mut().enumerate() {
            if sent < packets && rng.gen_bool(inject_rate) {
                let dest = rng.gen_range(n);
                *slot = Some(Packet {
                    dest_x: (dest % cols) as u8,
                    dest_y: (dest / cols) as u8,
                    local_idx: (pe % 8192) as u16,
                    slot: 0,
                    payload: pe as f32,
                });
            }
        }
        let granted = net.step(&inject).inject_ok.iter().filter(|&&g| g).count();
        sent += granted;
        cycles += 1;
        if cycles > 100_000_000 {
            bail!("NoC stress did not converge");
        }
    }
    let s = net.stats;
    println!(
        "{cols}x{rows} torus: {} pkts in {cycles} cycles = {:.3} pkts/cycle ({:.4}/PE)",
        s.delivered,
        s.delivered as f64 / cycles as f64,
        s.delivered as f64 / cycles as f64 / n as f64
    );
    println!(
        "  deflections: {} ({:.2}%), inject stalls: {}, avg latency {:.1} cyc, max {}",
        s.deflections,
        100.0 * s.deflections as f64 / s.delivered as f64,
        s.inject_stalls,
        s.total_latency as f64 / s.delivered as f64,
        s.max_latency
    );
    Ok(())
}

fn cmd_analyze(mut a: Args) -> Result<()> {
    use tdp::place::PlacementPolicy;
    use tdp::sim::Simulator;
    let workload = a.str_opt("workload")?;
    let graph = a.str_opt("graph")?;
    let cols = a.usize_or("cols", 16)?;
    let rows = a.usize_or("rows", 16)?;
    let stride = a.u64_or("stride", 0)?;
    let csv = a.str_opt("csv")?;
    let seed = a.u64_or("seed", 0)?;
    a.finish()?;
    let g = load_graph(workload, graph, seed)?;
    let prof = workload::profile(&g);
    println!("{}\n", prof.report());
    for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
        let mut cfg = OverlayConfig::default().with_dims(cols, rows).with_scheduler(kind);
        cfg.placement = PlacementPolicy::Chunked;
        let mut sim = Simulator::new(&g, cfg).map_err(|e| anyhow!("{e}"))?;
        // auto-stride: ~400 samples per run
        let est = (g.num_edges() as u64 / (cols * rows) as u64 + prof.depth as u64 * 12).max(400);
        sim.enable_trace(if stride == 0 { est / 400 } else { stride });
        let stats = sim.run().map_err(|e| anyhow!("{e}"))?;
        let trace = sim.trace().unwrap();
        println!("=== {} === ({} cycles)", kind.name(), stats.cycles);
        println!("  ready queue : {}  (peak {})", trace.sparkline(|s| s.ready_total, 48), trace.peak_ready());
        println!("  busy PEs    : {}  (mean {:.1}%)", trace.sparkline(|s| s.busy_pes, 48), 100.0 * trace.mean_busy(cols * rows));
        println!("  in-flight   : {}", trace.sparkline(|s| s.in_flight, 48));
        println!("  completion  : {}", trace.sparkline(|s| s.completed, 48));
        if let Some(path) = &csv {
            let file = format!("{path}.{}.csv", kind.toml_name());
            std::fs::write(&file, trace.to_csv())?;
            eprintln!("wrote {file}");
        }
    }
    Ok(())
}

fn cmd_workload_stats(mut a: Args) -> Result<()> {
    let workload = a.str_opt("workload")?;
    let graph = a.str_opt("graph")?;
    let pes = a.usize_or("pes", 256)?;
    let seed = a.u64_or("seed", 0)?;
    a.finish()?;
    let g = load_graph(workload, graph, seed)?;
    let prof = workload::profile(&g);
    println!("{}", prof.report());
    println!(
        "saturates a {pes}-PE overlay: {} (avg parallelism {:.1} vs {} PEs)",
        if prof.saturates(pes) { "YES" } else { "no" },
        prof.avg_width,
        pes
    );
    println!(
        "graph-memory footprint: {} items -> {} BRAM words",
        g.footprint(),
        BramConfig::words_used(g.len(), g.num_edges())
    );
    Ok(())
}

fn main() -> Result<()> {
    let mut argv = std::env::args().skip(1);
    let Some(cmd) = argv.next() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest: Vec<String> = argv.collect();
    let args = Args::parse(rest).map_err(|e| anyhow!("{e}\n\n{USAGE}"))?;
    match cmd.as_str() {
        "run" => cmd_run(args),
        "sweep" => cmd_sweep(args),
        "gen" => cmd_gen(args),
        "validate" => cmd_validate(args),
        "resources" => cmd_resources(args),
        "capacity" => cmd_capacity(args),
        "noc-stress" => cmd_noc_stress(args),
        "analyze" => cmd_analyze(args),
        "workload-stats" => cmd_workload_stats(args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}
