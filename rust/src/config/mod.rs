//! Configuration system: every architectural knob of the overlay, the
//! placement, and workload specs — TOML/JSON (de)serialization (via
//! `util::toml` / `util::json`) with paper-faithful defaults, and the
//! validated [`Overlay`] front door of the compile-once API
//! ([`Overlay`] → [`crate::program::Program`] →
//! [`crate::program::Session`], DESIGN.md §8).

use crate::engine::BackendKind;
use crate::pe::BramConfig;
use crate::place::{LocalOrder, PlacementPolicy};
use crate::sched::SchedulerKind;
use crate::util::json::{self, Json};
use crate::util::toml::{self, Doc, Value};
use std::collections::BTreeMap;
use std::path::Path;
use std::str::FromStr;

/// A rejected overlay configuration (the `ConfigError` arm of
/// [`crate::error::Error`]): every constraint violation
/// [`OverlayConfig::validate`] / [`OverlayBuilder::build`] can detect,
/// with a human-readable message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid overlay config: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

/// A *validated* hardware description — the only way to get one is
/// through a constructor that ran the constraint checks
/// ([`Overlay::builder`] / [`Overlay::from_config`]), so every API that
/// takes an `&Overlay` can assume the knobs are coherent instead of
/// re-validating or panicking deep in construction.
///
/// This is the first layer of the compile-once API:
/// `Overlay` (validated hardware) → [`crate::program::Program`] (placed
/// + labeled graph) → [`crate::program::Session`] (cheap repeatable run).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Overlay {
    cfg: OverlayConfig,
}

impl Overlay {
    /// Start a builder at the paper's 16×16 defaults.
    pub fn builder() -> OverlayBuilder {
        OverlayBuilder {
            cfg: OverlayConfig::default(),
        }
    }

    /// Validate an existing raw config.
    pub fn from_config(cfg: OverlayConfig) -> Result<Self, ConfigError> {
        cfg.validate()?;
        Ok(Self { cfg })
    }

    /// Wrap a config *without* validating — for the deprecated shims
    /// that must keep the seed behavior (garbage knobs fail as deep
    /// asserts, not typed errors). Never expose this publicly.
    pub(crate) fn trusted(cfg: OverlayConfig) -> Self {
        Self { cfg }
    }

    /// The validated knobs.
    pub fn config(&self) -> &OverlayConfig {
        &self.cfg
    }

    pub fn num_pes(&self) -> usize {
        self.cfg.num_pes()
    }
}

/// Typed builder for [`Overlay`]: set knobs, then `build()` — validation
/// is not skippable, so an invalid combination is caught at construction
/// instead of panicking mid-simulation.
#[derive(Debug, Clone)]
pub struct OverlayBuilder {
    cfg: OverlayConfig,
}

impl OverlayBuilder {
    /// Start from an existing config instead of the defaults.
    pub fn from_config(cfg: OverlayConfig) -> Self {
        Self { cfg }
    }

    /// Torus dimensions (cols × rows).
    pub fn dims(mut self, cols: usize, rows: usize) -> Self {
        self.cfg.cols = cols;
        self.cfg.rows = rows;
        self
    }

    pub fn scheduler(mut self, kind: SchedulerKind) -> Self {
        self.cfg.scheduler = kind;
        self
    }

    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.cfg.backend = backend;
        self
    }

    pub fn placement(mut self, policy: PlacementPolicy) -> Self {
        self.cfg.placement = policy;
        self
    }

    pub fn local_order(mut self, order: LocalOrder) -> Self {
        self.cfg.local_order = order;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn alu_latency(mut self, cycles: u64) -> Self {
        self.cfg.alu_latency = cycles;
        self
    }

    pub fn max_cycles(mut self, cycles: u64) -> Self {
        self.cfg.max_cycles = cycles;
        self
    }

    pub fn enforce_capacity(mut self, on: bool) -> Self {
        self.cfg.enforce_capacity = on;
        self
    }

    /// Enable the optimizing transform passes (dead-node elimination +
    /// constant replication) in the compile pipeline.
    pub fn opt(mut self, on: bool) -> Self {
        self.cfg.opt = on;
        self
    }

    pub fn bram(mut self, bram: BramConfig) -> Self {
        self.cfg.bram = bram;
        self
    }

    /// Multi-fabric sharding ([`crate::shard`]): `0` = auto (single
    /// fabric, sharded fallback when the graph does not fit), `N >= 1` =
    /// force an N-way sharded compile.
    pub fn shards(mut self, n: usize) -> Self {
        self.cfg.shards = n;
        self
    }

    /// Validate and produce the [`Overlay`].
    pub fn build(self) -> Result<Overlay, ConfigError> {
        Overlay::from_config(self.cfg)
    }
}

impl FromStr for SchedulerKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "in_order" | "in-order" | "inorder" | "fifo" => Ok(SchedulerKind::InOrder),
            "out_of_order" | "out-of-order" | "ooo" | "lod" => Ok(SchedulerKind::OutOfOrder),
            _ => Err(format!("unknown scheduler '{s}' (in_order | out_of_order)")),
        }
    }
}

impl SchedulerKind {
    pub fn toml_name(self) -> &'static str {
        match self {
            SchedulerKind::InOrder => "in_order",
            SchedulerKind::OutOfOrder => "out_of_order",
        }
    }
}

impl FromStr for BackendKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "lockstep" | "lock-step" | "reference" => Ok(BackendKind::Lockstep),
            "skip-ahead" | "skip_ahead" | "skipahead" | "event" => Ok(BackendKind::SkipAhead),
            _ => Err(format!("unknown backend '{s}' (lockstep | skip-ahead)")),
        }
    }
}

impl BackendKind {
    pub fn toml_name(self) -> &'static str {
        match self {
            BackendKind::Lockstep => "lockstep",
            BackendKind::SkipAhead => "skip_ahead",
        }
    }
}

impl FromStr for PlacementPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "round_robin" | "rr" => Ok(PlacementPolicy::RoundRobin),
            "random" => Ok(PlacementPolicy::Random),
            "block_contiguous" | "block" => Ok(PlacementPolicy::BlockContiguous),
            "chunked" => Ok(PlacementPolicy::Chunked),
            "traffic_aware" | "traffic" => Ok(PlacementPolicy::TrafficAware),
            _ => Err(format!(
                "unknown placement '{s}' (round_robin | random | block_contiguous | chunked | \
                 traffic_aware)"
            )),
        }
    }
}

impl PlacementPolicy {
    pub fn toml_name(self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round_robin",
            PlacementPolicy::Random => "random",
            PlacementPolicy::BlockContiguous => "block_contiguous",
            PlacementPolicy::Chunked => "chunked",
            PlacementPolicy::TrafficAware => "traffic_aware",
        }
    }
}

impl FromStr for LocalOrder {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "by_criticality" | "criticality" => Ok(LocalOrder::ByCriticality),
            "by_node_id" | "node_id" | "arrival" => Ok(LocalOrder::ByNodeId),
            _ => Err(format!("unknown local order '{s}' (by_criticality | by_node_id)")),
        }
    }
}

impl LocalOrder {
    pub fn toml_name(self) -> &'static str {
        match self {
            LocalOrder::ByCriticality => "by_criticality",
            LocalOrder::ByNodeId => "by_node_id",
        }
    }
}

/// Full overlay configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverlayConfig {
    /// torus width (columns). Paper design points: 1..16.
    pub cols: usize,
    /// torus height (rows).
    pub rows: usize,
    pub scheduler: SchedulerKind,
    pub bram: BramConfig,
    /// ALU retire latency in cycles (operand match + single-stage DSP).
    pub alu_latency: u64,
    pub placement: PlacementPolicy,
    pub local_order: LocalOrder,
    /// seed for placement / workload randomness
    pub seed: u64,
    /// hard cycle limit (safety net against livelock bugs)
    pub max_cycles: u64,
    /// enforce BRAM capacity at placement time (capacity experiments
    /// disable this to measure where designs *would* stop fitting)
    pub enforce_capacity: bool,
    /// run the optimizing transform passes (dead-node elimination +
    /// constant replication) in the compile pipeline. Off by default:
    /// the unoptimized artifact is the paper-faithful baseline
    pub opt: bool,
    /// simulation engine ([`crate::engine`]): the cycle-by-cycle
    /// reference or the bit-exact skip-ahead event backend
    pub backend: BackendKind,
    /// multi-fabric sharding ([`crate::shard`]): `0` (default) compiles
    /// for a single fabric, falling back to sharded execution when the
    /// graph does not fit and `enforce_capacity` is off; `N >= 1` forces
    /// an N-way sharded compile (1 exercises the sharded path over one
    /// fabric, bit-identical to a single-fabric run)
    pub shards: usize,
}

impl Default for OverlayConfig {
    fn default() -> Self {
        Self {
            cols: 16,
            rows: 16,
            scheduler: SchedulerKind::OutOfOrder,
            bram: BramConfig::paper(),
            alu_latency: 2,
            placement: PlacementPolicy::RoundRobin,
            local_order: LocalOrder::ByCriticality,
            seed: 0,
            max_cycles: 200_000_000,
            enforce_capacity: false,
            opt: false,
            backend: BackendKind::Lockstep,
            shards: 0,
        }
    }
}

impl OverlayConfig {
    pub fn num_pes(&self) -> usize {
        self.cols * self.rows
    }

    /// The paper's two Table-I design points.
    pub fn paper_1x1() -> Self {
        Self {
            cols: 1,
            rows: 1,
            ..Default::default()
        }
    }

    pub fn paper_16x16() -> Self {
        Self::default()
    }

    pub fn with_scheduler(mut self, kind: SchedulerKind) -> Self {
        self.scheduler = kind;
        self
    }

    pub fn with_dims(mut self, cols: usize, rows: usize) -> Self {
        self.cols = cols;
        self.rows = rows;
        self
    }

    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    /// Check every cross-knob constraint. Prefer [`Overlay::builder`] /
    /// [`Overlay::from_config`], which make validation non-optional.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let err = |msg: &str| Err(ConfigError(msg.to_string()));
        if self.cols == 0 || self.rows == 0 {
            return err("overlay dimensions must be >= 1");
        }
        if self.cols > 32 || self.rows > 32 {
            return err("torus coordinates are 5b: max 32x32 (packet format)");
        }
        if self.alu_latency == 0 {
            return err("alu_latency must be >= 1");
        }
        if self.max_cycles == 0 {
            return err("max_cycles must be >= 1");
        }
        if self.bram.brams_per_pe == 0 || self.bram.words_per_bram == 0 {
            return err("BRAM geometry must be non-zero");
        }
        // both would otherwise panic deep in construction: flag_bits_used
        // divides in BramConfig::flag_words, multipump sizes the
        // PortArbiter budget (>= 2 physical ports required)
        if self.bram.flag_bits_used == 0 || self.bram.flag_bits_used > self.bram.word_bits {
            return err("flag_bits_used must be in [1, word_bits]");
        }
        if self.bram.multipump == 0 {
            return err("multipump must be >= 1 (an M20K keeps its 2 physical ports)");
        }
        if self.bram.fifo_brams < 0.0 || self.bram.fifo_brams >= self.bram.brams_per_pe as f64 {
            return err("fifo_brams must be in [0, brams_per_pe)");
        }
        if self.shards > 64 {
            return err("shards must be <= 64 (0 = auto single-fabric)");
        }
        Ok(())
    }

    /// Recognized keys of the root table and the `[bram]` section —
    /// anything else is rejected by the strict loaders, so a typo'd knob
    /// fails loudly instead of silently keeping its default.
    const ROOT_KEYS: [&'static str; 12] = [
        "cols",
        "rows",
        "scheduler",
        "alu_latency",
        "placement",
        "local_order",
        "seed",
        "max_cycles",
        "enforce_capacity",
        "opt",
        "backend",
        "shards",
    ];
    const BRAM_KEYS: [&'static str; 6] = [
        "brams_per_pe",
        "words_per_bram",
        "word_bits",
        "flag_bits_used",
        "fifo_brams",
        "multipump",
    ];

    /// Reject unknown sections/keys in a parsed TOML document.
    fn check_known_keys(doc: &Doc) -> Result<(), String> {
        for (section, table) in &doc.sections {
            let allowed: &[&str] = match section.as_str() {
                "" => &Self::ROOT_KEYS,
                "bram" => &Self::BRAM_KEYS,
                other => return Err(format!("unknown config section '[{other}]'")),
            };
            for key in table.keys() {
                if !allowed.contains(&key.as_str()) {
                    let ctx = if section.is_empty() {
                        key.clone()
                    } else {
                        format!("{section}.{key}")
                    };
                    return Err(format!("unknown config key '{ctx}'"));
                }
            }
        }
        Ok(())
    }

    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = toml::parse(text).map_err(|e| e.to_string())?;
        Self::check_known_keys(&doc)?;
        let mut cfg = Self::default();
        let get_usize = |doc: &Doc, sec: &str, key: &str, cur: usize| -> Result<usize, String> {
            match doc.get(sec, key) {
                None => Ok(cur),
                Some(v) => v.as_usize().ok_or_else(|| format!("{key}: expected integer")),
            }
        };
        // u64 knobs above i64::MAX are written as strings (the TOML
        // subset's Int is i64) — accept both encodings
        let get_u64 = |doc: &Doc, key: &str, cur: u64| -> Result<u64, String> {
            match doc.get("", key) {
                None => Ok(cur),
                Some(Value::Int(i)) => u64::try_from(*i)
                    .map_err(|_| format!("{key}: expected non-negative integer")),
                Some(Value::Str(s)) => s
                    .parse::<u64>()
                    .map_err(|_| format!("{key}: expected non-negative integer")),
                Some(_) => Err(format!("{key}: expected non-negative integer")),
            }
        };
        cfg.cols = get_usize(&doc, "", "cols", cfg.cols)?;
        cfg.rows = get_usize(&doc, "", "rows", cfg.rows)?;
        cfg.shards = get_usize(&doc, "", "shards", cfg.shards)?;
        cfg.alu_latency = get_u64(&doc, "alu_latency", cfg.alu_latency)?;
        cfg.seed = get_u64(&doc, "seed", cfg.seed)?;
        cfg.max_cycles = get_u64(&doc, "max_cycles", cfg.max_cycles)?;
        if let Some(v) = doc.get("", "scheduler") {
            cfg.scheduler = v
                .as_str()
                .ok_or("scheduler: expected string")?
                .parse()?;
        }
        if let Some(v) = doc.get("", "placement") {
            cfg.placement = v.as_str().ok_or("placement: expected string")?.parse()?;
        }
        if let Some(v) = doc.get("", "local_order") {
            cfg.local_order = v.as_str().ok_or("local_order: expected string")?.parse()?;
        }
        if let Some(v) = doc.get("", "enforce_capacity") {
            cfg.enforce_capacity = v.as_bool().ok_or("enforce_capacity: expected bool")?;
        }
        if let Some(v) = doc.get("", "opt") {
            cfg.opt = v.as_bool().ok_or("opt: expected bool")?;
        }
        if let Some(v) = doc.get("", "backend") {
            cfg.backend = v.as_str().ok_or("backend: expected string")?.parse()?;
        }
        cfg.bram.brams_per_pe = get_usize(&doc, "bram", "brams_per_pe", cfg.bram.brams_per_pe)?;
        cfg.bram.words_per_bram =
            get_usize(&doc, "bram", "words_per_bram", cfg.bram.words_per_bram)?;
        cfg.bram.word_bits = get_usize(&doc, "bram", "word_bits", cfg.bram.word_bits)?;
        cfg.bram.flag_bits_used =
            get_usize(&doc, "bram", "flag_bits_used", cfg.bram.flag_bits_used)?;
        cfg.bram.multipump = get_usize(&doc, "bram", "multipump", cfg.bram.multipump)?;
        if let Some(v) = doc.get("bram", "fifo_brams") {
            cfg.bram.fifo_brams = v.as_f64().ok_or("fifo_brams: expected number")?;
        }
        cfg.validate().map_err(|e| e.0)?;
        Ok(cfg)
    }

    pub fn from_toml_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_toml(&text)
    }

    /// Exact TOML encoding for a u64 knob: Int up to i64::MAX, decimal
    /// string beyond (the strict loader accepts both) — a huge `seed`
    /// must survive save→load, not wrap negative.
    fn toml_u64(v: u64) -> Value {
        match i64::try_from(v) {
            Ok(i) => Value::Int(i),
            Err(_) => Value::Str(v.to_string()),
        }
    }

    pub fn to_toml(&self) -> String {
        let mut doc = Doc::new();
        doc.set("", "cols", Value::Int(self.cols as i64));
        doc.set("", "rows", Value::Int(self.rows as i64));
        doc.set("", "scheduler", Value::Str(self.scheduler.toml_name().into()));
        doc.set("", "alu_latency", Self::toml_u64(self.alu_latency));
        doc.set("", "placement", Value::Str(self.placement.toml_name().into()));
        doc.set("", "local_order", Value::Str(self.local_order.toml_name().into()));
        doc.set("", "seed", Self::toml_u64(self.seed));
        doc.set("", "max_cycles", Self::toml_u64(self.max_cycles));
        doc.set("", "enforce_capacity", Value::Bool(self.enforce_capacity));
        doc.set("", "opt", Value::Bool(self.opt));
        doc.set("", "backend", Value::Str(self.backend.toml_name().into()));
        doc.set("", "shards", Value::Int(self.shards as i64));
        doc.set("bram", "brams_per_pe", Value::Int(self.bram.brams_per_pe as i64));
        doc.set("bram", "words_per_bram", Value::Int(self.bram.words_per_bram as i64));
        doc.set("bram", "word_bits", Value::Int(self.bram.word_bits as i64));
        doc.set("bram", "flag_bits_used", Value::Int(self.bram.flag_bits_used as i64));
        doc.set("bram", "fifo_brams", Value::Float(self.bram.fifo_brams));
        doc.set("bram", "multipump", Value::Int(self.bram.multipump as i64));
        doc.render()
    }

    /// Exact JSON encoding for a u64 knob: a number while exactly
    /// representable as an f64 (≤ 2^53), a decimal string beyond (the
    /// strict loader accepts both) — never a silently rounded value.
    fn json_u64(v: u64) -> Json {
        if v <= (1u64 << 53) {
            Json::Num(v as f64)
        } else {
            Json::Str(v.to_string())
        }
    }

    /// JSON form of the config (same schema as the TOML form: flat knobs
    /// plus a nested `bram` object). u64 knobs above 2^53 are encoded as
    /// decimal strings (see [`OverlayConfig::from_json`]).
    pub fn to_json(&self) -> String {
        json::write(&self.to_json_value())
    }

    /// The [`OverlayConfig::to_json`] object as a [`Json`] value — for
    /// embedding in larger documents (service job specs).
    pub fn to_json_value(&self) -> Json {
        let mut bram = BTreeMap::new();
        bram.insert("brams_per_pe".to_string(), Json::Num(self.bram.brams_per_pe as f64));
        bram.insert("words_per_bram".to_string(), Json::Num(self.bram.words_per_bram as f64));
        bram.insert("word_bits".to_string(), Json::Num(self.bram.word_bits as f64));
        bram.insert("flag_bits_used".to_string(), Json::Num(self.bram.flag_bits_used as f64));
        bram.insert("fifo_brams".to_string(), Json::Num(self.bram.fifo_brams));
        bram.insert("multipump".to_string(), Json::Num(self.bram.multipump as f64));
        let mut root = BTreeMap::new();
        root.insert("cols".to_string(), Json::Num(self.cols as f64));
        root.insert("rows".to_string(), Json::Num(self.rows as f64));
        root.insert("scheduler".to_string(), Json::Str(self.scheduler.toml_name().into()));
        root.insert("alu_latency".to_string(), Self::json_u64(self.alu_latency));
        root.insert("placement".to_string(), Json::Str(self.placement.toml_name().into()));
        root.insert("local_order".to_string(), Json::Str(self.local_order.toml_name().into()));
        root.insert("seed".to_string(), Self::json_u64(self.seed));
        root.insert("max_cycles".to_string(), Self::json_u64(self.max_cycles));
        root.insert("enforce_capacity".to_string(), Json::Bool(self.enforce_capacity));
        root.insert("opt".to_string(), Json::Bool(self.opt));
        root.insert("backend".to_string(), Json::Str(self.backend.toml_name().into()));
        root.insert("shards".to_string(), Json::Num(self.shards as f64));
        root.insert("bram".to_string(), Json::Obj(bram));
        Json::Obj(root)
    }

    /// Strict inverse of [`OverlayConfig::to_json`]: absent keys keep
    /// their defaults, unknown keys are rejected, and the result is
    /// validated.
    pub fn from_json(text: &str) -> Result<Self, String> {
        let j = json::parse(text).map_err(|e| e.to_string())?;
        Self::from_json_value(&j)
    }

    /// Parse from an already-parsed [`Json`] value (see
    /// [`OverlayConfig::from_json`]).
    pub fn from_json_value(j: &Json) -> Result<Self, String> {
        let obj = j.as_obj().ok_or("config JSON must be an object")?;
        let mut cfg = Self::default();
        // JSON numbers are doubles: above 2^53 the parse silently rounds,
        // which would load a *different* config (e.g. a changed seed)
        // with no diagnostic — reject instead of guessing
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        let usz = |key: &str, v: &Json| -> Result<usize, String> {
            v.as_f64()
                .filter(|n| *n >= 0.0 && *n <= MAX_EXACT && n.fract() == 0.0)
                .map(|n| n as usize)
                .ok_or_else(|| format!("{key}: expected non-negative integer (< 2^53)"))
        };
        // u64 knobs: a number (exact below 2^53) or a decimal string
        // (the exact encoding to_json uses above that)
        let u64v = |key: &str, v: &Json| -> Result<u64, String> {
            match v {
                Json::Num(n) if *n >= 0.0 && *n <= MAX_EXACT && n.fract() == 0.0 => {
                    Ok(*n as u64)
                }
                Json::Str(s) => s
                    .parse::<u64>()
                    .map_err(|_| format!("{key}: cannot parse '{s}' as u64")),
                _ => Err(format!(
                    "{key}: expected non-negative integer (number < 2^53, or decimal string)"
                )),
            }
        };
        let strv = |key: &str, v: &Json| -> Result<String, String> {
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| format!("{key}: expected string"))
        };
        for (key, v) in obj {
            match key.as_str() {
                "cols" => cfg.cols = usz(key, v)?,
                "rows" => cfg.rows = usz(key, v)?,
                "scheduler" => cfg.scheduler = strv(key, v)?.parse()?,
                "alu_latency" => cfg.alu_latency = u64v(key, v)?,
                "placement" => cfg.placement = strv(key, v)?.parse()?,
                "local_order" => cfg.local_order = strv(key, v)?.parse()?,
                "seed" => cfg.seed = u64v(key, v)?,
                "max_cycles" => cfg.max_cycles = u64v(key, v)?,
                "enforce_capacity" => {
                    cfg.enforce_capacity = match v {
                        Json::Bool(b) => *b,
                        _ => return Err("enforce_capacity: expected bool".into()),
                    }
                }
                "opt" => {
                    cfg.opt = match v {
                        Json::Bool(b) => *b,
                        _ => return Err("opt: expected bool".into()),
                    }
                }
                "backend" => cfg.backend = strv(key, v)?.parse()?,
                "shards" => cfg.shards = usz(key, v)?,
                "bram" => {
                    let table = v.as_obj().ok_or("bram: expected object")?;
                    for (k, bv) in table {
                        match k.as_str() {
                            "brams_per_pe" => cfg.bram.brams_per_pe = usz(k, bv)?,
                            "words_per_bram" => cfg.bram.words_per_bram = usz(k, bv)?,
                            "word_bits" => cfg.bram.word_bits = usz(k, bv)?,
                            "flag_bits_used" => cfg.bram.flag_bits_used = usz(k, bv)?,
                            "fifo_brams" => {
                                cfg.bram.fifo_brams =
                                    bv.as_f64().ok_or("fifo_brams: expected number")?
                            }
                            "multipump" => cfg.bram.multipump = usz(k, bv)?,
                            other => return Err(format!("unknown config key 'bram.{other}'")),
                        }
                    }
                }
                other => return Err(format!("unknown config key '{other}'")),
            }
        }
        cfg.validate().map_err(|e| e.0)?;
        Ok(cfg)
    }
}

/// A named workload specification (CLI + experiment configs), parsed from
/// a TOML table with a `kind` discriminator.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// sparse-LU elimination DAG of a banded matrix
    LuBanded { n: usize, half_bw: usize, fill: f64 },
    /// sparse-LU of a uniform random matrix
    LuRandom { n: usize, density: f64 },
    /// sparse-LU of a power-law matrix
    LuPowerLaw { n: usize, avg_degree: usize },
    /// random layered DAG
    Layered {
        inputs: usize,
        levels: usize,
        width: usize,
        lookback: usize,
    },
    /// binary reduction tree
    Reduction { width: usize },
    /// 1-D 3-point stencil
    Stencil { width: usize, steps: usize },
    /// FFT butterfly
    Butterfly { width: usize },
    /// pure sequential pivot chain: sparse-LU of a tridiagonal matrix
    /// (the depth-dominated extreme of the factorization regimes)
    Chain { n: usize },
    /// deep pivot chain + wide power-law bulk updates in one graph
    /// ([`crate::workload::factorization_mix`] — the shape of real
    /// elimination DAGs)
    Mix {
        chain_n: usize,
        bulk_n: usize,
        bulk_deg: usize,
    },
    /// Matrix Market file on disk
    MatrixMarket { path: String },
}

impl WorkloadSpec {
    /// Parse from a TOML snippet like `kind = "lu_banded"\nn = 100\n...`.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = toml::parse(text).map_err(|e| e.to_string())?;
        let kind = doc
            .get("", "kind")
            .and_then(|v| v.as_str())
            .ok_or("workload spec needs kind = \"...\"")?;
        let usz = |key: &str| -> Result<usize, String> {
            doc.get("", key)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| format!("workload '{kind}' needs integer {key}"))
        };
        let flt = |key: &str| -> Result<f64, String> {
            doc.get("", key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("workload '{kind}' needs number {key}"))
        };
        Ok(match kind {
            "lu_banded" => WorkloadSpec::LuBanded {
                n: usz("n")?,
                half_bw: usz("half_bw")?,
                fill: flt("fill")?,
            },
            "lu_random" => WorkloadSpec::LuRandom {
                n: usz("n")?,
                density: flt("density")?,
            },
            "lu_power_law" => WorkloadSpec::LuPowerLaw {
                n: usz("n")?,
                avg_degree: usz("avg_degree")?,
            },
            "layered" => WorkloadSpec::Layered {
                inputs: usz("inputs")?,
                levels: usz("levels")?,
                width: usz("width")?,
                lookback: usz("lookback")?,
            },
            "reduction" => WorkloadSpec::Reduction { width: usz("width")? },
            "stencil" => WorkloadSpec::Stencil {
                width: usz("width")?,
                steps: usz("steps")?,
            },
            "butterfly" => WorkloadSpec::Butterfly { width: usz("width")? },
            "chain" => WorkloadSpec::Chain { n: usz("n")? },
            "mix" => WorkloadSpec::Mix {
                chain_n: usz("chain_n")?,
                bulk_n: usz("bulk_n")?,
                bulk_deg: usz("bulk_deg")?,
            },
            "matrix_market" => WorkloadSpec::MatrixMarket {
                path: doc
                    .get("", "path")
                    .and_then(|v| v.as_str())
                    .ok_or("matrix_market needs path")?
                    .to_string(),
            },
            _ => return Err(format!("unknown workload kind '{kind}'")),
        })
    }

    /// Materialize the dataflow graph.
    pub fn build(&self, seed: u64) -> Result<crate::graph::DataflowGraph, String> {
        use crate::workload::*;
        Ok(match self {
            WorkloadSpec::LuBanded { n, half_bw, fill } => {
                let m = SparseMatrix::banded(*n, *half_bw, *fill, seed);
                lu_factorization_graph(&m).0
            }
            WorkloadSpec::LuRandom { n, density } => {
                let m = SparseMatrix::random(*n, *density, seed);
                lu_factorization_graph(&m).0
            }
            WorkloadSpec::LuPowerLaw { n, avg_degree } => {
                let m = SparseMatrix::power_law(*n, *avg_degree, seed);
                lu_factorization_graph(&m).0
            }
            WorkloadSpec::Layered {
                inputs,
                levels,
                width,
                lookback,
            } => layered_random(*inputs, *levels, *width, *lookback, seed),
            WorkloadSpec::Reduction { width } => {
                reduction_tree(*width, crate::graph::Op::Add, seed)
            }
            WorkloadSpec::Stencil { width, steps } => stencil_1d(*width, *steps, seed),
            WorkloadSpec::Butterfly { width } => butterfly_graph(*width, seed),
            WorkloadSpec::Chain { n } => {
                let m = SparseMatrix::banded(*n, 1, 1.0, seed);
                lu_factorization_graph(&m).0
            }
            WorkloadSpec::Mix { chain_n, bulk_n, bulk_deg } => {
                crate::workload::factorization_mix(*chain_n, *bulk_n, *bulk_deg, seed)
            }
            WorkloadSpec::MatrixMarket { path } => {
                let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
                let m = parse_matrix_market(&text)?;
                lu_factorization_graph(&m).0
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_16x16() {
        let c = OverlayConfig::default();
        assert_eq!(c.num_pes(), 256);
        assert_eq!(c.bram.brams_per_pe, 8);
        c.validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let c = OverlayConfig::paper_1x1().with_scheduler(SchedulerKind::InOrder);
        let text = c.to_toml();
        let c2 = OverlayConfig::from_toml(&text).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn partial_toml_uses_defaults() {
        let c = OverlayConfig::from_toml("cols = 4\nrows = 2\n").unwrap();
        assert_eq!(c.num_pes(), 8);
        assert_eq!(c.scheduler, SchedulerKind::OutOfOrder);
        assert_eq!(c.bram.brams_per_pe, 8);
    }

    #[test]
    fn scheduler_aliases_parse() {
        for (s, k) in [
            ("fifo", SchedulerKind::InOrder),
            ("in-order", SchedulerKind::InOrder),
            ("ooo", SchedulerKind::OutOfOrder),
            ("lod", SchedulerKind::OutOfOrder),
        ] {
            assert_eq!(s.parse::<SchedulerKind>().unwrap(), k);
        }
        assert!("bogus".parse::<SchedulerKind>().is_err());
    }

    #[test]
    fn backend_aliases_parse() {
        for (s, k) in [
            ("lockstep", BackendKind::Lockstep),
            ("reference", BackendKind::Lockstep),
            ("skip-ahead", BackendKind::SkipAhead),
            ("skip_ahead", BackendKind::SkipAhead),
            ("skipahead", BackendKind::SkipAhead),
        ] {
            assert_eq!(s.parse::<BackendKind>().unwrap(), k);
        }
        assert!("bogus".parse::<BackendKind>().is_err());
    }

    #[test]
    fn placement_aliases_parse() {
        for (s, k) in [
            ("rr", PlacementPolicy::RoundRobin),
            ("block", PlacementPolicy::BlockContiguous),
            ("traffic_aware", PlacementPolicy::TrafficAware),
            ("traffic", PlacementPolicy::TrafficAware),
        ] {
            assert_eq!(s.parse::<PlacementPolicy>().unwrap(), k);
        }
        let e = "bogus".parse::<PlacementPolicy>().unwrap_err();
        assert!(e.contains("traffic_aware"), "error lists every policy: {e}");
    }

    #[test]
    fn opt_knob_roundtrips_and_defaults_off() {
        assert!(!OverlayConfig::default().opt);
        let c = OverlayConfig::from_toml("opt = true\n").unwrap();
        assert!(c.opt);
        assert_eq!(OverlayConfig::from_toml(&c.to_toml()).unwrap(), c);
        let j = OverlayConfig::from_json("{\"opt\": true}").unwrap();
        assert!(j.opt);
        assert!(OverlayConfig::from_json("{\"opt\": 1}").is_err());
    }

    #[test]
    fn backend_toml_roundtrip() {
        let c = OverlayConfig::paper_1x1().with_backend(BackendKind::SkipAhead);
        let c2 = OverlayConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(c2.backend, BackendKind::SkipAhead);
        let d = OverlayConfig::from_toml("backend = \"skip_ahead\"\n").unwrap();
        assert_eq!(d.backend, BackendKind::SkipAhead);
        assert_eq!(OverlayConfig::default().backend, BackendKind::Lockstep);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(OverlayConfig::from_toml("cols = 0\n").is_err());
        assert!(OverlayConfig::from_toml("backend = \"bogus\"\n").is_err());
        assert!(OverlayConfig::from_toml("cols = 64\n").is_err());
        assert!(OverlayConfig::from_toml("alu_latency = 0\n").is_err());
        assert!(OverlayConfig::from_toml("scheduler = \"bogus\"\n").is_err());
        assert!(OverlayConfig::from_toml("[bram]\nfifo_brams = 8.0\n").is_err());
        // regression: these used to pass validation and panic later —
        // flag_bits_used = 0 divided by zero in BramConfig::flag_words,
        // multipump = 0 tripped the PortArbiter budget assert, and
        // max_cycles = 0 made every run report a bogus cycle-limit error
        assert!(OverlayConfig::from_toml("[bram]\nflag_bits_used = 0\n").is_err());
        assert!(OverlayConfig::from_toml("[bram]\nflag_bits_used = 64\n").is_err());
        assert!(OverlayConfig::from_toml("[bram]\nmultipump = 0\n").is_err());
        assert!(OverlayConfig::from_toml("max_cycles = 0\n").is_err());
    }

    /// The smallest legal values of the newly-validated knobs must still
    /// construct and run (multipump = 1 is the no-multipump ablation).
    #[test]
    fn minimal_legal_bram_knobs_still_run() {
        let toml = "cols = 1\nrows = 1\n[bram]\nmultipump = 1\nflag_bits_used = 1\n";
        let c = OverlayConfig::from_toml(toml).unwrap();
        assert_eq!(c.bram.ports_per_cycle(), 2);
        let mut g = crate::graph::DataflowGraph::new();
        let a = g.add_input(1.0);
        let b = g.add_input(2.0);
        g.op(crate::graph::Op::Add, &[a, b]);
        let overlay = Overlay::from_config(c).unwrap();
        let program = crate::program::Program::compile(&g, &overlay).unwrap();
        let stats = program.session().run().unwrap();
        assert_eq!(stats.completed, 3);
    }

    #[test]
    fn unknown_toml_keys_rejected() {
        let e = OverlayConfig::from_toml("cols = 4\nbogus_knob = 1\n").unwrap_err();
        assert!(e.contains("bogus_knob"), "{e}");
        let e = OverlayConfig::from_toml("[bram]\nbogus = 1\n").unwrap_err();
        assert!(e.contains("bram.bogus"), "{e}");
        let e = OverlayConfig::from_toml("[nonsense]\nx = 1\n").unwrap_err();
        assert!(e.contains("nonsense"), "{e}");
    }

    /// The knob name lists exist in several places (struct, serializers,
    /// strict-loader allowlists); this pins them together so a knob
    /// added to the serializers but not the allowlists fails here with
    /// an explicit message instead of as a puzzling round-trip error.
    #[test]
    fn serializers_and_allowlists_stay_in_sync() {
        let doc = toml::parse(&OverlayConfig::default().to_toml()).unwrap();
        let root: Vec<&str> = doc.sections[""].keys().map(|s| s.as_str()).collect();
        let mut want = OverlayConfig::ROOT_KEYS.to_vec();
        want.sort_unstable();
        assert_eq!(root, want, "to_toml must write exactly the accepted root keys");
        let bram: Vec<&str> = doc.sections["bram"].keys().map(|s| s.as_str()).collect();
        let mut want_bram = OverlayConfig::BRAM_KEYS.to_vec();
        want_bram.sort_unstable();
        assert_eq!(bram, want_bram, "to_toml must write exactly the accepted [bram] keys");
        // and the JSON serializer emits the same schema (bram nested)
        let j = json::parse(&OverlayConfig::default().to_json()).unwrap();
        let obj = j.as_obj().unwrap();
        let mut json_root: Vec<&str> =
            obj.keys().map(|s| s.as_str()).filter(|k| *k != "bram").collect();
        json_root.sort_unstable();
        assert_eq!(json_root, want, "to_json must write exactly the accepted root keys");
        let json_bram: Vec<&str> =
            obj["bram"].as_obj().unwrap().keys().map(|s| s.as_str()).collect();
        assert_eq!(json_bram, want_bram);
    }

    #[test]
    fn json_roundtrip_defaults() {
        let c = OverlayConfig::default();
        let c2 = OverlayConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn unknown_json_keys_rejected() {
        assert!(OverlayConfig::from_json("{\"bogus\": 1}").is_err());
        assert!(OverlayConfig::from_json("{\"bram\": {\"bogus\": 1}}").is_err());
        assert!(OverlayConfig::from_json("{\"cols\": \"sixteen\"}").is_err());
        assert!(OverlayConfig::from_json("[1, 2]").is_err());
    }

    #[test]
    fn builder_validates_on_build() {
        let overlay = Overlay::builder()
            .dims(2, 3)
            .scheduler(SchedulerKind::InOrder)
            .backend(BackendKind::SkipAhead)
            .seed(7)
            .build()
            .unwrap();
        assert_eq!(overlay.num_pes(), 6);
        assert_eq!(overlay.config().scheduler, SchedulerKind::InOrder);
        assert_eq!(overlay.config().backend, BackendKind::SkipAhead);
        assert!(Overlay::builder().dims(0, 4).build().is_err());
        assert!(Overlay::builder().dims(33, 1).build().is_err());
        assert!(Overlay::builder().alu_latency(0).build().is_err());
        assert!(Overlay::from_config(OverlayConfig::default()).is_ok());
    }

    #[test]
    fn bram_section_overrides() {
        let c = OverlayConfig::from_toml("[bram]\nbrams_per_pe = 4\nfifo_brams = 2.5\n").unwrap();
        assert_eq!(c.bram.brams_per_pe, 4);
        assert_eq!(c.bram.fifo_brams, 2.5);
    }

    #[test]
    fn workload_specs_build() {
        let specs = [
            WorkloadSpec::LuBanded { n: 20, half_bw: 2, fill: 0.9 },
            WorkloadSpec::Layered { inputs: 4, levels: 3, width: 8, lookback: 1 },
            WorkloadSpec::Reduction { width: 16 },
            WorkloadSpec::Stencil { width: 8, steps: 2 },
            WorkloadSpec::Butterfly { width: 8 },
            WorkloadSpec::Chain { n: 16 },
            WorkloadSpec::Mix { chain_n: 12, bulk_n: 16, bulk_deg: 2 },
        ];
        for s in &specs {
            let g = s.build(1).unwrap();
            assert!(g.len() > 0);
            g.validate().unwrap();
        }
    }

    #[test]
    fn workload_spec_toml() {
        let s = WorkloadSpec::from_toml("kind = \"lu_banded\"\nn = 10\nhalf_bw = 2\nfill = 0.5\n")
            .unwrap();
        assert_eq!(s, WorkloadSpec::LuBanded { n: 10, half_bw: 2, fill: 0.5 });
        assert!(WorkloadSpec::from_toml("kind = \"nope\"\n").is_err());
        assert!(WorkloadSpec::from_toml("kind = \"lu_banded\"\nn = 10\n").is_err());
        let c = WorkloadSpec::from_toml("kind = \"chain\"\nn = 32\n").unwrap();
        assert_eq!(c, WorkloadSpec::Chain { n: 32 });
        let m =
            WorkloadSpec::from_toml("kind = \"mix\"\nchain_n = 20\nbulk_n = 40\nbulk_deg = 2\n")
                .unwrap();
        assert_eq!(m, WorkloadSpec::Mix { chain_n: 20, bulk_n: 40, bulk_deg: 2 });
    }
}
