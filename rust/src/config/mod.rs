//! Configuration system: every architectural knob of the overlay, the
//! placement, and workload specs — TOML loading (via `util::toml`) with
//! paper-faithful defaults.

use crate::engine::BackendKind;
use crate::pe::BramConfig;
use crate::place::{LocalOrder, PlacementPolicy};
use crate::sched::SchedulerKind;
use crate::util::toml::{self, Doc, Value};
use std::path::Path;
use std::str::FromStr;

impl FromStr for SchedulerKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "in_order" | "in-order" | "inorder" | "fifo" => Ok(SchedulerKind::InOrder),
            "out_of_order" | "out-of-order" | "ooo" | "lod" => Ok(SchedulerKind::OutOfOrder),
            _ => Err(format!("unknown scheduler '{s}' (in_order | out_of_order)")),
        }
    }
}

impl SchedulerKind {
    pub fn toml_name(self) -> &'static str {
        match self {
            SchedulerKind::InOrder => "in_order",
            SchedulerKind::OutOfOrder => "out_of_order",
        }
    }
}

impl FromStr for BackendKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "lockstep" | "lock-step" | "reference" => Ok(BackendKind::Lockstep),
            "skip-ahead" | "skip_ahead" | "skipahead" | "event" => Ok(BackendKind::SkipAhead),
            _ => Err(format!("unknown backend '{s}' (lockstep | skip-ahead)")),
        }
    }
}

impl BackendKind {
    pub fn toml_name(self) -> &'static str {
        match self {
            BackendKind::Lockstep => "lockstep",
            BackendKind::SkipAhead => "skip_ahead",
        }
    }
}

impl FromStr for PlacementPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "round_robin" | "rr" => Ok(PlacementPolicy::RoundRobin),
            "random" => Ok(PlacementPolicy::Random),
            "block_contiguous" | "block" => Ok(PlacementPolicy::BlockContiguous),
            "chunked" => Ok(PlacementPolicy::Chunked),
            _ => Err(format!(
                "unknown placement '{s}' (round_robin | random | block_contiguous | chunked)"
            )),
        }
    }
}

impl PlacementPolicy {
    pub fn toml_name(self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round_robin",
            PlacementPolicy::Random => "random",
            PlacementPolicy::BlockContiguous => "block_contiguous",
            PlacementPolicy::Chunked => "chunked",
        }
    }
}

impl FromStr for LocalOrder {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "by_criticality" | "criticality" => Ok(LocalOrder::ByCriticality),
            "by_node_id" | "node_id" | "arrival" => Ok(LocalOrder::ByNodeId),
            _ => Err(format!("unknown local order '{s}' (by_criticality | by_node_id)")),
        }
    }
}

impl LocalOrder {
    pub fn toml_name(self) -> &'static str {
        match self {
            LocalOrder::ByCriticality => "by_criticality",
            LocalOrder::ByNodeId => "by_node_id",
        }
    }
}

/// Full overlay configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverlayConfig {
    /// torus width (columns). Paper design points: 1..16.
    pub cols: usize,
    /// torus height (rows).
    pub rows: usize,
    pub scheduler: SchedulerKind,
    pub bram: BramConfig,
    /// ALU retire latency in cycles (operand match + single-stage DSP).
    pub alu_latency: u64,
    pub placement: PlacementPolicy,
    pub local_order: LocalOrder,
    /// seed for placement / workload randomness
    pub seed: u64,
    /// hard cycle limit (safety net against livelock bugs)
    pub max_cycles: u64,
    /// enforce BRAM capacity at placement time (capacity experiments
    /// disable this to measure where designs *would* stop fitting)
    pub enforce_capacity: bool,
    /// simulation engine ([`crate::engine`]): the cycle-by-cycle
    /// reference or the bit-exact skip-ahead event backend
    pub backend: BackendKind,
}

impl Default for OverlayConfig {
    fn default() -> Self {
        Self {
            cols: 16,
            rows: 16,
            scheduler: SchedulerKind::OutOfOrder,
            bram: BramConfig::paper(),
            alu_latency: 2,
            placement: PlacementPolicy::RoundRobin,
            local_order: LocalOrder::ByCriticality,
            seed: 0,
            max_cycles: 200_000_000,
            enforce_capacity: false,
            backend: BackendKind::Lockstep,
        }
    }
}

impl OverlayConfig {
    pub fn num_pes(&self) -> usize {
        self.cols * self.rows
    }

    /// The paper's two Table-I design points.
    pub fn paper_1x1() -> Self {
        Self {
            cols: 1,
            rows: 1,
            ..Default::default()
        }
    }

    pub fn paper_16x16() -> Self {
        Self::default()
    }

    pub fn with_scheduler(mut self, kind: SchedulerKind) -> Self {
        self.scheduler = kind;
        self
    }

    pub fn with_dims(mut self, cols: usize, rows: usize) -> Self {
        self.cols = cols;
        self.rows = rows;
        self
    }

    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.cols == 0 || self.rows == 0 {
            return Err("overlay dimensions must be >= 1".into());
        }
        if self.cols > 32 || self.rows > 32 {
            return Err("torus coordinates are 5b: max 32x32 (packet format)".into());
        }
        if self.alu_latency == 0 {
            return Err("alu_latency must be >= 1".into());
        }
        if self.max_cycles == 0 {
            return Err("max_cycles must be >= 1".into());
        }
        if self.bram.brams_per_pe == 0 || self.bram.words_per_bram == 0 {
            return Err("BRAM geometry must be non-zero".into());
        }
        // both would otherwise panic deep in construction: flag_bits_used
        // divides in BramConfig::flag_words, multipump sizes the
        // PortArbiter budget (>= 2 physical ports required)
        if self.bram.flag_bits_used == 0 || self.bram.flag_bits_used > self.bram.word_bits {
            return Err("flag_bits_used must be in [1, word_bits]".into());
        }
        if self.bram.multipump == 0 {
            return Err("multipump must be >= 1 (an M20K keeps its 2 physical ports)".into());
        }
        if self.bram.fifo_brams < 0.0 || self.bram.fifo_brams >= self.bram.brams_per_pe as f64 {
            return Err("fifo_brams must be in [0, brams_per_pe)".into());
        }
        Ok(())
    }

    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = toml::parse(text).map_err(|e| e.to_string())?;
        let mut cfg = Self::default();
        let get_usize = |doc: &Doc, sec: &str, key: &str, cur: usize| -> Result<usize, String> {
            match doc.get(sec, key) {
                None => Ok(cur),
                Some(v) => v.as_usize().ok_or_else(|| format!("{key}: expected integer")),
            }
        };
        let get_u64 = |doc: &Doc, key: &str, cur: u64| -> Result<u64, String> {
            match doc.get("", key) {
                None => Ok(cur),
                Some(v) => v
                    .as_i64()
                    .and_then(|i| u64::try_from(i).ok())
                    .ok_or_else(|| format!("{key}: expected non-negative integer")),
            }
        };
        cfg.cols = get_usize(&doc, "", "cols", cfg.cols)?;
        cfg.rows = get_usize(&doc, "", "rows", cfg.rows)?;
        cfg.alu_latency = get_u64(&doc, "alu_latency", cfg.alu_latency)?;
        cfg.seed = get_u64(&doc, "seed", cfg.seed)?;
        cfg.max_cycles = get_u64(&doc, "max_cycles", cfg.max_cycles)?;
        if let Some(v) = doc.get("", "scheduler") {
            cfg.scheduler = v
                .as_str()
                .ok_or("scheduler: expected string")?
                .parse()?;
        }
        if let Some(v) = doc.get("", "placement") {
            cfg.placement = v.as_str().ok_or("placement: expected string")?.parse()?;
        }
        if let Some(v) = doc.get("", "local_order") {
            cfg.local_order = v.as_str().ok_or("local_order: expected string")?.parse()?;
        }
        if let Some(v) = doc.get("", "enforce_capacity") {
            cfg.enforce_capacity = v.as_bool().ok_or("enforce_capacity: expected bool")?;
        }
        if let Some(v) = doc.get("", "backend") {
            cfg.backend = v.as_str().ok_or("backend: expected string")?.parse()?;
        }
        cfg.bram.brams_per_pe = get_usize(&doc, "bram", "brams_per_pe", cfg.bram.brams_per_pe)?;
        cfg.bram.words_per_bram =
            get_usize(&doc, "bram", "words_per_bram", cfg.bram.words_per_bram)?;
        cfg.bram.word_bits = get_usize(&doc, "bram", "word_bits", cfg.bram.word_bits)?;
        cfg.bram.flag_bits_used =
            get_usize(&doc, "bram", "flag_bits_used", cfg.bram.flag_bits_used)?;
        cfg.bram.multipump = get_usize(&doc, "bram", "multipump", cfg.bram.multipump)?;
        if let Some(v) = doc.get("bram", "fifo_brams") {
            cfg.bram.fifo_brams = v.as_f64().ok_or("fifo_brams: expected number")?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_toml_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_toml(&text)
    }

    pub fn to_toml(&self) -> String {
        let mut doc = Doc::new();
        doc.set("", "cols", Value::Int(self.cols as i64));
        doc.set("", "rows", Value::Int(self.rows as i64));
        doc.set("", "scheduler", Value::Str(self.scheduler.toml_name().into()));
        doc.set("", "alu_latency", Value::Int(self.alu_latency as i64));
        doc.set("", "placement", Value::Str(self.placement.toml_name().into()));
        doc.set("", "local_order", Value::Str(self.local_order.toml_name().into()));
        doc.set("", "seed", Value::Int(self.seed as i64));
        doc.set("", "max_cycles", Value::Int(self.max_cycles as i64));
        doc.set("", "enforce_capacity", Value::Bool(self.enforce_capacity));
        doc.set("", "backend", Value::Str(self.backend.toml_name().into()));
        doc.set("bram", "brams_per_pe", Value::Int(self.bram.brams_per_pe as i64));
        doc.set("bram", "words_per_bram", Value::Int(self.bram.words_per_bram as i64));
        doc.set("bram", "word_bits", Value::Int(self.bram.word_bits as i64));
        doc.set("bram", "flag_bits_used", Value::Int(self.bram.flag_bits_used as i64));
        doc.set("bram", "fifo_brams", Value::Float(self.bram.fifo_brams));
        doc.set("bram", "multipump", Value::Int(self.bram.multipump as i64));
        doc.render()
    }
}

/// A named workload specification (CLI + experiment configs), parsed from
/// a TOML table with a `kind` discriminator.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// sparse-LU elimination DAG of a banded matrix
    LuBanded { n: usize, half_bw: usize, fill: f64 },
    /// sparse-LU of a uniform random matrix
    LuRandom { n: usize, density: f64 },
    /// sparse-LU of a power-law matrix
    LuPowerLaw { n: usize, avg_degree: usize },
    /// random layered DAG
    Layered {
        inputs: usize,
        levels: usize,
        width: usize,
        lookback: usize,
    },
    /// binary reduction tree
    Reduction { width: usize },
    /// 1-D 3-point stencil
    Stencil { width: usize, steps: usize },
    /// FFT butterfly
    Butterfly { width: usize },
    /// Matrix Market file on disk
    MatrixMarket { path: String },
}

impl WorkloadSpec {
    /// Parse from a TOML snippet like `kind = "lu_banded"\nn = 100\n...`.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = toml::parse(text).map_err(|e| e.to_string())?;
        let kind = doc
            .get("", "kind")
            .and_then(|v| v.as_str())
            .ok_or("workload spec needs kind = \"...\"")?;
        let usz = |key: &str| -> Result<usize, String> {
            doc.get("", key)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| format!("workload '{kind}' needs integer {key}"))
        };
        let flt = |key: &str| -> Result<f64, String> {
            doc.get("", key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("workload '{kind}' needs number {key}"))
        };
        Ok(match kind {
            "lu_banded" => WorkloadSpec::LuBanded {
                n: usz("n")?,
                half_bw: usz("half_bw")?,
                fill: flt("fill")?,
            },
            "lu_random" => WorkloadSpec::LuRandom {
                n: usz("n")?,
                density: flt("density")?,
            },
            "lu_power_law" => WorkloadSpec::LuPowerLaw {
                n: usz("n")?,
                avg_degree: usz("avg_degree")?,
            },
            "layered" => WorkloadSpec::Layered {
                inputs: usz("inputs")?,
                levels: usz("levels")?,
                width: usz("width")?,
                lookback: usz("lookback")?,
            },
            "reduction" => WorkloadSpec::Reduction { width: usz("width")? },
            "stencil" => WorkloadSpec::Stencil {
                width: usz("width")?,
                steps: usz("steps")?,
            },
            "butterfly" => WorkloadSpec::Butterfly { width: usz("width")? },
            "matrix_market" => WorkloadSpec::MatrixMarket {
                path: doc
                    .get("", "path")
                    .and_then(|v| v.as_str())
                    .ok_or("matrix_market needs path")?
                    .to_string(),
            },
            _ => return Err(format!("unknown workload kind '{kind}'")),
        })
    }

    /// Materialize the dataflow graph.
    pub fn build(&self, seed: u64) -> Result<crate::graph::DataflowGraph, String> {
        use crate::workload::*;
        Ok(match self {
            WorkloadSpec::LuBanded { n, half_bw, fill } => {
                let m = SparseMatrix::banded(*n, *half_bw, *fill, seed);
                lu_factorization_graph(&m).0
            }
            WorkloadSpec::LuRandom { n, density } => {
                let m = SparseMatrix::random(*n, *density, seed);
                lu_factorization_graph(&m).0
            }
            WorkloadSpec::LuPowerLaw { n, avg_degree } => {
                let m = SparseMatrix::power_law(*n, *avg_degree, seed);
                lu_factorization_graph(&m).0
            }
            WorkloadSpec::Layered {
                inputs,
                levels,
                width,
                lookback,
            } => layered_random(*inputs, *levels, *width, *lookback, seed),
            WorkloadSpec::Reduction { width } => {
                reduction_tree(*width, crate::graph::Op::Add, seed)
            }
            WorkloadSpec::Stencil { width, steps } => stencil_1d(*width, *steps, seed),
            WorkloadSpec::Butterfly { width } => butterfly_graph(*width, seed),
            WorkloadSpec::MatrixMarket { path } => {
                let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
                let m = parse_matrix_market(&text)?;
                lu_factorization_graph(&m).0
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_16x16() {
        let c = OverlayConfig::default();
        assert_eq!(c.num_pes(), 256);
        assert_eq!(c.bram.brams_per_pe, 8);
        c.validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let c = OverlayConfig::paper_1x1().with_scheduler(SchedulerKind::InOrder);
        let text = c.to_toml();
        let c2 = OverlayConfig::from_toml(&text).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn partial_toml_uses_defaults() {
        let c = OverlayConfig::from_toml("cols = 4\nrows = 2\n").unwrap();
        assert_eq!(c.num_pes(), 8);
        assert_eq!(c.scheduler, SchedulerKind::OutOfOrder);
        assert_eq!(c.bram.brams_per_pe, 8);
    }

    #[test]
    fn scheduler_aliases_parse() {
        for (s, k) in [
            ("fifo", SchedulerKind::InOrder),
            ("in-order", SchedulerKind::InOrder),
            ("ooo", SchedulerKind::OutOfOrder),
            ("lod", SchedulerKind::OutOfOrder),
        ] {
            assert_eq!(s.parse::<SchedulerKind>().unwrap(), k);
        }
        assert!("bogus".parse::<SchedulerKind>().is_err());
    }

    #[test]
    fn backend_aliases_parse() {
        for (s, k) in [
            ("lockstep", BackendKind::Lockstep),
            ("reference", BackendKind::Lockstep),
            ("skip-ahead", BackendKind::SkipAhead),
            ("skip_ahead", BackendKind::SkipAhead),
            ("skipahead", BackendKind::SkipAhead),
        ] {
            assert_eq!(s.parse::<BackendKind>().unwrap(), k);
        }
        assert!("bogus".parse::<BackendKind>().is_err());
    }

    #[test]
    fn backend_toml_roundtrip() {
        let c = OverlayConfig::paper_1x1().with_backend(BackendKind::SkipAhead);
        let c2 = OverlayConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(c2.backend, BackendKind::SkipAhead);
        let d = OverlayConfig::from_toml("backend = \"skip_ahead\"\n").unwrap();
        assert_eq!(d.backend, BackendKind::SkipAhead);
        assert_eq!(OverlayConfig::default().backend, BackendKind::Lockstep);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(OverlayConfig::from_toml("cols = 0\n").is_err());
        assert!(OverlayConfig::from_toml("backend = \"bogus\"\n").is_err());
        assert!(OverlayConfig::from_toml("cols = 64\n").is_err());
        assert!(OverlayConfig::from_toml("alu_latency = 0\n").is_err());
        assert!(OverlayConfig::from_toml("scheduler = \"bogus\"\n").is_err());
        assert!(OverlayConfig::from_toml("[bram]\nfifo_brams = 8.0\n").is_err());
        // regression: these used to pass validation and panic later —
        // flag_bits_used = 0 divided by zero in BramConfig::flag_words,
        // multipump = 0 tripped the PortArbiter budget assert, and
        // max_cycles = 0 made every run report a bogus cycle-limit error
        assert!(OverlayConfig::from_toml("[bram]\nflag_bits_used = 0\n").is_err());
        assert!(OverlayConfig::from_toml("[bram]\nflag_bits_used = 64\n").is_err());
        assert!(OverlayConfig::from_toml("[bram]\nmultipump = 0\n").is_err());
        assert!(OverlayConfig::from_toml("max_cycles = 0\n").is_err());
    }

    /// The smallest legal values of the newly-validated knobs must still
    /// construct and run (multipump = 1 is the no-multipump ablation).
    #[test]
    fn minimal_legal_bram_knobs_still_run() {
        let toml = "cols = 1\nrows = 1\n[bram]\nmultipump = 1\nflag_bits_used = 1\n";
        let c = OverlayConfig::from_toml(toml).unwrap();
        assert_eq!(c.bram.ports_per_cycle(), 2);
        let mut g = crate::graph::DataflowGraph::new();
        let a = g.add_input(1.0);
        let b = g.add_input(2.0);
        g.op(crate::graph::Op::Add, &[a, b]);
        let stats = crate::engine::run_with_backend(&g, c).unwrap();
        assert_eq!(stats.completed, 3);
    }

    #[test]
    fn bram_section_overrides() {
        let c = OverlayConfig::from_toml("[bram]\nbrams_per_pe = 4\nfifo_brams = 2.5\n").unwrap();
        assert_eq!(c.bram.brams_per_pe, 4);
        assert_eq!(c.bram.fifo_brams, 2.5);
    }

    #[test]
    fn workload_specs_build() {
        let specs = [
            WorkloadSpec::LuBanded { n: 20, half_bw: 2, fill: 0.9 },
            WorkloadSpec::Layered { inputs: 4, levels: 3, width: 8, lookback: 1 },
            WorkloadSpec::Reduction { width: 16 },
            WorkloadSpec::Stencil { width: 8, steps: 2 },
            WorkloadSpec::Butterfly { width: 8 },
        ];
        for s in &specs {
            let g = s.build(1).unwrap();
            assert!(g.len() > 0);
            g.validate().unwrap();
        }
    }

    #[test]
    fn workload_spec_toml() {
        let s = WorkloadSpec::from_toml("kind = \"lu_banded\"\nn = 10\nhalf_bw = 2\nfill = 0.5\n")
            .unwrap();
        assert_eq!(s, WorkloadSpec::LuBanded { n: 10, half_bw: 2, fill: 0.5 });
        assert!(WorkloadSpec::from_toml("kind = \"nope\"\n").is_err());
        assert!(WorkloadSpec::from_toml("kind = \"lu_banded\"\nn = 10\n").is_err());
    }
}
