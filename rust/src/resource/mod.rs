//! Analytic FPGA resource & frequency model — regenerates Table I.
//!
//! We have no Quartus: the model is calibrated to the paper's published
//! synthesis points (Table I and its router footnote) and exposes the
//! scaling law between them. Calibration anchors:
//!
//! * one PE+router tile: ≈1.4 K ALMs, ≈2.2 K regs, 2 DSPs, 8 M20Ks;
//! * one Hoplite router alone: 130 ALMs, 350 regs, >400 MHz;
//! * 1×1 overlay: 306 MHz; 16×16 (256 PE): 258 MHz; ≈300 PEs: ≈250 MHz
//!   — a ≈6 MHz Fmax derate per doubling of PE count (routing pressure);
//! * device: Arria 10 10AX115S — 427,200 ALMs, 1,708,800 regs (4/ALM),
//!   1,518 DSPs, 2,713 M20Ks.

/// Arria 10 10AX115S device capacity.
#[derive(Debug, Clone, Copy)]
pub struct Device {
    pub alms: u64,
    pub regs: u64,
    pub dsps: u64,
    pub brams: u64,
}

pub const ARRIA10_10AX115S: Device = Device {
    alms: 427_200,
    regs: 1_708_800,
    dsps: 1_518,
    brams: 2_713,
};

/// Per-tile calibration constants (Table I anchors).
pub mod tile {
    /// full PE+router tile ALMs: 256 tiles = 367 K ALMs (Table I row 2)
    pub const ALMS: u64 = 1_434;
    /// registers per tile: 559 K / 256
    pub const REGS: u64 = 2_184;
    /// hardened FP DSP blocks per PE (ADD + MULTIPLY)
    pub const DSPS: u64 = 2;
    /// M20K blocks per PE
    pub const BRAMS: u64 = 8;
    /// Hoplite router share of the tile (footnote)
    pub const ROUTER_ALMS: u64 = 130;
    pub const ROUTER_REGS: u64 = 350;
}

/// Estimated utilization of one overlay design point.
#[derive(Debug, Clone, Copy)]
pub struct ResourceEstimate {
    pub pes: usize,
    pub alms: u64,
    pub regs: u64,
    pub dsps: u64,
    pub brams: u64,
    pub alm_pct: f64,
    pub reg_pct: f64,
    pub dsp_pct: f64,
    pub bram_pct: f64,
    pub fmax_mhz: f64,
}

/// Fmax model: 306 MHz single tile, derated ~6 MHz per doubling
/// (Table I: 306 @ 1, 258 @ 256; abstract: up to 300 PEs at ~250 MHz).
pub fn fmax_mhz(pes: usize) -> f64 {
    assert!(pes >= 1);
    306.0 - 6.0 * (pes as f64).log2()
}

/// Estimate resources for an overlay of `pes` processors on `dev`.
pub fn estimate(pes: usize, dev: &Device) -> ResourceEstimate {
    let alms = tile::ALMS * pes as u64;
    let regs = tile::REGS * pes as u64;
    let dsps = tile::DSPS * pes as u64;
    let brams = tile::BRAMS * pes as u64;
    ResourceEstimate {
        pes,
        alms,
        regs,
        dsps,
        brams,
        alm_pct: 100.0 * alms as f64 / dev.alms as f64,
        reg_pct: 100.0 * regs as f64 / dev.regs as f64,
        dsp_pct: 100.0 * dsps as f64 / dev.dsps as f64,
        bram_pct: 100.0 * brams as f64 / dev.brams as f64,
        fmax_mhz: fmax_mhz(pes),
    }
}

/// Largest overlay that fits the device (the abstract's "up to 300
/// processors"), assuming `margin` headroom on ALMs for glue logic.
pub fn max_overlay(dev: &Device, margin: f64) -> usize {
    let by_alm = (dev.alms as f64 * margin / tile::ALMS as f64) as usize;
    let by_reg = (dev.regs as f64 * margin / tile::REGS as f64) as usize;
    let by_dsp = dev.dsps / tile::DSPS;
    let by_bram = dev.brams / tile::BRAMS;
    by_alm
        .min(by_reg)
        .min(by_dsp as usize)
        .min(by_bram as usize)
}

/// Render the Table I rows (plus any extra design points).
pub fn table1(extra_points: &[usize]) -> Vec<ResourceEstimate> {
    let mut points = vec![1usize, 256];
    points.extend_from_slice(extra_points);
    points.sort_unstable();
    points.dedup();
    points
        .into_iter()
        .map(|p| estimate(p, &ARRIA10_10AX115S))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_row_one_pe() {
        let e = estimate(1, &ARRIA10_10AX115S);
        // paper: 1.4K ALMs (0.3%), 2.2K regs, 2 DSPs (0.1%), 8 BRAMs (0.3%), 306 MHz
        assert!((e.alms as f64 - 1_400.0).abs() < 100.0);
        assert!((e.regs as f64 - 2_200.0).abs() < 100.0);
        assert_eq!(e.dsps, 2);
        assert_eq!(e.brams, 8);
        assert!((e.fmax_mhz - 306.0).abs() < 1e-9);
        assert!(e.alm_pct < 0.5);
    }

    #[test]
    fn table1_row_256_pe() {
        let e = estimate(256, &ARRIA10_10AX115S);
        // paper: 367K ALMs (86%), 559K regs, 512 DSPs (34%), 2K BRAMs (75%), 258 MHz
        assert!((e.alms as f64 - 367_000.0).abs() < 1_000.0, "{}", e.alms);
        assert!((e.regs as f64 - 559_000.0).abs() < 1_000.0, "{}", e.regs);
        assert_eq!(e.dsps, 512);
        assert_eq!(e.brams, 2_048);
        assert!((e.fmax_mhz - 258.0).abs() < 0.01);
        assert!((e.alm_pct - 86.0).abs() < 1.0);
        assert!((e.dsp_pct - 34.0).abs() < 1.0);
        assert!((e.bram_pct - 75.0).abs() < 1.0);
    }

    #[test]
    fn abstract_claim_300_pes_at_250mhz() {
        // "we can create an overlay design of up to 300 processors ...
        // at frequencies up to 250 MHz"
        let max = max_overlay(&ARRIA10_10AX115S, 1.0);
        assert!(max >= 295, "device fits ~300 tiles, got {max}");
        let f = fmax_mhz(300);
        assert!(f >= 250.0, "300 PEs still ≥250 MHz, got {f}");
    }

    #[test]
    fn router_footnote() {
        assert_eq!(tile::ROUTER_ALMS, 130);
        assert_eq!(tile::ROUTER_REGS, 350);
        // router is a small fraction of the tile
        assert!(tile::ROUTER_ALMS * 4 < tile::ALMS);
    }

    #[test]
    fn fmax_monotone_decreasing() {
        let mut prev = f64::MAX;
        for p in [1usize, 4, 16, 64, 256, 300] {
            let f = fmax_mhz(p);
            assert!(f < prev);
            prev = f;
        }
    }

    #[test]
    fn table1_includes_anchor_rows() {
        let rows = table1(&[16, 64]);
        let pes: Vec<usize> = rows.iter().map(|r| r.pes).collect();
        assert_eq!(pes, vec![1, 16, 64, 256]);
    }
}
