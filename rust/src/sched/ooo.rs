//! The paper's out-of-order scheduler: RDY/PEND bit-flags + hierarchical
//! leading-one detection over criticality-sorted graph memory.

use super::ReadyScheduler;
use crate::lod::{HierLod, NO_READY, WORD_BITS};

/// Out-of-order, criticality-driven ready scheduler (§II-B).
///
/// State:
/// * `rdy` — one bit per local node, packed 32/word exactly as in the
///   paper's BRAM layout (32 of the M20K's 40 b used "for simpler
///   arithmetic"). Set on ALU writeback, cleared when the node is claimed
///   for fanout processing.
/// * `pend` — the paper's second flag vector ("to avoid data corruption,
///   we need RDY bit-flags to indicate if all fanouts of a node have been
///   sent"): set while fanout packets are in flight.
/// * `summary` — the OuterLOD's distributed-memory vector, one bit per
///   `rdy` word, maintained incrementally.
///
/// A pick is a deterministic 2-cycle OuterLOD→InnerLOD pass. Because the
/// placement sorts each PE's local memory in decreasing criticality, the
/// lowest set bit is the most critical ready node.
pub struct OutOfOrderLod {
    num_local: usize,
    rdy: Vec<u32>,
    pend: Vec<u32>,
    summary: Vec<u64>,
    lod: HierLod,
    ready_count: usize,
    pending_count: usize,
    max_occupancy: usize,
}

impl OutOfOrderLod {
    pub fn new(num_local: usize) -> Self {
        let words = num_local.div_ceil(WORD_BITS as usize).max(1);
        let lod = HierLod::new(words);
        let summary_words = lod.summary_words();
        Self {
            num_local,
            rdy: vec![0; words],
            pend: vec![0; words],
            summary: vec![0; summary_words],
            lod,
            ready_count: 0,
            pending_count: 0,
            max_occupancy: 0,
        }
    }

    /// The §II-B overhead arithmetic, per PE: `2 * ceil(addresses/32)`
    /// flag words for every BRAM of `addresses` words.
    pub fn paper_flag_words(words_per_bram: usize, brams: usize) -> usize {
        2 * words_per_bram.div_ceil(32) * brams
    }

    #[inline]
    fn set_bit(v: &mut [u32], idx: u32) {
        v[(idx / WORD_BITS) as usize] |= 1 << (idx % WORD_BITS);
    }

    #[inline]
    fn clear_bit(v: &mut [u32], idx: u32) {
        v[(idx / WORD_BITS) as usize] &= !(1 << (idx % WORD_BITS));
    }

    #[inline]
    fn bit(v: &[u32], idx: u32) -> bool {
        v[(idx / WORD_BITS) as usize] >> (idx % WORD_BITS) & 1 == 1
    }

    /// Is `local_idx` pending (picked, fanout in flight)?
    pub fn is_pending(&self, local_idx: u32) -> bool {
        Self::bit(&self.pend, local_idx)
    }

    /// Is `local_idx` currently flagged ready?
    pub fn is_ready(&self, local_idx: u32) -> bool {
        Self::bit(&self.rdy, local_idx)
    }

    /// Expose flag words (integration test cross-checks the Pallas LOD
    /// kernel against the hardware pick on live scheduler state).
    pub fn rdy_words(&self) -> &[u32] {
        &self.rdy
    }
}

impl ReadyScheduler for OutOfOrderLod {
    fn mark_ready(&mut self, local_idx: u32) {
        debug_assert!((local_idx as usize) < self.num_local);
        debug_assert!(!Self::bit(&self.rdy, local_idx), "node already ready");
        debug_assert!(!Self::bit(&self.pend, local_idx), "node already pending");
        Self::set_bit(&mut self.rdy, local_idx);
        self.summary[(local_idx / WORD_BITS) as usize / 64] |=
            1 << ((local_idx / WORD_BITS) as usize % 64);
        self.ready_count += 1;
        self.max_occupancy = self.max_occupancy.max(self.ready_count);
    }

    fn pick_latency(&self) -> u32 {
        HierLod::PICK_LATENCY // OuterLOD + InnerLOD, §II-B
    }

    fn take(&mut self) -> Option<u32> {
        let idx = self.lod.pick(&self.summary, &self.rdy);
        if idx == NO_READY {
            return None;
        }
        Self::clear_bit(&mut self.rdy, idx);
        let word = (idx / WORD_BITS) as usize;
        if self.rdy[word] == 0 {
            self.summary[word / 64] &= !(1 << (word % 64));
        }
        Self::set_bit(&mut self.pend, idx);
        self.ready_count -= 1;
        self.pending_count += 1;
        Some(idx)
    }

    fn is_empty(&self) -> bool {
        self.ready_count == 0
    }

    fn len(&self) -> usize {
        self.ready_count
    }

    fn fanout_done(&mut self, local_idx: u32) {
        debug_assert!(Self::bit(&self.pend, local_idx), "fanout_done without pick");
        Self::clear_bit(&mut self.pend, local_idx);
        self.pending_count -= 1;
    }

    fn mem_overhead_words(&self) -> usize {
        // RDY + PEND vectors in BRAM words (32 flags per word), plus the
        // outer summary lives in distributed memory (free BRAM-wise).
        2 * self.rdy.len()
    }

    fn max_occupancy(&self) -> usize {
        self.max_occupancy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_lowest_index_first() {
        let mut s = OutOfOrderLod::new(4096);
        for i in [4000u32, 37, 2048, 38] {
            s.mark_ready(i);
        }
        assert_eq!(s.take(), Some(37));
        assert_eq!(s.take(), Some(38));
        assert_eq!(s.take(), Some(2048));
        assert_eq!(s.take(), Some(4000));
        assert_eq!(s.take(), None);
    }

    #[test]
    fn lowest_index_is_most_critical_by_construction() {
        // Placement sorts local memory by decreasing criticality, so the
        // invariant "pick == min ready local index" is the §II-B property.
        let mut s = OutOfOrderLod::new(100);
        s.mark_ready(99);
        s.mark_ready(0);
        assert_eq!(s.take(), Some(0));
    }

    #[test]
    fn pend_guards_reselection() {
        let mut s = OutOfOrderLod::new(64);
        s.mark_ready(5);
        assert_eq!(s.take(), Some(5));
        assert!(s.is_pending(5));
        assert!(!s.is_ready(5));
        assert_eq!(s.take(), None, "picked node must not be re-picked");
        s.fanout_done(5);
        assert!(!s.is_pending(5));
    }

    #[test]
    fn summary_tracks_word_emptiness() {
        let mut s = OutOfOrderLod::new(32 * 70); // >64 words => 2 summary words
        s.mark_ready(32 * 69); // node in word 69
        assert_eq!(s.take(), Some(32 * 69));
        assert!(s.summary.iter().all(|&w| w == 0));
        assert_eq!(s.take(), None);
    }

    #[test]
    fn interleaving_preserves_priority() {
        let mut s = OutOfOrderLod::new(256);
        s.mark_ready(100);
        assert_eq!(s.take(), Some(100));
        s.mark_ready(50);
        s.mark_ready(150);
        assert_eq!(s.take(), Some(50), "newly ready lower index wins");
        s.fanout_done(100);
        assert_eq!(s.take(), Some(150));
    }

    #[test]
    fn paper_flag_overhead_is_six_percent() {
        // §II-B: 2 * ceil(512/32) = 32 locations per 512-word BRAM
        let per_bram = OutOfOrderLod::paper_flag_words(512, 1);
        assert_eq!(per_bram, 32);
        let overhead = per_bram as f64 / 512.0;
        assert!((overhead - 0.0625).abs() < 1e-9, "≈6% (paper)");
        // whole PE: 8 BRAMs -> 256 of 4096 words
        assert_eq!(OutOfOrderLod::paper_flag_words(512, 8), 256);
    }

    #[test]
    fn mem_overhead_scales_with_capacity() {
        let s = OutOfOrderLod::new(4096);
        // 4096 nodes: 128 RDY words + 128 PEND words
        assert_eq!(s.mem_overhead_words(), 256);
        let tiny = OutOfOrderLod::new(10);
        assert_eq!(tiny.mem_overhead_words(), 2);
    }

    #[test]
    fn occupancy_counting() {
        let mut s = OutOfOrderLod::new(64);
        for i in 0..10 {
            s.mark_ready(i);
        }
        assert_eq!(s.len(), 10);
        for _ in 0..10 {
            s.take();
        }
        assert_eq!(s.len(), 0);
        assert_eq!(s.max_occupancy(), 10);
    }

    #[test]
    fn boundary_indices() {
        let mut s = OutOfOrderLod::new(65);
        s.mark_ready(64);
        s.mark_ready(31);
        s.mark_ready(32);
        assert_eq!(s.take(), Some(31));
        assert_eq!(s.take(), Some(32));
        assert_eq!(s.take(), Some(64));
    }
}
