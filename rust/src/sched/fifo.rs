//! The in-order baseline: a BRAM FIFO of ready node ids, FCFS.

use super::ReadyScheduler;
use std::collections::VecDeque;

/// First-come-first-served ready queue.
///
/// Hardware cost model: to be deadlock-free the FIFO must be able to hold
/// *every* local node simultaneously (all could be ready at once), so the
/// worst-case depth equals the PE's node capacity — BRAM that the
/// out-of-order design instead spends on graph storage (see
/// `pe::BramConfig::fifo_words`). A bounded capacity models a
/// under-provisioned FIFO; overflows are counted, not dropped (hardware
/// would deadlock — the simulator keeps the node queued so runs finish,
/// and reports `overflows() > 0` as a sizing violation).
pub struct InOrderFifo {
    queue: VecDeque<u32>,
    capacity: usize,
    pending: u64, // picked but fanout not finished (stats only)
    max_occupancy: usize,
    overflows: u64,
}

impl InOrderFifo {
    pub fn new(num_local: usize, capacity: Option<usize>) -> Self {
        let capacity = capacity.unwrap_or(num_local.max(1));
        Self {
            queue: VecDeque::with_capacity(capacity.min(1 << 16)),
            capacity,
            pending: 0,
            max_occupancy: 0,
            overflows: 0,
        }
    }
}

impl ReadyScheduler for InOrderFifo {
    fn mark_ready(&mut self, local_idx: u32) {
        if self.queue.len() >= self.capacity {
            self.overflows += 1;
        }
        self.queue.push_back(local_idx);
        self.max_occupancy = self.max_occupancy.max(self.queue.len());
    }

    fn pick_latency(&self) -> u32 {
        1 // single-cycle FIFO pop
    }

    fn take(&mut self) -> Option<u32> {
        let n = self.queue.pop_front();
        if n.is_some() {
            self.pending += 1;
        }
        n
    }

    fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    fn len(&self) -> usize {
        self.queue.len()
    }

    fn fanout_done(&mut self, _local_idx: u32) {
        self.pending = self.pending.saturating_sub(1);
    }

    fn mem_overhead_words(&self) -> usize {
        // one 40 b word per FIFO entry (13 b node id fits comfortably)
        self.capacity
    }

    fn max_occupancy(&self) -> usize {
        self.max_occupancy
    }

    fn overflows(&self) -> u64 {
        self.overflows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ReadyScheduler;

    #[test]
    fn strict_fcfs_order() {
        let mut f = InOrderFifo::new(64, None);
        for i in [5u32, 1, 9, 0, 3] {
            f.mark_ready(i);
        }
        let got: Vec<u32> = std::iter::from_fn(|| f.take()).collect();
        assert_eq!(got, vec![5, 1, 9, 0, 3], "FIFO must preserve arrival order");
    }

    #[test]
    fn overflow_counted_not_dropped() {
        let mut f = InOrderFifo::new(64, Some(2));
        f.mark_ready(0);
        f.mark_ready(1);
        f.mark_ready(2);
        assert_eq!(f.overflows(), 1);
        assert_eq!(f.len(), 3, "simulator keeps the node to avoid deadlock");
    }

    #[test]
    fn worst_case_capacity_is_local_node_count() {
        let f = InOrderFifo::new(1000, None);
        assert_eq!(f.mem_overhead_words(), 1000);
    }

    #[test]
    fn occupancy_high_water_mark() {
        let mut f = InOrderFifo::new(8, None);
        f.mark_ready(1);
        f.mark_ready(2);
        f.take();
        f.mark_ready(3);
        assert_eq!(f.max_occupancy(), 2);
    }

    #[test]
    fn interleaved_take_and_mark() {
        let mut f = InOrderFifo::new(8, None);
        f.mark_ready(1);
        assert_eq!(f.take(), Some(1));
        f.mark_ready(2);
        f.mark_ready(3);
        assert_eq!(f.take(), Some(2));
        f.fanout_done(1);
        assert_eq!(f.take(), Some(3));
        assert!(f.is_empty());
    }
}
