//! Ready-node schedulers — the paper's contribution (§II-B).
//!
//! When a node's result has been computed it becomes *ready for fanout
//! processing*: the packet-generation unit must walk its fanout edge list
//! and inject one packet per edge. Packet generation is multi-cycle
//! (multiple fanouts, network congestion), so ready nodes queue up; the
//! *scheduler* decides which ready node the packet-gen unit serves next.
//!
//! * [`InOrderFifo`] — the state of the art the paper compares against:
//!   a BRAM FIFO of ready node ids, FCFS. Cheap control, but (a) the FIFO
//!   must be sized for the deadlock-free worst case, burning BRAMs that
//!   could hold graph, and (b) arrival order ignores node *importance*.
//! * [`OutOfOrderLod`] — the paper's scheduler: per-node RDY/PEND bit
//!   flags packed 32-per-word (≈6 % memory overhead), a hierarchical
//!   leading-one detector picking the lowest-address ready node in a
//!   deterministic 2-cycle pass, and graph memory sorted in decreasing
//!   criticality so lowest address == most critical.

mod ablation;
mod fifo;
mod ooo;

pub use ablation::{LifoSched, RandomSched};
pub use fifo::InOrderFifo;
pub use ooo::OutOfOrderLod;

/// Which scheduler a PE uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerKind {
    InOrder,
    #[default]
    OutOfOrder,
}

impl SchedulerKind {
    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::InOrder => "in-order",
            SchedulerKind::OutOfOrder => "out-of-order",
        }
    }
}

/// Common interface the PE packet-generation unit drives.
pub trait ReadyScheduler {
    /// Node `local_idx` finished its ALU writeback: flag it ready.
    fn mark_ready(&mut self, local_idx: u32);

    /// Cycles from starting a scheduling pass to having the node id
    /// (FIFO pop: 1; hierarchical LOD: 2 — paper §II-B).
    fn pick_latency(&self) -> u32;

    /// Completion cycle of a scheduling pass started at `started_at` —
    /// the pick-wake event the skip-ahead engine jumps to.
    fn pick_completion(&self, started_at: u64) -> u64 {
        started_at + self.pick_latency() as u64
    }

    /// Claim the next node (highest priority ready). Clears its RDY state;
    /// the node stays pending until [`ReadyScheduler::fanout_done`].
    fn take(&mut self) -> Option<u32>;

    fn is_empty(&self) -> bool;

    /// Currently-ready node count (occupancy).
    fn len(&self) -> usize;

    /// All fanout packets of `local_idx` accepted by the network.
    fn fanout_done(&mut self, local_idx: u32);

    /// BRAM words this scheduler's state costs (resource model input).
    fn mem_overhead_words(&self) -> usize;

    /// High-water mark of ready occupancy (FIFO sizing evidence).
    fn max_occupancy(&self) -> usize;

    /// Ready-queue overflow events (in-order only; 0 when sized right).
    fn overflows(&self) -> u64 {
        0
    }
}

/// Devirtualized scheduler dispatch: one enum per PE instead of a
/// `Box<dyn ReadyScheduler + Send>`, so the simulator's per-cycle hot
/// path (`is_empty`/`take`/`mark_ready` on every active PE) compiles to
/// an inlined match instead of a vtable call per query. The trait stays
/// the behavioural contract; the conformance suite still exercises every
/// implementation — including this enum — through trait objects.
pub enum Scheduler {
    Fifo(InOrderFifo),
    Lod(OutOfOrderLod),
    Lifo(LifoSched),
    Random(RandomSched),
}

impl Scheduler {
    /// The scheduler `kind` selects (the two paper designs). The
    /// ablation variants (`Lifo`/`Random`) are constructed explicitly.
    pub fn new(kind: SchedulerKind, num_local: usize, fifo_capacity: Option<usize>) -> Self {
        match kind {
            SchedulerKind::InOrder => Scheduler::Fifo(InOrderFifo::new(num_local, fifo_capacity)),
            SchedulerKind::OutOfOrder => Scheduler::Lod(OutOfOrderLod::new(num_local)),
        }
    }
}

macro_rules! dispatch {
    ($self:expr, $s:ident => $body:expr) => {
        match $self {
            Scheduler::Fifo($s) => $body,
            Scheduler::Lod($s) => $body,
            Scheduler::Lifo($s) => $body,
            Scheduler::Random($s) => $body,
        }
    };
}

impl ReadyScheduler for Scheduler {
    #[inline]
    fn mark_ready(&mut self, local_idx: u32) {
        dispatch!(self, s => s.mark_ready(local_idx))
    }

    #[inline]
    fn pick_latency(&self) -> u32 {
        dispatch!(self, s => s.pick_latency())
    }

    #[inline]
    fn take(&mut self) -> Option<u32> {
        dispatch!(self, s => s.take())
    }

    #[inline]
    fn is_empty(&self) -> bool {
        dispatch!(self, s => s.is_empty())
    }

    #[inline]
    fn len(&self) -> usize {
        dispatch!(self, s => s.len())
    }

    #[inline]
    fn fanout_done(&mut self, local_idx: u32) {
        dispatch!(self, s => s.fanout_done(local_idx))
    }

    fn mem_overhead_words(&self) -> usize {
        dispatch!(self, s => s.mem_overhead_words())
    }

    fn max_occupancy(&self) -> usize {
        dispatch!(self, s => s.max_occupancy())
    }

    fn overflows(&self) -> u64 {
        dispatch!(self, s => s.overflows())
    }
}

/// Construct a scheduler for a PE with `num_local` nodes.
///
/// `fifo_capacity` bounds the in-order ready queue (None = unbounded,
/// i.e. worst-case-sized as deadlock freedom demands).
pub fn make_scheduler(
    kind: SchedulerKind,
    num_local: usize,
    fifo_capacity: Option<usize>,
) -> Scheduler {
    Scheduler::new(kind, num_local, fifo_capacity)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared conformance suite run against both schedulers.
    fn conformance(mut s: Box<dyn ReadyScheduler + Send>) {
        assert!(s.is_empty());
        assert_eq!(s.take(), None);
        s.mark_ready(3);
        s.mark_ready(7);
        assert_eq!(s.len(), 2);
        let a = s.take().unwrap();
        let b = s.take().unwrap();
        assert_eq!(s.take(), None);
        let mut got = vec![a, b];
        got.sort_unstable();
        assert_eq!(got, vec![3, 7]);
        s.fanout_done(a);
        s.fanout_done(b);
        assert!(s.is_empty());
        assert_eq!(s.max_occupancy(), 2);
    }

    #[test]
    fn both_schedulers_conform() {
        conformance(Box::new(InOrderFifo::new(16, None)));
        conformance(Box::new(OutOfOrderLod::new(16)));
    }

    #[test]
    fn ablation_schedulers_conform() {
        conformance(Box::new(LifoSched::new(16)));
        conformance(Box::new(RandomSched::new(16, 7)));
    }

    /// The devirtualized enum must be indistinguishable from the boxed
    /// trait objects it replaces — run every variant through the same
    /// conformance suite, as a trait object.
    #[test]
    fn enum_dispatch_conforms() {
        conformance(Box::new(Scheduler::new(SchedulerKind::InOrder, 16, None)));
        conformance(Box::new(Scheduler::new(SchedulerKind::OutOfOrder, 16, None)));
        conformance(Box::new(Scheduler::Lifo(LifoSched::new(16))));
        conformance(Box::new(Scheduler::Random(RandomSched::new(16, 11))));
    }

    /// Enum dispatch and direct construction agree operation-for-
    /// operation on an interleaved mark/take/fanout script.
    #[test]
    fn enum_matches_concrete_schedulers() {
        for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
            let mut via_enum = Scheduler::new(kind, 64, None);
            let mut concrete: Box<dyn ReadyScheduler + Send> = match kind {
                SchedulerKind::InOrder => Box::new(InOrderFifo::new(64, None)),
                SchedulerKind::OutOfOrder => Box::new(OutOfOrderLod::new(64)),
            };
            for i in [9u32, 3, 27, 14] {
                via_enum.mark_ready(i);
                concrete.mark_ready(i);
            }
            for _ in 0..4 {
                assert_eq!(via_enum.len(), concrete.len());
                let (a, b) = (via_enum.take(), concrete.take());
                assert_eq!(a, b, "{kind:?}");
                via_enum.fanout_done(a.unwrap());
                concrete.fanout_done(b.unwrap());
            }
            assert_eq!(via_enum.take(), None);
            assert_eq!(via_enum.max_occupancy(), concrete.max_occupancy());
            assert_eq!(via_enum.mem_overhead_words(), concrete.mem_overhead_words());
        }
    }

    #[test]
    fn pick_latencies_match_paper() {
        let f = make_scheduler(SchedulerKind::InOrder, 8, None);
        let o = make_scheduler(SchedulerKind::OutOfOrder, 8, None);
        assert_eq!(f.pick_latency(), 1);
        assert_eq!(o.pick_latency(), 2);
        assert_eq!(f.pick_completion(10), 11);
        assert_eq!(o.pick_completion(10), 12);
    }
}
