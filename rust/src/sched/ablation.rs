//! Ablation schedulers — alternative out-of-order pick orders that
//! isolate *why* the paper's scheduler wins: it is not out-of-orderness
//! per se but picking by **criticality**. Neither of these exists in the
//! paper; they bound the design space in `sched_micro`'s ablation.

use super::ReadyScheduler;
use crate::util::rng::Rng;

/// Most-recently-ready first (a stack). Same bit-flag storage cost as
/// the LOD design; depth-first-ish order.
pub struct LifoSched {
    stack: Vec<u32>,
    pending: u64,
    max_occupancy: usize,
    num_local: usize,
}

impl LifoSched {
    pub fn new(num_local: usize) -> Self {
        Self {
            stack: Vec::new(),
            pending: 0,
            max_occupancy: 0,
            num_local,
        }
    }
}

impl ReadyScheduler for LifoSched {
    fn mark_ready(&mut self, local_idx: u32) {
        self.stack.push(local_idx);
        self.max_occupancy = self.max_occupancy.max(self.stack.len());
    }

    fn pick_latency(&self) -> u32 {
        1
    }

    fn take(&mut self) -> Option<u32> {
        let n = self.stack.pop();
        if n.is_some() {
            self.pending += 1;
        }
        n
    }

    fn is_empty(&self) -> bool {
        self.stack.is_empty()
    }

    fn len(&self) -> usize {
        self.stack.len()
    }

    fn fanout_done(&mut self, _local_idx: u32) {
        self.pending = self.pending.saturating_sub(1);
    }

    fn mem_overhead_words(&self) -> usize {
        self.num_local.max(1) // stack sized like the FIFO
    }

    fn max_occupancy(&self) -> usize {
        self.max_occupancy
    }
}

/// Uniform-random ready pick (seeded): out-of-order but criticality-blind.
pub struct RandomSched {
    ready: Vec<u32>,
    rng: Rng,
    pending: u64,
    max_occupancy: usize,
    num_local: usize,
}

impl RandomSched {
    pub fn new(num_local: usize, seed: u64) -> Self {
        Self {
            ready: Vec::new(),
            rng: Rng::seed_from_u64(seed),
            pending: 0,
            max_occupancy: 0,
            num_local,
        }
    }
}

impl ReadyScheduler for RandomSched {
    fn mark_ready(&mut self, local_idx: u32) {
        self.ready.push(local_idx);
        self.max_occupancy = self.max_occupancy.max(self.ready.len());
    }

    fn pick_latency(&self) -> u32 {
        2 // charge the LOD's pick latency for a fair comparison
    }

    fn take(&mut self) -> Option<u32> {
        if self.ready.is_empty() {
            return None;
        }
        let i = self.rng.gen_range(self.ready.len());
        self.pending += 1;
        Some(self.ready.swap_remove(i))
    }

    fn is_empty(&self) -> bool {
        self.ready.is_empty()
    }

    fn len(&self) -> usize {
        self.ready.len()
    }

    fn fanout_done(&mut self, _local_idx: u32) {
        self.pending = self.pending.saturating_sub(1);
    }

    fn mem_overhead_words(&self) -> usize {
        2 * self.num_local.div_ceil(32) // flag-vector equivalent
    }

    fn max_occupancy(&self) -> usize {
        self.max_occupancy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifo_order() {
        let mut s = LifoSched::new(16);
        for i in [1u32, 2, 3] {
            s.mark_ready(i);
        }
        assert_eq!(s.take(), Some(3));
        s.mark_ready(9);
        assert_eq!(s.take(), Some(9));
        assert_eq!(s.take(), Some(2));
        assert_eq!(s.take(), Some(1));
        assert_eq!(s.take(), None);
    }

    #[test]
    fn random_is_a_permutation() {
        let mut s = RandomSched::new(64, 7);
        for i in 0..20u32 {
            s.mark_ready(i);
        }
        let mut got: Vec<u32> = std::iter::from_fn(|| s.take()).collect();
        got.sort_unstable();
        assert_eq!(got, (0..20).collect::<Vec<u32>>());
    }

    #[test]
    fn random_is_seed_deterministic() {
        let run = |seed| {
            let mut s = RandomSched::new(64, seed);
            for i in 0..10u32 {
                s.mark_ready(i);
            }
            std::iter::from_fn(|| s.take()).collect::<Vec<u32>>()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }

    #[test]
    fn occupancy_tracked() {
        let mut s = LifoSched::new(8);
        s.mark_ready(0);
        s.mark_ready(1);
        s.take();
        assert_eq!(s.max_occupancy(), 2);
        assert_eq!(s.len(), 1);
    }
}
