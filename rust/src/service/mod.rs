//! The service layer (DESIGN.md §9): job-oriented execution over a
//! content-addressed Program cache.
//!
//! The paper's economics — node labeling and placement are a *static
//! one-time* cost amortized over execution — only pay off if the system
//! is shaped like a request server: many independent jobs multiplexed
//! over compiled fabrics, the framing HBM-era graph accelerators
//! (ReGraph, streaming task-graph schedulers) use. This module is that
//! shape:
//!
//! * [`JobSpec`] — one request: a workload spec string
//!   ([`crate::workload::Spec`] grammar, e.g. `chain:4096:seed=7`),
//!   scheduler, engine backend, overlay overrides, cycle budget; JSON
//!   in, one object per `tdp batch` line.
//! * [`Engine`] — a long-lived executor owning the caches: workload
//!   graphs by canonical spec, compiled [`crate::program::SharedProgram`]s
//!   by [`cache::CacheKey`] (canonical spec × graph fingerprint ×
//!   normalized overlay shape, LRU-bounded, hit/miss counters exposed).
//!   Duplicate and
//!   concurrent requests compile exactly once and fan out as cheap
//!   sessions; `submit_batch` shards across `util::par` workers with
//!   deterministic result order.
//! * [`JobResult`] — one response: canonical workload, variant, graph
//!   shape, cache provenance, compile/run timing and the full
//!   [`crate::sim::SimStats`]; JSON out.
//!
//! `coordinator::fig1_sweep` and `tdp batch` / `tdp run --format json`
//! are thin clients of this module.

pub mod cache;

mod engine;
mod job;

pub use engine::{CacheStats, Engine, DEFAULT_CACHE_CAPACITY};
pub use job::{JobResult, JobSpec};
