//! The content-addressed Program cache.
//!
//! Key = canonical workload spec × graph fingerprint × normalized
//! overlay shape (see [`CacheKey`]); value = an `Arc<SharedProgram>` —
//! the one-time
//! compile artifact any number of sessions fan out from. Both engine
//! caches (programs here, workload graphs upstream) are the same
//! bounded [`Lru`] map, so the engine serves unbounded request streams
//! with bounded memory and exposes hit/miss/eviction counters for
//! observability.

use crate::config::OverlayConfig;
use crate::engine::BackendKind;
use crate::program::SharedProgram;
use crate::sched::SchedulerKind;
use crate::shard::ShardedProgram;
use std::collections::BTreeMap;
use std::sync::Arc;

/// The content address of a compiled program.
///
/// `workload` (the canonical spec string) rides along with the graph
/// fingerprint so a 64-bit FNV collision between two *different* specs
/// can never silently serve the wrong artifact — the fingerprint's job
/// is to keep two spellings of the same content together, the spec
/// string's job is to keep different content apart.
///
/// `overlay` is the JSON of the overlay config with the *session-level*
/// knobs normalized away: `backend` and `max_cycles` never affect the
/// compile artifact, and `scheduler` only affects it when
/// `enforce_capacity` is set (the capacity verdict depends on the
/// scheduler's BRAM budget) — so without enforcement one artifact
/// serves every scheduler × backend variant, which is exactly the
/// amortization the paper's static one-time labeling promises.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct CacheKey {
    /// [`crate::graph::DataflowGraph::fingerprint`] of the built graph
    pub fingerprint: u64,
    /// canonical workload spec ([`crate::workload::Spec::canonical`])
    pub workload: String,
    /// normalized overlay config, JSON-encoded (stable key order)
    pub overlay: String,
}

impl CacheKey {
    /// Build the key for running the graph of `workload` (canonical
    /// spec, fingerprinting to `fingerprint`) on `cfg`.
    pub fn new(fingerprint: u64, workload: &str, cfg: &OverlayConfig) -> Self {
        let mut norm = *cfg;
        norm.backend = BackendKind::Lockstep;
        norm.max_cycles = OverlayConfig::default().max_cycles;
        if !norm.enforce_capacity {
            norm.scheduler = SchedulerKind::OutOfOrder;
        }
        Self {
            fingerprint,
            workload: workload.to_string(),
            overlay: norm.to_json(),
        }
    }
}

struct Slot<V> {
    value: V,
    /// logical timestamp of the last get/insert (LRU order)
    last_used: u64,
}

/// Bounded least-recently-used map. Not internally synchronized — the
/// engine wraps it in a `Mutex` and layers single-flight on top.
pub struct Lru<K: Ord, V> {
    entries: BTreeMap<K, Slot<V>>,
    capacity: usize,
    tick: u64,
    evictions: u64,
}

/// A cached compile artifact: single-fabric or sharded. Which one a key
/// resolves to is itself a pure function of the key (the `shards` knob
/// rides in the normalized overlay JSON, and the auto-shard fallback
/// decides on the normalized scheduler), so every job sharing a key
/// gets the same artifact kind.
#[derive(Clone)]
pub enum Compiled {
    Single(Arc<SharedProgram>),
    Sharded(Arc<ShardedProgram>),
}

/// The engine's Program cache: compiled artifacts by content address.
pub type ProgramCache = Lru<CacheKey, Compiled>;

impl<K: Ord + Clone, V: Clone> Lru<K, V> {
    /// A cache holding at most `capacity` values (min 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            entries: BTreeMap::new(),
            capacity: capacity.max(1),
            tick: 0,
            evictions: 0,
        }
    }

    /// Look up `key`, refreshing its LRU position on a hit.
    pub fn get(&mut self, key: &K) -> Option<V> {
        self.tick += 1;
        let tick = self.tick;
        self.entries.get_mut(key).map(|slot| {
            slot.last_used = tick;
            slot.value.clone()
        })
    }

    /// Insert `value` under `key`, evicting the least-recently-used
    /// entry if the cache is full.
    pub fn insert(&mut self, key: K, value: V) {
        self.tick += 1;
        if !self.entries.contains_key(&key) && self.entries.len() >= self.capacity {
            if let Some(victim) = self
                .entries
                .iter()
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(k, _)| k.clone())
            {
                self.entries.remove(&victim);
                self.evictions += 1;
            }
        }
        self.entries.insert(
            key,
            Slot {
                value,
                last_used: self.tick,
            },
        );
    }

    /// Number of resident values.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no values are resident.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total evictions since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(fp: u64) -> CacheKey {
        CacheKey::new(fp, "chain:8", &OverlayConfig::default())
    }

    #[test]
    fn session_level_knobs_normalize_out_of_the_key() {
        let base = OverlayConfig::default();
        let a = CacheKey::new(7, "chain:8", &base);
        let b = CacheKey::new(7, "chain:8", &base.with_backend(BackendKind::SkipAhead));
        let c = CacheKey::new(7, "chain:8", &base.with_scheduler(SchedulerKind::InOrder));
        let mut d_cfg = base;
        d_cfg.max_cycles = 123;
        let d = CacheKey::new(7, "chain:8", &d_cfg);
        assert_eq!(a, b, "backend is a session knob");
        assert_eq!(a, c, "scheduler is a session knob without capacity enforcement");
        assert_eq!(a, d, "max_cycles is a session knob");
        // compile-relevant knobs stay in the key
        assert_ne!(a, CacheKey::new(8, "chain:8", &base), "fingerprint");
        assert_ne!(a, CacheKey::new(7, "chain:8", &base.with_dims(4, 4)), "overlay shape");
        let mut seeded = base;
        seeded.seed = 9;
        assert_ne!(a, CacheKey::new(7, "chain:8", &seeded), "placement seed");
        // a different spec never shares a slot, even on an (engineered)
        // fingerprint collision
        assert_ne!(a, CacheKey::new(7, "chain:9", &base), "workload spec");
        // with enforcement, the capacity verdict is per-scheduler
        let mut enf = base;
        enf.enforce_capacity = true;
        assert_ne!(
            CacheKey::new(7, "chain:8", &enf),
            CacheKey::new(7, "chain:8", &enf.with_scheduler(SchedulerKind::InOrder))
        );
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut cache: Lru<CacheKey, u32> = Lru::new(2);
        cache.insert(key(1), 10);
        cache.insert(key(2), 20);
        assert_eq!(cache.get(&key(1)), Some(10)); // refresh 1 → 2 is now LRU
        cache.insert(key(3), 30);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.get(&key(2)), None, "2 was evicted");
        assert_eq!(cache.get(&key(1)), Some(10));
        assert_eq!(cache.get(&key(3)), Some(30));
        // re-inserting an existing key does not evict
        cache.insert(key(1), 11);
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.get(&key(1)), Some(11));
    }

    #[test]
    fn capacity_floor_is_one() {
        let mut cache: Lru<String, u8> = Lru::new(0);
        cache.insert("a".into(), 1);
        assert_eq!(cache.len(), 1);
        assert!(!cache.is_empty());
        cache.insert("b".into(), 2);
        assert_eq!(cache.len(), 1, "bounded at the floor");
        assert_eq!(cache.get(&"b".to_string()), Some(2));
    }
}
