//! The long-lived execution engine: jobs in, results out, compiles
//! amortized through the content-addressed Program cache.

use super::cache::{CacheKey, Compiled, Lru, ProgramCache};
use super::job::{JobResult, JobSpec, ShardInfo};
use crate::config::Overlay;
use crate::error::{panic_message, Error};
use crate::faultinject::FaultPlan;
use crate::graph::{DataflowGraph, GraphStats};
use crate::program::SharedProgram;
use crate::sched::SchedulerKind;
use crate::shard::ShardedProgram;
use crate::sim::CancelToken;
use crate::telemetry::Histogram;
use crate::util::json::{self, Json};
use crate::util::par::run_parallel;
use crate::workload::Spec;
use std::collections::{BTreeMap, BTreeSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Default bound of both engine caches (compiled programs / built
/// workload graphs resident at once).
pub const DEFAULT_CACHE_CAPACITY: usize = 64;

/// Counters the engine exposes for observability (`tdp batch` prints
/// them to stderr after a run).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// jobs served from an already-compiled program
    pub hits: u64,
    /// jobs that compiled and cached a new program
    pub misses: u64,
    /// programs dropped by the LRU bound
    pub evictions: u64,
    /// programs currently resident
    pub entries: usize,
    /// workload graphs currently resident in the graph cache
    pub graphs: usize,
    /// graphs dropped by the LRU bound
    pub graph_evictions: u64,
}

/// Sentinel of [`Flight::acquire`]: the in-flight build this waiter was
/// blocked on panicked. The flight latch was already cleared (a fresh
/// submitter becomes the next leader and retries from scratch), so the
/// waiter surfaces a typed [`Error::CompilePoisoned`] instead of
/// hanging forever or silently re-racing a build that just blew up.
struct FlightPoisoned;

/// The latch state proper: `pending` holds keys whose build is owned by
/// some thread; `poison_epoch` counts, per key, how many of its builds
/// have ever panicked. A waiter snapshots the key's epoch before
/// blocking and fails poisoned if it moved while it slept — fresh
/// acquirers (arriving after the poison cleared `pending`) see an
/// unchanged current epoch and simply become the new leader.
struct FlightState<K: Ord> {
    pending: BTreeSet<K>,
    poison_epoch: BTreeMap<K, u64>,
}

/// Per-key single-flight latch: at most one thread builds a given key
/// at a time — a racing duplicate waits for the winner instead of
/// paying the build again — while *distinct* keys build fully in
/// parallel (no lock is held across a build).
///
/// Protocol: [`Flight::acquire`] either returns a cached value or
/// grants the exclusive build right for `key`; the winner builds with
/// no locks held, publishes into the cache, then [`Flight::release`]s
/// (success *and* failure — a failed build wakes the waiters, who
/// re-race and surface their own error). A build that *panics* instead
/// calls [`Flight::poison`], which clears the flight and fails the
/// current waiters poisoned (DESIGN.md §15). Lock order is always
/// flight state → cache; the build path takes them one at a time, so
/// the two mutexes can never deadlock.
struct Flight<K: Ord + Clone> {
    state: Mutex<FlightState<K>>,
    cv: Condvar,
    /// acquires that had to block on another thread's in-flight build
    /// (counted once per acquire, not per spurious wakeup) — the
    /// single-flight contention signal of [`Engine::metrics_snapshot`]
    waits: AtomicU64,
}

impl<K: Ord + Clone> Flight<K> {
    fn new() -> Self {
        Self {
            state: Mutex::new(FlightState {
                pending: BTreeSet::new(),
                poison_epoch: BTreeMap::new(),
            }),
            cv: Condvar::new(),
            waits: AtomicU64::new(0),
        }
    }

    /// `Ok(Some(value))` on a cache hit (possibly after waiting for an
    /// in-flight build of `key`), `Ok(None)` when the caller now owns
    /// the build right and must call [`Flight::release`] (or, on a
    /// panic, [`Flight::poison`]) when done, `Err(FlightPoisoned)` when
    /// the build this caller was waiting on panicked. `lookup` takes
    /// the cache's own lock internally and is re-run after every
    /// wakeup.
    fn acquire<V>(
        &self,
        key: &K,
        mut lookup: impl FnMut() -> Option<V>,
    ) -> Result<Option<V>, FlightPoisoned> {
        let mut state = self.state.lock().expect("flight lock");
        let mut waited: Option<u64> = None;
        loop {
            if let Some(v) = lookup() {
                return Ok(Some(v));
            }
            if let Some(snapshot) = waited {
                if state.poison_epoch.get(key).copied().unwrap_or(0) > snapshot {
                    return Err(FlightPoisoned);
                }
            }
            if !state.pending.contains(key) {
                state.pending.insert(key.clone());
                return Ok(None);
            }
            if waited.is_none() {
                waited = Some(state.poison_epoch.get(key).copied().unwrap_or(0));
                self.waits.fetch_add(1, Ordering::Relaxed);
            }
            state = self.cv.wait(state).expect("flight lock");
        }
    }

    fn waits(&self) -> u64 {
        self.waits.load(Ordering::Relaxed)
    }

    /// Give up the build right for `key` and wake every waiter.
    fn release(&self, key: &K) {
        self.state.lock().expect("flight lock").pending.remove(key);
        self.cv.notify_all();
    }

    /// The build of `key` panicked: clear the flight (the next fresh
    /// submitter retries as the new leader) and bump the key's poison
    /// epoch so every thread currently waiting on it fails poisoned.
    fn poison(&self, key: &K) {
        let mut state = self.state.lock().expect("flight lock");
        state.pending.remove(key);
        *state.poison_epoch.entry(key.clone()).or_insert(0) += 1;
        drop(state);
        self.cv.notify_all();
    }
}

/// A built graph plus the derived identity the service needs per job.
struct GraphEntry {
    graph: Arc<DataflowGraph>,
    fingerprint: u64,
    stats: GraphStats,
}

/// Per-key latency cap of [`Engine::metrics_snapshot`]: beyond this many
/// distinct canonical workloads, further keys fold into `"__other__"` so
/// the snapshot (and the engine's memory) stays bounded under adversarial
/// key cardinality.
const METRICS_KEY_CAP: usize = 64;

/// Compile/run latency histograms of one canonical workload key.
#[derive(Default, Clone, Copy)]
struct LatencyPair {
    jobs: u64,
    compile: Histogram,
    run: Histogram,
}

/// The mutable half of the engine's metrics (everything not already an
/// atomic or derivable from the caches).
#[derive(Default)]
struct EngineMetrics {
    jobs: u64,
    failures: u64,
    sharded: u64,
    /// failures bucketed by [`Error::code`] ("deadline_exceeded",
    /// "panicked", "compile_poisoned", ...) — the fault-tolerance
    /// observability of DESIGN.md §15. Bounded: codes are a small
    /// closed set.
    failure_codes: BTreeMap<&'static str, u64>,
    compile: Histogram,
    run: Histogram,
    per_key: BTreeMap<String, LatencyPair>,
}

impl EngineMetrics {
    fn record(&mut self, result: &JobResult) {
        self.jobs += 1;
        if result.shards.is_some() {
            self.sharded += 1;
        }
        if !result.cache_hit {
            self.compile.observe(result.compile_micros);
        }
        self.run.observe(result.run_micros);
        let key = if self.per_key.len() >= METRICS_KEY_CAP
            && !self.per_key.contains_key(&result.workload)
        {
            "__other__".to_string()
        } else {
            result.workload.clone()
        };
        let pair = self.per_key.entry(key).or_default();
        pair.jobs += 1;
        if !result.cache_hit {
            pair.compile.observe(result.compile_micros);
        }
        pair.run.observe(result.run_micros);
    }
}

/// A long-lived, thread-safe job executor.
///
/// `Engine` owns two bounded LRU caches: workload graphs keyed by
/// canonical spec string (so repeated requests skip generation), and
/// compiled [`SharedProgram`]s keyed by [`CacheKey`] — graph
/// fingerprint × normalized overlay shape (so repeated *and concurrent*
/// requests for the same workload compile exactly once, then fan out as
/// cheap sessions). Builds run with no lock held — distinct workloads
/// generate and compile in parallel — and a per-key [`Flight`] latch
/// keeps racing duplicates single-flight. Simulations, the dominant
/// cost, never touch either lock.
pub struct Engine {
    graphs: Mutex<Lru<String, Arc<GraphEntry>>>,
    graph_flight: Flight<String>,
    programs: Mutex<ProgramCache>,
    program_flight: Flight<CacheKey>,
    hits: AtomicU64,
    misses: AtomicU64,
    metrics: Mutex<EngineMetrics>,
    /// deterministic fault-injection plan (chaos testing, DESIGN.md
    /// §15); `None` in production engines
    faults: Option<Arc<FaultPlan>>,
    /// canonical specs whose injected compile panic already fired —
    /// each `compile_panic` site fires once per engine, so the retry
    /// after poison recovery succeeds and proves the latch healed
    fired_panics: Mutex<BTreeSet<String>>,
    injected_panics: AtomicU64,
    injected_delays: AtomicU64,
    injected_overruns: AtomicU64,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    /// An engine with the default cache bound.
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_CACHE_CAPACITY)
    }

    /// An engine whose caches hold at most `capacity` programs and
    /// `capacity` graphs.
    pub fn with_capacity(capacity: usize) -> Self {
        Self::with_capacity_and_faults(capacity, None)
    }

    /// An engine with a deterministic fault-injection plan attached
    /// (`tdp serve --fault-plan` / `tdp batch --fault-plan`): the
    /// plan's content-keyed sites fire on matching jobs — compile
    /// panics (once per spec, exercising poison recovery), submit
    /// delays, forced deadline overruns — and its `barrier_drop` sites
    /// apply to sharded runs. Same plan + same job stream ⇒ same
    /// outcome codes, independent of worker count.
    pub fn with_capacity_and_faults(capacity: usize, faults: Option<Arc<FaultPlan>>) -> Self {
        Self {
            graphs: Mutex::new(Lru::new(capacity)),
            graph_flight: Flight::new(),
            programs: Mutex::new(ProgramCache::new(capacity)),
            program_flight: Flight::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            metrics: Mutex::new(EngineMetrics::default()),
            faults,
            fired_panics: Mutex::new(BTreeSet::new()),
            injected_panics: AtomicU64::new(0),
            injected_delays: AtomicU64::new(0),
            injected_overruns: AtomicU64::new(0),
        }
    }

    /// Execute one job to completion. Thread-safe: any number of threads
    /// may submit concurrently, and duplicate (workload, overlay) keys
    /// still compile exactly once. Results are deterministic — a cache
    /// hit replays the identical placement, so its [`JobResult::stats`]
    /// are bit-identical to a cold compile of the same job.
    pub fn submit(&self, job: &JobSpec) -> Result<JobResult, Error> {
        let result = self.submit_inner(job);
        let mut metrics = self.metrics.lock().expect("metrics lock");
        match &result {
            Ok(r) => metrics.record(r),
            Err(e) => {
                metrics.jobs += 1;
                metrics.failures += 1;
                *metrics.failure_codes.entry(e.code()).or_insert(0) += 1;
            }
        }
        drop(metrics);
        result
    }

    fn submit_inner(&self, job: &JobSpec) -> Result<JobResult, Error> {
        let spec: Spec = job.workload.parse().map_err(Error::Spec)?;
        let canon = spec.canonical();
        let cfg = job.effective_config();
        let overlay = Overlay::from_config(cfg)?;
        // fault injection: per-job submit delay (latency chaos)
        if let Some(ms) =
            self.faults.as_ref().and_then(|p| p.delay_ms(&job.workload, &canon))
        {
            self.injected_delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(ms));
        }
        let entry = self.graph_entry(&spec, &canon)?;
        let key = CacheKey::new(entry.fingerprint, &canon, &cfg);

        let lookup = || self.programs.lock().expect("program cache lock").get(&key);
        let (compiled, cache_hit, compile_micros) =
            match self.program_flight.acquire(&key, lookup) {
                Err(FlightPoisoned) => {
                    return Err(Error::CompilePoisoned { what: canon });
                }
                Ok(Some(compiled)) => (compiled, true, 0),
                Ok(None) => {
                    // we own the build right: compile with no locks
                    // held, inside an unwind boundary so a panicking
                    // compile (injected or real) poisons the flight
                    // instead of wedging every waiter
                    let t0 = Instant::now();
                    let fire = self
                        .faults
                        .as_ref()
                        .is_some_and(|p| p.compile_panic_armed(&job.workload, &canon))
                        && self
                            .fired_panics
                            .lock()
                            .expect("fired panics lock")
                            .insert(canon.clone());
                    let built = catch_unwind(AssertUnwindSafe(|| {
                        if fire {
                            self.injected_panics.fetch_add(1, Ordering::Relaxed);
                            panic!("fault injection: compile_panic for {canon}");
                        }
                        Self::build_compiled(&entry.graph, &overlay)
                    }));
                    match built {
                        Ok(Ok(compiled)) => {
                            self.programs
                                .lock()
                                .expect("program cache lock")
                                .insert(key.clone(), compiled.clone());
                            self.program_flight.release(&key);
                            (compiled, false, t0.elapsed().as_micros() as u64)
                        }
                        Ok(Err(e)) => {
                            self.program_flight.release(&key);
                            return Err(Error::Compile(e));
                        }
                        Err(payload) => {
                            self.program_flight.poison(&key);
                            return Err(Error::Panicked {
                                stage: "compile",
                                message: panic_message(payload.as_ref()),
                            });
                        }
                    }
                }
            };
        if cache_hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }

        // deadline / cancellation token: an injected overrun runs with
        // an already-expired token (forcing the deadline path without
        // waiting out a real budget); otherwise the job's own
        // `timeout_ms` arms it, and no token means no polling cost
        let token = if self
            .faults
            .as_ref()
            .is_some_and(|p| p.deadline_overrun(&job.workload, &canon))
        {
            self.injected_overruns.fetch_add(1, Ordering::Relaxed);
            Some(CancelToken::already_expired())
        } else {
            job.timeout_ms.map(CancelToken::with_deadline_ms)
        };

        let t0 = Instant::now();
        // the run is a second unwind boundary: a panicking simulation
        // fails this one job, not the worker thread it ran on
        let ran = catch_unwind(AssertUnwindSafe(
            || -> Result<(crate::sim::SimStats, Option<ShardInfo>), Error> {
                match &compiled {
                    Compiled::Single(program) => {
                        let view = program.program();
                        let mut session = view
                            .session()
                            .with_scheduler(job.scheduler)
                            .with_backend(job.backend)
                            .with_max_cycles(cfg.max_cycles);
                        if let Some(t) = &token {
                            session = session.with_cancel(t);
                        }
                        let stats = session.run().map_err(Error::from)?;
                        Ok((stats, None))
                    }
                    Compiled::Sharded(sharded) => {
                        let mut session = sharded
                            .session()
                            .with_scheduler(job.scheduler)
                            .with_backend(job.backend)
                            .with_max_cycles(cfg.max_cycles);
                        if let Some(t) = &token {
                            session = session.with_cancel(t);
                        }
                        if let Some(plan) = self.faults.as_deref() {
                            session = session.with_fault_plan(plan);
                        }
                        let run = session.run().map_err(Error::from)?;
                        let part = sharded.partition();
                        let info = ShardInfo {
                            count: sharded.num_shards(),
                            cut_edges: part.cut_edges.len(),
                            cut_weight: part.cut_weight,
                            epoch: sharded.epoch(),
                            epochs: run.epochs,
                            boundary_values: run.boundary_values,
                            boundary_stalls: run.boundary_stalls,
                            shard_cycles: run.shard_cycles,
                        };
                        Ok((run.stats, Some(info)))
                    }
                }
            },
        ));
        let (stats, shards) = match ran {
            Ok(out) => out?,
            Err(payload) => {
                return Err(Error::Panicked {
                    stage: "run",
                    message: panic_message(payload.as_ref()),
                })
            }
        };
        let run_micros = t0.elapsed().as_micros() as u64;

        Ok(JobResult {
            workload: canon,
            scheduler: job.scheduler,
            backend: job.backend,
            fingerprint: entry.fingerprint,
            cache_hit,
            compile_micros,
            run_micros,
            nodes: entry.stats.nodes,
            edges: entry.stats.edges,
            depth: entry.stats.depth,
            stats,
            shards,
        })
    }

    /// Compile `graph` for `overlay` into the artifact its cache key
    /// resolves to: sharded when the `shards` knob forces it, single
    /// fabric otherwise — falling back to an auto-sized sharded compile
    /// when the program does not fit one fabric and capacity is not
    /// enforced. The fallback verdict uses the *normalized* scheduler
    /// (out-of-order — the one the cache key stores when capacity
    /// enforcement is off), so the decision is a pure function of the
    /// key and every job sharing the key gets the same artifact.
    fn build_compiled(
        graph: &Arc<DataflowGraph>,
        overlay: &Overlay,
    ) -> Result<Compiled, crate::program::CompileError> {
        let cfg = overlay.config();
        if cfg.shards >= 1 {
            let sharded = ShardedProgram::compile(Arc::clone(graph), overlay, cfg.shards)?;
            return Ok(Compiled::Sharded(Arc::new(sharded)));
        }
        let single = SharedProgram::compile(Arc::clone(graph), overlay)?;
        if !cfg.enforce_capacity && !single.program().fits(SchedulerKind::OutOfOrder) {
            let n = single.program().min_shards(SchedulerKind::OutOfOrder);
            let sharded = ShardedProgram::compile(Arc::clone(graph), overlay, n)?;
            return Ok(Compiled::Sharded(Arc::new(sharded)));
        }
        Ok(Compiled::Single(Arc::new(single)))
    }

    /// Fan `jobs` across `workers` OS threads ([`run_parallel`]).
    /// Results come back in job order regardless of completion order,
    /// so batch output is deterministic for every worker count.
    pub fn submit_batch(
        &self,
        jobs: &[JobSpec],
        workers: usize,
    ) -> Vec<Result<JobResult, Error>> {
        run_parallel(jobs.to_vec(), workers, |job: JobSpec| self.submit(&job))
    }

    /// Current cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        let programs = self.programs.lock().expect("program cache lock");
        let graphs = self.graphs.lock().expect("graph cache lock");
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: programs.evictions(),
            entries: programs.len(),
            graphs: graphs.len(),
            graph_evictions: graphs.evictions(),
        }
    }

    /// A stable JSON document of every engine metric — cache hit/miss/
    /// eviction counts, single-flight waits, job totals and compile/run
    /// latency histograms (global and per canonical workload key, with
    /// p50/p90/p99). This is the payload the future `tdp serve` stats
    /// endpoint returns; `tdp batch --metrics-out` dumps it today. The
    /// layout is versioned (`version: 1`): keys are only ever added.
    pub fn metrics_snapshot(&self) -> Json {
        let cache = self.cache_stats();
        let metrics = self.metrics.lock().expect("metrics lock");
        let num = |v: u64| Json::Num(v as f64);

        let mut cache_obj = BTreeMap::new();
        cache_obj.insert("hits".to_string(), num(cache.hits));
        cache_obj.insert("misses".to_string(), num(cache.misses));
        cache_obj.insert("evictions".to_string(), num(cache.evictions));
        cache_obj.insert("entries".to_string(), num(cache.entries as u64));
        cache_obj.insert("graphs".to_string(), num(cache.graphs as u64));
        cache_obj.insert("graph_evictions".to_string(), num(cache.graph_evictions));

        let mut flight = BTreeMap::new();
        flight.insert("program_waits".to_string(), num(self.program_flight.waits()));
        flight.insert("graph_waits".to_string(), num(self.graph_flight.waits()));

        let mut jobs = BTreeMap::new();
        jobs.insert("submitted".to_string(), num(metrics.jobs));
        jobs.insert("failed".to_string(), num(metrics.failures));
        jobs.insert("sharded".to_string(), num(metrics.sharded));
        let codes: BTreeMap<String, Json> = metrics
            .failure_codes
            .iter()
            .map(|(code, n)| ((*code).to_string(), num(*n)))
            .collect();
        jobs.insert("failure_codes".to_string(), Json::Obj(codes));

        let mut faults = BTreeMap::new();
        faults.insert("armed".to_string(), Json::Bool(self.faults.is_some()));
        faults.insert(
            "injected_compile_panics".to_string(),
            num(self.injected_panics.load(Ordering::Relaxed)),
        );
        faults.insert(
            "injected_delays".to_string(),
            num(self.injected_delays.load(Ordering::Relaxed)),
        );
        faults.insert(
            "injected_overruns".to_string(),
            num(self.injected_overruns.load(Ordering::Relaxed)),
        );

        let mut latency = BTreeMap::new();
        latency.insert("compile_micros".to_string(), metrics.compile.to_json_value());
        latency.insert("run_micros".to_string(), metrics.run.to_json_value());

        let workloads: BTreeMap<String, Json> = metrics
            .per_key
            .iter()
            .map(|(k, pair)| {
                let mut m = BTreeMap::new();
                m.insert("jobs".to_string(), num(pair.jobs));
                m.insert("compile_micros".to_string(), pair.compile.to_json_value());
                m.insert("run_micros".to_string(), pair.run.to_json_value());
                (k.clone(), Json::Obj(m))
            })
            .collect();

        let mut root = BTreeMap::new();
        root.insert("version".to_string(), Json::Num(1.0));
        root.insert("cache".to_string(), Json::Obj(cache_obj));
        root.insert("faults".to_string(), Json::Obj(faults));
        root.insert("flight".to_string(), Json::Obj(flight));
        root.insert("jobs".to_string(), Json::Obj(jobs));
        root.insert("latency".to_string(), Json::Obj(latency));
        root.insert("workloads".to_string(), Json::Obj(workloads));
        Json::Obj(root)
    }

    /// Compact JSON text of [`Engine::metrics_snapshot`].
    pub fn metrics_snapshot_json(&self) -> String {
        json::write(&self.metrics_snapshot())
    }

    /// Build (or fetch) the graph for `spec` (whose canonical string is
    /// `canon`) — single-flight per canonical spec, generation itself
    /// outside every lock.
    fn graph_entry(&self, spec: &Spec, canon: &str) -> Result<Arc<GraphEntry>, Error> {
        let canon = canon.to_string();
        let lookup = || self.graphs.lock().expect("graph cache lock").get(&canon);
        match self.graph_flight.acquire(&canon, lookup) {
            Err(FlightPoisoned) => return Err(Error::CompilePoisoned { what: canon }),
            Ok(Some(entry)) => return Ok(entry),
            Ok(None) => {}
        }
        let built = catch_unwind(AssertUnwindSafe(|| spec.build()));
        let result = match built {
            Ok(Ok(graph)) => {
                let graph = Arc::new(graph);
                let entry = Arc::new(GraphEntry {
                    fingerprint: graph.fingerprint(),
                    stats: graph.stats(),
                    graph,
                });
                self.graphs
                    .lock()
                    .expect("graph cache lock")
                    .insert(canon.clone(), Arc::clone(&entry));
                Ok(entry)
            }
            Ok(Err(msg)) => Err(Error::Spec(msg)),
            Err(payload) => {
                self.graph_flight.poison(&canon);
                return Err(Error::Panicked {
                    stage: "generate",
                    message: panic_message(payload.as_ref()),
                });
            }
        };
        self.graph_flight.release(&canon);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::BackendKind;
    use crate::sched::SchedulerKind;

    fn job(workload: &str, cols: usize, rows: usize) -> JobSpec {
        let mut j = JobSpec::new(workload);
        j.overlay = j.overlay.with_dims(cols, rows);
        j
    }

    #[test]
    fn duplicate_jobs_hit_the_cache_with_identical_stats() {
        let engine = Engine::new();
        let j = job("reduction:64", 2, 2);
        let cold = engine.submit(&j).unwrap();
        let warm = engine.submit(&j).unwrap();
        assert!(!cold.cache_hit);
        assert!(warm.cache_hit);
        assert_eq!(warm.compile_micros, 0);
        assert_eq!(warm.stats, cold.stats, "hits replay bit-identical stats");
        assert_eq!(warm.fingerprint, cold.fingerprint);
        let s = engine.cache_stats();
        assert_eq!((s.hits, s.misses, s.entries, s.graphs), (1, 1, 1, 1));
    }

    #[test]
    fn scheduler_and_backend_variants_share_one_program() {
        let engine = Engine::new();
        let mut variants = Vec::new();
        for sched in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
            for backend in [BackendKind::Lockstep, BackendKind::SkipAhead] {
                let mut j = job("layered:8:4:16:2:seed=3", 2, 2);
                j.scheduler = sched;
                j.backend = backend;
                variants.push(j);
            }
        }
        let results: Vec<JobResult> = variants
            .iter()
            .map(|j| engine.submit(j).unwrap())
            .collect();
        let s = engine.cache_stats();
        assert_eq!(s.misses, 1, "one compile serves all four variants");
        assert_eq!(s.hits, 3);
        // backends bit-exact per scheduler; schedulers genuinely differ
        assert_eq!(results[0].stats, results[1].stats);
        assert_eq!(results[2].stats, results[3].stats);
        assert_eq!(results[0].stats.scheduler, SchedulerKind::InOrder);
        assert_eq!(results[2].stats.scheduler, SchedulerKind::OutOfOrder);
    }

    #[test]
    fn submit_batch_preserves_job_order() {
        let engine = Engine::new();
        let jobs: Vec<JobSpec> = ["reduction:32", "chain:16", "reduction:32", "butterfly:16"]
            .iter()
            .map(|w| job(w, 2, 2))
            .collect();
        let results = engine.submit_batch(&jobs, 4);
        assert_eq!(results.len(), 4);
        for (j, r) in jobs.iter().zip(&results) {
            assert_eq!(r.as_ref().unwrap().workload, j.workload);
        }
        assert_eq!(
            results[0].as_ref().unwrap().stats,
            results[2].as_ref().unwrap().stats,
            "duplicate jobs agree"
        );
    }

    #[test]
    fn errors_map_to_typed_arms() {
        let engine = Engine::new();
        // bad spec string
        match engine.submit(&JobSpec::new("bogus:1")) {
            Err(Error::Spec(msg)) => assert!(msg.contains("bogus"), "{msg}"),
            other => panic!("expected Spec error, got {other:?}"),
        }
        // invalid overlay
        let bad = job("reduction:16", 0, 4);
        assert!(matches!(engine.submit(&bad), Err(Error::Config(_))));
        // cycle-limited run: typed exhaustion with partial progress
        let mut limited = job("reduction:64", 2, 2);
        limited.max_cycles = Some(3);
        match engine.submit(&limited) {
            Err(Error::CyclesExhausted(p)) => {
                assert_eq!(p.cycles, 3);
                assert!(p.total > 0);
                assert!(p.incomplete_nodes() > 0, "3 cycles cannot finish reduction:64");
            }
            other => panic!("expected cycles_exhausted, got {other:?}"),
        }
        // failed jobs poison nothing: the same engine keeps serving, and
        // a compile failure releases the flight latch for retries
        let mut too_big = job("layered:64:32:128:2", 1, 1);
        too_big.overlay.enforce_capacity = true;
        assert!(matches!(engine.submit(&too_big), Err(Error::Compile(_))));
        assert!(matches!(engine.submit(&too_big), Err(Error::Compile(_))));
        assert!(engine.submit(&job("reduction:64", 2, 2)).is_ok());
    }

    /// `metrics_snapshot()` must agree with `cache_stats()` and count
    /// jobs, failures and latency observations exactly — the stable
    /// document the future `tdp serve` stats endpoint returns.
    #[test]
    fn metrics_snapshot_counts_jobs_failures_and_latency() {
        let engine = Engine::new();
        let j = job("reduction:64", 2, 2);
        engine.submit(&j).unwrap(); // miss
        engine.submit(&j).unwrap(); // hit
        assert!(engine.submit(&JobSpec::new("bogus:1")).is_err());

        let snap = engine.metrics_snapshot();
        assert_eq!(snap.get("version").unwrap().as_u64(), Some(1));
        let cache = snap.get("cache").unwrap();
        let s = engine.cache_stats();
        assert_eq!(cache.get("hits").unwrap().as_u64(), Some(s.hits));
        assert_eq!(cache.get("misses").unwrap().as_u64(), Some(s.misses));
        assert_eq!(cache.get("evictions").unwrap().as_u64(), Some(s.evictions));
        assert_eq!(cache.get("entries").unwrap().as_usize(), Some(s.entries));
        let jobs = snap.get("jobs").unwrap();
        assert_eq!(jobs.get("submitted").unwrap().as_u64(), Some(3));
        assert_eq!(jobs.get("failed").unwrap().as_u64(), Some(1));
        // one compile observation (the miss), two run observations
        let latency = snap.get("latency").unwrap();
        let compile = latency.get("compile_micros").unwrap();
        assert_eq!(compile.get("count").unwrap().as_u64(), Some(1));
        assert!(compile.get("p99").is_some());
        let run = latency.get("run_micros").unwrap();
        assert_eq!(run.get("count").unwrap().as_u64(), Some(2));
        // per-workload breakdown keyed by canonical spec
        let per = snap
            .get("workloads")
            .unwrap()
            .get("reduction:64")
            .unwrap();
        assert_eq!(per.get("jobs").unwrap().as_u64(), Some(2));
        assert_eq!(
            per.get("compile_micros").unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
        // the text form is valid JSON parsing back to the same document
        let text = engine.metrics_snapshot_json();
        let parsed = json::parse(&text).unwrap();
        assert_eq!(json::write(&parsed), text);
    }

    /// Racing duplicates of one key must register single-flight waits in
    /// the snapshot (the winner builds, everyone else blocks).
    #[test]
    fn metrics_snapshot_surfaces_flight_waits() {
        let engine = Engine::new();
        let j = job("lu_banded:48:4:0.9", 2, 2);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let engine = &engine;
                let j = &j;
                s.spawn(move || engine.submit(j).unwrap());
            }
        });
        let snap = engine.metrics_snapshot();
        let waits = snap
            .get("flight")
            .unwrap()
            .get("program_waits")
            .unwrap()
            .as_u64()
            .unwrap()
            + snap
                .get("flight")
                .unwrap()
                .get("graph_waits")
                .unwrap()
                .as_u64()
                .unwrap();
        // timing-dependent: most runs see all 3 losers wait, but any
        // loser arriving after publication hits the cache directly
        assert!(waits <= 6, "at most 3 losers per flight, got {waits}");
        assert_eq!(engine.cache_stats().misses, 1, "still exactly one compile");
    }

    /// `shards = N` in the overlay forces a sharded compile; the result
    /// carries partition provenance and replays bit-identically from
    /// the cache, and a forced N=1 matches the single-fabric run.
    #[test]
    fn forced_shard_jobs_carry_provenance_and_replay_identically() {
        let engine = Engine::new();
        let mut j = job("reduction:64", 2, 2);
        j.overlay.shards = 2;
        let cold = engine.submit(&j).unwrap();
        let info = cold.shards.as_ref().expect("forced-shard provenance");
        assert_eq!(info.count, 2);
        assert_eq!(info.shard_cycles.len(), 2);
        assert!(info.epoch > 0);
        let warm = engine.submit(&j).unwrap();
        assert!(warm.cache_hit);
        assert_eq!(warm.stats, cold.stats, "sharded hits replay bit-identical stats");
        assert_eq!(warm.shards, cold.shards);

        let base = engine.submit(&job("reduction:64", 2, 2)).unwrap();
        assert!(base.shards.is_none(), "fitting jobs stay single-fabric");
        let mut n1 = job("reduction:64", 2, 2);
        n1.overlay.shards = 1;
        let one = engine.submit(&n1).unwrap();
        assert_eq!(one.stats, base.stats, "forced N=1 is bit-identical to single-fabric");
        assert_eq!(one.shards.as_ref().unwrap().boundary_values, 0);
    }

    /// A graph that cannot fit one fabric (the capacity-enforced variant
    /// above fails its compile) auto-falls back to a sharded compile and
    /// runs to completion, with provenance and the `sharded` jobs
    /// counter surfacing the fallback.
    #[test]
    fn oversized_graphs_auto_shard_to_completion() {
        let engine = Engine::new();
        let j = job("layered:64:32:128:2", 1, 1);
        let r = engine.submit(&j).unwrap();
        let info = r.shards.as_ref().expect("auto-shard provenance");
        assert!(info.count >= 2, "needs more than one fabric, got {}", info.count);
        assert_eq!(r.stats.completed, r.stats.total_nodes, "ran to completion");
        let r2 = engine.submit(&j).unwrap();
        assert!(r2.cache_hit);
        assert_eq!(r2.stats, r.stats);
        assert_eq!(r2.shards, r.shards);
        let snap = engine.metrics_snapshot();
        assert_eq!(
            snap.get("jobs").unwrap().get("sharded").unwrap().as_u64(),
            Some(2)
        );
    }

    /// A `timeout_ms: 0` job fails typed `deadline_exceeded` on both
    /// backends, carrying partial progress — detection lags the budget
    /// by at most one `CANCEL_CHECK_INTERVAL`, and the chain workload
    /// is deep enough that neither backend can finish inside the lag.
    #[test]
    fn deadline_jobs_fail_typed_with_partial_stats() {
        let engine = Engine::new();
        for backend in [BackendKind::Lockstep, BackendKind::SkipAhead] {
            let mut j = job("chain:4096", 2, 2);
            j.backend = backend;
            j.timeout_ms = Some(0);
            match engine.submit(&j) {
                Err(Error::Deadline(p)) => {
                    assert!(p.total > 0, "{backend:?}");
                    assert!(p.completed < p.total, "{backend:?}: expired at submit");
                }
                other => panic!("{backend:?}: expected deadline, got {other:?}"),
            }
        }
        // a generous deadline does not perturb the run
        let mut ok = job("chain:4096", 2, 2);
        ok.timeout_ms = Some(600_000);
        let timed = engine.submit(&ok).unwrap();
        let bare = engine.submit(&job("chain:4096", 2, 2)).unwrap();
        assert_eq!(timed.stats, bare.stats, "deadline arm is observational");
        // failures were bucketed by code in the snapshot
        let snap = engine.metrics_snapshot();
        let codes = snap.get("jobs").unwrap().get("failure_codes").unwrap();
        assert_eq!(codes.get("deadline_exceeded").unwrap().as_u64(), Some(2));
    }

    /// An injected compile panic fires once: the panicking job reports
    /// `panicked`, the flight latch is poisoned-then-cleared (never
    /// wedged), the cache stays unpoisoned, and the next identical job
    /// compiles successfully — the poison-recovery protocol end to end.
    #[test]
    fn compile_panic_poisons_once_then_recovers() {
        let j = job("reduction:64", 2, 2);
        let plan = FaultPlan {
            compile_panics: vec![j.workload.clone()],
            ..FaultPlan::default()
        };
        let engine =
            Engine::with_capacity_and_faults(DEFAULT_CACHE_CAPACITY, Some(Arc::new(plan)));
        match engine.submit(&j) {
            Err(Error::Panicked { stage, message }) => {
                assert_eq!(stage, "compile");
                assert!(message.contains("fault injection"), "{message}");
            }
            other => panic!("expected panicked, got {other:?}"),
        }
        // retry: the injected panic is spent, the compile succeeds and
        // a third submit is a clean cache hit
        let retry = engine.submit(&j).unwrap();
        assert!(!retry.cache_hit, "poison evicted nothing — this is a fresh compile");
        assert!(engine.submit(&j).unwrap().cache_hit);
        let snap = engine.metrics_snapshot();
        let faults = snap.get("faults").unwrap();
        assert_eq!(faults.get("armed"), Some(&Json::Bool(true)));
        assert_eq!(faults.get("injected_compile_panics").unwrap().as_u64(), Some(1));
        let codes = snap.get("jobs").unwrap().get("failure_codes").unwrap();
        assert_eq!(codes.get("panicked").unwrap().as_u64(), Some(1));
    }

    /// Concurrent duplicates of a panicking compile: the leader reports
    /// `panicked`; every other thread gets `compile_poisoned` (it was
    /// waiting on the doomed flight) or a clean result (it arrived
    /// after the latch cleared and became the retry leader, or hit the
    /// retry's cache). Nothing hangs, and the engine keeps serving.
    #[test]
    fn waiters_on_a_panicked_compile_fail_poisoned_not_hung() {
        let j = job("lu_banded:48:4:0.9", 2, 2);
        let plan = FaultPlan {
            compile_panics: vec![j.workload.clone()],
            ..FaultPlan::default()
        };
        let engine =
            Engine::with_capacity_and_faults(DEFAULT_CACHE_CAPACITY, Some(Arc::new(plan)));
        let results: Vec<Result<JobResult, Error>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let engine = &engine;
                    let j = &j;
                    s.spawn(move || engine.submit(j))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("submit thread")).collect()
        });
        let panicked = results
            .iter()
            .filter(|r| matches!(r, Err(Error::Panicked { .. })))
            .count();
        assert_eq!(panicked, 1, "the injected panic fires exactly once");
        for r in &results {
            match r {
                Ok(_) | Err(Error::Panicked { .. }) | Err(Error::CompilePoisoned { .. }) => {}
                other => panic!("unexpected outcome {other:?}"),
            }
        }
        // the engine is healthy: the same job now compiles clean
        assert!(engine.submit(&j).is_ok());
    }

    /// Injected overruns and delays are deterministic per plan: the
    /// matching job always fails `deadline_exceeded`, non-matching jobs
    /// are untouched, and the injection counters surface it.
    #[test]
    fn injected_overruns_and_delays_are_content_keyed() {
        let victim = job("reduction:64", 2, 2);
        let bystander = job("chain:16", 2, 2);
        let plan = FaultPlan {
            deadline_overruns: vec![victim.workload.clone()],
            job_delays: vec![(bystander.workload.clone(), 1)],
            ..FaultPlan::default()
        };
        let engine =
            Engine::with_capacity_and_faults(DEFAULT_CACHE_CAPACITY, Some(Arc::new(plan)));
        for _ in 0..2 {
            assert!(
                matches!(engine.submit(&victim), Err(Error::Deadline(_))),
                "overrun fires on every matching submit"
            );
        }
        assert!(engine.submit(&bystander).is_ok(), "delayed jobs still succeed");
        let snap = engine.metrics_snapshot();
        let faults = snap.get("faults").unwrap();
        assert_eq!(faults.get("injected_overruns").unwrap().as_u64(), Some(2));
        assert_eq!(faults.get("injected_delays").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn lru_bound_applies_to_both_caches() {
        let engine = Engine::with_capacity(2);
        for w in ["reduction:8", "reduction:12", "reduction:16"] {
            engine.submit(&job(w, 2, 2)).unwrap();
        }
        let s = engine.cache_stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.graphs, 2, "graph cache is bounded too");
        assert_eq!(s.graph_evictions, 1);
        // the evicted workload recompiles (miss), the resident ones hit
        engine.submit(&job("reduction:8", 2, 2)).unwrap();
        assert_eq!(engine.cache_stats().misses, 4);
    }
}
