//! The request/response types of the service layer.
//!
//! A [`JobSpec`] names *what* to run (a workload spec string, see
//! [`crate::workload::Spec`]) and *how* (scheduler, engine backend,
//! overlay knobs, cycle budget); a [`JobResult`] carries the full
//! [`SimStats`] plus compile/run timing and cache provenance. Both are
//! JSON documents (`util::json`), one per line in `tdp batch` streams.

use crate::config::OverlayConfig;
use crate::engine::BackendKind;
use crate::sched::SchedulerKind;
use crate::sim::SimStats;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// One execution request: a workload spec string plus the run variant
/// and overlay overrides.
///
/// JSON form (only `workload` is required):
///
/// ```json
/// {"workload": "chain:4096:seed=7", "scheduler": "out_of_order",
///  "backend": "skip_ahead", "cols": 16, "rows": 16,
///  "max_cycles": 1000000, "overlay": { ...full OverlayConfig... }}
/// ```
///
/// `overlay` (when present) is a full [`OverlayConfig`] object; the
/// flat `cols` / `rows` / `seed` / `shards` keys are shorthand applied
/// on top of it, and `scheduler` / `backend` / `max_cycles` always win
/// over the values inside `overlay` — they are session-level knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// workload spec string (`crate::workload::Spec` grammar)
    pub workload: String,
    pub scheduler: SchedulerKind,
    pub backend: BackendKind,
    /// base overlay knobs (scheduler/backend/max_cycles inside are
    /// superseded by the fields above)
    pub overlay: OverlayConfig,
    /// cycle-budget override; `None` keeps the overlay's limit
    pub max_cycles: Option<u64>,
    /// wall-clock deadline in milliseconds, measured from the moment
    /// the engine starts the job; `None` runs unbounded. Expiry stops
    /// the run within [`crate::sim::CANCEL_CHECK_INTERVAL`] cycles and
    /// the job fails with `deadline_exceeded` carrying partial progress
    /// (DESIGN.md §15).
    pub timeout_ms: Option<u64>,
}

impl JobSpec {
    /// A job at the default overlay (paper 16×16, lockstep, OoO).
    pub fn new(workload: &str) -> Self {
        let overlay = OverlayConfig::default();
        Self {
            workload: workload.to_string(),
            scheduler: overlay.scheduler,
            backend: overlay.backend,
            overlay,
            max_cycles: None,
            timeout_ms: None,
        }
    }

    /// The fully-resolved overlay config this job runs under.
    pub fn effective_config(&self) -> OverlayConfig {
        let mut cfg = self.overlay;
        cfg.scheduler = self.scheduler;
        cfg.backend = self.backend;
        if let Some(mc) = self.max_cycles {
            cfg.max_cycles = mc;
        }
        cfg
    }

    /// Parse a job from a JSON document (one `tdp batch` input line).
    /// Strict: unknown keys are rejected.
    pub fn from_json(text: &str) -> Result<Self, String> {
        Self::from_json_value(&json::parse(text).map_err(|e| e.to_string())?)
    }

    /// Parse from an already-parsed [`Json`] value.
    pub fn from_json_value(j: &Json) -> Result<Self, String> {
        let obj = j.as_obj().ok_or("job spec must be a JSON object")?;
        // base overlay first, so the flat shorthand keys override it
        // regardless of key order in the document
        let mut overlay = match obj.get("overlay") {
            Some(v) => OverlayConfig::from_json_value(v)?,
            None => OverlayConfig::default(),
        };
        let mut workload = None;
        let mut scheduler = None;
        let mut backend = None;
        let mut max_cycles = None;
        let mut timeout_ms = None;
        for (key, v) in obj {
            match key.as_str() {
                "overlay" => {} // consumed above
                "workload" => {
                    workload =
                        Some(v.as_str().ok_or("workload: expected string")?.to_string())
                }
                "scheduler" => {
                    scheduler = Some(
                        v.as_str()
                            .ok_or("scheduler: expected string")?
                            .parse::<SchedulerKind>()?,
                    )
                }
                "backend" => {
                    backend = Some(
                        v.as_str()
                            .ok_or("backend: expected string")?
                            .parse::<BackendKind>()?,
                    )
                }
                "cols" => {
                    overlay.cols = v
                        .as_u64()
                        .ok_or("cols: expected non-negative integer")?
                        as usize
                }
                "rows" => {
                    overlay.rows = v
                        .as_u64()
                        .ok_or("rows: expected non-negative integer")?
                        as usize
                }
                "seed" => {
                    overlay.seed = v.as_u64().ok_or("seed: expected non-negative integer")?
                }
                "shards" => {
                    overlay.shards = v
                        .as_u64()
                        .ok_or("shards: expected non-negative integer")?
                        as usize
                }
                "max_cycles" => {
                    max_cycles =
                        Some(v.as_u64().ok_or("max_cycles: expected non-negative integer")?)
                }
                "timeout_ms" => {
                    timeout_ms =
                        Some(v.as_u64().ok_or("timeout_ms: expected non-negative integer")?)
                }
                other => return Err(format!("unknown job key '{other}'")),
            }
        }
        let workload = workload.ok_or("job spec needs \"workload\"")?;
        Ok(Self {
            workload,
            scheduler: scheduler.unwrap_or(overlay.scheduler),
            backend: backend.unwrap_or(overlay.backend),
            overlay,
            max_cycles,
            timeout_ms,
        })
    }

    /// JSON form: workload + variant + the full base overlay (so a spec
    /// written by `to_json` is self-contained and round-trips exactly).
    pub fn to_json_value(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("workload".to_string(), Json::Str(self.workload.clone()));
        m.insert(
            "scheduler".to_string(),
            Json::Str(self.scheduler.toml_name().to_string()),
        );
        m.insert(
            "backend".to_string(),
            Json::Str(self.backend.toml_name().to_string()),
        );
        if let Some(mc) = self.max_cycles {
            m.insert("max_cycles".to_string(), Json::Num(mc as f64));
        }
        if let Some(tm) = self.timeout_ms {
            m.insert("timeout_ms".to_string(), Json::Num(tm as f64));
        }
        m.insert("overlay".to_string(), self.overlay.to_json_value());
        Json::Obj(m)
    }

    /// Compact JSON text of [`JobSpec::to_json_value`].
    pub fn to_json(&self) -> String {
        json::write(&self.to_json_value())
    }
}

/// Sharded-execution provenance of a [`JobResult`]: how the graph was
/// partitioned and what the boundary channels carried
/// ([`crate::shard`]). Present exactly when the job ran sharded —
/// either forced (`shards >= 1`) or by the auto fallback for graphs
/// that do not fit one fabric.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardInfo {
    /// number of fabric shards the graph ran across
    pub count: usize,
    /// graph edges crossing a shard boundary
    pub cut_edges: usize,
    /// criticality-weighted cut cost ([`crate::passes::partition`])
    pub cut_weight: u64,
    /// epoch length E == modeled boundary-link latency (cycles)
    pub epoch: u64,
    /// epoch barriers the run synchronized at
    pub epochs: u64,
    /// values carried across boundary channels
    pub boundary_values: u64,
    /// channel-capacity stall events at barriers
    pub boundary_stalls: u64,
    /// completion cycle of each shard
    pub shard_cycles: Vec<u64>,
}

impl ShardInfo {
    pub fn to_json_value(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.count as f64));
        m.insert("cut_edges".to_string(), Json::Num(self.cut_edges as f64));
        m.insert("cut_weight".to_string(), Json::Num(self.cut_weight as f64));
        m.insert("epoch".to_string(), Json::Num(self.epoch as f64));
        m.insert("epochs".to_string(), Json::Num(self.epochs as f64));
        m.insert(
            "boundary_values".to_string(),
            Json::Num(self.boundary_values as f64),
        );
        m.insert(
            "boundary_stalls".to_string(),
            Json::Num(self.boundary_stalls as f64),
        );
        m.insert(
            "shard_cycles".to_string(),
            Json::Arr(self.shard_cycles.iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        Json::Obj(m)
    }
}

/// One execution response: the workload's canonical spec, the variant it
/// ran under, graph shape, cache provenance, timing and the full
/// simulation counters.
#[derive(Debug, Clone, PartialEq)]
pub struct JobResult {
    /// canonical workload spec ([`crate::workload::Spec::canonical`])
    pub workload: String,
    pub scheduler: SchedulerKind,
    pub backend: BackendKind,
    /// content fingerprint of the built graph
    /// ([`crate::graph::DataflowGraph::fingerprint`])
    pub fingerprint: u64,
    /// did the Program come out of the engine's cache?
    pub cache_hit: bool,
    /// one-time compile cost actually paid by this job (0 on a hit)
    pub compile_micros: u64,
    /// simulation wall time
    pub run_micros: u64,
    pub nodes: usize,
    pub edges: usize,
    pub depth: usize,
    /// the full counter set of the run
    pub stats: SimStats,
    /// sharded-execution provenance; `None` for single-fabric runs
    pub shards: Option<ShardInfo>,
}

impl JobResult {
    /// JSON form (one `tdp batch` output line). The fingerprint is a
    /// 16-digit hex *string*: u64 values do not survive f64 JSON
    /// numbers above 2^53.
    pub fn to_json_value(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("workload".to_string(), Json::Str(self.workload.clone()));
        m.insert(
            "scheduler".to_string(),
            Json::Str(self.scheduler.toml_name().to_string()),
        );
        m.insert(
            "backend".to_string(),
            Json::Str(self.backend.toml_name().to_string()),
        );
        m.insert(
            "fingerprint".to_string(),
            Json::Str(format!("{:016x}", self.fingerprint)),
        );
        m.insert("cache_hit".to_string(), Json::Bool(self.cache_hit));
        m.insert("compile_micros".to_string(), Json::Num(self.compile_micros as f64));
        m.insert("run_micros".to_string(), Json::Num(self.run_micros as f64));
        m.insert("nodes".to_string(), Json::Num(self.nodes as f64));
        m.insert("edges".to_string(), Json::Num(self.edges as f64));
        m.insert("depth".to_string(), Json::Num(self.depth as f64));
        m.insert("stats".to_string(), self.stats.to_json_value());
        if let Some(info) = &self.shards {
            m.insert("shards".to_string(), info.to_json_value());
        }
        Json::Obj(m)
    }

    /// Compact JSON text of [`JobResult::to_json_value`].
    pub fn to_json(&self) -> String {
        json::write(&self.to_json_value())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_json_roundtrip() {
        let mut job = JobSpec::new("chain:64:seed=3");
        job.scheduler = SchedulerKind::InOrder;
        job.backend = BackendKind::SkipAhead;
        job.overlay = job.overlay.with_dims(4, 4);
        job.max_cycles = Some(9000);
        job.timeout_ms = Some(2500);
        let back = JobSpec::from_json(&job.to_json()).unwrap();
        assert_eq!(back, job);
        assert_eq!(back.timeout_ms, Some(2500));
        assert_eq!(back.effective_config().cols, 4);
        assert_eq!(back.effective_config().max_cycles, 9000);
        assert_eq!(back.effective_config().backend, BackendKind::SkipAhead);
    }

    #[test]
    fn minimal_job_uses_defaults() {
        let job = JobSpec::from_json("{\"workload\": \"reduction:64\"}").unwrap();
        assert_eq!(job.workload, "reduction:64");
        assert_eq!(job.scheduler, SchedulerKind::OutOfOrder);
        assert_eq!(job.backend, BackendKind::Lockstep);
        assert_eq!(job.effective_config(), OverlayConfig::default());
    }

    #[test]
    fn shorthand_overrides_embedded_overlay() {
        // cols/rows/seed win over the overlay object, whatever the key order
        let text = format!(
            "{{\"cols\": 2, \"overlay\": {}, \"rows\": 3, \"workload\": \"chain:8\", \"seed\": 11}}",
            OverlayConfig::default().with_dims(8, 8).to_json()
        );
        let job = JobSpec::from_json(&text).unwrap();
        assert_eq!((job.overlay.cols, job.overlay.rows), (2, 3));
        assert_eq!(job.overlay.seed, 11);
        // session-level keys win over the overlay object too
        let text = format!(
            "{{\"workload\": \"chain:8\", \"scheduler\": \"in_order\", \"overlay\": {}}}",
            OverlayConfig::default().to_json() // overlay says out_of_order
        );
        let job = JobSpec::from_json(&text).unwrap();
        assert_eq!(job.scheduler, SchedulerKind::InOrder);
        assert_eq!(job.effective_config().scheduler, SchedulerKind::InOrder);
    }

    #[test]
    fn malformed_jobs_rejected() {
        assert!(JobSpec::from_json("{}").is_err(), "workload is required");
        assert!(JobSpec::from_json("[]").is_err());
        assert!(JobSpec::from_json("{\"workload\": \"x\", \"bogus\": 1}").is_err());
        assert!(JobSpec::from_json("{\"workload\": \"x\", \"scheduler\": \"nope\"}").is_err());
        assert!(JobSpec::from_json("{\"workload\": \"x\", \"max_cycles\": -1}").is_err());
        assert!(JobSpec::from_json("{\"workload\": \"x\", \"timeout_ms\": -5}").is_err());
        assert!(JobSpec::from_json("not json").is_err());
    }

    /// Strict mode is the protocol's typo guard: a misspelled field
    /// must be a parse error naming the offending key, not a silently
    /// ignored knob that runs the job under different settings.
    #[test]
    fn misspelled_fields_are_named_in_the_error() {
        for (doc, bad_key) in [
            ("{\"workload\": \"chain:8\", \"schedular\": \"in_order\"}", "schedular"),
            ("{\"workload\": \"chain:8\", \"max_cycle\": 100}", "max_cycle"),
            ("{\"workloads\": \"chain:8\"}", "workloads"),
            ("{\"workload\": \"chain:8\", \"overlays\": {}}", "overlays"),
        ] {
            let err = JobSpec::from_json(doc).unwrap_err();
            assert!(
                err.contains(bad_key),
                "error for {doc} should name '{bad_key}', got: {err}"
            );
        }
        // the same documents through the daemon's parser path
        let err = JobSpec::from_json_value(
            &json::parse("{\"workload\": \"chain:8\", \"colz\": 4}").unwrap(),
        )
        .unwrap_err();
        assert!(err.contains("colz"), "{err}");
    }

    #[test]
    fn job_result_json_shape() {
        use crate::noc::NetworkStats;
        let stats = SimStats::collect(
            10,
            3,
            3,
            SchedulerKind::OutOfOrder,
            NetworkStats::default(),
            vec![Default::default(); 2],
        );
        let r = JobResult {
            workload: "chain:8".into(),
            scheduler: SchedulerKind::OutOfOrder,
            backend: BackendKind::Lockstep,
            fingerprint: 0xda70_7bbb_d2f6_ebdc,
            cache_hit: true,
            compile_micros: 0,
            run_micros: 42,
            nodes: 3,
            edges: 2,
            depth: 2,
            stats: stats.clone(),
            shards: None,
        };
        let j = json::parse(&r.to_json()).unwrap();
        assert_eq!(j.get("fingerprint").unwrap().as_str(), Some("da707bbbd2f6ebdc"));
        assert_eq!(j.get("cache_hit"), Some(&Json::Bool(true)));
        let back = SimStats::from_json_value(j.get("stats").unwrap()).unwrap();
        assert_eq!(back, stats, "stats nest losslessly inside the result");
    }
}
