//! Deterministic fault injection (DESIGN.md §15): a serializable
//! [`FaultPlan`] that arms failures at named sites inside the engine
//! and the sharded runtime, so chaos runs are *bit-reproducible* —
//! the plan is data (pure function of a seed via
//! [`FaultPlan::from_seed`], or hand-written JSON), and every decision
//! is keyed by job content, never by wall clock or scheduling order.
//!
//! Sites:
//! * `compile_panic` — the first compile of a listed workload panics
//!   inside the engine's unwind boundary (exercises panic isolation
//!   and single-flight poison recovery; the retry compiles clean).
//! * `job_delay` — every submit of a listed workload sleeps first
//!   (stragglers for queue/deadline interplay).
//! * `deadline_overrun` — a listed workload runs with an
//!   already-expired [`crate::sim::CancelToken`], so it stops at its
//!   first cancellation check with a typed deadline error.
//! * `barrier_drop` — a sharded run's boundary channel delivers
//!   nothing from a given epoch on (exercises the epoch watchdog).
//!
//! Wire the plan in with `Engine::with_capacity_and_faults`, `tdp
//! serve --fault-plan <file>` or `tdp batch --fault-plan <file>`.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// A boundary channel silenced from `from_epoch` on: everything it
/// would deliver at the barrier is discarded instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BarrierDrop {
    /// index into the sharded program's canonical channel order
    pub channel: usize,
    /// first epoch (0-based) at which deliveries are dropped
    pub from_epoch: u64,
}

/// A deterministic, serializable chaos schedule. Workload matching is
/// by exact string against the job's `workload` field or its canonical
/// spec form, so decisions are independent of worker count and
/// submission interleaving.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// provenance: the seed this plan was derived from (0 for
    /// hand-written plans)
    pub seed: u64,
    /// workloads whose *first* compile panics (once per engine)
    pub compile_panics: Vec<String>,
    /// (workload, milliseconds) submits that sleep before executing
    pub job_delays: Vec<(String, u64)>,
    /// workloads forced to run with an already-expired deadline
    pub deadline_overruns: Vec<String>,
    /// sharded boundary channels silenced from an epoch on
    pub barrier_drops: Vec<BarrierDrop>,
}

/// splitmix64 — the derivation PRNG of [`FaultPlan::from_seed`]: tiny,
/// stable across platforms, and good enough to spread picks.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// Derive a plan as a pure function of `seed` over a candidate
    /// workload list: roughly one third of the candidates get a compile
    /// panic, one third a forced deadline overrun, and one quarter a
    /// small delay (buckets may overlap). Same seed + same candidates →
    /// identical plan, always.
    pub fn from_seed(seed: u64, workloads: &[&str]) -> Self {
        let mut state = seed ^ 0x7464_705f_6661_756c; // "tdp_faul"
        let mut plan = FaultPlan { seed, ..FaultPlan::default() };
        for w in workloads {
            let roll = splitmix64(&mut state);
            if roll % 3 == 0 {
                plan.compile_panics.push((*w).to_string());
            }
            if (roll >> 8) % 3 == 0 {
                plan.deadline_overruns.push((*w).to_string());
            }
            if (roll >> 16) % 4 == 0 {
                plan.job_delays.push(((*w).to_string(), 1 + (roll >> 24) % 20));
            }
        }
        plan
    }

    fn matches(list: &[String], workload: &str, canon: &str) -> bool {
        list.iter().any(|w| w == workload || w == canon)
    }

    /// Is a `compile_panic` armed for this job? (The caller tracks
    /// fire-once state — see `Engine`.)
    pub fn compile_panic_armed(&self, workload: &str, canon: &str) -> bool {
        Self::matches(&self.compile_panics, workload, canon)
    }

    /// The `job_delay` for this job, if armed.
    pub fn delay_ms(&self, workload: &str, canon: &str) -> Option<u64> {
        self.job_delays
            .iter()
            .find(|(w, _)| w == workload || w == canon)
            .map(|&(_, ms)| ms)
    }

    /// Is a `deadline_overrun` armed for this job?
    pub fn deadline_overrun(&self, workload: &str, canon: &str) -> bool {
        Self::matches(&self.deadline_overruns, workload, canon)
    }

    /// Is boundary channel `channel` silenced at `epoch`?
    pub fn barrier_dropped(&self, channel: usize, epoch: u64) -> bool {
        self.barrier_drops
            .iter()
            .any(|d| d.channel == channel && epoch >= d.from_epoch)
    }

    /// Anything armed at all? (`tdp serve` logs a warning banner when
    /// a plan is live.)
    pub fn is_armed(&self) -> bool {
        !(self.compile_panics.is_empty()
            && self.job_delays.is_empty()
            && self.deadline_overruns.is_empty()
            && self.barrier_drops.is_empty())
    }

    /// The versioned JSON image (`version: 1`; keys only ever added).
    pub fn to_json_value(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("version".to_string(), Json::Num(1.0));
        root.insert("seed".to_string(), Json::Num(self.seed as f64));
        root.insert(
            "compile_panics".to_string(),
            Json::Arr(self.compile_panics.iter().map(|w| Json::Str(w.clone())).collect()),
        );
        root.insert(
            "job_delays".to_string(),
            Json::Arr(
                self.job_delays
                    .iter()
                    .map(|(w, ms)| {
                        let mut m = BTreeMap::new();
                        m.insert("workload".to_string(), Json::Str(w.clone()));
                        m.insert("delay_ms".to_string(), Json::Num(*ms as f64));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        root.insert(
            "deadline_overruns".to_string(),
            Json::Arr(self.deadline_overruns.iter().map(|w| Json::Str(w.clone())).collect()),
        );
        root.insert(
            "barrier_drops".to_string(),
            Json::Arr(
                self.barrier_drops
                    .iter()
                    .map(|d| {
                        let mut m = BTreeMap::new();
                        m.insert("channel".to_string(), Json::Num(d.channel as f64));
                        m.insert("from_epoch".to_string(), Json::Num(d.from_epoch as f64));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        Json::Obj(root)
    }

    /// Compact JSON text of [`FaultPlan::to_json_value`].
    pub fn to_json_string(&self) -> String {
        json::write(&self.to_json_value())
    }

    /// Parse the JSON image back — strict: unknown keys and malformed
    /// entries are errors, so a typo'd chaos plan fails loudly instead
    /// of silently injecting nothing.
    pub fn from_json_value(v: &Json) -> Result<Self, String> {
        let obj = v.as_obj().ok_or("fault plan must be a JSON object")?;
        let mut plan = FaultPlan::default();
        for (k, val) in obj {
            match k.as_str() {
                "version" => {
                    let ver = val.as_u64().ok_or("'version' must be a number")?;
                    if ver != 1 {
                        return Err(format!("unsupported fault-plan version {ver}"));
                    }
                }
                "seed" => plan.seed = val.as_u64().ok_or("'seed' must be a number")?,
                "compile_panics" => plan.compile_panics = str_list(val, k)?,
                "deadline_overruns" => plan.deadline_overruns = str_list(val, k)?,
                "job_delays" => {
                    for entry in val.as_arr().ok_or("'job_delays' must be an array")? {
                        let w = entry
                            .get("workload")
                            .and_then(Json::as_str)
                            .ok_or("job_delays entry needs a 'workload' string")?;
                        let ms = entry
                            .get("delay_ms")
                            .and_then(Json::as_u64)
                            .ok_or("job_delays entry needs a 'delay_ms' number")?;
                        plan.job_delays.push((w.to_string(), ms));
                    }
                }
                "barrier_drops" => {
                    for entry in val.as_arr().ok_or("'barrier_drops' must be an array")? {
                        let channel = entry
                            .get("channel")
                            .and_then(Json::as_usize)
                            .ok_or("barrier_drops entry needs a 'channel' number")?;
                        let from_epoch = entry
                            .get("from_epoch")
                            .and_then(Json::as_u64)
                            .ok_or("barrier_drops entry needs a 'from_epoch' number")?;
                        plan.barrier_drops.push(BarrierDrop { channel, from_epoch });
                    }
                }
                other => return Err(format!("unknown fault-plan key '{other}'")),
            }
        }
        Ok(plan)
    }

    /// Parse from JSON text (`--fault-plan <file>` contents).
    pub fn parse(text: &str) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| format!("fault plan: {e}"))?;
        Self::from_json_value(&v)
    }
}

fn str_list(v: &Json, key: &str) -> Result<Vec<String>, String> {
    v.as_arr()
        .ok_or_else(|| format!("'{key}' must be an array of strings"))?
        .iter()
        .map(|s| {
            s.as_str()
                .map(str::to_string)
                .ok_or_else(|| format!("'{key}' entries must be strings"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_seed_is_pure() {
        let workloads = ["chain:64", "reduction:32", "butterfly:16", "lu_banded:48:4:0.9"];
        let a = FaultPlan::from_seed(42, &workloads);
        let b = FaultPlan::from_seed(42, &workloads);
        assert_eq!(a, b, "same seed, same plan — always");
        let c = FaultPlan::from_seed(43, &workloads);
        assert_ne!(a, c, "different seed should perturb the plan");
        assert_eq!(a.seed, 42);
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let plan = FaultPlan {
            seed: 7,
            compile_panics: vec!["chain:64".into(), "reduction:32".into()],
            job_delays: vec![("butterfly:16".into(), 12)],
            deadline_overruns: vec!["chain:64".into()],
            barrier_drops: vec![BarrierDrop { channel: 3, from_epoch: 2 }],
        };
        let text = plan.to_json_string();
        let back = FaultPlan::parse(&text).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.to_json_string(), text, "serialization is canonical");
    }

    #[test]
    fn strict_parse_rejects_unknowns_and_bad_shapes() {
        assert!(FaultPlan::parse("[1,2]").is_err());
        assert!(FaultPlan::parse(r#"{"bogus": 1}"#).unwrap_err().contains("bogus"));
        assert!(FaultPlan::parse(r#"{"version": 9}"#).unwrap_err().contains("version"));
        assert!(FaultPlan::parse(r#"{"compile_panics": [1]}"#).is_err());
        assert!(FaultPlan::parse(r#"{"job_delays": [{"workload": "x"}]}"#).is_err());
        assert!(FaultPlan::parse(r#"{"barrier_drops": [{"channel": 0}]}"#).is_err());
        let ok = FaultPlan::parse(r#"{"version": 1, "seed": 5}"#).unwrap();
        assert_eq!(ok.seed, 5);
        assert!(!ok.is_armed());
    }

    #[test]
    fn queries_match_raw_or_canonical_form() {
        let plan = FaultPlan {
            compile_panics: vec!["chain:64".into()],
            job_delays: vec![("chain:64".into(), 9)],
            deadline_overruns: vec!["reduction:32".into()],
            barrier_drops: vec![BarrierDrop { channel: 1, from_epoch: 4 }],
            ..FaultPlan::default()
        };
        assert!(plan.is_armed());
        assert!(plan.compile_panic_armed("chain:64:seed=0", "chain:64"));
        assert!(!plan.compile_panic_armed("chain:65", "chain:65"));
        assert_eq!(plan.delay_ms("chain:64", "chain:64"), Some(9));
        assert_eq!(plan.delay_ms("other", "other"), None);
        assert!(plan.deadline_overrun("reduction:32", "reduction:32"));
        assert!(!plan.barrier_dropped(1, 3), "before from_epoch");
        assert!(plan.barrier_dropped(1, 4));
        assert!(plan.barrier_dropped(1, 9), "dropped channels stay dropped");
        assert!(!plan.barrier_dropped(0, 9), "other channels unaffected");
    }
}
