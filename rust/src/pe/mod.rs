//! Processing element substrate: BRAM geometry / capacity model and the
//! PE datapath building blocks (ALU pipeline, packet-generation unit).
//! The cycle-level composition lives in [`crate::sim`].

mod bram;
mod datapath;
mod ports;

pub use bram::{BramConfig, CapacityReport};
pub use datapath::{AluPipeline, PacketGen, PgState};
pub use ports::{PortArbiter, Unit};
