//! BRAM port-budget model — the §II-C multipumping feature.
//!
//! An M20K has two physical ports; the paper clocks the RAMs at 2× the
//! fabric clock ("we multipump our BRAMs to create additional virtual
//! read/write ports"), giving the PE datapath **4 virtual ports per
//! fabric cycle** over its graph-memory bank group. Each datapath unit
//! consumes ports when it touches graph memory:
//!
//! | unit            | ports/op | what it reads/writes                |
//! |-----------------|----------|-------------------------------------|
//! | receive/match   | 2        | instruction+operand read, operand wr|
//! | ALU writeback   | 1        | result write (+ RDY flag write)     |
//! | packet-gen      | 1        | fanout-edge read                    |
//!
//! With multipump=2 all three units proceed concurrently (2+1+1 = 4),
//! which is the paper's design point: accept one packet AND inject one
//! packet per cycle. Without multipumping (2 ports) the units contend
//! and the arbiter stalls the lowest-priority ones — the ablation
//! `cargo bench --bench ports_ablation` quantifies what multipumping
//! buys.
//!
//! Priority (fixed, datapath order): receive > writeback > packet-gen.

/// Per-cycle port accounting for one PE's BRAM bank group.
#[derive(Debug, Clone)]
pub struct PortArbiter {
    budget: u32,
    available: u32,
    /// stall counters per unit (receive, writeback, pktgen)
    pub stalls: [u64; 3],
    pub grants: [u64; 3],
}

/// Datapath units in priority order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    Receive = 0,
    Writeback = 1,
    PacketGen = 2,
}

impl Unit {
    /// BRAM ports one operation of this unit consumes.
    pub fn ports(self) -> u32 {
        match self {
            Unit::Receive => 2,
            Unit::Writeback => 1,
            Unit::PacketGen => 1,
        }
    }
}

impl PortArbiter {
    /// `budget` = virtual ports per fabric cycle (2 × multipump).
    pub fn new(budget: u32) -> Self {
        assert!(budget >= 2, "an M20K group has at least its 2 physical ports");
        Self {
            budget,
            available: budget,
            stalls: [0; 3],
            grants: [0; 3],
        }
    }

    /// Start a new fabric cycle.
    #[inline]
    pub fn reset(&mut self) {
        self.available = self.budget;
    }

    /// Try to grant `unit` its ports this cycle.
    #[inline]
    pub fn request(&mut self, unit: Unit) -> bool {
        let need = unit.ports();
        if self.available >= need {
            self.available -= need;
            self.grants[unit as usize] += 1;
            true
        } else {
            self.stalls[unit as usize] += 1;
            false
        }
    }

    pub fn budget(&self) -> u32 {
        self.budget
    }

    /// Can all three units run concurrently every cycle?
    pub fn full_concurrency(&self) -> bool {
        self.budget >= Unit::Receive.ports() + Unit::Writeback.ports() + Unit::PacketGen.ports()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multipumped_budget_runs_all_units() {
        let mut a = PortArbiter::new(4); // paper: 2 ports x 2 pump
        assert!(a.full_concurrency());
        a.reset();
        assert!(a.request(Unit::Receive));
        assert!(a.request(Unit::Writeback));
        assert!(a.request(Unit::PacketGen));
        assert_eq!(a.stalls, [0, 0, 0]);
    }

    #[test]
    fn unpumped_budget_contends() {
        let mut a = PortArbiter::new(2); // no multipump
        assert!(!a.full_concurrency());
        a.reset();
        assert!(a.request(Unit::Receive)); // takes both ports
        assert!(!a.request(Unit::Writeback));
        assert!(!a.request(Unit::PacketGen));
        assert_eq!(a.stalls, [0, 1, 1]);
        // next cycle without receive: writeback + pktgen fit
        a.reset();
        assert!(a.request(Unit::Writeback));
        assert!(a.request(Unit::PacketGen));
    }

    #[test]
    fn grants_and_stalls_accumulate() {
        let mut a = PortArbiter::new(2);
        for _ in 0..10 {
            a.reset();
            a.request(Unit::Receive);
            a.request(Unit::PacketGen);
        }
        assert_eq!(a.grants[Unit::Receive as usize], 10);
        assert_eq!(a.stalls[Unit::PacketGen as usize], 10);
    }

    #[test]
    #[should_panic]
    fn sub_physical_budget_rejected() {
        PortArbiter::new(1);
    }
}
