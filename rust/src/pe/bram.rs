//! M20K BRAM geometry and the graph-memory capacity model (§II-B, §III).
//!
//! An Arria 10 M20K holds 20 Kb, configured 512×40 b. Each TDP is built
//! from 8 of them (Table I) and *multipumps* them (clocking the RAM at 2×
//! the fabric clock) to synthesize extra virtual ports.
//!
//! Graph-memory encoding (paper: "the graph structure is carefully
//! encoded in order to maximize every bit"): a node costs
//! [`BramConfig::NODE_WORDS`] words (instruction + operand/result
//! storage); a fanout edge costs [`BramConfig::EDGE_WORDS`] word (a 24 b
//! destination descriptor fits one 40 b word).
//!
//! Scheduler-dependent overheads:
//! * out-of-order: `2*ceil(512/32) = 32` flag words per BRAM ≈ 6 %
//!   (RDY + fanout-pending vectors, §II-B);
//! * in-order: ready/token FIFOs sized for the deadlock-free worst case.
//!   The paper reports the end points (256-PE FIFO overlay ⇒ ≈100 K
//!   nodes+edges; OoO ⇒ ≈5×); it does not give the FIFO sizing formula,
//!   so `fifo_brams` defaults to 6.5 of 8 — the value at which the
//!   in-order graph budget is exactly 1/5 of the out-of-order one
//!   (3840/5 = 768 words = 1.5 BRAMs). See DESIGN.md §2.

use crate::sched::SchedulerKind;

/// BRAM + memory-layout parameters of one PE.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BramConfig {
    /// M20K blocks per PE (paper: 8).
    pub brams_per_pe: usize,
    /// words per BRAM in the 512×40 b configuration.
    pub words_per_bram: usize,
    /// word width in bits (40).
    pub word_bits: usize,
    /// flag bits used per word ("for simpler arithmetic, we use only 32").
    pub flag_bits_used: usize,
    /// BRAMs reserved for FIFOs in the in-order design (may be
    /// fractional: half a BRAM = 256 words). Calibrated default: 6.5.
    pub fifo_brams: f64,
    /// multipump factor: virtual-port multiplier on the M20K's 2 physical
    /// ports (paper multipumps 2×: 4 virtual ports per BRAM per cycle).
    pub multipump: usize,
}

impl BramConfig {
    /// BRAM words one node costs (instruction word + operand/result word).
    pub const NODE_WORDS: usize = 2;
    /// BRAM words one fanout edge costs.
    pub const EDGE_WORDS: usize = 1;

    pub fn paper() -> Self {
        Self {
            brams_per_pe: 8,
            words_per_bram: 512,
            word_bits: 40,
            flag_bits_used: 32,
            fifo_brams: 6.5,
            multipump: 2,
        }
    }

    /// Total physical words of graph-memory BRAM in one PE.
    pub fn total_words(&self) -> usize {
        self.brams_per_pe * self.words_per_bram
    }

    /// Flag-vector overhead of the OoO scheduler, §II-B arithmetic.
    pub fn flag_words(&self) -> usize {
        2 * self.words_per_bram.div_ceil(self.flag_bits_used) * self.brams_per_pe
    }

    /// Words consumed by in-order FIFOs (worst-case deadlock-free sizing).
    pub fn fifo_words(&self) -> usize {
        (self.fifo_brams * self.words_per_bram as f64).round() as usize
    }

    /// Words available for graph storage under each scheduler.
    pub fn graph_words(&self, kind: SchedulerKind) -> usize {
        match kind {
            SchedulerKind::InOrder => self.total_words() - self.fifo_words(),
            SchedulerKind::OutOfOrder => self.total_words() - self.flag_words(),
        }
    }

    /// Max local nodes addressable (ignoring edges) — bounds FIFO sizing.
    pub fn max_local_nodes(&self, kind: SchedulerKind) -> usize {
        self.graph_words(kind) / Self::NODE_WORDS
    }

    /// Does a local subgraph of `nodes`/`edges` fit this PE?
    pub fn fits(&self, nodes: usize, edges: usize, kind: SchedulerKind) -> bool {
        nodes * Self::NODE_WORDS + edges * Self::EDGE_WORDS <= self.graph_words(kind)
    }

    /// Words used by a local subgraph.
    pub fn words_used(nodes: usize, edges: usize) -> usize {
        nodes * Self::NODE_WORDS + edges * Self::EDGE_WORDS
    }

    /// Virtual BRAM port budget per fabric cycle (dual-port × multipump).
    pub fn ports_per_cycle(&self) -> usize {
        2 * self.multipump
    }

    /// Full capacity report for an overlay of `num_pes`.
    pub fn capacity_report(&self, num_pes: usize) -> CapacityReport {
        let in_words = self.graph_words(SchedulerKind::InOrder);
        let ooo_words = self.graph_words(SchedulerKind::OutOfOrder);
        CapacityReport {
            num_pes,
            graph_words_per_pe_inorder: in_words,
            graph_words_per_pe_ooo: ooo_words,
            flag_overhead_pct: 100.0 * self.flag_words() as f64 / self.total_words() as f64,
            capacity_ratio: ooo_words as f64 / in_words as f64,
        }
    }
}

impl Default for BramConfig {
    fn default() -> Self {
        Self::paper()
    }
}

/// §III capacity comparison summary.
#[derive(Debug, Clone, Copy)]
pub struct CapacityReport {
    pub num_pes: usize,
    pub graph_words_per_pe_inorder: usize,
    pub graph_words_per_pe_ooo: usize,
    pub flag_overhead_pct: f64,
    pub capacity_ratio: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry() {
        let b = BramConfig::paper();
        assert_eq!(b.total_words(), 4096);
        assert_eq!(b.ports_per_cycle(), 4);
        // 20Kb = 512 * 40b exactly
        assert_eq!(b.words_per_bram * b.word_bits, 20 * 1024);
    }

    #[test]
    fn flag_overhead_matches_paper_six_percent() {
        let b = BramConfig::paper();
        // 2*ceil(512/32) = 32 words/BRAM, 256 words over 8 BRAMs
        assert_eq!(b.flag_words(), 256);
        let pct = b.flag_words() as f64 / b.total_words() as f64;
        assert!((pct - 0.0625).abs() < 1e-12, "≈6% (paper §II-B)");
    }

    #[test]
    fn ooo_graph_budget() {
        let b = BramConfig::paper();
        assert_eq!(b.graph_words(SchedulerKind::OutOfOrder), 3840);
    }

    #[test]
    fn capacity_ratio_is_about_five() {
        let b = BramConfig::paper();
        let r = b.capacity_report(256);
        assert!(
            (r.capacity_ratio - 5.0).abs() < 0.01,
            "calibrated to the paper's ≈5x: {}",
            r.capacity_ratio
        );
    }

    #[test]
    fn fits_is_monotone() {
        let b = BramConfig::paper();
        assert!(b.fits(100, 200, SchedulerKind::OutOfOrder));
        assert!(!b.fits(2000, 1000, SchedulerKind::OutOfOrder));
        // in-order budget is much smaller
        assert!(b.fits(100, 200, SchedulerKind::InOrder));
        assert!(!b.fits(300, 300, SchedulerKind::InOrder));
    }

    #[test]
    fn words_used_encoding() {
        assert_eq!(BramConfig::words_used(10, 15), 35);
    }

    #[test]
    fn custom_geometry() {
        // a half-size PE (4 BRAMs) still computes coherent budgets
        let b = BramConfig {
            brams_per_pe: 4,
            ..BramConfig::paper()
        };
        assert_eq!(b.total_words(), 2048);
        assert_eq!(b.flag_words(), 128);
        assert!(b.graph_words(SchedulerKind::OutOfOrder) == 1920);
    }
}
