//! PE datapath building blocks (§II-A): the DSP ALU pipeline and the
//! packet-generation unit's state machine. The simulator composes these
//! per PE and drives them once per cycle.

use std::collections::VecDeque;

/// The PE's floating-point ALU: two hardened DSP blocks (ADD + MULTIPLY
/// mode) in single-stage pipeline mode. Accepts at most one issue per
/// cycle (operand match happens on packet arrival, ≤1 packet/cycle);
/// results retire `latency` cycles later (writeback sets the RDY flag).
#[derive(Debug, Clone)]
pub struct AluPipeline {
    latency: u64,
    /// (retire cycle, local node index) — monotonically ordered.
    in_flight: VecDeque<(u64, u32)>,
    pub issued: u64,
}

impl AluPipeline {
    pub fn new(latency: u64) -> Self {
        assert!(latency >= 1);
        Self {
            latency,
            in_flight: VecDeque::new(),
            issued: 0,
        }
    }

    /// Issue a fired node at `cycle`. Single-stage DSP pipeline: always
    /// accepts one issue per cycle (the caller guarantees rate ≤ 1).
    pub fn issue(&mut self, cycle: u64, local_idx: u32) {
        debug_assert!(
            self.in_flight.back().map_or(true, |&(c, _)| c < cycle + self.latency || c == cycle + self.latency),
        );
        self.in_flight.push_back((cycle + self.latency, local_idx));
        self.issued += 1;
    }

    /// Pop all nodes retiring at `cycle`.
    pub fn retire(&mut self, cycle: u64, out: &mut Vec<u32>) {
        while let Some(idx) = self.pop_due(cycle) {
            out.push(idx);
        }
    }

    /// Is a result waiting to retire at `cycle`?
    #[inline]
    pub fn front_due(&self, cycle: u64) -> bool {
        self.in_flight.front().is_some_and(|&(c, _)| c <= cycle)
    }

    /// Pop one due retirement (port-limited writeback path).
    #[inline]
    pub fn pop_due(&mut self, cycle: u64) -> Option<u32> {
        if self.front_due(cycle) {
            self.in_flight.pop_front().map(|(_, idx)| idx)
        } else {
            None
        }
    }

    pub fn is_empty(&self) -> bool {
        self.in_flight.is_empty()
    }

    pub fn occupancy(&self) -> usize {
        self.in_flight.len()
    }

    /// Cycle the oldest in-flight result retires, if any — the ALU's
    /// next-wake time for the skip-ahead engine's event horizon.
    #[inline]
    pub fn next_retire_cycle(&self) -> Option<u64> {
        self.in_flight.front().map(|&(c, _)| c)
    }
}

/// Packet-generation unit state (§II-A: "a non-deterministic multi-cycle
/// process: (1) nodes can have multiple fanouts, and (2) the network may
/// be congested").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PgState {
    /// no node claimed; will start a scheduling pass if any node is ready
    Idle,
    /// scheduling pass in progress (FIFO pop: 1 cycle; LOD: 2 cycles)
    Picking { done_at: u64 },
    /// emitting fanout packets of `local_idx`, next edge `edge`
    Draining { local_idx: u32, edge: u32 },
}

/// Packet-generation unit bookkeeping (stats + state).
#[derive(Debug, Clone)]
pub struct PacketGen {
    pub state: PgState,
    /// cycles spent actually emitting packets
    pub busy_cycles: u64,
    /// cycles stalled on network backpressure
    pub stall_cycles: u64,
    /// completed scheduling passes
    pub picks: u64,
}

impl PacketGen {
    pub fn new() -> Self {
        Self {
            state: PgState::Idle,
            busy_cycles: 0,
            stall_cycles: 0,
            picks: 0,
        }
    }

    pub fn is_idle(&self) -> bool {
        self.state == PgState::Idle
    }
}

impl Default for PacketGen {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alu_retires_in_order_after_latency() {
        let mut alu = AluPipeline::new(2);
        alu.issue(10, 5);
        alu.issue(11, 6);
        let mut out = Vec::new();
        alu.retire(11, &mut out);
        assert!(out.is_empty());
        alu.retire(12, &mut out);
        assert_eq!(out, vec![5]);
        alu.retire(13, &mut out);
        assert_eq!(out, vec![5, 6]);
        assert!(alu.is_empty());
        assert_eq!(alu.issued, 2);
    }

    #[test]
    fn alu_latency_one_retires_next_cycle() {
        let mut alu = AluPipeline::new(1);
        alu.issue(0, 9);
        let mut out = Vec::new();
        alu.retire(0, &mut out);
        assert!(out.is_empty(), "no same-cycle retire");
        alu.retire(1, &mut out);
        assert_eq!(out, vec![9]);
    }

    #[test]
    fn alu_pipelined_throughput_one_per_cycle() {
        let mut alu = AluPipeline::new(3);
        for c in 0..10u64 {
            alu.issue(c, c as u32);
        }
        assert_eq!(alu.occupancy(), 10);
        let mut out = Vec::new();
        alu.retire(12, &mut out); // cycles 3..=12 retire ids 0..=9
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn next_retire_cycle_tracks_oldest() {
        let mut alu = AluPipeline::new(4);
        assert_eq!(alu.next_retire_cycle(), None);
        alu.issue(10, 1);
        alu.issue(12, 2);
        assert_eq!(alu.next_retire_cycle(), Some(14));
        assert_eq!(alu.pop_due(14), Some(1));
        assert_eq!(alu.next_retire_cycle(), Some(16));
    }

    #[test]
    fn pg_starts_idle() {
        let pg = PacketGen::new();
        assert!(pg.is_idle());
        assert_eq!(pg.picks, 0);
    }
}
