//! Experiment coordinator — the L3 orchestration layer.
//!
//! Drives the simulator across (workload × overlay × scheduler) sweeps on
//! a thread pool, validates simulated numerics against both the native
//! reference and the PJRT `graph_eval` oracle, and renders the paper's
//! tables/figures as CSV/markdown.

mod experiments;
mod report;

pub use experiments::{
    capacity_experiment, fig1_config, fig1_sweep, graph_fits, run_one, scheduler_comparison,
    CapacityRow, Fig1Row, RunOutcome,
};
pub use report::{render_csv, render_markdown, Table};

use crate::config::OverlayConfig;
use crate::engine::{self, SimBackend};
use crate::graph::DataflowGraph;
use crate::runtime::XlaRuntime;
use crate::sim::{SimError, SimStats};

/// Outcome of validating one simulated execution.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub stats: SimStats,
    /// max |sim − native evaluate| (bit-exactness expected: 0.0)
    pub max_abs_err_native: f32,
    /// max |sim − PJRT graph_eval| if the oracle was used
    pub max_abs_err_pjrt: Option<f32>,
    pub nodes_checked: usize,
}

impl ValidationReport {
    pub fn passed(&self) -> bool {
        self.max_abs_err_native == 0.0
            && self.max_abs_err_pjrt.map_or(true, |e| e == 0.0)
    }
}

fn max_abs_err(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            if x.is_nan() && y.is_nan() {
                0.0
            } else {
                (x - y).abs()
            }
        })
        .fold(0.0f32, f32::max)
}

/// Run `g` on the overlay (through the engine backend `cfg.backend`
/// selects) and validate the computed node values against the native
/// topological evaluation and (when the graph fits the artifact geometry
/// and `rt` is given) the PJRT oracle.
pub fn validate(
    g: &DataflowGraph,
    cfg: OverlayConfig,
    rt: Option<&XlaRuntime>,
) -> Result<ValidationReport, SimError> {
    let mut backend = engine::make_backend(g, cfg)?;
    let stats = backend.run()?;
    let native = g.evaluate();
    let err_native = max_abs_err(backend.values(), &native);
    let err_pjrt = rt.and_then(|rt| {
        rt.graph_eval(g)
            .ok()
            .map(|oracle| max_abs_err(backend.values(), &oracle))
    });
    Ok(ValidationReport {
        stats,
        max_abs_err_native: err_native,
        max_abs_err_pjrt: err_pjrt,
        nodes_checked: g.len(),
    })
}

/// Run a set of jobs on `threads` OS threads (simple static partition —
/// jobs are similar-sized simulator runs).
pub fn run_parallel<T, F>(jobs: Vec<T>, threads: usize, f: F) -> Vec<<F as JobFn<T>>::Out>
where
    T: Send,
    F: JobFn<T> + Sync,
    <F as JobFn<T>>::Out: Send,
{
    let threads = threads.max(1);
    let mut out: Vec<Option<<F as JobFn<T>>::Out>> = Vec::new();
    out.resize_with(jobs.len(), || None);
    let jobs: Vec<(usize, T)> = jobs.into_iter().enumerate().collect();
    let chunks: Vec<Vec<(usize, T)>> = {
        let mut cs: Vec<Vec<(usize, T)>> = (0..threads).map(|_| Vec::new()).collect();
        for (i, job) in jobs {
            cs[i % threads].push((i, job));
        }
        cs
    };
    let slots: Vec<std::sync::Mutex<Vec<(usize, <F as JobFn<T>>::Out)>>> =
        (0..threads).map(|_| std::sync::Mutex::new(Vec::new())).collect();
    std::thread::scope(|s| {
        for (t, chunk) in chunks.into_iter().enumerate() {
            let f = &f;
            let slot = &slots[t];
            s.spawn(move || {
                let mut results = Vec::with_capacity(chunk.len());
                for (i, job) in chunk {
                    results.push((i, f.call(job)));
                }
                *slot.lock().unwrap() = results;
            });
        }
    });
    for slot in slots {
        for (i, r) in slot.into_inner().unwrap() {
            out[i] = Some(r);
        }
    }
    out.into_iter().map(|o| o.expect("job completed")).collect()
}

/// Function-object trait for [`run_parallel`] (stable-rust friendly).
pub trait JobFn<T> {
    type Out;
    fn call(&self, job: T) -> Self::Out;
}

impl<T, O, F: Fn(T) -> O> JobFn<T> for F {
    type Out = O;
    fn call(&self, job: T) -> O {
        self(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::layered_random;

    #[test]
    fn validate_small_graph_native() {
        let g = layered_random(8, 4, 12, 2, 1);
        let cfg = OverlayConfig::default().with_dims(2, 2);
        let rep = validate(&g, cfg, None).unwrap();
        assert!(rep.passed(), "sim values must be bit-exact: {rep:?}");
        assert_eq!(rep.nodes_checked, g.len());
    }

    #[test]
    fn validate_honors_backend_choice() {
        use crate::engine::BackendKind;
        let g = layered_random(8, 4, 12, 2, 1);
        let base = OverlayConfig::default().with_dims(2, 2);
        let lock = validate(&g, base.with_backend(BackendKind::Lockstep), None).unwrap();
        let skip = validate(&g, base.with_backend(BackendKind::SkipAhead), None).unwrap();
        assert!(lock.passed() && skip.passed());
        assert_eq!(lock.stats, skip.stats, "backends must produce identical stats");
    }

    #[test]
    fn run_parallel_preserves_order() {
        let jobs: Vec<u64> = (0..37).collect();
        let out = run_parallel(jobs, 4, |j: u64| j * 2);
        assert_eq!(out, (0..37).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn run_parallel_single_thread() {
        let out = run_parallel(vec![1, 2, 3], 1, |j: i32| j + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
