//! Experiment coordinator — the L3 orchestration layer.
//!
//! Drives the simulator across (workload × overlay × scheduler) sweeps on
//! a thread pool, validates simulated numerics against both the native
//! reference and the PJRT `graph_eval` oracle, and renders the paper's
//! tables/figures as CSV/markdown.

mod experiments;
mod report;

#[allow(deprecated)]
pub use experiments::{graph_fits, run_one};
pub use experiments::{
    capacity_experiment, fig1_config, fig1_sweep, fig1_sweep_on, scheduler_comparison,
    CapacityRow, Fig1Row, RunOutcome,
};
pub use report::{render_csv, render_json, render_markdown, Table};

/// Re-exported for compatibility: the job pool now lives in
/// [`crate::util::par`].
pub use crate::util::par::{run_parallel, JobFn};

use crate::config::{Overlay, OverlayConfig};
use crate::graph::DataflowGraph;
use crate::program::Program;
use crate::runtime::XlaRuntime;
use crate::sim::{SimError, SimStats};

/// Outcome of validating one simulated execution.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub stats: SimStats,
    /// max |sim − native evaluate| (bit-exactness expected: 0.0)
    pub max_abs_err_native: f32,
    /// max |sim − PJRT graph_eval| if the oracle was used
    pub max_abs_err_pjrt: Option<f32>,
    pub nodes_checked: usize,
}

impl ValidationReport {
    pub fn passed(&self) -> bool {
        self.max_abs_err_native == 0.0
            && self.max_abs_err_pjrt.map_or(true, |e| e == 0.0)
    }
}

fn max_abs_err(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            if x.is_nan() && y.is_nan() {
                0.0
            } else {
                (x - y).abs()
            }
        })
        .fold(0.0f32, f32::max)
}

/// Run `g` on the overlay (through the engine backend `cfg.backend`
/// selects) and validate the computed node values against the native
/// topological evaluation and (when the graph fits the artifact geometry
/// and `rt` is given) the PJRT oracle.
pub fn validate(
    g: &DataflowGraph,
    cfg: OverlayConfig,
    rt: Option<&XlaRuntime>,
) -> Result<ValidationReport, SimError> {
    let program = Program::compile(g, &Overlay::trusted(cfg)).map_err(SimError::from)?;
    let mut backend = program.session().backend()?;
    let stats = backend.run()?;
    let native = g.evaluate();
    let err_native = max_abs_err(backend.values(), &native);
    let err_pjrt = rt.and_then(|rt| {
        rt.graph_eval(g)
            .ok()
            .map(|oracle| max_abs_err(backend.values(), &oracle))
    });
    Ok(ValidationReport {
        stats,
        max_abs_err_native: err_native,
        max_abs_err_pjrt: err_pjrt,
        nodes_checked: g.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::layered_random;

    #[test]
    fn validate_small_graph_native() {
        let g = layered_random(8, 4, 12, 2, 1);
        let cfg = OverlayConfig::default().with_dims(2, 2);
        let rep = validate(&g, cfg, None).unwrap();
        assert!(rep.passed(), "sim values must be bit-exact: {rep:?}");
        assert_eq!(rep.nodes_checked, g.len());
    }

    #[test]
    fn validate_honors_backend_choice() {
        use crate::engine::BackendKind;
        let g = layered_random(8, 4, 12, 2, 1);
        let base = OverlayConfig::default().with_dims(2, 2);
        let lock = validate(&g, base.with_backend(BackendKind::Lockstep), None).unwrap();
        let skip = validate(&g, base.with_backend(BackendKind::SkipAhead), None).unwrap();
        assert!(lock.passed() && skip.passed());
        assert_eq!(lock.stats, skip.stats, "backends must produce identical stats");
    }
}
