//! The paper's experiments as reusable drivers (benches and the CLI call
//! into these; DESIGN.md §4 maps each to its table/figure).
//!
//! All drivers run on the compile-once API (DESIGN.md §8): each workload
//! is compiled to a [`Program`] exactly once per overlay shape, and
//! every scheduler/backend variant runs as a cheap [`Session`] over the
//! shared artifact — `tests/compile_once.rs` enforces it.

use crate::config::{Overlay, OverlayConfig};
use crate::error::Error;
use crate::graph::DataflowGraph;
use crate::pe::BramConfig;
use crate::program::{Program, Session};
use crate::sched::SchedulerKind;
use crate::service::{Engine, JobResult, JobSpec};
use crate::sim::{SimError, SimStats};
use crate::workload::Spec;

/// One (workload, scheduler) simulation outcome.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub label: String,
    pub scheduler: SchedulerKind,
    pub nodes: usize,
    pub edges: usize,
    pub cycles: u64,
    pub utilization: f64,
    pub deflections: u64,
}

/// A row of Figure 1: one graph size, both schedulers, the speedup.
#[derive(Debug, Clone, PartialEq)]
pub struct Fig1Row {
    pub label: String,
    pub nodes_plus_edges: usize,
    pub depth: usize,
    pub cycles_inorder: u64,
    pub cycles_ooo: u64,
    /// cycles(in-order) / cycles(out-of-order) — >1 means OoO wins
    pub speedup: f64,
}

/// Run one graph under `kind` on the configured overlay, through the
/// engine backend `cfg.backend` selects.
#[deprecated(
    note = "compile once with `Program::compile` and run through `Session` — \
            this shim re-places and re-labels the graph on every call"
)]
pub fn run_one(
    g: &DataflowGraph,
    cfg: OverlayConfig,
    kind: SchedulerKind,
) -> Result<SimStats, SimError> {
    let overlay = Overlay::trusted(cfg.with_scheduler(kind));
    let program = Program::compile(g, &overlay).map_err(SimError::from)?;
    program.session().run()
}

/// The overlay configuration Figure 1 is measured on: the paper's 16×16
/// overlay with locality-preserving (chunked) placement — the regime
/// where per-PE ready queues form and scheduling order matters.
pub fn fig1_config() -> OverlayConfig {
    let mut cfg = OverlayConfig::default();
    cfg.placement = crate::place::PlacementPolicy::Chunked;
    cfg
}

/// Figure 1: out-of-order speedup over in-order vs. dataflow graph size.
///
/// A thin client of the service layer: `workloads` are (label,
/// [`Spec`]) pairs (see `workload::fig1_specs`), turned into a
/// (workload × scheduler) [`JobSpec`] grid and submitted to a
/// [`Engine`] batch — graph generation, the compile-exactly-once
/// guarantee (content-addressed Program cache: placement + criticality
/// labeling are static one-time costs, §II-B) and worker-pool sharding
/// all live in [`Engine::submit_batch`] now. Rows are assembled from
/// the [`JobResult`]s and presented smallest graph first.
///
/// The grid is laid out scheduler-major (all in-order cells, then all
/// out-of-order cells) so the pool's static `i % jobs` chunking spreads
/// the slow in-order runs across every worker instead of pinning them
/// to the even ones. Batch results come back in job order, so the rows
/// — and any report rendered from them — are identical for every
/// `jobs` value.
pub fn fig1_sweep(
    workloads: &[(String, Spec)],
    cfg: OverlayConfig,
    jobs: usize,
) -> Result<Vec<Fig1Row>, Error> {
    fig1_sweep_on(&Engine::new(), workloads, cfg, jobs)
}

/// [`fig1_sweep`] over a caller-owned [`Engine`] — lets the CLI reuse a
/// warm Program cache across sweeps and read
/// [`Engine::metrics_snapshot`] afterwards (`tdp sweep --metrics-out`).
pub fn fig1_sweep_on(
    engine: &Engine,
    workloads: &[(String, Spec)],
    cfg: OverlayConfig,
    jobs: usize,
) -> Result<Vec<Fig1Row>, Error> {
    Overlay::from_config(cfg)?; // fail fast, before any generation
    let n = workloads.len();
    let grid: Vec<JobSpec> = [SchedulerKind::InOrder, SchedulerKind::OutOfOrder]
        .into_iter()
        .flat_map(|kind| {
            workloads.iter().map(move |(_, spec)| JobSpec {
                workload: spec.canonical(),
                scheduler: kind,
                backend: cfg.backend,
                overlay: cfg,
                max_cycles: None,
                timeout_ms: None,
            })
        })
        .collect();
    let results: Vec<JobResult> = engine
        .submit_batch(&grid, jobs)
        .into_iter()
        .collect::<Result<_, _>>()?;
    let mut rows: Vec<Fig1Row> = (0..n)
        .map(|i| {
            let (r_in, r_ooo) = (&results[i], &results[n + i]);
            Fig1Row {
                label: workloads[i].0.clone(),
                nodes_plus_edges: r_in.nodes + r_in.edges,
                depth: r_in.depth,
                cycles_inorder: r_in.stats.cycles,
                cycles_ooo: r_ooo.stats.cycles,
                speedup: r_in.stats.cycles as f64 / r_ooo.stats.cycles as f64,
            }
        })
        .collect();
    // fill-in makes footprint noisy across seeds; present in size order
    // (deterministic: ties break on the label)
    rows.sort_by(|a, b| {
        (a.nodes_plus_edges, &a.label).cmp(&(b.nodes_plus_edges, &b.label))
    });
    Ok(rows)
}

/// Detailed scheduler comparison on one workload (used by `tdp run` and
/// the ablation bench): compiles once, runs both schedulers as sessions
/// over the shared [`Program`], and returns both outcomes.
pub fn scheduler_comparison(
    g: &DataflowGraph,
    cfg: OverlayConfig,
    label: &str,
) -> Result<Vec<RunOutcome>, Error> {
    let program = Program::compile(g, &Overlay::from_config(cfg)?)?;
    [SchedulerKind::InOrder, SchedulerKind::OutOfOrder]
        .into_iter()
        .map(|kind| {
            let s = Session::new(&program).with_scheduler(kind).run()?;
            Ok(RunOutcome {
                label: label.to_string(),
                scheduler: kind,
                nodes: g.len(),
                edges: g.num_edges(),
                cycles: s.cycles,
                utilization: s.avg_pe_utilization,
                deflections: s.net.deflections,
            })
        })
        .collect()
}

/// §III capacity row: largest graph footprint each scheduler's BRAM
/// budget can store on a `num_pes` overlay.
#[derive(Debug, Clone)]
pub struct CapacityRow {
    pub num_pes: usize,
    pub max_items_inorder: usize,
    pub max_items_ooo: usize,
    pub ratio: f64,
}

/// Compute §III storable-graph capacity, both analytically (balanced
/// placement, measured node:edge mix) and against a concrete graph
/// stream: we grow LU workloads until placement stops fitting.
pub fn capacity_experiment(bram: &BramConfig, num_pes: usize, edge_per_node: f64) -> CapacityRow {
    // words(n, e) = 2n + e with e = edge_per_node * n, balanced over PEs
    let per_node_words = BramConfig::NODE_WORDS as f64 + edge_per_node;
    let items = |budget_words: usize| -> usize {
        let nodes = (budget_words as f64 * num_pes as f64) / per_node_words;
        (nodes * (1.0 + edge_per_node)) as usize
    };
    let max_in = items(bram.graph_words(SchedulerKind::InOrder));
    let max_ooo = items(bram.graph_words(SchedulerKind::OutOfOrder));
    CapacityRow {
        num_pes,
        max_items_inorder: max_in,
        max_items_ooo: max_ooo,
        ratio: max_ooo as f64 / max_in as f64,
    }
}

/// Empirical capacity check: does `g` fit the overlay under `kind`?
#[deprecated(
    note = "compile a `Program` once and query `Program::fits` for every \
            scheduler — this shim re-places the graph on each call"
)]
pub fn graph_fits(g: &DataflowGraph, cfg: &OverlayConfig, kind: SchedulerKind) -> bool {
    let mut probe = *cfg;
    // fits() is a query, not an error: never fail the compile itself
    probe.enforce_capacity = false;
    match Program::compile(g, &Overlay::trusted(probe)) {
        Ok(program) => program.fits(kind),
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{layered_random, lu_factorization_graph, SparseMatrix};

    fn specs(list: &[(&str, &str)]) -> Vec<(String, Spec)> {
        list.iter()
            .map(|(label, s)| (label.to_string(), s.parse().unwrap()))
            .collect()
    }

    #[test]
    fn fig1_rows_have_sane_speedups() {
        let ws = specs(&[
            ("a", "layered:16:8:32:2:seed=1"),
            ("b", "layered:16:16:48:2:seed=2"),
        ]);
        let cfg = OverlayConfig::default().with_dims(4, 4);
        let rows = fig1_sweep(&ws, cfg, 2).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.speedup > 0.5 && r.speedup < 3.0, "{r:?}");
            assert!(r.cycles_inorder > 0 && r.cycles_ooo > 0);
        }
        // rows carry the real graph shape and come back smallest first
        assert!(rows[0].nodes_plus_edges <= rows[1].nodes_plus_edges);
        assert!(rows.iter().all(|r| r.nodes_plus_edges > 0 && r.depth > 0));
    }

    /// The sweep matches the pre-service path: compile the same graph by
    /// hand and run sessions — the engine route must be bit-identical.
    #[test]
    fn fig1_sweep_matches_direct_program_path() {
        let ws = specs(&[("a", "layered:12:6:24:2:seed=5")]);
        let cfg = OverlayConfig::default().with_dims(4, 4);
        let rows = fig1_sweep(&ws, cfg, 2).unwrap();
        let g = ws[0].1.build().unwrap();
        let overlay = Overlay::from_config(cfg).unwrap();
        let program = Program::compile(&g, &overlay).unwrap();
        for (kind, cycles) in [
            (SchedulerKind::InOrder, rows[0].cycles_inorder),
            (SchedulerKind::OutOfOrder, rows[0].cycles_ooo),
        ] {
            let direct = program.session().with_scheduler(kind).run().unwrap();
            assert_eq!(direct.cycles, cycles, "{kind:?}");
        }
        assert_eq!(rows[0].nodes_plus_edges, g.footprint());
        assert_eq!(rows[0].depth, g.stats().depth);
    }

    /// Determinism across worker counts: the acceptance bar behind the
    /// CLI guarantee that `sweep --jobs N` reports byte-match `--jobs 1`.
    #[test]
    fn fig1_sweep_rows_invariant_under_job_count() {
        let ws = specs(&[
            ("a", "layered:12:6:24:2:seed=1"),
            ("b", "layered:16:8:32:2:seed=2"),
            ("c", "layered:8:4:16:1:seed=3"),
        ]);
        let cfg = OverlayConfig::default().with_dims(4, 4);
        let serial = fig1_sweep(&ws, cfg, 1).unwrap();
        for jobs in [2, 4, 16] {
            assert_eq!(fig1_sweep(&ws, cfg, jobs).unwrap(), serial, "jobs = {jobs}");
        }
    }

    /// A caller-owned engine keeps its Program cache warm across sweeps:
    /// the second identical sweep is all hits, and the engine's metrics
    /// snapshot reflects every submitted job.
    #[test]
    fn fig1_sweep_on_reuses_engine_cache_across_sweeps() {
        let ws = specs(&[
            ("a", "layered:12:6:24:2:seed=7"),
            ("b", "layered:8:4:16:1:seed=8"),
        ]);
        let cfg = OverlayConfig::default().with_dims(4, 4);
        let engine = Engine::new();
        let first = fig1_sweep_on(&engine, &ws, cfg, 2).unwrap();
        let cold = engine.cache_stats();
        assert_eq!(cold.misses, 2, "one compile per workload");
        let second = fig1_sweep_on(&engine, &ws, cfg, 2).unwrap();
        assert_eq!(first, second, "warm sweep must be bit-identical");
        let warm = engine.cache_stats();
        assert_eq!(warm.misses, cold.misses, "second sweep compiles nothing");
        assert_eq!(warm.hits, cold.hits + 4, "2 workloads x 2 schedulers, all hits");
        let snap = engine.metrics_snapshot();
        let jobs = snap.get("jobs").unwrap().get("submitted").unwrap();
        assert_eq!(jobs.as_u64().unwrap(), 8, "2 sweeps x 4 grid cells");
    }

    #[test]
    fn fig1_sweep_rejects_invalid_config() {
        let ws = specs(&[("a", "layered:4:2:4:1")]);
        let mut cfg = OverlayConfig::default();
        cfg.cols = 0;
        assert!(matches!(fig1_sweep(&ws, cfg, 1), Err(Error::Config(_))));
        // an unbuildable spec surfaces as a typed Spec error
        let mut with_bad_spec =
            vec![("x".to_string(), "layered:4:2:4:1".parse::<Spec>().unwrap())];
        with_bad_spec[0].1.workload = crate::config::WorkloadSpec::MatrixMarket {
            path: "/nonexistent/matrix.mtx".into(),
        };
        assert!(matches!(
            fig1_sweep(&with_bad_spec, OverlayConfig::default().with_dims(2, 2), 1),
            Err(Error::Spec(_))
        ));
    }

    /// The deprecated shim still produces bit-identical stats to the
    /// compile-once path, on both backends.
    #[test]
    #[allow(deprecated)]
    fn run_one_shim_matches_program_path_on_both_backends() {
        use crate::engine::BackendKind;
        let g = layered_random(16, 8, 32, 2, 1);
        let cfg = OverlayConfig::default().with_dims(4, 4);
        for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
            let a = run_one(&g, cfg, kind).unwrap();
            let b = run_one(&g, cfg.with_backend(BackendKind::SkipAhead), kind).unwrap();
            assert_eq!(a, b, "{kind:?}: backend choice must not change stats");
            let overlay = Overlay::from_config(cfg.with_scheduler(kind)).unwrap();
            let fresh = Program::compile(&g, &overlay).unwrap().session().run().unwrap();
            assert_eq!(a, fresh, "{kind:?}: shim must match the Program path");
        }
    }

    #[test]
    fn capacity_matches_paper_claims() {
        // §III: 256-PE FIFO overlay ≈100K items; OoO ≈5x larger.
        // LU graphs measure ~2 edges per node.
        let row = capacity_experiment(&BramConfig::paper(), 256, 2.0);
        assert!((row.ratio - 5.0).abs() < 0.05, "ratio {}", row.ratio);
        assert!(
            row.max_items_inorder >= 100_000 && row.max_items_inorder <= 160_000,
            "paper: ≈100K, got {}",
            row.max_items_inorder
        );
        assert!(row.max_items_ooo >= 490_000, "got {}", row.max_items_ooo);
    }

    #[test]
    fn program_fits_respects_scheduler_budget() {
        let m = SparseMatrix::banded(80, 3, 0.8, 3);
        let (g, _) = lu_factorization_graph(&m);
        let cfg = OverlayConfig::default().with_dims(2, 2);
        // ~2K nodes on 4 PEs: fits OoO (3840 w/PE) but not in-order (768 w/PE)
        let program = Program::compile(&g, &Overlay::from_config(cfg).unwrap()).unwrap();
        assert!(program.fits(SchedulerKind::OutOfOrder));
        assert!(!program.fits(SchedulerKind::InOrder));
        // the deprecated shim agrees
        #[allow(deprecated)]
        {
            assert!(graph_fits(&g, &cfg, SchedulerKind::OutOfOrder));
            assert!(!graph_fits(&g, &cfg, SchedulerKind::InOrder));
        }
    }

    #[test]
    fn scheduler_comparison_runs_both() {
        let g = layered_random(8, 6, 16, 2, 0);
        let out =
            scheduler_comparison(&g, OverlayConfig::default().with_dims(2, 2), "t").unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].scheduler, SchedulerKind::InOrder);
        assert_eq!(out[1].scheduler, SchedulerKind::OutOfOrder);
    }
}
