//! Report rendering: experiment rows → CSV / markdown / JSON tables.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// A simple column-oriented table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(row);
    }
}

/// Render as CSV (headers + rows).
pub fn render_csv(t: &Table) -> String {
    let mut out = String::new();
    out.push_str(&t.headers.join(","));
    out.push('\n');
    for row in &t.rows {
        let escaped: Vec<String> = row
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        out.push_str(&escaped.join(","));
        out.push('\n');
    }
    out
}

/// Render as one JSON object: `{"title": ..., "headers": [...],
/// "rows": [[...], ...]}` (cells stay strings — formatting decisions,
/// e.g. speedup precision, are made by the table builder). One trailing
/// newline so files and pipes end cleanly.
pub fn render_json(t: &Table) -> String {
    let mut m = BTreeMap::new();
    m.insert("title".to_string(), Json::Str(t.title.clone()));
    m.insert(
        "headers".to_string(),
        Json::Arr(t.headers.iter().map(|h| Json::Str(h.clone())).collect()),
    );
    m.insert(
        "rows".to_string(),
        Json::Arr(
            t.rows
                .iter()
                .map(|row| Json::Arr(row.iter().map(|c| Json::Str(c.clone())).collect()))
                .collect(),
        ),
    );
    let mut out = json::write(&Json::Obj(m));
    out.push('\n');
    out
}

/// Render as a github-markdown table with a title line.
pub fn render_markdown(t: &Table) -> String {
    let mut widths: Vec<usize> = t.headers.iter().map(|h| h.len()).collect();
    for row in &t.rows {
        for (i, c) in row.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        let padded: Vec<String> = cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
            .collect();
        format!("| {} |", padded.join(" | "))
    };
    let mut out = format!("### {}\n\n", t.title);
    out.push_str(&fmt_row(&t.headers));
    out.push('\n');
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    out.push_str(&fmt_row(&sep));
    out.push('\n');
    for row in &t.rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Speedups", &["size", "speedup"]);
        t.push(vec!["100".into(), "1.05".into()]);
        t.push(vec!["100,000".into(), "1.50".into()]);
        t
    }

    #[test]
    fn csv_escapes_commas() {
        let csv = render_csv(&sample());
        assert!(csv.starts_with("size,speedup\n"));
        assert!(csv.contains("\"100,000\""));
    }

    #[test]
    fn markdown_is_aligned() {
        let md = render_markdown(&sample());
        assert!(md.contains("### Speedups"));
        assert!(md.contains("| size    | speedup |"));
        let lines: Vec<&str> = md.lines().filter(|l| l.starts_with('|')).collect();
        let lens: Vec<usize> = lines.iter().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{md}");
    }

    #[test]
    fn json_parses_and_preserves_cells() {
        let text = render_json(&sample());
        assert!(text.ends_with('\n'));
        let j = json::parse(text.trim_end()).unwrap();
        assert_eq!(j.get("title").unwrap().as_str(), Some("Speedups"));
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].as_arr().unwrap()[0].as_str(), Some("100,000"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push(vec!["1".into()]);
    }
}
