//! The crate-wide error surface: everything the compile-once pipeline
//! ([`crate::config::Overlay`] → [`crate::program::Program`] →
//! [`crate::program::Session`]) can fail with, as one enum the CLI maps
//! to non-zero exit codes. Layer-local APIs keep their precise types
//! ([`ConfigError`], [`CompileError`], [`SimError`]); `Error` is the
//! union the orchestration layer ([`crate::coordinator`]) and `main`
//! propagate.

use crate::config::ConfigError;
use crate::program::CompileError;
use crate::sim::SimError;

/// Partial-progress snapshot carried by every mid-run failure
/// ([`Error::Deadline`] / [`Error::Cancelled`] /
/// [`Error::CyclesExhausted`]): how far the simulation got before it
/// was stopped, so a timed-out or cancelled job still reports useful
/// work instead of silence (DESIGN.md §15).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Partial {
    /// fabric cycles retired before the stop
    pub cycles: u64,
    /// graph nodes whose fanout processing completed
    pub completed: usize,
    /// total graph nodes
    pub total: usize,
}

impl Partial {
    /// Completion fraction in `[0, 1]` (1.0 for an empty graph).
    pub fn fraction(&self) -> f64 {
        if self.total == 0 {
            1.0
        } else {
            self.completed as f64 / self.total as f64
        }
    }

    /// Nodes still outstanding at the stop.
    pub fn incomplete_nodes(&self) -> usize {
        self.total.saturating_sub(self.completed)
    }
}

/// A failure anywhere in the spec → validate → compile → run pipeline.
#[derive(Debug, Clone, PartialEq)]
pub enum Error {
    /// a job/workload specification could not be parsed or built
    /// (service layer: bad spec string, malformed job JSON, unreadable
    /// matrix file)
    Spec(String),
    /// the overlay description is invalid (validation phase)
    Config(ConfigError),
    /// the one-time compile phase failed (placement/capacity)
    Compile(CompileError),
    /// the simulation itself failed (runtime capacity, verifier,
    /// boundary livelock) — everything without a dedicated arm below
    Sim(SimError),
    /// the job's wall-clock deadline (`JobSpec.timeout_ms`) expired
    /// mid-run; detection lags the budget by at most one
    /// [`crate::sim::CANCEL_CHECK_INTERVAL`]
    Deadline(Partial),
    /// the job was cooperatively cancelled mid-run (client gone, queue
    /// shed, daemon shutdown)
    Cancelled(Partial),
    /// `max_cycles` elapsed before the graph completed — the structured
    /// image of [`SimError::CycleLimitExceeded`] at the job layer, so
    /// exhaustion is distinguishable from success and carries its
    /// partial progress
    CyclesExhausted(Partial),
    /// the single-flight compile this job was waiting on panicked in
    /// its leader; the flight latch was cleared, so resubmitting
    /// retries the compile from scratch
    CompilePoisoned { what: String },
    /// the job panicked inside the engine (compile or run); `message`
    /// is the panic payload. The worker that caught it stays healthy.
    Panicked { stage: &'static str, message: String },
}

impl Error {
    /// The partial-progress snapshot, for mid-run failures.
    pub fn partial(&self) -> Option<Partial> {
        match self {
            Error::Deadline(p) | Error::Cancelled(p) | Error::CyclesExhausted(p) => Some(*p),
            _ => None,
        }
    }

    /// A stable machine-readable failure class, used as the `code`
    /// field of batch/serve error payloads.
    pub fn code(&self) -> &'static str {
        match self {
            Error::Spec(_) => "invalid_spec",
            Error::Config(_) => "invalid_config",
            Error::Compile(_) => "compile_failed",
            Error::Sim(_) => "sim_failed",
            Error::Deadline(_) => "deadline_exceeded",
            Error::Cancelled(_) => "cancelled",
            Error::CyclesExhausted(_) => "cycles_exhausted",
            Error::CompilePoisoned { .. } => "compile_poisoned",
            Error::Panicked { .. } => "panicked",
        }
    }
}

/// Best-effort text of a `catch_unwind` payload (the `&str` / `String`
/// forms `panic!` produces; anything else gets a fixed placeholder) —
/// what [`Error::Panicked`] carries as its `message`.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Spec(msg) => write!(f, "invalid job spec: {msg}"),
            Error::Config(e) => write!(f, "{e}"),
            Error::Compile(e) => write!(f, "compile failed: {e}"),
            Error::Sim(e) => write!(f, "simulation failed: {e}"),
            Error::Deadline(p) => write!(
                f,
                "deadline exceeded at cycle {}: {}/{} nodes complete",
                p.cycles, p.completed, p.total
            ),
            Error::Cancelled(p) => write!(
                f,
                "job cancelled at cycle {}: {}/{} nodes complete",
                p.cycles, p.completed, p.total
            ),
            Error::CyclesExhausted(p) => write!(
                f,
                "cycle limit hit at {}: {}/{} nodes complete, {} incomplete",
                p.cycles,
                p.completed,
                p.total,
                p.incomplete_nodes()
            ),
            Error::CompilePoisoned { what } => write!(
                f,
                "compile poisoned: the in-flight compile of {what} panicked; resubmit to retry"
            ),
            Error::Panicked { stage, message } => {
                write!(f, "job panicked during {stage}: {message}")
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Config(e) => Some(e),
            Error::Compile(e) => Some(e),
            Error::Sim(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ConfigError> for Error {
    fn from(e: ConfigError) -> Self {
        Error::Config(e)
    }
}

impl From<CompileError> for Error {
    fn from(e: CompileError) -> Self {
        Error::Compile(e)
    }
}

impl From<SimError> for Error {
    fn from(e: SimError) -> Self {
        // The three early-stop shapes become first-class job-layer
        // outcomes with their partial progress; everything else stays a
        // wrapped simulator error.
        match e {
            SimError::CycleLimitExceeded { cycle, completed, total } => {
                Error::CyclesExhausted(Partial { cycles: cycle, completed, total })
            }
            SimError::DeadlineExceeded { cycle, completed, total } => {
                Error::Deadline(Partial { cycles: cycle, completed, total })
            }
            SimError::Cancelled { cycle, completed, total } => {
                Error::Cancelled(Partial { cycles: cycle, completed, total })
            }
            other => Error::Sim(other),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_and_display() {
        let c: Error = ConfigError("bad knob".into()).into();
        assert!(c.to_string().contains("bad knob"));
        let k: Error = CompileError::CapacityExceeded {
            pe: 3,
            words_needed: 10,
            words_available: 5,
        }
        .into();
        assert!(k.to_string().contains("PE 3"), "{k}");
        let s: Error = SimError::CapacityExceeded { pe: 1, words_needed: 9, words_available: 4 }
            .into();
        assert!(matches!(s, Error::Sim(_)), "{s:?}");
        assert_ne!(c, k);
        for e in [c, k, s] {
            assert!(std::error::Error::source(&e).is_some());
        }
        let j = Error::Spec("unknown workload kind 'bogus'".into());
        assert!(j.to_string().contains("invalid job spec"), "{j}");
        assert!(std::error::Error::source(&j).is_none());
    }

    /// The three early-stop SimError shapes surface as structured
    /// job-layer outcomes with their partial progress attached.
    #[test]
    fn early_stops_become_structured_arms() {
        let exhausted: Error =
            SimError::CycleLimitExceeded { cycle: 9, completed: 1, total: 4 }.into();
        let Error::CyclesExhausted(p) = exhausted else {
            panic!("want CyclesExhausted, got {exhausted:?}");
        };
        assert_eq!((p.cycles, p.completed, p.total), (9, 1, 4));
        assert_eq!(p.incomplete_nodes(), 3);
        assert!((p.fraction() - 0.25).abs() < 1e-12);
        let shown = Error::CyclesExhausted(p).to_string();
        assert!(shown.contains("cycle limit"), "{shown}");
        assert!(shown.contains("3 incomplete"), "{shown}");

        let dl: Error = SimError::DeadlineExceeded { cycle: 2048, completed: 5, total: 10 }.into();
        assert!(matches!(dl, Error::Deadline(_)), "{dl:?}");
        assert_eq!(dl.code(), "deadline_exceeded");
        assert_eq!(dl.partial().unwrap().completed, 5);
        assert!(dl.to_string().contains("deadline exceeded"), "{dl}");

        let cn: Error = SimError::Cancelled { cycle: 7, completed: 0, total: 3 }.into();
        assert!(matches!(cn, Error::Cancelled(_)), "{cn:?}");
        assert_eq!(cn.code(), "cancelled");

        let po = Error::CompilePoisoned { what: "chain:64".into() };
        assert_eq!(po.code(), "compile_poisoned");
        assert!(po.partial().is_none());
        let pa = Error::Panicked { stage: "compile", message: "boom".into() };
        assert_eq!(pa.code(), "panicked");
        assert!(pa.to_string().contains("boom"), "{pa}");
    }

    #[test]
    fn panic_payloads_downcast_to_text() {
        let p = std::panic::catch_unwind(|| panic!("boom {}", 1)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "boom 1");
        let p = std::panic::catch_unwind(|| panic!("plain")).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "plain");
        let p = std::panic::catch_unwind(|| std::panic::panic_any(7u32)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "opaque panic payload");
    }
}
