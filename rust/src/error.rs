//! The crate-wide error surface: everything the compile-once pipeline
//! ([`crate::config::Overlay`] → [`crate::program::Program`] →
//! [`crate::program::Session`]) can fail with, as one enum the CLI maps
//! to non-zero exit codes. Layer-local APIs keep their precise types
//! ([`ConfigError`], [`CompileError`], [`SimError`]); `Error` is the
//! union the orchestration layer ([`crate::coordinator`]) and `main`
//! propagate.

use crate::config::ConfigError;
use crate::program::CompileError;
use crate::sim::SimError;

/// A failure anywhere in the spec → validate → compile → run pipeline.
#[derive(Debug, Clone, PartialEq)]
pub enum Error {
    /// a job/workload specification could not be parsed or built
    /// (service layer: bad spec string, malformed job JSON, unreadable
    /// matrix file)
    Spec(String),
    /// the overlay description is invalid (validation phase)
    Config(ConfigError),
    /// the one-time compile phase failed (placement/capacity)
    Compile(CompileError),
    /// the simulation itself failed (cycle limit, runtime capacity)
    Sim(SimError),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Spec(msg) => write!(f, "invalid job spec: {msg}"),
            Error::Config(e) => write!(f, "{e}"),
            Error::Compile(e) => write!(f, "compile failed: {e}"),
            Error::Sim(e) => write!(f, "simulation failed: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Spec(_) => None,
            Error::Config(e) => Some(e),
            Error::Compile(e) => Some(e),
            Error::Sim(e) => Some(e),
        }
    }
}

impl From<ConfigError> for Error {
    fn from(e: ConfigError) -> Self {
        Error::Config(e)
    }
}

impl From<CompileError> for Error {
    fn from(e: CompileError) -> Self {
        Error::Compile(e)
    }
}

impl From<SimError> for Error {
    fn from(e: SimError) -> Self {
        Error::Sim(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_and_display() {
        let c: Error = ConfigError("bad knob".into()).into();
        assert!(c.to_string().contains("bad knob"));
        let k: Error = CompileError::CapacityExceeded {
            pe: 3,
            words_needed: 10,
            words_available: 5,
        }
        .into();
        assert!(k.to_string().contains("PE 3"), "{k}");
        let s: Error = SimError::CycleLimitExceeded { cycle: 9, completed: 1, total: 2 }.into();
        assert!(s.to_string().contains("cycle limit"), "{s}");
        assert_ne!(c, k);
        for e in [c, k, s] {
            assert!(std::error::Error::source(&e).is_some());
        }
        let j = Error::Spec("unknown workload kind 'bogus'".into());
        assert!(j.to_string().contains("invalid job spec"), "{j}");
        assert!(std::error::Error::source(&j).is_none());
    }
}
