//! Criticality-weighted traffic-aware placement
//! ([`PlacementPolicy::TrafficAware`]).
//!
//! The paper's Fig. 1 gains come from static analysis; this pass spends
//! a little more compile time on the same idea. Every fanout edge
//! `u → v` will cross the Hoplite torus from `u`'s PE to `v`'s PE, and
//! on a unidirectional torus the expected latency of that crossing is
//! the deterministic hop count (east then south, wrapping). Edges out
//! of *critical* nodes gate the completion front, so the objective is
//!
//! ```text
//! cost(assignment) = Σ_{u→v} (1 + criticality(u)) · hops(pe(u), pe(v))
//! ```
//!
//! minimized in two phases, both deterministic for a given seed:
//!
//! 1. **greedy clustering seed** — nodes are visited in topological
//!    order and placed on the candidate PE (an operand's PE or the
//!    least-loaded PE) with the cheapest weighted distance to their
//!    already-placed operands, under a strict per-PE node cap so load
//!    balance (the other half of the paper's placement story) is never
//!    sacrificed;
//! 2. **bounded simulated-annealing refinement** — random *swaps* of
//!    two nodes' PEs (swaps preserve the load profile exactly),
//!    accepted when they lower the cost or with Boltzmann probability
//!    under a geometric cooling schedule, for `min(200k, 16n)` moves.
//!
//! Randomness comes from [`crate::util::rng::Rng`] seeded from the
//! overlay seed, so the placement is reproducible across runs and
//! platforms; `tests/passes.rs` pins that.

use crate::graph::{DataflowGraph, NodeKind};
use crate::util::rng::Rng;

/// What the traffic-aware pass did, for `--dump-passes` reporting and
/// telemetry gauges.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrafficReport {
    /// weighted-hop cost after the greedy clustering seed
    pub initial_cost: u64,
    /// weighted-hop cost after annealing refinement
    pub final_cost: u64,
    /// swap moves attempted
    pub moves_tried: u64,
    /// swap moves accepted
    pub moves_accepted: u64,
}

/// Deterministic Hoplite hop count from PE `from` to PE `to` on a
/// unidirectional `cols`×`rows` torus (east then south, wrapping).
#[inline]
fn hops(from: usize, to: usize, cols: usize, rows: usize) -> u64 {
    let (xs, ys) = (from % cols, from / cols);
    let (xd, yd) = (to % cols, to / cols);
    (((xd + cols - xs) % cols) + ((yd + rows - ys) % rows)) as u64
}

/// Edge weight: criticality of the producer plus one (so zero-slack
/// and zero-criticality edges still count distance).
#[inline]
fn weight(crit: &[u32], src: usize) -> u64 {
    1 + crit[src] as u64
}

/// The objective the pass minimizes: total criticality-weighted
/// expected hop distance of `pe_of` on a `cols`×`rows` torus. Public so
/// reports (`tdp perf`, `--dump-passes`) can score any placement,
/// including the baseline policies.
pub fn placement_cost(
    g: &DataflowGraph,
    crit: &[u32],
    pe_of: &[u32],
    cols: usize,
    rows: usize,
) -> u64 {
    let mut cost = 0u64;
    for (u, node) in g.nodes().iter().enumerate() {
        let w = weight(crit, u);
        for &(dst, _) in &node.fanout {
            cost += w * hops(pe_of[u] as usize, pe_of[dst as usize] as usize, cols, rows);
        }
    }
    cost
}

/// One incident edge of a node, prepared for O(degree) swap deltas:
/// the node at the other end, the edge weight, and whether this node
/// is the source (`out`) or the destination of the edge.
#[derive(Clone, Copy)]
struct Incident {
    other: u32,
    w: u64,
    out: bool,
}

/// The node→PE assignment of [`PlacementPolicy::TrafficAware`]:
/// greedy clustering seed + bounded annealing refinement, as described
/// in the module docs. `crit` must be one label per node.
pub(crate) fn traffic_assign(
    g: &DataflowGraph,
    crit: &[u32],
    cols: usize,
    rows: usize,
    seed: u64,
) -> (Vec<u32>, TrafficReport) {
    let n = g.len();
    let num_pes = cols * rows;
    debug_assert_eq!(crit.len(), n, "criticality labeling size mismatch");
    if num_pes <= 1 || n == 0 {
        let report = TrafficReport {
            initial_cost: 0,
            final_cost: 0,
            moves_tried: 0,
            moves_accepted: 0,
        };
        return (vec![0u32; n], report);
    }

    // -------- phase 1: greedy clustering seed (topological order) ----
    // strict per-PE cap: the most even split possible, so the seed can
    // never starve the fabric of parallelism to chase locality
    let cap = n.div_ceil(num_pes);
    let mut load = vec![0usize; num_pes];
    let mut pe_of = vec![0u32; n];
    let mut candidates: Vec<usize> = Vec::with_capacity(4);
    for (i, node) in g.nodes().iter().enumerate() {
        candidates.clear();
        if let NodeKind::Operation { op, src } = node.kind {
            for &s in &src[..op.arity()] {
                let pe = pe_of[s as usize] as usize;
                if load[pe] < cap && !candidates.contains(&pe) {
                    candidates.push(pe);
                }
            }
        }
        // the least-loaded PE (lowest index on ties) is always an
        // option — it is what keeps inputs and cap-spill spread out
        let spread = (0..num_pes).min_by_key(|&pe| (load[pe], pe)).unwrap_or(0);
        if !candidates.contains(&spread) {
            candidates.push(spread);
        }
        let best = candidates
            .iter()
            .copied()
            .min_by_key(|&cand| {
                let mut cost = 0u64;
                if let NodeKind::Operation { op, src } = node.kind {
                    for &s in &src[..op.arity()] {
                        cost += weight(crit, s as usize)
                            * hops(pe_of[s as usize] as usize, cand, cols, rows);
                    }
                }
                (cost, load[cand], cand)
            })
            .unwrap_or(spread);
        pe_of[i] = best as u32;
        load[best] += 1;
    }
    let initial_cost = placement_cost(g, crit, &pe_of, cols, rows);

    // -------- phase 2: bounded annealing over PE swaps ---------------
    // incident-edge lists make a swap delta O(deg(a) + deg(b))
    let mut adj: Vec<Vec<Incident>> = vec![Vec::new(); n];
    for (u, node) in g.nodes().iter().enumerate() {
        let w = weight(crit, u);
        for &(dst, _) in &node.fanout {
            adj[u].push(Incident { other: dst, w, out: true });
            adj[dst as usize].push(Incident { other: u as u32, w, out: false });
        }
    }
    let incident_cost = |m: usize, pe_of: &[u32]| -> i64 {
        let mut c = 0i64;
        for e in &adj[m] {
            let (from, to) = if e.out {
                (pe_of[m] as usize, pe_of[e.other as usize] as usize)
            } else {
                (pe_of[e.other as usize] as usize, pe_of[m] as usize)
            };
            c += (e.w * hops(from, to, cols, rows)) as i64;
        }
        c
    };
    // edges between a and b appear in both incident sums; subtract one
    // copy so before/after deltas stay exact
    let between = |a: usize, b: usize, pe_of: &[u32]| -> i64 {
        let mut c = 0i64;
        for e in &adj[a] {
            if e.other as usize == b {
                let (from, to) = if e.out { (a, b) } else { (b, a) };
                c += (e.w * hops(pe_of[from] as usize, pe_of[to] as usize, cols, rows)) as i64;
            }
        }
        c
    };
    let moves = 200_000u64.min(16 * n as u64);
    let mut accepted = 0u64;
    let mut tried = 0u64;
    if n >= 2 && moves > 0 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x5452_4146_4649_43); // "TRAFFIC"
        let mut temp = (initial_cost as f64 / g.num_edges().max(1) as f64).max(1.0);
        let alpha = 0.01f64.powf(1.0 / moves as f64);
        for _ in 0..moves {
            let a = rng.gen_range(n);
            let b = rng.gen_range(n);
            temp *= alpha;
            if a == b || pe_of[a] == pe_of[b] {
                continue;
            }
            tried += 1;
            let before = incident_cost(a, &pe_of) + incident_cost(b, &pe_of)
                - between(a, b, &pe_of);
            pe_of.swap(a, b);
            let after = incident_cost(a, &pe_of) + incident_cost(b, &pe_of)
                - between(a, b, &pe_of);
            let delta = after - before;
            if delta <= 0 || rng.gen_f64() < (-(delta as f64) / temp).exp() {
                accepted += 1;
            } else {
                pe_of.swap(a, b); // revert
            }
        }
    }
    let final_cost = placement_cost(g, crit, &pe_of, cols, rows);
    let report = TrafficReport {
        initial_cost,
        final_cost,
        moves_tried: tried,
        moves_accepted: accepted,
    };
    (pe_of, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::criticality;
    use crate::workload::layered_random;

    #[test]
    fn assignment_is_seed_deterministic_and_balanced() {
        let g = layered_random(10, 8, 30, 2, 7);
        let crit = criticality::criticality(&g);
        let (a, ra) = traffic_assign(&g, &crit, 4, 4, 5);
        let (b, rb) = traffic_assign(&g, &crit, 4, 4, 5);
        assert_eq!(a, b, "same seed, same assignment");
        assert_eq!(ra, rb);
        // per-PE load never exceeds the even-split cap (swaps preserve it)
        let cap = g.len().div_ceil(16);
        let mut load = vec![0usize; 16];
        for &pe in &a {
            load[pe as usize] += 1;
            assert!((pe as usize) < 16);
        }
        assert!(load.iter().all(|&l| l <= cap), "load {load:?} exceeds cap {cap}");
    }

    #[test]
    fn annealing_never_worsens_the_greedy_seed() {
        let g = layered_random(12, 6, 24, 2, 3);
        let crit = criticality::criticality(&g);
        let (pe_of, report) = traffic_assign(&g, &crit, 3, 3, 11);
        assert_eq!(report.final_cost, placement_cost(&g, &crit, &pe_of, 3, 3));
        assert!(
            report.final_cost <= report.initial_cost,
            "refinement must not lose ground: {report:?}"
        );
    }

    #[test]
    fn beats_round_robin_on_weighted_hops() {
        let g = layered_random(16, 8, 40, 2, 1);
        let crit = criticality::criticality(&g);
        let rr: Vec<u32> = (0..g.len()).map(|i| (i % 16) as u32).collect();
        let rr_cost = placement_cost(&g, &crit, &rr, 4, 4);
        let (_, report) = traffic_assign(&g, &crit, 4, 4, 0);
        assert!(
            report.final_cost < rr_cost,
            "traffic-aware {} vs round-robin {rr_cost}",
            report.final_cost
        );
    }

    #[test]
    fn single_pe_is_trivial() {
        let g = layered_random(4, 3, 6, 2, 0);
        let crit = criticality::criticality(&g);
        let (pe_of, report) = traffic_assign(&g, &crit, 1, 1, 9);
        assert!(pe_of.iter().all(|&p| p == 0));
        assert_eq!(report.final_cost, 0);
    }
}
