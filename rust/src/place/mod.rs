//! Node → PE placement.
//!
//! A [`Placement`] maps every graph node to a PE and to a *local index*
//! inside that PE's graph memory. For the out-of-order scheduler the local
//! index order **is** the scheduling priority (§II-B): nodes are laid out
//! in decreasing criticality so the LOD's lowest-address pick is the most
//! critical ready node. The in-order scheduler ignores layout order.

mod traffic;

pub use traffic::{placement_cost, TrafficReport};

use crate::criticality;
use crate::graph::{DataflowGraph, NodeId};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of placements built (see [`build_count`]).
static BUILDS: AtomicU64 = AtomicU64::new(0);

/// Number of [`Placement`] constructions since process start.
///
/// Placement is the dominant one-time compile cost of a
/// [`crate::program::Program`]; compile-once tests snapshot this counter
/// around a sweep to prove the same placement is shared across every
/// scheduler and backend variant. Monotonic and process-global: compare
/// *deltas*, and only from a test that owns the whole process.
pub fn build_count() -> u64 {
    BUILDS.load(Ordering::Relaxed)
}

/// Partitioning strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// node id modulo PE count — the classic scatter used by token
    /// dataflow studies (spreads every level across all PEs).
    #[default]
    RoundRobin,
    /// uniform random assignment (seeded).
    Random,
    /// contiguous blocks of the topological order (locality-preserving,
    /// fewer network packets, less parallelism).
    BlockContiguous,
    /// chunks of `CHUNK` consecutive topo-order nodes dealt round-robin:
    /// the practical middle ground a real toolflow uses — locality within
    /// a chunk, load balance across PEs. This is the Fig. 1 default.
    Chunked,
    /// criticality-weighted traffic-aware assignment (the compile
    /// pipeline's placement pass): greedy operand-locality clustering
    /// seed plus bounded simulated-annealing refinement, minimizing
    /// expected unidirectional Hoplite hop distance weighted by source
    /// criticality. Deterministic for a given seed; [`placement_cost`]
    /// is the objective it minimizes.
    TrafficAware,
}

/// Chunk size for [`PlacementPolicy::Chunked`] (nodes per deal).
pub const CHUNK_SIZE: usize = 64;

/// Local memory ordering inside each PE.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LocalOrder {
    /// decreasing criticality — the paper's §II-B layout.
    #[default]
    ByCriticality,
    /// placement arrival order (ablation: OoO without the heuristic).
    ByNodeId,
}

/// The PE-major dense node numbering of a [`Placement`]
/// ([`Placement::dense_layout`]): a bijection between graph node ids
/// ("global") and contiguous `(pe, local)` addresses ("dense").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DenseLayout {
    /// CSR over PEs: PE `p`'s nodes are dense ids `pe_base[p]..pe_base[p+1]`
    /// (length `num_pes + 1`).
    pub pe_base: Vec<u32>,
    /// dense id → graph node id (the concatenated local memory layouts)
    pub global_of: Vec<u32>,
    /// graph node id → dense id (inverse permutation)
    pub dense_of: Vec<u32>,
}

/// The complete placement of a graph onto `num_pes` PEs.
#[derive(Debug, Clone)]
pub struct Placement {
    pub num_pes: usize,
    /// node -> PE
    pub pe_of: Vec<u32>,
    /// node -> local index within its PE's graph memory
    pub local_of: Vec<u32>,
    /// per PE: local index -> node (the memory layout)
    pub nodes_of: Vec<Vec<NodeId>>,
}

impl Placement {
    /// Build a placement with the given policy and local ordering.
    ///
    /// Policies that need torus geometry ([`PlacementPolicy::TrafficAware`])
    /// use the squarest factorization of `num_pes`
    /// ([`squarest_dims`]); paths that know the real overlay shape
    /// (compile pipeline, direct simulator construction) call
    /// [`Placement::build_for_torus`] instead.
    pub fn build(
        g: &DataflowGraph,
        num_pes: usize,
        policy: PlacementPolicy,
        order: LocalOrder,
        seed: u64,
    ) -> Self {
        let (cols, rows) = squarest_dims(num_pes);
        Self::build_for_torus(g, cols, rows, policy, order, seed, None)
    }

    /// Build with a precomputed criticality labeling — the compile-once
    /// path ([`crate::program::Program::compile`]) labels the graph once
    /// and hands the labels down so the sort does not recompute them.
    /// `crit[n]` must be the labeling [`criticality::criticality`] would
    /// return for `g` (one entry per node).
    pub fn build_with(
        g: &DataflowGraph,
        num_pes: usize,
        policy: PlacementPolicy,
        order: LocalOrder,
        seed: u64,
        crit: &[u32],
    ) -> Self {
        let (cols, rows) = squarest_dims(num_pes);
        Self::build_for_torus(g, cols, rows, policy, order, seed, Some(crit))
    }

    /// Build for an explicit `cols`×`rows` torus — the geometry-aware
    /// entry point the compile pipeline's placement pass and direct
    /// simulator construction share, so both sides of a parity
    /// comparison see identical assignments even on non-square tori.
    /// `crit` is an optional precomputed labeling; when `None` and the
    /// policy or local order needs one, it is computed exactly once
    /// here and reused for both assignment and local-memory sorting.
    pub fn build_for_torus(
        g: &DataflowGraph,
        cols: usize,
        rows: usize,
        policy: PlacementPolicy,
        order: LocalOrder,
        seed: u64,
        crit: Option<&[u32]>,
    ) -> Self {
        let num_pes = cols * rows;
        assert!(num_pes > 0);
        let needs_crit =
            order == LocalOrder::ByCriticality || policy == PlacementPolicy::TrafficAware;
        let computed;
        let crit: Option<&[u32]> = match crit {
            Some(c) => Some(c),
            None if needs_crit => {
                computed = criticality::criticality(g);
                Some(&computed)
            }
            None => None,
        };
        let pe_of = Self::assign(g, cols, rows, policy, seed, crit);
        Self::from_assignment_with(g, num_pes, pe_of, order, crit)
    }

    /// The node→PE assignment of `policy` (shared by every `build*`
    /// constructor). `crit` is `Some` whenever the policy needs labels
    /// (the `build*` wrappers guarantee it).
    fn assign(
        g: &DataflowGraph,
        cols: usize,
        rows: usize,
        policy: PlacementPolicy,
        seed: u64,
        crit: Option<&[u32]>,
    ) -> Vec<u32> {
        let num_pes = cols * rows;
        assert!(num_pes > 0);
        let n = g.len();
        if policy == PlacementPolicy::TrafficAware {
            let crit = crit.expect("traffic-aware placement needs criticality labels");
            return traffic::traffic_assign(g, crit, cols, rows, seed).0;
        }
        let mut pe_of = vec![0u32; n];
        match policy {
            PlacementPolicy::RoundRobin => {
                for (i, pe) in pe_of.iter_mut().enumerate() {
                    *pe = (i % num_pes) as u32;
                }
            }
            PlacementPolicy::Random => {
                let mut rng = Rng::seed_from_u64(seed);
                for pe in pe_of.iter_mut() {
                    *pe = rng.gen_range(num_pes) as u32;
                }
            }
            PlacementPolicy::BlockContiguous => {
                let per = n.div_ceil(num_pes);
                for (i, pe) in pe_of.iter_mut().enumerate() {
                    *pe = (i / per) as u32;
                }
            }
            PlacementPolicy::Chunked => {
                for (i, pe) in pe_of.iter_mut().enumerate() {
                    *pe = ((i / CHUNK_SIZE) % num_pes) as u32;
                }
            }
            PlacementPolicy::TrafficAware => unreachable!("dispatched above"),
        }
        pe_of
    }

    /// Build from an explicit node→PE map (used by tests and ablations).
    pub fn from_assignment(
        g: &DataflowGraph,
        num_pes: usize,
        pe_of: Vec<u32>,
        order: LocalOrder,
    ) -> Self {
        Self::from_assignment_with(g, num_pes, pe_of, order, None)
    }

    fn from_assignment_with(
        g: &DataflowGraph,
        num_pes: usize,
        pe_of: Vec<u32>,
        order: LocalOrder,
        crit: Option<&[u32]>,
    ) -> Self {
        BUILDS.fetch_add(1, Ordering::Relaxed);
        let n = g.len();
        assert_eq!(pe_of.len(), n);
        let mut nodes_of: Vec<Vec<NodeId>> = vec![Vec::new(); num_pes];
        for (node, &pe) in pe_of.iter().enumerate() {
            assert!((pe as usize) < num_pes, "PE index out of range");
            nodes_of[pe as usize].push(node as NodeId);
        }
        if order == LocalOrder::ByCriticality {
            let computed;
            let crit: &[u32] = match crit {
                Some(c) => {
                    debug_assert_eq!(c.len(), n, "criticality labeling size mismatch");
                    c
                }
                None => {
                    computed = criticality::criticality(g);
                    &computed
                }
            };
            for local in nodes_of.iter_mut() {
                criticality::sort_by_criticality(local, crit);
            }
        }
        let mut local_of = vec![0u32; n];
        for locals in &nodes_of {
            for (idx, &node) in locals.iter().enumerate() {
                local_of[node as usize] = idx as u32;
            }
        }
        Self {
            num_pes,
            pe_of,
            local_of,
            nodes_of,
        }
    }

    /// The PE-major dense re-indexing of this placement: dense id
    /// `pe_base[pe] + local` enumerates nodes grouped by PE in
    /// local-memory order — under [`LocalOrder::ByCriticality`] that is
    /// the paper's criticality-sorted BRAM image order, so consecutive
    /// dense ids are exactly the addresses a PE's scheduler and
    /// packet-gen unit walk. The compiled runtime tables
    /// ([`crate::program::RuntimeTables`]) lay all per-node metadata and
    /// dynamic state out in this order.
    pub fn dense_layout(&self) -> DenseLayout {
        let n = self.pe_of.len();
        let mut pe_base = Vec::with_capacity(self.num_pes + 1);
        let mut global_of = Vec::with_capacity(n);
        pe_base.push(0u32);
        for locals in &self.nodes_of {
            global_of.extend_from_slice(locals);
            pe_base.push(global_of.len() as u32);
        }
        let mut dense_of = vec![0u32; n];
        for (dense, &global) in global_of.iter().enumerate() {
            dense_of[global as usize] = dense as u32;
        }
        DenseLayout { pe_base, global_of, dense_of }
    }

    /// Largest local node count across PEs (capacity check input).
    pub fn max_local_nodes(&self) -> usize {
        self.nodes_of.iter().map(|v| v.len()).max().unwrap_or(0)
    }

    /// Largest local footprint (nodes + their fanout edges) across PEs —
    /// what actually has to fit in a PE's graph memory.
    pub fn max_local_footprint(&self, g: &DataflowGraph) -> usize {
        self.nodes_of
            .iter()
            .map(|locals| {
                locals
                    .iter()
                    .map(|&n| 1 + g.node(n).fanout.len())
                    .sum::<usize>()
            })
            .max()
            .unwrap_or(0)
    }
}

/// The squarest `(cols, rows)` factorization of `num_pes` (`cols >= rows`,
/// `cols * rows == num_pes`) — the torus shape assumed by geometry-aware
/// placement when only a PE count is given (prime counts degrade to a
/// 1-row ring). Paths that know the real overlay shape should pass it to
/// [`Placement::build_for_torus`] instead.
pub fn squarest_dims(num_pes: usize) -> (usize, usize) {
    assert!(num_pes > 0);
    let mut best = (num_pes, 1);
    let mut d = 1;
    while d * d <= num_pes {
        if num_pes % d == 0 {
            best = (num_pes / d, d);
        }
        d += 1;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;
    use crate::workload::layered_random;

    fn sample() -> DataflowGraph {
        layered_random(8, 6, 16, 2, 9)
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let g = sample();
        let p = Placement::build(&g, 4, PlacementPolicy::RoundRobin, LocalOrder::ByNodeId, 0);
        let counts: Vec<usize> = p.nodes_of.iter().map(|v| v.len()).collect();
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn mapping_is_bijective() {
        let g = sample();
        for policy in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::Random,
            PlacementPolicy::BlockContiguous,
            PlacementPolicy::TrafficAware,
        ] {
            let p = Placement::build(&g, 5, policy, LocalOrder::ByCriticality, 3);
            let mut seen = vec![false; g.len()];
            for (pe, locals) in p.nodes_of.iter().enumerate() {
                for (idx, &node) in locals.iter().enumerate() {
                    assert_eq!(p.pe_of[node as usize] as usize, pe);
                    assert_eq!(p.local_of[node as usize] as usize, idx);
                    assert!(!seen[node as usize], "node placed twice");
                    seen[node as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn criticality_order_is_decreasing() {
        let g = sample();
        let crit = criticality::criticality(&g);
        let p = Placement::build(&g, 3, PlacementPolicy::RoundRobin, LocalOrder::ByCriticality, 0);
        for locals in &p.nodes_of {
            for w in locals.windows(2) {
                assert!(
                    crit[w[0] as usize] >= crit[w[1] as usize],
                    "local memory must be sorted by decreasing criticality"
                );
            }
        }
    }

    #[test]
    fn single_pe_gets_everything() {
        let g = sample();
        let p = Placement::build(&g, 1, PlacementPolicy::Random, LocalOrder::ByCriticality, 7);
        assert_eq!(p.nodes_of[0].len(), g.len());
        assert_eq!(p.max_local_nodes(), g.len());
    }

    #[test]
    fn local_footprint_counts_edges() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(1.0);
        let b = g.add_input(2.0);
        let c = g.op(Op::Add, &[a, b]);
        let _ = g.op(Op::Mul, &[c, c]);
        let p = Placement::build(&g, 1, PlacementPolicy::RoundRobin, LocalOrder::ByNodeId, 0);
        // footprint = 4 nodes + 4 edges (a->c, b->c, c->d x2)
        assert_eq!(p.max_local_footprint(&g), 8);
    }

    /// The compile-once path (precomputed labels) must produce the exact
    /// placement the self-labeling path does — this is what lets a
    /// `Program` stand in for per-run placement bit-for-bit.
    #[test]
    fn build_with_precomputed_criticality_matches_build() {
        let g = sample();
        let crit = criticality::criticality(&g);
        for policy in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::Random,
            PlacementPolicy::BlockContiguous,
            PlacementPolicy::Chunked,
            PlacementPolicy::TrafficAware,
        ] {
            for order in [LocalOrder::ByCriticality, LocalOrder::ByNodeId] {
                let a = Placement::build(&g, 4, policy, order, 9);
                let b = Placement::build_with(&g, 4, policy, order, 9, &crit);
                assert_eq!(a.pe_of, b.pe_of, "{policy:?}/{order:?}");
                assert_eq!(a.local_of, b.local_of, "{policy:?}/{order:?}");
                assert_eq!(a.nodes_of, b.nodes_of, "{policy:?}/{order:?}");
            }
        }
    }

    /// `dense_layout` is a bijection consistent with `pe_of`/`local_of`:
    /// dense id = pe_base[pe] + local, and the two permutations invert
    /// each other.
    #[test]
    fn dense_layout_is_consistent_bijection() {
        let g = sample();
        for policy in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::Random,
            PlacementPolicy::Chunked,
        ] {
            let p = Placement::build(&g, 5, policy, LocalOrder::ByCriticality, 3);
            let d = p.dense_layout();
            assert_eq!(d.pe_base.len(), 6);
            assert_eq!(d.pe_base[0], 0);
            assert_eq!(d.pe_base[5] as usize, g.len());
            assert_eq!(d.global_of.len(), g.len());
            for global in 0..g.len() {
                let dense = d.dense_of[global] as usize;
                assert_eq!(d.global_of[dense] as usize, global, "{policy:?}");
                let pe = p.pe_of[global] as usize;
                let local = p.local_of[global];
                assert_eq!(dense as u32, d.pe_base[pe] + local, "{policy:?}");
            }
            for pe in 0..5 {
                assert_eq!(
                    (d.pe_base[pe + 1] - d.pe_base[pe]) as usize,
                    p.nodes_of[pe].len()
                );
            }
        }
    }

    #[test]
    fn squarest_dims_factorizes() {
        assert_eq!(squarest_dims(1), (1, 1));
        assert_eq!(squarest_dims(4), (2, 2));
        assert_eq!(squarest_dims(12), (4, 3));
        assert_eq!(squarest_dims(7), (7, 1), "primes degrade to a ring");
        assert_eq!(squarest_dims(256), (16, 16));
    }

    #[test]
    fn random_is_seed_deterministic() {
        let g = sample();
        let p1 = Placement::build(&g, 7, PlacementPolicy::Random, LocalOrder::ByNodeId, 5);
        let p2 = Placement::build(&g, 7, PlacementPolicy::Random, LocalOrder::ByNodeId, 5);
        assert_eq!(p1.pe_of, p2.pe_of);
    }
}
