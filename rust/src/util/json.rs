//! Minimal JSON: recursive-descent parser + writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); used for graph (de)serialization and the
//! artifact manifest. Object key order is preserved on write.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Exact u64 view: a non-negative integral number ≤ 2^53 (beyond
    /// that an f64 silently rounds, so we refuse rather than guess).
    pub fn as_u64(&self) -> Option<u64> {
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        self.as_f64()
            .filter(|n| *n >= 0.0 && *n <= MAX_EXACT && n.fract() == 0.0)
            .map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError {
            at: self.i,
            msg: msg.to_string(),
        })
    }

    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a value"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        match text.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err("bad number"),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError {
                                    at: self.i,
                                    msg: "bad \\u escape".into(),
                                })?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| JsonError {
                            at: self.i,
                            msg: "invalid utf-8".into(),
                        })?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_into(j: &Json, out: &mut String) {
    match j {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9.0e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(v) => {
            out.push('[');
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_into(v, out);
            }
            out.push('}');
        }
    }
}

/// Serialize compactly.
pub fn write(j: &Json) -> String {
    let mut s = String::new();
    write_into(j, &mut s);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\"y\n"}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2], Json::Num(-300.0));
        assert_eq!(v.get("b").unwrap().get("c"), Some(&Json::Bool(true)));
        assert_eq!(v.get("e").unwrap().as_str(), Some("x\"y\n"));
        let text2 = write(&v);
        assert_eq!(parse(&text2).unwrap(), v);
    }

    #[test]
    fn numbers() {
        for (t, n) in [("0", 0.0), ("-1", -1.0), ("3.25", 3.25), ("1e3", 1000.0), ("2E-2", 0.02)] {
            assert_eq!(parse(t).unwrap(), Json::Num(n));
        }
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn float_precision_roundtrips() {
        let v = Json::Num(0.1 + 0.2);
        let t = write(&v);
        assert_eq!(parse(&t).unwrap(), v);
    }

    #[test]
    fn as_u64_is_exact() {
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(parse("9007199254740992").unwrap().as_u64(), Some(1 << 53));
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("2.5").unwrap().as_u64(), None);
        assert_eq!(parse("9007199254740994").unwrap().as_u64(), None, "beyond 2^53");
        assert_eq!(parse("\"7\"").unwrap().as_u64(), None, "strings are not numbers");
    }

    #[test]
    fn integers_write_without_point() {
        assert_eq!(write(&Json::Num(42.0)), "42");
        assert_eq!(write(&Json::Num(-7.0)), "-7");
        assert_eq!(write(&Json::Num(2.5)), "2.5");
    }
}
