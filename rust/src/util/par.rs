//! Scoped-thread job pool: run a batch of similar-sized jobs on N OS
//! threads with a simple static partition, returning results in job
//! order regardless of completion order (the determinism guarantee the
//! sweep reports rely on).

/// Run a set of jobs on `threads` OS threads (simple static partition —
/// jobs are similar-sized simulator runs). Results come back in job
/// order: report rows are byte-identical for every thread count.
pub fn run_parallel<T, F>(jobs: Vec<T>, threads: usize, f: F) -> Vec<<F as JobFn<T>>::Out>
where
    T: Send,
    F: JobFn<T> + Sync,
    <F as JobFn<T>>::Out: Send,
{
    let threads = threads.max(1);
    let total = jobs.len();
    let jobs: Vec<(usize, T)> = jobs.into_iter().enumerate().collect();
    let chunks: Vec<Vec<(usize, T)>> = {
        let mut cs: Vec<Vec<(usize, T)>> = (0..threads).map(|_| Vec::new()).collect();
        for (i, job) in jobs {
            cs[i % threads].push((i, job));
        }
        cs
    };
    let slots: Vec<std::sync::Mutex<Vec<(usize, <F as JobFn<T>>::Out)>>> =
        (0..threads).map(|_| std::sync::Mutex::new(Vec::new())).collect();
    std::thread::scope(|s| {
        for (t, chunk) in chunks.into_iter().enumerate() {
            let f = &f;
            let slot = &slots[t];
            s.spawn(move || {
                let mut results = Vec::with_capacity(chunk.len());
                for (i, job) in chunk {
                    results.push((i, f.call(job)));
                }
                *slot.lock().unwrap() = results;
            });
        }
    });
    // Every job ran exactly once: a panicking worker has already
    // propagated through the scope's implicit join, so reaching this
    // point means all (index, result) pairs are present — restore job
    // order by index.
    let mut results: Vec<(usize, <F as JobFn<T>>::Out)> = Vec::with_capacity(total);
    for slot in slots {
        results.append(&mut slot.into_inner().unwrap());
    }
    debug_assert_eq!(results.len(), total);
    results.sort_unstable_by_key(|e| e.0);
    results.into_iter().map(|(_, r)| r).collect()
}

/// Function-object trait for [`run_parallel`] (stable-rust friendly).
pub trait JobFn<T> {
    type Out;
    fn call(&self, job: T) -> Self::Out;
}

impl<T, O, F: Fn(T) -> O> JobFn<T> for F {
    type Out = O;
    fn call(&self, job: T) -> O {
        self(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_parallel_preserves_order() {
        let jobs: Vec<u64> = (0..37).collect();
        let out = run_parallel(jobs, 4, |j: u64| j * 2);
        assert_eq!(out, (0..37).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn run_parallel_single_thread() {
        let out = run_parallel(vec![1, 2, 3], 1, |j: i32| j + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn run_parallel_more_threads_than_jobs() {
        let out = run_parallel(vec![5usize], 16, |j: usize| j * j);
        assert_eq!(out, vec![25]);
    }

    #[test]
    fn run_parallel_empty() {
        let out = run_parallel(Vec::<u32>::new(), 4, |j: u32| j);
        assert!(out.is_empty());
    }
}
