//! Flag-style CLI argument parser (the `clap` substitute).
//!
//! Grammar: `tdp <subcommand> [--flag value | --flag | --flag=value]...`
//! Typed accessors consume recognized flags; [`Args::finish`] rejects
//! anything left over, so typos fail loudly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Parsed arguments of one subcommand.
#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, Option<String>>,
}

impl Args {
    /// Parse `argv` (everything after the subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, CliError> {
        let mut flags = BTreeMap::new();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                return Err(CliError(format!("unexpected positional argument '{arg}'")));
            };
            if name.is_empty() {
                return Err(CliError("bare '--' not supported".into()));
            }
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), Some(v.to_string()));
            } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                flags.insert(name.to_string(), Some(it.next().unwrap()));
            } else {
                flags.insert(name.to_string(), None); // boolean flag
            }
        }
        Ok(Self { flags })
    }

    /// String flag with default.
    pub fn str_or(&mut self, name: &str, default: &str) -> Result<String, CliError> {
        match self.flags.remove(name) {
            None => Ok(default.to_string()),
            Some(Some(v)) => Ok(v),
            Some(None) => Err(CliError(format!("--{name} needs a value"))),
        }
    }

    /// Optional string flag.
    pub fn str_opt(&mut self, name: &str) -> Result<Option<String>, CliError> {
        match self.flags.remove(name) {
            None => Ok(None),
            Some(Some(v)) => Ok(Some(v)),
            Some(None) => Err(CliError(format!("--{name} needs a value"))),
        }
    }

    /// Required string flag.
    pub fn str_req(&mut self, name: &str) -> Result<String, CliError> {
        self.str_opt(name)?
            .ok_or_else(|| CliError(format!("--{name} is required")))
    }

    fn parse_num<T: std::str::FromStr>(name: &str, v: String) -> Result<T, CliError> {
        v.parse()
            .map_err(|_| CliError(format!("--{name}: cannot parse '{v}'")))
    }

    pub fn usize_or(&mut self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.str_opt(name)? {
            None => Ok(default),
            Some(v) => Self::parse_num(name, v),
        }
    }

    pub fn u64_or(&mut self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.str_opt(name)? {
            None => Ok(default),
            Some(v) => Self::parse_num(name, v),
        }
    }

    pub fn f64_or(&mut self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.str_opt(name)? {
            None => Ok(default),
            Some(v) => Self::parse_num(name, v),
        }
    }

    /// Boolean switch (present = true).
    pub fn switch(&mut self, name: &str) -> bool {
        matches!(self.flags.remove(name), Some(_))
    }

    /// Comma-separated usize list.
    pub fn usize_list(&mut self, name: &str) -> Result<Vec<usize>, CliError> {
        match self.str_opt(name)? {
            None => Ok(vec![]),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| Self::parse_num(name, s.to_string()))
                .collect(),
        }
    }

    /// Error on unconsumed flags.
    pub fn finish(self) -> Result<(), CliError> {
        if let Some(k) = self.flags.keys().next() {
            return Err(CliError(format!("unknown flag --{k}")));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn space_and_equals_forms() {
        let mut a = args(&["--cols", "8", "--rows=4", "--verbose"]);
        assert_eq!(a.usize_or("cols", 1).unwrap(), 8);
        assert_eq!(a.usize_or("rows", 1).unwrap(), 4);
        assert!(a.switch("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let mut a = args(&[]);
        assert_eq!(a.usize_or("cols", 16).unwrap(), 16);
        assert_eq!(a.f64_or("rate", 0.5).unwrap(), 0.5);
        assert_eq!(a.str_or("fmt", "md").unwrap(), "md");
        assert!(!a.switch("detail"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = args(&["--bogus", "1"]);
        assert!(a.finish().is_err());
    }

    #[test]
    fn required_flag() {
        let mut a = args(&[]);
        assert!(a.str_req("workload").is_err());
    }

    #[test]
    fn numeric_parse_errors() {
        let mut a = args(&["--cols", "abc"]);
        assert!(a.usize_or("cols", 1).is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(vec!["run".to_string()]).is_err());
    }

    #[test]
    fn list_flag() {
        let mut a = args(&["--points", "1,16,256"]);
        assert_eq!(a.usize_list("points").unwrap(), vec![1, 16, 256]);
    }

    #[test]
    fn boolean_flag_followed_by_flag() {
        let mut a = args(&["--detail", "--cols", "4"]);
        assert!(a.switch("detail"));
        assert_eq!(a.usize_or("cols", 1).unwrap(), 4);
    }
}
