//! In-tree substrates replacing crates that are unavailable in the
//! offline build universe (DESIGN.md §2): a deterministic PRNG (`rand`),
//! a JSON parser/writer (`serde_json`), a TOML-subset parser (`toml`),
//! a flag-style CLI argument parser (`clap`), and a scoped-thread job
//! pool (`rayon`).

pub mod cli;
pub mod json;
pub mod par;
pub mod rng;
pub mod toml;
