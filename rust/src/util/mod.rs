//! In-tree substrates replacing crates that are unavailable in the
//! offline build universe (DESIGN.md §2): a deterministic PRNG (`rand`),
//! a JSON parser/writer (`serde_json`), a TOML-subset parser (`toml`),
//! and a flag-style CLI argument parser (`clap`).

pub mod cli;
pub mod json;
pub mod rng;
pub mod toml;
