//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Workload generation, placement and traffic synthesis all need seeded,
//! reproducible randomness; the `rand` crate is not in the offline
//! universe, so this is the standard xoshiro256** (Blackman & Vigna)
//! with the usual convenience samplers.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a u64 (SplitMix64 expansion).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift, debiased enough for
    /// simulation workloads).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn gen_range_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.gen_range(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn gen_f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.gen_f64() as f32
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        let mut c = Rng::seed_from_u64(8);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.gen_range(17);
            assert!(x < 17);
        }
        for _ in 0..10_000 {
            let x = r.gen_range_in(5, 9);
            assert!((5..9).contains(&x));
        }
    }

    #[test]
    fn gen_range_is_roughly_uniform() {
        let mut r = Rng::seed_from_u64(3);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[r.gen_range(8)] += 1;
        }
        for &c in &counts {
            let expect = n / 8;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket {c} vs expected {expect}"
            );
        }
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(5);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn gen_bool_probability() {
        let mut r = Rng::seed_from_u64(9);
        let hits = (0..100_000).filter(|_| r.gen_bool(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }

    #[test]
    fn zero_seed_is_fine() {
        let mut r = Rng::seed_from_u64(0);
        // xoshiro must not get stuck at zero state thanks to splitmix init
        assert_ne!(r.next_u64(), 0);
        assert_ne!(r.next_u64(), r.next_u64());
    }
}
