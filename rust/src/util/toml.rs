//! TOML-subset parser for config files.
//!
//! Supports what [`crate::config`] needs: top-level and `[section]`
//! key/value pairs with string, integer, float and boolean values,
//! comments, and blank lines. (No arrays-of-tables, dates or multi-line
//! strings — config files here don't use them.)

use std::collections::BTreeMap;
use std::fmt;

/// A scalar TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => {
                if x.fract() == 0.0 {
                    write!(f, "{x:.1}")
                } else {
                    write!(f, "{x}")
                }
            }
            Value::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// Parsed document: `sections[""]` is the root table.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn new() -> Self {
        Self::default()
    }

    /// Value at (section, key); section "" = root.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|t| t.get(key))
    }

    pub fn set(&mut self, section: &str, key: &str, v: Value) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), v);
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        if let Some(root) = self.sections.get("") {
            for (k, v) in root {
                out.push_str(&format!("{k} = {v}\n"));
            }
        }
        for (name, table) in &self.sections {
            if name.is_empty() {
                continue;
            }
            out.push_str(&format!("\n[{name}]\n"));
            for (k, v) in table {
                out.push_str(&format!("{k} = {v}\n"));
            }
        }
        out
    }
}

/// Parse error with line number (1-based).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

fn parse_value(raw: &str, line: usize) -> Result<Value, TomlError> {
    let raw = raw.trim();
    let err = |msg: &str| TomlError {
        line,
        msg: msg.to_string(),
    };
    if raw.is_empty() {
        return Err(err("missing value"));
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string"))?;
        let mut s = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('"') => s.push('"'),
                    Some('\\') => s.push('\\'),
                    Some('n') => s.push('\n'),
                    Some('t') => s.push('\t'),
                    _ => return Err(err("bad escape")),
                }
            } else {
                s.push(c);
            }
        }
        return Ok(Value::Str(s));
    }
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    // int before float: "42" parses as both
    if let Ok(i) = raw.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(&format!("cannot parse value '{raw}'")))
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc, TomlError> {
    let mut doc = Doc::new();
    let mut section = String::new();
    for (ln, raw_line) in text.lines().enumerate() {
        let line_no = ln + 1;
        // strip comments (naive: '#' not inside a string — handle by
        // scanning with a quote flag)
        let mut in_str = false;
        let mut cut = raw_line.len();
        for (i, c) in raw_line.char_indices() {
            match c {
                '"' => in_str = !in_str,
                '#' if !in_str => {
                    cut = i;
                    break;
                }
                _ => {}
            }
        }
        let line = raw_line[..cut].trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| TomlError {
                    line: line_no,
                    msg: "unterminated section header".into(),
                })?
                .trim();
            if name.is_empty() || name.contains('[') {
                return Err(TomlError {
                    line: line_no,
                    msg: "bad section name".into(),
                });
            }
            section = name.to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| TomlError {
            line: line_no,
            msg: "expected key = value".into(),
        })?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(TomlError {
                line: line_no,
                msg: "empty key".into(),
            });
        }
        let value = parse_value(&line[eq + 1..], line_no)?;
        doc.set(&section, key, value);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_root_and_sections() {
        let doc = parse(
            "cols = 16\nname = \"test\" # trailing comment\n\n[bram]\nbrams_per_pe = 8\nfifo_brams = 6.5\nenabled = true\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "cols"), Some(&Value::Int(16)));
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("test"));
        assert_eq!(doc.get("bram", "fifo_brams").unwrap().as_f64(), Some(6.5));
        assert_eq!(doc.get("bram", "enabled"), Some(&Value::Bool(true)));
    }

    #[test]
    fn render_roundtrip() {
        let mut doc = Doc::new();
        doc.set("", "a", Value::Int(1));
        doc.set("", "s", Value::Str("hi \"there\"".into()));
        doc.set("sec", "f", Value::Float(2.5));
        doc.set("sec", "g", Value::Float(3.0));
        let text = doc.render();
        let doc2 = parse(&text).unwrap();
        assert_eq!(doc, doc2);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse("k = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn negative_and_underscore_numbers() {
        let doc = parse("a = -3\nb = 1_000\nc = -0.25\n").unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Int(-3)));
        assert_eq!(doc.get("", "b"), Some(&Value::Int(1000)));
        assert_eq!(doc.get("", "c"), Some(&Value::Float(-0.25)));
    }

    #[test]
    fn errors_have_line_numbers() {
        let e = parse("a = 1\nbogus line\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("k = \n").is_err());
        assert!(parse("k = \"oops\n").is_err());
    }

    #[test]
    fn int_float_coercion() {
        let doc = parse("x = 2\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_f64(), Some(2.0));
        assert_eq!(doc.get("", "x").unwrap().as_usize(), Some(2));
    }
}
