//! Leading-one detector (LOD) circuit models — §II-B.
//!
//! The FPGA circuit is a combinational priority encoder; the hierarchical
//! scheduler composes an *OuterLOD* over a summary vector (one bit per
//! flag word, held in distributed memory/LUTRAM) with a 32 b *InnerLOD*
//! over the selected flag word (held in BRAM). The software model uses the
//! same layout the Pallas kernel (`python/compile/kernels/lod.py`) and the
//! scheduler bitsets use: node `w*32 + b` ↔ bit `b` (LSB-first) of word
//! `w`; the "leading one" is the **lowest** node index with its bit set.

/// Sentinel for "no bit set" (matches `kernels/lod.py::NO_READY`).
pub const NO_READY: u32 = 1 << 30;

/// Bits per flag word — the paper uses 32 of the M20K's 40 b word.
pub const WORD_BITS: u32 = 32;

/// Combinational LOD over a single word: position of the least-significant
/// set bit, or `None`.
#[inline]
pub fn lod32(word: u32) -> Option<u32> {
    if word == 0 {
        None
    } else {
        Some(word.trailing_zeros())
    }
}

/// Naive scan over packed words — the paper's strawman ("in the worst case
/// scan 256 memory locations"). Kept as the correctness oracle and for the
/// ablation bench.
pub fn naive_scan(words: &[u32]) -> u32 {
    for (w, &word) in words.iter().enumerate() {
        if let Some(b) = lod32(word) {
            return w as u32 * WORD_BITS + b;
        }
    }
    NO_READY
}

/// Hierarchical LOD: a summary bitset over flag words + per-word inner
/// detection — the paper's deterministic 2-cycle pick.
///
/// `summary` must have bit `w` set iff `words[w] != 0`; callers (the OoO
/// scheduler) maintain it incrementally on flag updates.
#[derive(Debug, Clone)]
pub struct HierLod {
    /// number of flag words covered
    num_words: usize,
}

impl HierLod {
    pub fn new(num_words: usize) -> Self {
        Self { num_words }
    }

    /// Latency of one pick in PE cycles (OuterLOD cycle + InnerLOD cycle).
    pub const PICK_LATENCY: u32 = 2;

    /// Outer summary words needed (u64 summary words in the model; the
    /// hardware uses a 128 b LUTRAM vector).
    pub fn summary_words(&self) -> usize {
        self.num_words.div_ceil(64)
    }

    /// Two-level pick: leading word via the summary, leading bit via the
    /// inner LOD. O(summary words) + O(1), vs. the naive O(words) scan.
    pub fn pick(&self, summary: &[u64], words: &[u32]) -> u32 {
        debug_assert_eq!(words.len(), self.num_words);
        debug_assert_eq!(summary.len(), self.summary_words());
        for (sw, &s) in summary.iter().enumerate() {
            if s != 0 {
                let w = sw * 64 + s.trailing_zeros() as usize;
                debug_assert!(words[w] != 0, "summary bit set for empty word {w}");
                return w as u32 * WORD_BITS + words[w].trailing_zeros();
            }
        }
        NO_READY
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn summary_of(words: &[u32]) -> Vec<u64> {
        let mut s = vec![0u64; words.len().div_ceil(64)];
        for (w, &word) in words.iter().enumerate() {
            if word != 0 {
                s[w / 64] |= 1 << (w % 64);
            }
        }
        s
    }

    #[test]
    fn lod32_cases() {
        assert_eq!(lod32(0), None);
        assert_eq!(lod32(1), Some(0));
        assert_eq!(lod32(0x8000_0000), Some(31));
        assert_eq!(lod32(0b1100), Some(2));
    }

    #[test]
    fn naive_scan_empty() {
        assert_eq!(naive_scan(&[0; 256]), NO_READY);
        assert_eq!(naive_scan(&[]), NO_READY);
    }

    #[test]
    fn hier_matches_naive_on_random_vectors() {
        let mut rng = Rng::seed_from_u64(0xBEEF);
        for nwords in [1usize, 3, 64, 128, 256] {
            let lod = HierLod::new(nwords);
            for density in [0.0, 0.01, 0.3, 1.0] {
                for _ in 0..50 {
                    let words: Vec<u32> = (0..nwords)
                        .map(|_| {
                            let mut w = 0u32;
                            for b in 0..32 {
                                if rng.gen_bool(density) {
                                    w |= 1 << b;
                                }
                            }
                            w
                        })
                        .collect();
                    let s = summary_of(&words);
                    assert_eq!(lod.pick(&s, &words), naive_scan(&words));
                }
            }
        }
    }

    #[test]
    fn hier_single_bit_positions() {
        let nwords = 128;
        let lod = HierLod::new(nwords);
        for node in [0u32, 31, 32, 63, 64, 2047, 4095] {
            let mut words = vec![0u32; nwords];
            words[(node / 32) as usize] = 1 << (node % 32);
            let s = summary_of(&words);
            assert_eq!(lod.pick(&s, &words), node);
        }
    }

    #[test]
    fn pick_latency_is_two_cycles() {
        // normative constant from the paper ("deterministic 2-cycle
        // process"); the scheduler model depends on it.
        assert_eq!(HierLod::PICK_LATENCY, 2);
    }
}
