//! Lightweight instrumentation (DESIGN.md §11): a [`Registry`] of named
//! counters, gauges and log2-bucketed histograms, plus timed [`Span`]s,
//! exported as a Chrome/Perfetto trace ([`perfetto_json`]).
//!
//! The registry is **passed in, never global**: instrumented code takes
//! a [`Telemetry`] (`Option<&Registry>`) and the disabled path is a
//! literal no-op — no clock read, no lock, no allocation (the
//! overhead-when-disabled contract the CI telemetry smoke measures).
//! Recording is coarse-grained by design (compile stages, run phases,
//! service jobs); the per-cycle hot loop keeps its own plain counters
//! (`SimStats`, [`crate::sim::ActivityReport`]) and never touches the
//! registry lock.

mod perfetto;

pub use perfetto::{perfetto_json, trace_counter_series, CounterSeries};

use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// The instrumentation handle threaded through instrumented code paths:
/// `None` disables telemetry at zero cost.
pub type Telemetry<'a> = Option<&'a Registry>;

/// One recorded timed span (relative to the registry's epoch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// grouping track ("compile", "run", ...) — the Perfetto thread
    pub track: &'static str,
    pub name: &'static str,
    pub start_micros: u64,
    pub dur_micros: u64,
}

/// A log2-bucketed histogram of non-negative integer observations
/// (latencies in µs, cycle counts): bucket `b` holds values whose bit
/// length is `b`, i.e. `[2^(b-1), 2^b)` for `b > 0` and exactly `0` for
/// `b = 0`. Fixed 65-slot storage, `Copy`, no allocation.
#[derive(Debug, Clone, Copy)]
pub struct Histogram {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    buckets: [u64; 65],
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
            buckets: [0; 65],
        }
    }
}

impl Histogram {
    pub fn observe(&mut self, v: u64) {
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.buckets[(64 - v.leading_zeros()) as usize] += 1;
    }

    /// Approximate percentile (`p` in [0, 1]): the upper bound of the
    /// bucket holding the rank-`ceil(p·count)` observation, clamped to
    /// the observed [min, max]. Exact to within one power of two.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let hi = if b >= 64 { u64::MAX } else { (1u64 << b) - 1 };
                return hi.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Summary object: `{count, sum, min, max, p50, p90, p99}` (the
    /// latency format of [`crate::service::Engine::metrics_snapshot`]).
    pub fn to_json_value(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.count as f64));
        m.insert("sum".to_string(), Json::Num(self.sum as f64));
        let min = if self.count == 0 { 0 } else { self.min };
        m.insert("min".to_string(), Json::Num(min as f64));
        m.insert("max".to_string(), Json::Num(self.max as f64));
        m.insert("p50".to_string(), Json::Num(self.percentile(0.50) as f64));
        m.insert("p90".to_string(), Json::Num(self.percentile(0.90) as f64));
        m.insert("p99".to_string(), Json::Num(self.percentile(0.99) as f64));
        Json::Obj(m)
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, Histogram>,
    spans: Vec<Span>,
}

/// A registry of named metrics and spans. Thread-safe (one mutex over
/// all state — recording is coarse-grained, never per fabric cycle);
/// keys are `&'static str` so recording never allocates.
pub struct Registry {
    epoch: Instant,
    inner: Mutex<Inner>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Self {
            epoch: Instant::now(),
            inner: Mutex::new(Inner::default()),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().expect("telemetry registry lock")
    }

    /// Add `delta` to counter `key` (created at zero).
    pub fn count(&self, key: &'static str, delta: u64) {
        *self.lock().counters.entry(key).or_insert(0) += delta;
    }

    /// Set gauge `key` to `value` (last write wins).
    pub fn gauge(&self, key: &'static str, value: f64) {
        self.lock().gauges.insert(key, value);
    }

    /// Record `v` into histogram `key`.
    pub fn observe(&self, key: &'static str, v: u64) {
        self.lock().hists.entry(key).or_default().observe(v);
    }

    /// Current value of counter `key` (0 if never touched).
    pub fn counter(&self, key: &str) -> u64 {
        self.lock().counters.get(key).copied().unwrap_or(0)
    }

    /// Current value of gauge `key`.
    pub fn gauge_value(&self, key: &str) -> Option<f64> {
        self.lock().gauges.get(key).copied()
    }

    /// Snapshot of histogram `key`.
    pub fn histogram(&self, key: &str) -> Option<Histogram> {
        self.lock().hists.get(key).copied()
    }

    /// Snapshot of every recorded span, in completion order.
    pub fn spans(&self) -> Vec<Span> {
        self.lock().spans.clone()
    }

    /// Start a timed span; it records itself on drop (RAII).
    pub fn span(&self, track: &'static str, name: &'static str) -> SpanGuard<'_> {
        SpanGuard {
            reg: self,
            track,
            name,
            t0: Instant::now(),
        }
    }

    /// Record a span that ran from `start` for `dur`.
    pub fn record_span(
        &self,
        track: &'static str,
        name: &'static str,
        start: Instant,
        dur: Duration,
    ) {
        let start_micros = start.saturating_duration_since(self.epoch).as_micros() as u64;
        self.lock().spans.push(Span {
            track,
            name,
            start_micros,
            dur_micros: dur.as_micros() as u64,
        });
    }

    /// Everything in one JSON object: `{counters, gauges, histograms,
    /// spans}` (histograms as summaries, spans with track/name/µs).
    pub fn to_json_value(&self) -> Json {
        let inner = self.lock();
        let mut root = BTreeMap::new();
        root.insert(
            "counters".to_string(),
            Json::Obj(
                inner
                    .counters
                    .iter()
                    .map(|(k, &v)| (k.to_string(), Json::Num(v as f64)))
                    .collect(),
            ),
        );
        root.insert(
            "gauges".to_string(),
            Json::Obj(
                inner
                    .gauges
                    .iter()
                    .map(|(k, &v)| (k.to_string(), Json::Num(v)))
                    .collect(),
            ),
        );
        root.insert(
            "histograms".to_string(),
            Json::Obj(
                inner
                    .hists
                    .iter()
                    .map(|(k, v)| (k.to_string(), v.to_json_value()))
                    .collect(),
            ),
        );
        root.insert(
            "spans".to_string(),
            Json::Arr(
                inner
                    .spans
                    .iter()
                    .map(|s| {
                        let mut m = BTreeMap::new();
                        m.insert("track".to_string(), Json::Str(s.track.to_string()));
                        m.insert("name".to_string(), Json::Str(s.name.to_string()));
                        m.insert("start_micros".to_string(), Json::Num(s.start_micros as f64));
                        m.insert("dur_micros".to_string(), Json::Num(s.dur_micros as f64));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        Json::Obj(root)
    }

    /// Compact JSON text of [`Registry::to_json_value`].
    pub fn to_json(&self) -> String {
        json::write(&self.to_json_value())
    }
}

/// RAII guard of an in-flight span (see [`Registry::span`]).
pub struct SpanGuard<'r> {
    reg: &'r Registry,
    track: &'static str,
    name: &'static str,
    t0: Instant,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        self.reg.record_span(self.track, self.name, self.t0, self.t0.elapsed());
    }
}

/// Counter increment through an optional registry — a no-op on `None`.
#[inline]
pub fn count(t: Telemetry<'_>, key: &'static str, delta: u64) {
    if let Some(reg) = t {
        reg.count(key, delta);
    }
}

/// Histogram observation through an optional registry — a no-op on
/// `None`.
#[inline]
pub fn observe(t: Telemetry<'_>, key: &'static str, v: u64) {
    if let Some(reg) = t {
        reg.observe(key, v);
    }
}

/// Run `f` inside a timed span when telemetry is enabled; with `None`
/// this is exactly `f()` — no clock read, no lock (the zero-cost
/// contract instrumented call sites rely on).
#[inline]
pub fn timed<T>(
    t: Telemetry<'_>,
    track: &'static str,
    name: &'static str,
    f: impl FnOnce() -> T,
) -> T {
    match t {
        None => f(),
        Some(reg) => {
            let _span = reg.span(track, name);
            f()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_accumulate() {
        let reg = Registry::new();
        reg.count("jobs", 1);
        reg.count("jobs", 2);
        reg.gauge("occupancy", 0.5);
        reg.gauge("occupancy", 0.75);
        assert_eq!(reg.counter("jobs"), 3);
        assert_eq!(reg.counter("untouched"), 0);
        assert_eq!(reg.gauge_value("occupancy"), Some(0.75));
    }

    #[test]
    fn histogram_buckets_and_percentiles() {
        let mut h = Histogram::default();
        for v in 1..=100u64 {
            h.observe(v);
        }
        assert_eq!(h.count, 100);
        assert_eq!(h.sum, 5050);
        assert_eq!((h.min, h.max), (1, 100));
        // log2 buckets: percentiles land within one power of two
        let p50 = h.percentile(0.50);
        assert!((32..=63).contains(&p50), "p50 of 1..=100 in [32,63], got {p50}");
        assert_eq!(h.percentile(0.99), 100, "p99 bucket clamps to observed max");
        assert_eq!(h.percentile(0.0), 1);
        // zero values land in bucket 0
        let mut z = Histogram::default();
        z.observe(0);
        assert_eq!(z.percentile(0.5), 0);
        assert_eq!(Histogram::default().percentile(0.9), 0, "empty is safe");
        // no overflow at the top bucket
        let mut big = Histogram::default();
        big.observe(u64::MAX);
        assert_eq!(big.percentile(1.0), u64::MAX);
    }

    #[test]
    fn histogram_summary_json_shape() {
        let mut h = Histogram::default();
        h.observe(10);
        h.observe(20);
        let j = h.to_json_value();
        for key in ["count", "sum", "min", "max", "p50", "p90", "p99"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.get("count").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("sum").unwrap().as_u64(), Some(30));
        // empty histograms report min 0, not u64::MAX
        let empty = Histogram::default().to_json_value();
        assert_eq!(empty.get("min").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn spans_record_track_name_duration() {
        let reg = Registry::new();
        {
            let _s = reg.span("compile", "place");
            std::thread::sleep(Duration::from_millis(2));
        }
        timed(Some(&reg), "run", "in-order", || ());
        let spans = reg.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!((spans[0].track, spans[0].name), ("compile", "place"));
        assert!(spans[0].dur_micros >= 1_000, "slept ~2ms: {spans:?}");
        assert_eq!((spans[1].track, spans[1].name), ("run", "in-order"));
        // spans start at/after the registry epoch and nest sanely
        assert!(spans[1].start_micros >= spans[0].start_micros);
    }

    #[test]
    fn disabled_helpers_are_passthrough() {
        // the None path must not require a registry at all
        count(None, "x", 1);
        observe(None, "y", 2);
        let mut ran = false;
        let out = timed(None, "t", "n", || {
            ran = true;
            42
        });
        assert!(ran);
        assert_eq!(out, 42);
    }

    #[test]
    fn registry_json_is_parseable_and_complete() {
        let reg = Registry::new();
        reg.count("compile.programs", 1);
        reg.gauge("g", 1.5);
        reg.observe("run.cycles", 1234);
        timed(Some(&reg), "compile", "criticality", || ());
        let text = reg.to_json();
        let j = json::parse(&text).unwrap();
        assert_eq!(
            j.get("counters").unwrap().get("compile.programs").unwrap().as_u64(),
            Some(1)
        );
        assert!(j.get("gauges").unwrap().get("g").is_some());
        assert_eq!(
            j.get("histograms").unwrap().get("run.cycles").unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
        assert_eq!(j.get("spans").unwrap().as_arr().unwrap().len(), 1);
    }
}
