//! Chrome/Perfetto trace-event export (DESIGN.md §11).
//!
//! The output is the JSON object form of the trace-event format —
//! `{"traceEvents": [...], "displayTimeUnit": "ms"}` — loadable in
//! `chrome://tracing` and [ui.perfetto.dev](https://ui.perfetto.dev).
//! Two processes are emitted:
//!
//! * `pid 1` — **host**: every registry [`Span`](super::Span) as a
//!   complete (`"ph": "X"`) event, one Perfetto thread per span track
//!   ("compile", "run", ...), timestamps in wall-clock µs since the
//!   registry epoch;
//! * `pid 2` — **fabric**: per-cycle counter (`"ph": "C"`) series from
//!   a run's [`crate::sim::Trace`] samples, timestamps in *simulated*
//!   cycles (rendered as µs: 1 cycle = 1 µs).

use super::Registry;
use crate::sim::Trace;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

const HOST_PID: f64 = 1.0;
const FABRIC_PID: f64 = 2.0;

/// One named counter track: `(timestamp µs, value)` points.
#[derive(Debug, Clone)]
pub struct CounterSeries {
    pub name: String,
    pub points: Vec<(u64, f64)>,
}

/// The per-cycle run-phase counters of one traced run, prefixed so
/// several runs (e.g. both schedulers) can share one trace file.
pub fn trace_counter_series(prefix: &str, trace: &Trace) -> Vec<CounterSeries> {
    let series: [(&str, fn(&crate::sim::Sample) -> f64); 4] = [
        ("ready_total", |s| s.ready_total as f64),
        ("busy_pes", |s| s.busy_pes as f64),
        ("in_flight", |s| s.in_flight as f64),
        ("completed", |s| s.completed as f64),
    ];
    series
        .iter()
        .map(|(name, f)| CounterSeries {
            name: format!("{prefix}/{name}"),
            points: trace.samples.iter().map(|s| (s.cycle, f(s))).collect(),
        })
        .collect()
}

fn meta_event(name: &str, pid: f64, tid: f64, value: &str) -> Json {
    let mut m = BTreeMap::new();
    m.insert("ph".to_string(), Json::Str("M".to_string()));
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("pid".to_string(), Json::Num(pid));
    m.insert("tid".to_string(), Json::Num(tid));
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(value.to_string()));
    m.insert("args".to_string(), Json::Obj(args));
    Json::Obj(m)
}

/// Render the registry's spans plus optional fabric counter series as
/// one Chrome trace-event JSON document.
pub fn perfetto_json(reg: &Registry, counters: &[CounterSeries]) -> String {
    let mut events = Vec::new();
    events.push(meta_event("process_name", HOST_PID, 0.0, "tdp host"));

    // one Perfetto thread per span track, in order of first appearance
    let spans = reg.spans();
    let mut track_tid: BTreeMap<&'static str, f64> = BTreeMap::new();
    for s in &spans {
        let next = track_tid.len() as f64 + 1.0;
        let tid = *track_tid.entry(s.track).or_insert(next);
        if tid == next {
            events.push(meta_event("thread_name", HOST_PID, tid, s.track));
        }
        let mut m = BTreeMap::new();
        m.insert("ph".to_string(), Json::Str("X".to_string()));
        m.insert("name".to_string(), Json::Str(s.name.to_string()));
        m.insert("cat".to_string(), Json::Str(s.track.to_string()));
        m.insert("ts".to_string(), Json::Num(s.start_micros as f64));
        m.insert("dur".to_string(), Json::Num(s.dur_micros as f64));
        m.insert("pid".to_string(), Json::Num(HOST_PID));
        m.insert("tid".to_string(), Json::Num(tid));
        events.push(Json::Obj(m));
    }

    if !counters.is_empty() {
        events.push(meta_event(
            "process_name",
            FABRIC_PID,
            0.0,
            "simulated fabric (1 cycle = 1us)",
        ));
        for series in counters {
            for &(ts, v) in &series.points {
                let mut m = BTreeMap::new();
                m.insert("ph".to_string(), Json::Str("C".to_string()));
                m.insert("name".to_string(), Json::Str(series.name.clone()));
                m.insert("ts".to_string(), Json::Num(ts as f64));
                m.insert("pid".to_string(), Json::Num(FABRIC_PID));
                m.insert("tid".to_string(), Json::Num(0.0));
                let mut args = BTreeMap::new();
                args.insert("value".to_string(), Json::Num(v));
                m.insert("args".to_string(), Json::Obj(args));
                events.push(Json::Obj(m));
            }
        }
    }

    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(events));
    root.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    json::write(&Json::Obj(root))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Sample;

    fn count_ph(events: &[Json], ph: &str) -> usize {
        events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some(ph))
            .count()
    }

    #[test]
    fn export_is_valid_trace_event_json() {
        let reg = Registry::new();
        super::super::timed(Some(&reg), "compile", "criticality", || ());
        super::super::timed(Some(&reg), "compile", "place", || ());
        super::super::timed(Some(&reg), "run", "out-of-order", || ());

        let mut trace = Trace::new(1);
        for c in 0..3u64 {
            trace.push(Sample {
                cycle: c,
                ready_total: c as usize,
                ready_max: 1,
                busy_pes: 2,
                in_flight: 1,
                completed: c as usize,
            });
        }
        let counters = trace_counter_series("ooo", &trace);
        assert_eq!(counters.len(), 4);
        assert_eq!(counters[0].name, "ooo/ready_total");
        assert_eq!(counters[0].points.len(), 3);

        let text = perfetto_json(&reg, &counters);
        let j = json::parse(&text).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(count_ph(events, "X"), 3, "one complete event per span");
        assert_eq!(count_ph(events, "C"), 12, "4 series x 3 samples");
        // spans carry cat/ts/dur and land on the host process; the two
        // tracks get distinct Perfetto threads
        let span = events
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .unwrap();
        for key in ["name", "cat", "ts", "dur", "pid", "tid"] {
            assert!(span.get(key).is_some(), "span missing {key}");
        }
        let tids: std::collections::BTreeSet<u64> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .map(|e| e.get("tid").unwrap().as_u64().unwrap())
            .collect();
        assert_eq!(tids.len(), 2, "compile and run are separate threads");
        assert_eq!(j.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    }

    #[test]
    fn spanless_counterless_export_still_valid() {
        let reg = Registry::new();
        let j = json::parse(&perfetto_json(&reg, &[])).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1, "just the host process_name record");
    }
}
