//! Cycle-level overlay simulator: PEs (§II-A datapath) + Hoplite torus,
//! stepped in lockstep one fabric cycle at a time. This is the reference
//! model; [`crate::engine`] wraps it behind the [`crate::engine::SimBackend`]
//! trait and adds a skip-ahead event backend that jumps over quiescent
//! regions (see the `pub(crate)` event-horizon hooks at the bottom of
//! `Simulator`).
//!
//! Per-cycle pipeline (all PEs in parallel, double-buffered network):
//! 1. packet-gen units drive this cycle's injection requests;
//! 2. the network switches; grants and ejects become visible;
//! 3. each PE consumes its ejected packet: operand store → dataflow
//!    firing rule → ALU issue;
//! 4. ALU retirements write back and flag nodes ready (scheduler);
//! 5. packet-gen state machines advance (scheduling passes, fanout
//!    drains, completion).
//!
//! Stages (3)–(5) are fused and walk the active-PE worklist instead of
//! sweeping the whole fabric, so host cost tracks *activity*, not
//! `num_pes` (DESIGN.md §7) — bit-exactly, as `tests/engine_parity.rs`
//! enforces.
//!
//! The per-cycle loop reads only the baked
//! [`RuntimeTables`](crate::program::RuntimeTables) (DESIGN.md §10):
//! per-node dynamic state is indexed by *dense id* (`pe_base[pe] +
//! local`, the PE's local-memory order), fanout packets are single
//! indexed loads from the pre-formed CSR route table, and no
//! `graph::Node` is dereferenced — the graph object model is a
//! compile-time input only.

mod activity;
mod cancel;
mod stats;
mod trace;

pub use activity::ActivityReport;
pub use cancel::{CancelCause, CancelToken, CANCEL_CHECK_INTERVAL};
pub use stats::{PeStats, SimStats};
pub use trace::{Sample, Trace};

use crate::config::OverlayConfig;
use crate::graph::{DataflowGraph, Op};
use crate::noc::{Network, Packet};
use crate::pe::{AluPipeline, BramConfig, PacketGen, PgState, PortArbiter, Unit};
use crate::place::Placement;
use crate::program::RuntimeTables;
use crate::sched::{ReadyScheduler, Scheduler, SchedulerKind};
use std::sync::Arc;

/// Simulation failure modes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// `max_cycles` elapsed before the graph completed (livelock guard).
    CycleLimitExceeded { cycle: u64, completed: usize, total: usize },
    /// a PE's local subgraph exceeds its BRAM budget
    /// (only when `enforce_capacity` is set).
    CapacityExceeded { pe: usize, words_needed: usize, words_available: usize },
    /// the compile pass pipeline's verifier rejected the graph with
    /// `errors` error-severity diagnostics (run `tdp check` for the
    /// full report) — the simulator-error image of
    /// [`crate::program::CompileError::InvalidGraph`].
    InvalidProgram { errors: usize },
    /// the run's [`CancelToken`] wall-clock deadline expired; carries
    /// the partial progress at the check point (polled every
    /// [`CANCEL_CHECK_INTERVAL`] cycles, so at most one interval late).
    DeadlineExceeded { cycle: u64, completed: usize, total: usize },
    /// the run's [`CancelToken`] was explicitly cancelled (client gone,
    /// queue shed, shutdown); carries the partial progress at the check
    /// point.
    Cancelled { cycle: u64, completed: usize, total: usize },
    /// a sharded run made zero progress for a full watchdog window —
    /// no node completed anywhere and no boundary value moved — with
    /// work still outstanding: a boundary livelock (e.g. a dropped
    /// channel). Fails fast instead of spinning to `max_cycles`;
    /// `stuck_shard` is the lowest incomplete shard and `waiting` its
    /// feeding channels' `src→dst` shard pairs.
    ShardStalled {
        epoch: u64,
        cycle: u64,
        completed: usize,
        total: usize,
        stuck_shard: usize,
        waiting: Vec<(usize, usize)>,
    },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::CycleLimitExceeded { cycle, completed, total } => write!(
                f,
                "cycle limit hit at {cycle}: {completed}/{total} nodes complete"
            ),
            SimError::CapacityExceeded { pe, words_needed, words_available } => write!(
                f,
                "PE {pe} needs {words_needed} BRAM words, has {words_available}"
            ),
            SimError::InvalidProgram { errors } => write!(
                f,
                "program failed verification with {errors} error diagnostic(s); \
                 run `tdp check` for the report"
            ),
            SimError::DeadlineExceeded { cycle, completed, total } => write!(
                f,
                "deadline exceeded at cycle {cycle}: {completed}/{total} nodes complete"
            ),
            SimError::Cancelled { cycle, completed, total } => write!(
                f,
                "cancelled at cycle {cycle}: {completed}/{total} nodes complete"
            ),
            SimError::ShardStalled { epoch, cycle, completed, total, stuck_shard, waiting } => {
                write!(
                    f,
                    "sharded run stalled: zero progress through epoch {epoch} (cycle {cycle}, \
                     {completed}/{total} nodes complete); shard {stuck_shard} is stuck waiting \
                     on boundary channel(s) {waiting:?}"
                )
            }
        }
    }
}

impl std::error::Error for SimError {}

/// The per-PE BRAM budget check (no-op unless `cfg.enforce_capacity`),
/// shared by the compile phase ([`crate::program::Program::compile`])
/// and direct simulator construction — one implementation, so the
/// compile-time and runtime capacity verdicts (and their error fields)
/// can never diverge.
pub(crate) fn check_capacity(
    g: &DataflowGraph,
    place: &Placement,
    cfg: &OverlayConfig,
) -> Result<(), SimError> {
    check_capacity_counts(
        place.nodes_of.iter().map(|locals| {
            let nodes = locals.len();
            let edges: usize = locals.iter().map(|&n| g.node(n).fanout.len()).sum();
            (nodes, edges)
        }),
        cfg,
    )
}

/// The counts core of [`check_capacity`], shared with the baked-table
/// view ([`RuntimeTables::pe_counts`]) — one budget comparison, whatever
/// the source of the per-PE `(nodes, edges)` counts.
pub(crate) fn check_capacity_counts(
    counts: impl IntoIterator<Item = (usize, usize)>,
    cfg: &OverlayConfig,
) -> Result<(), SimError> {
    if !cfg.enforce_capacity {
        return Ok(());
    }
    let budget = cfg.bram.graph_words(cfg.scheduler);
    for (pe, (nodes, edges)) in counts.into_iter().enumerate() {
        let need = BramConfig::words_used(nodes, edges);
        if need > budget {
            return Err(SimError::CapacityExceeded {
                pe,
                words_needed: need,
                words_available: budget,
            });
        }
    }
    Ok(())
}

struct PeUnit {
    sched: Scheduler,
    alu: AluPipeline,
    pg: PacketGen,
    /// BRAM virtual-port arbiter (multipump model, §II-C)
    ports: PortArbiter,
    /// skid buffer between the scheduling unit and packet-gen
    next_node: Option<u32>,
    /// in-flight scheduling pass completes at this cycle
    pick_done_at: Option<u64>,
    busy_cycles: u64,
    /// packets this PE consumed off the network (operand deliveries)
    ejects: u64,
}

/// The overlay simulator for one (graph, placement, config) instance.
///
/// All hot-loop inputs live in the baked [`RuntimeTables`], held behind
/// an [`Arc`] so a compiled [`crate::program::Program`] can hand the
/// same image to any number of concurrent sessions without re-placing
/// (or even re-flattening) the graph; the one-shot constructors bake a
/// private copy from their freshly built placement.
pub struct Simulator<'g> {
    g: &'g DataflowGraph,
    tables: Arc<RuntimeTables>,
    cfg: OverlayConfig,
    net: Network,
    pes: Vec<PeUnit>,
    // flat per-node state, indexed by *dense id* (pe-major local order)
    value: Vec<f32>,
    operand: Vec<[f32; 2]>,
    arrived: Vec<u8>,
    computed: Vec<bool>,
    /// graph-node-id mirror of `value`, written once per node at seed /
    /// fire time — keeps [`Simulator::values`] (and the engine parity
    /// contract) in node-id order without permuting on the hot path
    value_global: Vec<f32>,
    completed: usize,
    cycle: u64,
    inject_req: Vec<Option<Packet>>,
    /// PEs with `inject_req` set, i.e. exactly the `Some` slots — handed
    /// to [`Network::step_sparse`] so neither side scans the fabric
    injectors: Vec<u32>,
    // per-cycle network-result copies (preallocated; the network's own
    // StepResult buffers are reused and cannot be borrowed across the
    // PE-update phase). Only slots of PEs with a delivery / an injection
    // are written, and they are consumed the same cycle.
    eject_buf: Vec<Option<Packet>>,
    grant_buf: Vec<bool>,
    /// The active-PE worklist: exactly the PEs that can do anything —
    /// ready or claimed nodes, an in-flight scheduling pass, ALU
    /// occupancy, or a draining packet-gen unit. The per-cycle PE update
    /// visits only these (plus PEs receiving a packet, which join here);
    /// a fully idle PE costs nothing.
    active: Vec<u32>,
    /// membership flags for `active` (index = PE)
    is_active: Vec<bool>,
    /// PEs whose packet-gen unit is mid-drain (O(1) quiescence check for
    /// the skip-ahead engine; every Draining PE injects or stalls each
    /// cycle, so `draining_pes == 0` ⟺ no injection requests pending).
    draining_pes: usize,
    trace: Option<Trace>,
    /// Cooperative cancellation / deadline handle, polled every
    /// [`CANCEL_CHECK_INTERVAL`] cycles by the run loops (`None` = the
    /// checks compile down to a skipped branch).
    cancel: Option<CancelToken>,
    /// Deferred-seed inputs (sharded execution's boundary proxies):
    /// graph node id → indices into `tables.seeds` left unseeded at
    /// construction, waiting for [`Simulator::inject_value`]. Holds every
    /// replica of a deferred input, so one injection seeds them all.
    deferred: std::collections::BTreeMap<u32, Vec<usize>>,
}

impl<'g> Simulator<'g> {
    /// Build a simulator; places the graph according to `cfg` (on the
    /// overlay's actual torus geometry, so geometry-aware policies like
    /// [`crate::place::PlacementPolicy::TrafficAware`] see the real
    /// shape).
    pub fn new(g: &'g DataflowGraph, cfg: OverlayConfig) -> Result<Self, SimError> {
        let place = Placement::build_for_torus(
            g,
            cfg.cols,
            cfg.rows,
            cfg.placement,
            cfg.local_order,
            cfg.seed,
            None,
        );
        Self::with_placement(g, place, cfg)
    }

    /// Build with an explicit placement (tests, ablations).
    pub fn with_placement(
        g: &'g DataflowGraph,
        place: Placement,
        cfg: OverlayConfig,
    ) -> Result<Self, SimError> {
        Self::with_shared_placement(g, Arc::new(place), cfg)
    }

    /// Build over an already-compiled, shared placement — the
    /// compile-once path ([`crate::program::Session`]): no placement or
    /// labeling work happens here, only per-PE unit construction.
    pub fn with_shared_placement(
        g: &'g DataflowGraph,
        place: Arc<Placement>,
        cfg: OverlayConfig,
    ) -> Result<Self, SimError> {
        Self::with_scheduler_factory_shared(g, place, cfg, |kind, num_local| {
            Scheduler::new(kind, num_local, None)
        })
    }

    /// Build with a custom scheduler constructor — the ablation hook
    /// (e.g. `sched::{LifoSched, RandomSched}` in `sched_micro`).
    pub fn with_scheduler_factory<F>(
        g: &'g DataflowGraph,
        place: Placement,
        cfg: OverlayConfig,
        factory: F,
    ) -> Result<Self, SimError>
    where
        F: Fn(SchedulerKind, usize) -> Scheduler,
    {
        Self::with_scheduler_factory_shared(g, Arc::new(place), cfg, factory)
    }

    /// [`Simulator::with_scheduler_factory`] over a shared placement.
    /// Bakes a private [`RuntimeTables`] image from the placement; the
    /// compile-once path ([`Simulator::with_tables_and_factory`]) hands
    /// the image in instead and skips the flattening.
    pub fn with_scheduler_factory_shared<F>(
        g: &'g DataflowGraph,
        place: Arc<Placement>,
        cfg: OverlayConfig,
        factory: F,
    ) -> Result<Self, SimError>
    where
        F: Fn(SchedulerKind, usize) -> Scheduler,
    {
        assert_eq!(place.num_pes, cfg.num_pes());
        let tables = RuntimeTables::build_shared(g, &place, cfg.cols, cfg.rows);
        Self::with_tables_and_factory(g, tables, cfg, factory)
    }

    /// Build over a baked runtime image (the
    /// [`crate::program::Session`] execution path — no placement,
    /// labeling or flattening work here) at the default scheduler.
    pub fn with_tables(
        g: &'g DataflowGraph,
        tables: Arc<RuntimeTables>,
        cfg: OverlayConfig,
    ) -> Result<Self, SimError> {
        Self::with_tables_and_factory(g, tables, cfg, |kind, num_local| {
            Scheduler::new(kind, num_local, None)
        })
    }

    /// [`Simulator::with_tables`] with a custom scheduler constructor
    /// (ablations over a compiled artifact).
    pub fn with_tables_and_factory<F>(
        g: &'g DataflowGraph,
        tables: Arc<RuntimeTables>,
        cfg: OverlayConfig,
        factory: F,
    ) -> Result<Self, SimError>
    where
        F: Fn(SchedulerKind, usize) -> Scheduler,
    {
        Self::with_tables_factory_deferred(g, tables, cfg, factory, &[])
    }

    /// [`Simulator::with_tables`] with some inputs left unseeded: the
    /// graph node ids in `deferred` (sharded execution's boundary
    /// proxies) hold no token until [`Simulator::inject_value`] delivers
    /// one. Ids not present in the seed table are ignored.
    pub fn with_tables_deferred(
        g: &'g DataflowGraph,
        tables: Arc<RuntimeTables>,
        cfg: OverlayConfig,
        deferred: &[u32],
    ) -> Result<Self, SimError> {
        Self::with_tables_factory_deferred(
            g,
            tables,
            cfg,
            |kind, num_local| Scheduler::new(kind, num_local, None),
            deferred,
        )
    }

    fn with_tables_factory_deferred<F>(
        g: &'g DataflowGraph,
        tables: Arc<RuntimeTables>,
        cfg: OverlayConfig,
        factory: F,
        deferred: &[u32],
    ) -> Result<Self, SimError>
    where
        F: Fn(SchedulerKind, usize) -> Scheduler,
    {
        assert_eq!(tables.num_pes, cfg.num_pes());
        assert_eq!(tables.cols, cfg.cols, "tables baked for another torus shape");
        assert_eq!(tables.len(), g.len(), "tables baked for another graph");
        tables.check_capacity(&cfg)?;
        let n = tables.len();
        let tables_values_len = tables.values_len;
        let num_pes = cfg.num_pes();
        let pes = (0..num_pes)
            .map(|pe| PeUnit {
                sched: factory(cfg.scheduler, tables.local_count(pe)),
                alu: AluPipeline::new(cfg.alu_latency),
                pg: PacketGen::new(),
                ports: PortArbiter::new(cfg.bram.ports_per_cycle() as u32),
                next_node: None,
                pick_done_at: None,
                busy_cycles: 0,
                ejects: 0,
            })
            .collect();
        let mut sim = Self {
            g,
            tables,
            cfg,
            net: Network::new(cfg.cols, cfg.rows),
            pes,
            value: vec![0f32; n],
            operand: vec![[0f32; 2]; n],
            arrived: vec![0u8; n],
            computed: vec![false; n],
            // sized by the *external* id domain: the original graph's
            // node count when the tables were baked remapped
            value_global: vec![0f32; tables_values_len],
            completed: 0,
            cycle: 0,
            inject_req: vec![None; num_pes],
            injectors: Vec::new(),
            eject_buf: vec![None; num_pes],
            grant_buf: vec![false; num_pes],
            active: Vec::new(),
            is_active: vec![false; num_pes],
            draining_pes: 0,
            trace: None,
            cancel: None,
            deferred: std::collections::BTreeMap::new(),
        };
        for (i, s) in sim.tables.seeds.iter().enumerate() {
            if deferred.contains(&s.global) {
                sim.deferred.entry(s.global).or_default().push(i);
            }
        }
        sim.seed_inputs();
        Ok(sim)
    }

    /// Inputs hold their token at cycle 0: value set, flagged ready for
    /// fanout processing (which puts their PEs on the active worklist).
    /// The baked seed list is in graph node-id order — the order inputs
    /// have always been marked ready in, which in-order FIFOs observe.
    fn seed_inputs(&mut self) {
        let tables = Arc::clone(&self.tables);
        for s in &tables.seeds {
            if self.deferred.contains_key(&s.global) {
                continue; // awaits inject_value
            }
            self.value[s.dense as usize] = s.value;
            self.value_global[s.global as usize] = s.value;
            self.computed[s.dense as usize] = true;
            let pe = s.pe as usize;
            self.pes[pe].sched.mark_ready(s.local);
            if !self.is_active[pe] {
                self.is_active[pe] = true;
                self.active.push(pe as u32);
            }
        }
    }

    /// Packet for fanout `edge` of dense node `dense`: one indexed load
    /// from the baked route table plus the payload write.
    #[inline]
    fn packet_for(&self, dense: usize, edge: u32) -> Packet {
        self.tables.packet(dense, edge, self.value[dense])
    }

    /// Record a [`Trace`] of overlay state every `stride` cycles.
    pub fn enable_trace(&mut self, stride: u64) {
        self.trace = Some(Trace::new(stride));
    }

    /// The recorded trace, if tracing was enabled.
    pub fn trace(&self) -> Option<&Trace> {
        self.trace.as_ref()
    }

    /// Sample current overlay state (tracing). Walks only the active
    /// worklist — a PE off the list is fully idle by the eviction
    /// invariant (empty ready set, idle packet-gen, empty ALU), so it
    /// contributes zero to every series and skipping it is exact; the
    /// traced hot loop never pays a full-fabric scan.
    fn sample(&self) -> Sample {
        let mut ready_total = 0;
        let mut ready_max = 0;
        let mut busy = 0;
        for &pe in &self.active {
            let pe = &self.pes[pe as usize];
            let r = pe.sched.len();
            ready_total += r;
            ready_max = ready_max.max(r);
            if !pe.pg.is_idle() || !pe.alu.is_empty() {
                busy += 1;
            }
        }
        Sample {
            cycle: self.cycle,
            ready_total,
            ready_max,
            busy_pes: busy,
            in_flight: self.net.in_flight(),
            completed: self.completed,
        }
    }

    /// Advance one cycle. Returns true when the run is complete.
    ///
    /// Cost is proportional to *activity*, not fabric size: the network
    /// visits only routers with traffic, and the PE update walks the
    /// active worklist — a 16×16 overlay running a sequential chain pays
    /// for ~1 PE per cycle, not 256.
    pub(crate) fn step(&mut self) -> bool {
        // (1)+(2) network switches on this cycle's injection requests;
        // results are copied out sparsely (deliveries + injector grants)
        {
            let res = self.net.step_sparse(&self.inject_req, &self.injectors);
            for &pe in &res.ejected_pes {
                let pe = pe as usize;
                self.eject_buf[pe] = res.ejected[pe];
                // a delivery (re)activates the destination PE
                if !self.is_active[pe] {
                    self.is_active[pe] = true;
                    self.active.push(pe as u32);
                }
            }
            for &pe in &self.injectors {
                self.grant_buf[pe as usize] = res.inject_ok[pe as usize];
            }
        }
        self.injectors.clear();

        // (3)-(5) fused per active PE (stages only couple through the
        // network, which already switched, so per-PE order is free)
        let mut i = 0;
        while i < self.active.len() {
            let pe = self.active[i] as usize;
            self.step_pe(pe);
            let unit = &self.pes[pe];
            if unit.pg.state == PgState::Idle
                && unit.next_node.is_none()
                && unit.pick_done_at.is_none()
                && unit.alu.is_empty()
                && unit.sched.is_empty()
            {
                // fully idle: only a future delivery can wake this PE
                self.is_active[pe] = false;
                self.active.swap_remove(i);
            } else {
                i += 1;
            }
        }

        // take/restore the trace so sampling can borrow `self` freely —
        // no aliasing dance, no unwrap. The final cycle is always
        // sampled (guarded against a stride-aligned duplicate) so a run
        // shorter than the stride still records its end state.
        let done = self.is_complete();
        if let Some(mut trace) = self.trace.take() {
            if trace.due(self.cycle) || (done && trace.last_cycle() != Some(self.cycle)) {
                trace.push(self.sample());
            }
            self.trace = Some(trace);
        }
        self.cycle += 1;
        done
    }

    /// One cycle of one PE: stages (3) eject consume, (4) ALU retire,
    /// (5) packet-gen — identical semantics to the former per-stage
    /// all-PE sweeps. Every per-node read is an indexed load off the
    /// baked tables at `base + local`; no `graph::Node` is touched and
    /// no address is derived by div/mod.
    fn step_pe(&mut self, pe: usize) {
        let base = self.tables.pe_base[pe];
        // (3) consume the ejected packet: operand store -> firing -> issue
        self.pes[pe].ports.reset();
        if let Some(pkt) = self.eject_buf[pe].take() {
            self.pes[pe].ejects += 1;
            // receive has top priority; budget >= 2 always grants it
            let granted = self.pes[pe].ports.request(Unit::Receive);
            debug_assert!(granted);
            let dense = (base + pkt.local_idx as u32) as usize;
            debug_assert!(!self.computed[dense], "operand for computed node");
            self.operand[dense][pkt.slot as usize] = pkt.payload;
            self.arrived[dense] += 1;
            if self.arrived[dense] == self.tables.arity[dense] {
                // dataflow firing rule satisfied: evaluate + issue
                let op = Op::from_code8(self.tables.op[dense]).expect("interior node");
                let v = op.eval(self.operand[dense][0], self.operand[dense][1]);
                self.value[dense] = v;
                self.value_global[self.tables.global_of[dense] as usize] = v;
                self.pes[pe].alu.issue(self.cycle, pkt.local_idx as u32);
            }
        }

        // (4) ALU retirements: writeback + RDY flag (one writeback port
        // request per result; with the paper's 2x multipump this never
        // stalls, without it results wait for a free port)
        {
            let unit = &mut self.pes[pe];
            while unit.alu.front_due(self.cycle) {
                if !unit.ports.request(Unit::Writeback) {
                    break; // retry next cycle
                }
                let local = unit.alu.pop_due(self.cycle).unwrap();
                unit.sched.mark_ready(local);
                self.computed[(base + local) as usize] = true;
            }
        }

        // (5) packet-gen state machine + next cycle's injection request
        let granted = self.grant_buf[pe];
        // resolve last cycle's drain first
        if let PgState::Draining { local_idx, edge } = self.pes[pe].pg.state {
            if self.inject_req[pe].is_some() {
                if granted {
                    let next = edge + 1;
                    self.pes[pe].pg.busy_cycles += 1;
                    if next == self.tables.route_len((base + local_idx) as usize) {
                        self.pes[pe].sched.fanout_done(local_idx);
                        self.completed += 1;
                        self.pes[pe].pg.state = PgState::Idle;
                        self.draining_pes -= 1;
                    } else {
                        self.pes[pe].pg.state = PgState::Draining {
                            local_idx,
                            edge: next,
                        };
                    }
                } else {
                    self.pes[pe].pg.stall_cycles += 1;
                }
            }
        }
        self.inject_req[pe] = None;

        // Scheduling unit — runs *concurrently* with the drain
        // pipeline (in hardware the LOD/FIFO pop overlaps packet
        // generation; the claimed node waits in a 1-entry skid
        // buffer). Pick latency is only exposed when the PE is idle.
        if self.pes[pe].next_node.is_none() {
            match self.pes[pe].pick_done_at {
                None => {
                    if !self.pes[pe].sched.is_empty() {
                        let done = self.pes[pe].sched.pick_completion(self.cycle);
                        self.pes[pe].pick_done_at = Some(done);
                    }
                }
                Some(done_at) if self.cycle >= done_at => {
                    self.pes[pe].pick_done_at = None;
                    if let Some(local) = self.pes[pe].sched.take() {
                        self.pes[pe].pg.picks += 1;
                        self.pes[pe].next_node = Some(local);
                    }
                }
                Some(_) => {}
            }
        }

        // Packet-gen unit: when idle, adopt the claimed node.
        if self.pes[pe].pg.state == PgState::Idle {
            if let Some(local) = self.pes[pe].next_node.take() {
                if self.tables.route_len((base + local) as usize) == 0 {
                    // sink: nothing to send
                    self.pes[pe].sched.fanout_done(local);
                    self.completed += 1;
                } else {
                    self.pes[pe].pg.state = PgState::Draining {
                        local_idx: local,
                        edge: 0,
                    };
                    self.draining_pes += 1;
                }
            }
        }

        // emit this cycle's injection request (needs a fanout-edge
        // read port; stalls without multipumping when receive is hot)
        if let PgState::Draining { local_idx, edge } = self.pes[pe].pg.state {
            if self.pes[pe].ports.request(Unit::PacketGen) {
                self.inject_req[pe] = Some(self.packet_for((base + local_idx) as usize, edge));
                self.injectors.push(pe as u32);
            } else {
                self.pes[pe].pg.stall_cycles += 1;
            }
        }

        // utilization accounting
        if !self.pes[pe].pg.is_idle() || !self.pes[pe].alu.is_empty() {
            self.pes[pe].busy_cycles += 1;
        }
    }

    /// Every node completed its fanout and the overlay has fully drained.
    /// (`injectors` lists exactly the pending `inject_req` slots, so the
    /// emptiness check is O(1), not an O(num_pes) scan.)
    pub(crate) fn is_complete(&self) -> bool {
        self.completed == self.g.len() && self.net.is_empty() && self.injectors.is_empty()
    }

    /// Nothing can change overlay state until a scheduled event fires: no
    /// packets in flight (deflection routing makes in-flight cycles
    /// irreducible), no packet-gen unit mid-drain (a Draining PE injects
    /// or stalls every cycle), and no tracing (samples are per-cycle
    /// observations). The skip-ahead engine's O(1) gate.
    pub(crate) fn quiescent(&self) -> bool {
        self.net.is_empty() && self.draining_pes == 0 && self.trace.is_none()
    }

    /// Earliest cycle at which a scheduled event fires: an ALU retirement
    /// (writeback → RDY flag) or a scheduling-pass completion. Returns
    /// `Some(self.cycle)` when work is already actionable this cycle —
    /// ready nodes with no pass started, or a claimed node awaiting
    /// adoption — and `None` when nothing is pending at all (a quiescent
    /// `None` with the graph incomplete is a livelock).
    pub(crate) fn next_event_cycle(&self) -> Option<u64> {
        // only active PEs can hold a pending event: an idle PE has an
        // empty ALU, an empty ready set, no pass in flight and no
        // claimed node (that is what evicted it from the worklist)
        let mut next: Option<u64> = None;
        for &pe in &self.active {
            let unit = &self.pes[pe as usize];
            if (unit.next_node.is_some() && unit.pg.is_idle())
                || (unit.pick_done_at.is_none() && !unit.sched.is_empty())
            {
                return Some(self.cycle);
            }
            for cand in [unit.alu.next_retire_cycle(), unit.pick_done_at] {
                if let Some(c) = cand {
                    next = Some(next.map_or(c, |n| n.min(c)));
                }
            }
        }
        next
    }

    /// Jump the clock across a quiescent region to `target`, applying the
    /// per-cycle accounting the skipped lockstep steps would have done —
    /// while quiescent the only live counter is PE busy time (a PE with
    /// results in its ALU pipeline counts as busy every cycle). The
    /// network's internal clock is not advanced: it is only ever used for
    /// latency deltas within a single routing episode, and no packet
    /// exists across a quiescent region.
    pub(crate) fn jump_to(&mut self, target: u64) {
        debug_assert!(self.quiescent(), "jump through non-quiescent state");
        let delta = target.saturating_sub(self.cycle);
        if delta == 0 {
            return;
        }
        // only active PEs can hold ALU results (idle ⟹ empty pipeline)
        for &pe in &self.active {
            let unit = &mut self.pes[pe as usize];
            if !unit.alu.is_empty() {
                unit.busy_cycles += delta;
            }
        }
        self.cycle = target;
    }

    /// Nodes whose fanout processing has completed.
    pub(crate) fn completed_nodes(&self) -> usize {
        self.completed
    }

    pub(crate) fn total_nodes(&self) -> usize {
        self.g.len()
    }

    pub(crate) fn max_cycles(&self) -> u64 {
        self.cfg.max_cycles
    }

    /// Attach a cooperative cancellation / deadline token, polled every
    /// [`CANCEL_CHECK_INTERVAL`] cycles by [`Simulator::run`] /
    /// [`Simulator::run_until`] (and, through the shared token, by the
    /// skip-ahead engine's own loops).
    pub fn set_cancel(&mut self, token: CancelToken) {
        self.cancel = Some(token);
    }

    /// The attached cancellation token, if any.
    pub fn cancel_token(&self) -> Option<&CancelToken> {
        self.cancel.as_ref()
    }

    /// The typed early-stop error for `cause` at the current progress —
    /// one construction site shared by both engines so the partial
    /// stats they report can never diverge.
    pub(crate) fn cancel_error(&self, cause: CancelCause) -> SimError {
        match cause {
            CancelCause::Deadline => SimError::DeadlineExceeded {
                cycle: self.cycle,
                completed: self.completed,
                total: self.g.len(),
            },
            CancelCause::Cancelled => SimError::Cancelled {
                cycle: self.cycle,
                completed: self.completed,
                total: self.g.len(),
            },
        }
    }

    /// Poll the cancel token if the cycle counter is on a check
    /// boundary. One mask + branch per cycle when no token is attached.
    #[inline]
    fn check_cancel(&self) -> Option<SimError> {
        if self.cycle & (CANCEL_CHECK_INTERVAL - 1) != 0 {
            return None;
        }
        let cause = self.cancel.as_ref()?.fired()?;
        Some(self.cancel_error(cause))
    }

    /// Run to completion.
    pub fn run(&mut self) -> Result<SimStats, SimError> {
        // entry poll: a token that fired before the run started (an
        // already-expired deadline, an injected overrun) must stop the
        // run deterministically even when the whole graph would finish
        // inside one check interval
        if let Some(cause) = self.cancel.as_ref().and_then(CancelToken::fired) {
            return Err(self.cancel_error(cause));
        }
        while !self.step() {
            if self.cycle >= self.cfg.max_cycles {
                return Err(SimError::CycleLimitExceeded {
                    cycle: self.cycle,
                    completed: self.completed,
                    total: self.g.len(),
                });
            }
            if let Some(e) = self.check_cancel() {
                return Err(e);
            }
        }
        Ok(self.stats())
    }

    /// Run until the graph completes (`Ok(true)`) or the clock reaches
    /// `bound` (`Ok(false)`) — the sharded runtime's epoch slice. The
    /// step/limit-check order matches [`Simulator::run`] exactly, so a
    /// run chopped into epochs is cycle- and error-identical to an
    /// unchopped one.
    pub fn run_until(&mut self, bound: u64) -> Result<bool, SimError> {
        if self.is_complete() {
            return Ok(true);
        }
        // same entry poll as `run` (the epoch runner also re-checks at
        // every barrier, so the two paths agree on pre-fired tokens)
        if let Some(cause) = self.cancel.as_ref().and_then(CancelToken::fired) {
            return Err(self.cancel_error(cause));
        }
        while self.cycle < bound {
            if self.step() {
                return Ok(true);
            }
            if self.cycle >= self.cfg.max_cycles {
                return Err(SimError::CycleLimitExceeded {
                    cycle: self.cycle,
                    completed: self.completed,
                    total: self.g.len(),
                });
            }
            if let Some(e) = self.check_cancel() {
                return Err(e);
            }
        }
        Ok(false)
    }

    /// Deliver a token to a deferred-seed input (sharded execution's
    /// boundary injection): seeds every replica of graph node `global` —
    /// value written, flagged ready, PE activated — exactly as
    /// `seed_inputs` would have at cycle 0, but at the current cycle.
    /// No-op unless `global` was deferred at construction and not yet
    /// injected.
    pub fn inject_value(&mut self, global: u32, value: f32) {
        let Some(idxs) = self.deferred.remove(&global) else {
            return;
        };
        let tables = Arc::clone(&self.tables);
        for i in idxs {
            let s = &tables.seeds[i];
            self.value[s.dense as usize] = value;
            self.value_global[s.global as usize] = value;
            self.computed[s.dense as usize] = true;
            let pe = s.pe as usize;
            self.pes[pe].sched.mark_ready(s.local);
            if !self.is_active[pe] {
                self.is_active[pe] = true;
                self.active.push(pe as u32);
            }
        }
    }

    /// Has graph node `global` produced its value? (True from seed /
    /// injection / ALU-retire time on; the boundary-harvest predicate of
    /// the sharded runtime.)
    pub fn node_computed(&self, global: u32) -> bool {
        let dense = self.tables.dense_of[global as usize];
        dense != u32::MAX && self.computed[dense as usize]
    }

    /// Final (or current) node values in graph node-id order — validated
    /// against the PJRT `graph_eval` artifact and
    /// `DataflowGraph::evaluate`. (Internally state is dense-indexed;
    /// this is the node-id mirror maintained at seed / fire time.)
    pub fn values(&self) -> &[f32] {
        &self.value_global
    }

    pub fn all_computed(&self) -> bool {
        self.computed.iter().all(|&c| c)
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Collect statistics.
    pub fn stats(&self) -> SimStats {
        let pe_stats: Vec<PeStats> = self
            .pes
            .iter()
            .map(|p| PeStats {
                busy_cycles: p.busy_cycles,
                alu_ops: p.alu.issued,
                ejects: p.ejects,
                picks: p.pg.picks,
                pg_busy: p.pg.busy_cycles,
                pg_stalls: p.pg.stall_cycles,
                port_stalls: p.ports.stalls.iter().sum(),
                max_ready: p.sched.max_occupancy(),
                sched_mem_words: p.sched.mem_overhead_words(),
                fifo_overflows: p.sched.overflows(),
            })
            .collect();
        SimStats::collect(
            self.cycle,
            self.g.len(),
            self.completed,
            self.cfg.scheduler,
            self.net.stats,
            pe_stats,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;
    use crate::workload::{layered_random, lu_factorization_graph, reduction_tree, SparseMatrix};

    fn run_graph(g: &DataflowGraph, cfg: OverlayConfig) -> (SimStats, Vec<f32>) {
        let mut sim = Simulator::new(g, cfg).unwrap();
        let stats = sim.run().unwrap();
        (stats, sim.values().to_vec())
    }

    fn check_values(g: &DataflowGraph, got: &[f32]) {
        let want = g.evaluate();
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a == b) || (a.is_nan() && b.is_nan()),
                "node {i}: sim={a}, ref={b}"
            );
        }
    }

    #[test]
    fn single_add_on_1x1() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(2.0);
        let b = g.add_input(3.0);
        g.op(Op::Add, &[a, b]);
        let cfg = OverlayConfig::paper_1x1();
        let (stats, vals) = run_graph(&g, cfg);
        assert_eq!(vals[2], 5.0);
        assert!(stats.cycles > 0);
        assert_eq!(stats.completed, 3);
    }

    #[test]
    fn diamond_both_schedulers_same_values() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(3.0);
        let b = g.add_input(4.0);
        let s = g.op(Op::Add, &[a, b]);
        let p = g.op(Op::Mul, &[a, b]);
        g.op(Op::Div, &[s, p]);
        for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
            let cfg = OverlayConfig::paper_1x1().with_scheduler(kind);
            let (_, vals) = run_graph(&g, cfg);
            check_values(&g, &vals);
        }
    }

    #[test]
    fn layered_graph_multi_pe_matches_reference() {
        let g = layered_random(16, 8, 24, 2, 3);
        for (cols, rows) in [(1, 1), (2, 2), (4, 4), (5, 3)] {
            for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
                let cfg = OverlayConfig::default()
                    .with_dims(cols, rows)
                    .with_scheduler(kind);
                let (stats, vals) = run_graph(&g, cfg);
                check_values(&g, &vals);
                assert_eq!(stats.completed, g.len());
            }
        }
    }

    #[test]
    fn lu_graph_simulates_correctly() {
        let m = SparseMatrix::banded(24, 3, 0.9, 7);
        let (g, _) = lu_factorization_graph(&m);
        let cfg = OverlayConfig::default().with_dims(4, 4);
        let (stats, vals) = run_graph(&g, cfg);
        check_values(&g, &vals);
        assert!(stats.net.delivered > 0);
    }

    #[test]
    fn reduction_tree_completes() {
        let g = reduction_tree(64, Op::Add, 1);
        let cfg = OverlayConfig::default().with_dims(3, 3);
        let (stats, vals) = run_graph(&g, cfg);
        check_values(&g, &vals);
        assert_eq!(stats.total_nodes, g.len());
    }

    #[test]
    fn unary_chain_via_network() {
        let mut g = DataflowGraph::new();
        let mut prev = g.add_input(1.5);
        for _ in 0..10 {
            prev = g.op(Op::Neg, &[prev]);
        }
        let cfg = OverlayConfig::default().with_dims(2, 2);
        let (_, vals) = run_graph(&g, cfg);
        check_values(&g, &vals);
        assert_eq!(vals[10], 1.5 * (-1f32).powi(10));
    }

    #[test]
    fn same_source_both_operands() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(3.0);
        let sq = g.op(Op::Mul, &[a, a]);
        g.op(Op::Add, &[sq, a]);
        let (_, vals) = run_graph(&g, OverlayConfig::paper_1x1());
        assert_eq!(vals[1], 9.0);
        assert_eq!(vals[2], 12.0);
    }

    #[test]
    fn cycle_limit_error_reported() {
        let g = layered_random(8, 4, 8, 1, 0);
        let mut cfg = OverlayConfig::default().with_dims(2, 2);
        cfg.max_cycles = 3;
        let mut sim = Simulator::new(&g, cfg).unwrap();
        match sim.run() {
            Err(SimError::CycleLimitExceeded { cycle, .. }) => assert_eq!(cycle, 3),
            other => panic!("expected cycle limit, got {other:?}"),
        }
    }

    #[test]
    fn capacity_enforcement() {
        let g = layered_random(64, 32, 128, 2, 0); // ~4K nodes on 1 PE
        let mut cfg = OverlayConfig::paper_1x1();
        cfg.enforce_capacity = true;
        match Simulator::new(&g, cfg) {
            Err(SimError::CapacityExceeded { words_needed, words_available, .. }) => {
                assert!(words_needed > words_available);
            }
            other => panic!("expected capacity error, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn ooo_not_slower_than_inorder_on_wide_graphs() {
        // a wide, shallow graph with skewed criticality: OoO should win
        // (or at least tie) once ready queues form.
        let m = SparseMatrix::banded(80, 4, 0.9, 5);
        let (g, _) = lu_factorization_graph(&m);
        let base = OverlayConfig::default().with_dims(4, 4);
        let (s_in, _) = run_graph(&g, base.with_scheduler(SchedulerKind::InOrder));
        let (s_ooo, _) = run_graph(&g, base.with_scheduler(SchedulerKind::OutOfOrder));
        assert!(
            (s_ooo.cycles as f64) <= 1.10 * s_in.cycles as f64,
            "OoO {} vs in-order {}",
            s_ooo.cycles,
            s_in.cycles
        );
    }

    #[test]
    fn quiescence_hooks_after_completion() {
        let g = layered_random(8, 4, 12, 2, 3);
        let cfg = OverlayConfig::default().with_dims(2, 2);
        let mut sim = Simulator::new(&g, cfg).unwrap();
        sim.run().unwrap();
        assert_eq!(sim.draining_pes, 0, "drain counter must return to zero");
        assert!(sim.quiescent());
        assert!(sim.is_complete());
        assert_eq!(sim.next_event_cycle(), None, "no events after completion");
    }

    #[test]
    fn initial_state_has_actionable_event() {
        let g = layered_random(4, 2, 4, 1, 0);
        let cfg = OverlayConfig::default().with_dims(2, 2);
        let sim = Simulator::new(&g, cfg).unwrap();
        // inputs are seeded ready with no pick started: the horizon must
        // report "actionable now" so skip-ahead never jumps past cycle 0
        assert_eq!(sim.next_event_cycle(), Some(0));
    }

    #[test]
    fn stats_consistency() {
        let g = layered_random(8, 6, 12, 2, 2);
        let (stats, _) = run_graph(&g, OverlayConfig::default().with_dims(2, 2));
        assert_eq!(stats.completed, g.len());
        // every edge becomes exactly one delivered packet
        assert_eq!(stats.net.delivered as usize, g.num_edges());
        assert_eq!(stats.net.injected, stats.net.delivered);
        // ALU ops = interior nodes
        let alu_total: u64 = stats.pe.iter().map(|p| p.alu_ops).sum();
        assert_eq!(alu_total as usize, g.len() - g.num_inputs());
        // picks = nodes: each node is marked ready exactly once (inputs
        // at seed time, interiors at their single writeback), and a
        // ready node is claimed by exactly one completed pass
        let picks: u64 = stats.pe.iter().map(|p| p.picks).sum();
        assert_eq!(picks as usize, g.len());
        // every delivered packet is consumed by exactly one PE
        let ejects: u64 = stats.pe.iter().map(|p| p.ejects).sum();
        assert_eq!(ejects, stats.net.delivered);
    }

    /// Regression (satellite): a run shorter than the sampling stride
    /// used to record nothing — the final cycle must always be sampled,
    /// without duplicating a stride-aligned last sample.
    #[test]
    fn trace_samples_final_cycle_even_when_stride_exceeds_run() {
        let g = layered_random(8, 4, 12, 2, 3);
        let cfg = OverlayConfig::default().with_dims(2, 2);

        // stride far beyond the run length: exactly cycle 0 + final cycle
        let mut sim = Simulator::new(&g, cfg).unwrap();
        sim.enable_trace(1_000_000);
        let stats = sim.run().unwrap();
        let trace = sim.trace().unwrap();
        assert_eq!(trace.samples.len(), 2, "cycle 0 and the final cycle");
        assert_eq!(trace.last_cycle(), Some(stats.cycles - 1));
        assert_eq!(trace.samples.last().unwrap().completed, g.len());

        // stride 1 samples every cycle with no duplicate at the end
        let mut sim = Simulator::new(&g, cfg).unwrap();
        sim.enable_trace(1);
        let stats = sim.run().unwrap();
        let trace = sim.trace().unwrap();
        assert_eq!(trace.samples.len() as u64, stats.cycles);
        let cycles: Vec<u64> = trace.samples.iter().map(|s| s.cycle).collect();
        for w in cycles.windows(2) {
            assert!(w[0] < w[1], "strictly increasing sample cycles");
        }
    }

    /// `sample()` walks only the active worklist; this pins its claim
    /// of exactness by recomputing every sampled series with a
    /// full-fabric scan after each step — if the eviction invariant
    /// ever weakens (a PE leaving the worklist with a non-empty ready
    /// set, busy packet-gen or occupied ALU), the two diverge here.
    #[test]
    fn sample_active_only_matches_full_fabric_scan() {
        let g = layered_random(12, 5, 16, 2, 4);
        let cfg = OverlayConfig::default().with_dims(4, 4);
        let mut sim = Simulator::new(&g, cfg).unwrap();
        let mut steps = 0u64;
        loop {
            let done = sim.step();
            let s = sim.sample();
            let mut ready_total = 0;
            let mut ready_max = 0;
            let mut busy = 0;
            for pe in &sim.pes {
                let r = pe.sched.len();
                ready_total += r;
                ready_max = ready_max.max(r);
                if !pe.pg.is_idle() || !pe.alu.is_empty() {
                    busy += 1;
                }
            }
            assert_eq!(s.ready_total, ready_total, "cycle {}", sim.cycle);
            assert_eq!(s.ready_max, ready_max, "cycle {}", sim.cycle);
            assert_eq!(s.busy_pes, busy, "cycle {}", sim.cycle);
            steps += 1;
            if done || steps > 100_000 {
                break;
            }
        }
        assert!(sim.is_complete(), "run must finish within the step budget");
    }

    #[test]
    fn worklist_drains_with_completion() {
        let g = layered_random(12, 5, 16, 2, 4);
        let cfg = OverlayConfig::default().with_dims(4, 4);
        let mut sim = Simulator::new(&g, cfg).unwrap();
        sim.run().unwrap();
        assert!(
            sim.active.is_empty(),
            "all PEs must leave the worklist once idle"
        );
        assert!(sim.is_active.iter().all(|&a| !a));
        assert!(sim.injectors.is_empty());
    }

    /// An under-provisioned in-order ready FIFO must surface its
    /// overflow count through the full simulator into `SimStats` (the
    /// sizing-violation evidence the §III capacity argument rests on).
    #[test]
    fn bounded_fifo_overflows_surface_in_sim_stats() {
        use crate::sched::InOrderFifo;
        // wide and shallow on one PE: many nodes ready simultaneously
        let g = layered_random(16, 2, 24, 2, 8);
        let cfg = OverlayConfig::paper_1x1().with_scheduler(SchedulerKind::InOrder);
        let place = Placement::build(&g, 1, cfg.placement, cfg.local_order, cfg.seed);
        let mut sim = Simulator::with_scheduler_factory(&g, place, cfg, |_, num_local| {
            Scheduler::Fifo(InOrderFifo::new(num_local, Some(1)))
        })
        .unwrap();
        let stats = sim.run().unwrap();
        assert_eq!(stats.completed, g.len(), "overflowing FIFO still completes");
        assert!(
            stats.total_fifo_overflows > 0,
            "capacity-1 FIFO must overflow: {stats:?}"
        );
        assert_eq!(
            stats.total_fifo_overflows,
            stats.pe.iter().map(|p| p.fifo_overflows).sum::<u64>()
        );
        // the unbounded default never overflows on the same run
        let baseline = Simulator::new(&g, cfg).unwrap().run().unwrap();
        assert_eq!(baseline.total_fifo_overflows, 0);
    }

    /// The ablation schedulers run the full simulator too (the enum has
    /// `Lifo`/`Random` variants precisely so `sched_micro` can), and all
    /// pick orders compute identical values.
    #[test]
    fn ablation_schedulers_complete_through_simulator() {
        use crate::sched::{LifoSched, RandomSched};
        let g = layered_random(12, 4, 16, 2, 6);
        let cfg = OverlayConfig::default().with_dims(2, 2);
        for which in 0..2 {
            let place = Placement::build(&g, 4, cfg.placement, cfg.local_order, cfg.seed);
            let mut sim = Simulator::with_scheduler_factory(&g, place, cfg, move |_, n| {
                if which == 0 {
                    Scheduler::Lifo(LifoSched::new(n))
                } else {
                    Scheduler::Random(RandomSched::new(n, 42))
                }
            })
            .unwrap();
            let stats = sim.run().unwrap();
            assert_eq!(stats.completed, g.len());
            check_values(&g, sim.values());
        }
    }
}
