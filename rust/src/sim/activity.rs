//! Per-PE / per-router activity heatmaps (DESIGN.md §11).
//!
//! All counters here are folded into walks the simulator already does —
//! the active-PE worklist and the active-router set — so accounting
//! costs nothing on idle fabric and the report is a pure read-out at the
//! end of a run: `tdp analyze` renders the glyph grids, `--json-out`
//! emits [`ActivityReport::to_json_value`].

use super::Simulator;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// End-of-run spatial activity counters, every series indexed
/// `y * cols + x` (the torus/PE layout).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ActivityReport {
    pub cols: usize,
    pub rows: usize,
    pub cycles: u64,
    /// ALU issues per PE (interior-node firings)
    pub pe_firings: Vec<u64>,
    /// packets consumed off the network per PE
    pub pe_ejects: Vec<u64>,
    /// cycles with a non-idle packet-gen or occupied ALU, per PE
    pub pe_busy: Vec<u64>,
    /// packet-gen + BRAM-port stall cycles per PE
    pub pe_stalls: Vec<u64>,
    /// ready-queue occupancy high-water mark per PE
    pub pe_max_ready: Vec<u64>,
    /// packets switched per router (arrivals + accepted injections)
    pub router_traffic: Vec<u64>,
    /// deflections per router
    pub router_deflections: Vec<u64>,
}

const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

impl ActivityReport {
    fn series(&self) -> [(&'static str, &[u64]); 7] {
        [
            ("pe.firings", &self.pe_firings),
            ("pe.ejects", &self.pe_ejects),
            ("pe.busy_cycles", &self.pe_busy),
            ("pe.stalls", &self.pe_stalls),
            ("pe.max_ready", &self.pe_max_ready),
            ("router.traffic", &self.router_traffic),
            ("router.deflections", &self.router_deflections),
        ]
    }

    /// One series as a `rows × cols` glyph grid: `·` for zero, eight
    /// shade levels scaled to the series maximum otherwise.
    pub fn heatmap(&self, title: &str, series: &[u64]) -> String {
        debug_assert_eq!(series.len(), self.cols * self.rows);
        let max = series.iter().copied().max().unwrap_or(0);
        let total: u64 = series.iter().sum();
        let mut out = String::new();
        let _ = writeln!(out, "{title}  (max {max}, total {total})");
        for y in 0..self.rows {
            out.push_str("  ");
            for x in 0..self.cols {
                let v = series[y * self.cols + x];
                out.push(if v == 0 {
                    '·'
                } else {
                    GLYPHS[((v as u128 * (GLYPHS.len() as u128 - 1)) / max as u128) as usize]
                });
            }
            out.push('\n');
        }
        out
    }

    /// All heatmaps, one block per series.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, series) in self.series() {
            out.push_str(&self.heatmap(name, series));
        }
        out
    }

    /// Stable JSON document mirroring the heatmaps (flat arrays in
    /// `y * cols + x` order).
    pub fn to_json_value(&self) -> Json {
        fn arr(v: &[u64]) -> Json {
            Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
        }
        let mut pe = BTreeMap::new();
        pe.insert("firings".to_string(), arr(&self.pe_firings));
        pe.insert("ejects".to_string(), arr(&self.pe_ejects));
        pe.insert("busy_cycles".to_string(), arr(&self.pe_busy));
        pe.insert("stalls".to_string(), arr(&self.pe_stalls));
        pe.insert("max_ready".to_string(), arr(&self.pe_max_ready));
        let mut router = BTreeMap::new();
        router.insert("traffic".to_string(), arr(&self.router_traffic));
        router.insert("deflections".to_string(), arr(&self.router_deflections));
        let mut m = BTreeMap::new();
        m.insert("cols".to_string(), Json::Num(self.cols as f64));
        m.insert("rows".to_string(), Json::Num(self.rows as f64));
        m.insert("cycles".to_string(), Json::Num(self.cycles as f64));
        m.insert("pe".to_string(), Json::Obj(pe));
        m.insert("router".to_string(), Json::Obj(router));
        Json::Obj(m)
    }
}

impl<'g> Simulator<'g> {
    /// Snapshot the spatial activity counters (any time; typically after
    /// [`Simulator::run`]).
    pub fn activity(&self) -> ActivityReport {
        ActivityReport {
            cols: self.cfg.cols,
            rows: self.cfg.rows,
            cycles: self.cycle,
            pe_firings: self.pes.iter().map(|p| p.alu.issued).collect(),
            pe_ejects: self.pes.iter().map(|p| p.ejects).collect(),
            pe_busy: self.pes.iter().map(|p| p.busy_cycles).collect(),
            pe_stalls: self
                .pes
                .iter()
                .map(|p| p.pg.stall_cycles + p.ports.stalls.iter().sum::<u64>())
                .collect(),
            pe_max_ready: self
                .pes
                .iter()
                .map(|p| p.sched.max_occupancy() as u64)
                .collect(),
            router_traffic: self.net.router_traffic().to_vec(),
            router_deflections: self.net.router_deflections().to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OverlayConfig;
    use crate::workload::layered_random;

    fn report() -> ActivityReport {
        let g = layered_random(12, 5, 16, 2, 4);
        let cfg = OverlayConfig::default().with_dims(4, 4);
        let mut sim = Simulator::new(&g, cfg).unwrap();
        let stats = sim.run().unwrap();
        let act = sim.activity();
        // the heatmap series are the same counters SimStats aggregates
        assert_eq!(
            act.pe_firings.iter().sum::<u64>(),
            stats.pe.iter().map(|p| p.alu_ops).sum::<u64>()
        );
        assert_eq!(act.pe_ejects.iter().sum::<u64>(), stats.net.delivered);
        assert_eq!(
            act.router_deflections.iter().sum::<u64>(),
            stats.net.deflections
        );
        assert_eq!(act.cycles, stats.cycles);
        act
    }

    #[test]
    fn activity_matches_stats_and_renders() {
        let act = report();
        assert_eq!(act.pe_firings.len(), 16);
        let txt = act.render();
        // 7 series, each a header + 4 grid rows of 4 glyphs
        assert_eq!(txt.lines().count(), 7 * (1 + act.rows));
        assert!(txt.contains("pe.firings"));
        assert!(txt.contains("router.traffic"));
        for line in txt.lines().filter(|l| l.starts_with("  ")) {
            assert_eq!(line.chars().count(), 2 + act.cols, "grid row: {line:?}");
        }
    }

    #[test]
    fn activity_json_is_flat_and_parseable() {
        let act = report();
        let text = crate::util::json::write(&act.to_json_value());
        let j = crate::util::json::parse(&text).unwrap();
        assert_eq!(j.get("cols").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("rows").unwrap().as_usize(), Some(4));
        let firings = j
            .get("pe")
            .unwrap()
            .get("firings")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(firings.len(), 16);
        let sum: u64 = firings.iter().map(|v| v.as_u64().unwrap()).sum();
        assert_eq!(sum, act.pe_firings.iter().sum::<u64>());
        assert!(j.get("router").unwrap().get("deflections").is_some());
    }

    #[test]
    fn heatmap_zero_series_all_dots() {
        let act = ActivityReport {
            cols: 2,
            rows: 2,
            cycles: 0,
            pe_firings: vec![0; 4],
            pe_ejects: vec![0; 4],
            pe_busy: vec![0; 4],
            pe_stalls: vec![0; 4],
            pe_max_ready: vec![0; 4],
            router_traffic: vec![0; 4],
            router_deflections: vec![0; 4],
        };
        let grid = act.heatmap("x", &act.pe_firings);
        assert!(grid.contains("(max 0, total 0)"));
        assert_eq!(grid.matches('·').count(), 4);
    }
}
