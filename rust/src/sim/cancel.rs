//! Cooperative cancellation and per-job wall-clock deadlines
//! (DESIGN.md §15).
//!
//! A [`CancelToken`] is a shared flag + optional deadline that the hot
//! loops poll every [`CANCEL_CHECK_INTERVAL`] fabric cycles (the
//! lockstep stepper masks on the cycle counter; the skip-ahead engine
//! counts loop iterations, each of which advances at least one cycle,
//! and re-checks after every jump) and the sharded runtime polls at
//! every epoch barrier. Polling this sparsely keeps the check free in
//! practice — one relaxed atomic load, and an `Instant::now()` syscall
//! only once per interval — while bounding detection lag to one
//! interval (≤ 1024 cycles) past the budget.
//!
//! Cancellation is *cooperative*: firing the token never interrupts a
//! step mid-cycle; the run returns a typed
//! [`SimError::Cancelled`](crate::sim::SimError::Cancelled) /
//! [`SimError::DeadlineExceeded`](crate::sim::SimError::DeadlineExceeded)
//! carrying the partial progress (cycles retired, nodes completed) at
//! the check point.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How often (in fabric cycles / hot-loop iterations) the simulation
/// loops poll their [`CancelToken`]. A power of two so the lockstep
/// check is a single mask of the cycle counter.
pub const CANCEL_CHECK_INTERVAL: u64 = 1024;

/// Why a run was stopped early.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelCause {
    /// [`CancelToken::cancel`] was called (client gone, shed, shutdown).
    Cancelled,
    /// the token's wall-clock deadline expired.
    Deadline,
}

#[derive(Debug)]
struct Inner {
    cancelled: AtomicBool,
    /// `None` = no deadline, cancellation-only token.
    deadline: Option<Instant>,
}

/// A shared, cheaply clonable cancellation handle: an `AtomicBool`
/// (explicit cancellation) plus an optional wall-clock deadline.
///
/// Clones share state — cancelling any clone fires every holder. Attach
/// to a run with [`crate::engine::SimBackend::set_cancel`] /
/// [`crate::program::Session::with_cancel`] /
/// [`crate::shard::ShardSession::with_cancel`].
#[derive(Debug, Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl CancelToken {
    /// A token with no deadline; fires only via [`CancelToken::cancel`].
    pub fn new() -> Self {
        Self::build(None)
    }

    /// A token that fires [`CancelCause::Deadline`] once `budget` has
    /// elapsed from now.
    pub fn with_deadline(budget: Duration) -> Self {
        Self::build(Instant::now().checked_add(budget))
    }

    /// [`CancelToken::with_deadline`] in milliseconds — the
    /// `JobSpec.timeout_ms` unit.
    pub fn with_deadline_ms(budget_ms: u64) -> Self {
        Self::with_deadline(Duration::from_millis(budget_ms))
    }

    /// A token whose deadline is already in the past — the
    /// fault-injection "forced deadline overrun": the run stops at its
    /// first check with [`CancelCause::Deadline`].
    pub fn already_expired() -> Self {
        Self::build(Some(Instant::now()))
    }

    fn build(deadline: Option<Instant>) -> Self {
        Self {
            inner: Arc::new(Inner {
                cancelled: AtomicBool::new(false),
                deadline,
            }),
        }
    }

    /// Fire the token: every run polling it stops at its next check
    /// with [`CancelCause::Cancelled`]. Idempotent.
    pub fn cancel(&self) {
        self.inner.cancelled.store(true, Ordering::Relaxed);
    }

    /// Has [`CancelToken::cancel`] been called? (Does not consult the
    /// deadline; use [`CancelToken::fired`] for the full check.)
    pub fn is_cancelled(&self) -> bool {
        self.inner.cancelled.load(Ordering::Relaxed)
    }

    /// The poll: explicit cancellation first (one relaxed load), then
    /// the deadline (one `Instant::now()` — only reached when armed).
    pub fn fired(&self) -> Option<CancelCause> {
        if self.inner.cancelled.load(Ordering::Relaxed) {
            return Some(CancelCause::Cancelled);
        }
        match self.inner.deadline {
            Some(d) if Instant::now() >= d => Some(CancelCause::Deadline),
            _ => None,
        }
    }

    /// Time left until the deadline (`None` if no deadline is set;
    /// `Some(0)` once expired) — the queue's shed-before-dispatch test.
    pub fn remaining(&self) -> Option<Duration> {
        self.inner
            .deadline
            .map(|d| d.saturating_duration_since(Instant::now()))
    }
}

impl Default for CancelToken {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_token_fires_only_on_cancel() {
        let t = CancelToken::new();
        assert_eq!(t.fired(), None);
        assert!(!t.is_cancelled());
        assert_eq!(t.remaining(), None);
        let clone = t.clone();
        clone.cancel();
        assert_eq!(t.fired(), Some(CancelCause::Cancelled), "clones share state");
        assert!(t.is_cancelled());
    }

    #[test]
    fn expired_deadline_fires_immediately() {
        let t = CancelToken::already_expired();
        assert_eq!(t.fired(), Some(CancelCause::Deadline));
        assert_eq!(t.remaining(), Some(Duration::ZERO));
    }

    #[test]
    fn generous_deadline_does_not_fire() {
        let t = CancelToken::with_deadline_ms(60_000);
        assert_eq!(t.fired(), None);
        assert!(t.remaining().unwrap() > Duration::from_secs(1));
    }

    #[test]
    fn cancel_wins_over_live_deadline() {
        let t = CancelToken::with_deadline_ms(60_000);
        t.cancel();
        assert_eq!(t.fired(), Some(CancelCause::Cancelled));
    }

    #[test]
    fn interval_is_a_power_of_two() {
        assert!(CANCEL_CHECK_INTERVAL.is_power_of_two());
    }
}
