//! Simulation statistics: per-PE and aggregate.

use crate::noc::NetworkStats;
use crate::sched::SchedulerKind;

/// Per-PE counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeStats {
    pub busy_cycles: u64,
    pub alu_ops: u64,
    pub picks: u64,
    pub pg_busy: u64,
    pub pg_stalls: u64,
    /// BRAM port-arbitration stalls (0 with the paper's 2x multipump)
    pub port_stalls: u64,
    pub max_ready: usize,
    pub sched_mem_words: usize,
    pub fifo_overflows: u64,
}

/// Aggregate result of one simulation run.
///
/// `PartialEq` compares every counter (completion cycle, network stats,
/// all per-PE counters) — the equality the `engine::parity` harness
/// asserts between the lockstep and skip-ahead backends.
#[derive(Debug, Clone, PartialEq)]
pub struct SimStats {
    pub cycles: u64,
    pub total_nodes: usize,
    pub completed: usize,
    pub scheduler: SchedulerKind,
    pub net: NetworkStats,
    pub pe: Vec<PeStats>,
    // aggregates
    pub avg_pe_utilization: f64,
    pub max_ready_occupancy: usize,
    pub total_fifo_overflows: u64,
    pub total_pg_stalls: u64,
}

impl SimStats {
    pub fn collect(
        cycles: u64,
        total_nodes: usize,
        completed: usize,
        scheduler: SchedulerKind,
        net: NetworkStats,
        pe: Vec<PeStats>,
    ) -> Self {
        let busy: u64 = pe.iter().map(|p| p.busy_cycles).sum();
        let avg_pe_utilization = if cycles == 0 || pe.is_empty() {
            0.0
        } else {
            busy as f64 / (cycles as f64 * pe.len() as f64)
        };
        let max_ready_occupancy = pe.iter().map(|p| p.max_ready).max().unwrap_or(0);
        let total_fifo_overflows = pe.iter().map(|p| p.fifo_overflows).sum();
        let total_pg_stalls = pe.iter().map(|p| p.pg_stalls).sum();
        Self {
            cycles,
            total_nodes,
            completed,
            scheduler,
            net,
            pe,
            avg_pe_utilization,
            max_ready_occupancy,
            total_fifo_overflows,
            total_pg_stalls,
        }
    }

    /// Wall-clock estimate at `freq_mhz` (resource model supplies Fmax).
    pub fn runtime_us(&self, freq_mhz: f64) -> f64 {
        self.cycles as f64 / freq_mhz
    }

    /// ALU operations per cycle across the overlay (throughput metric).
    pub fn ops_per_cycle(&self) -> f64 {
        let ops: u64 = self.pe.iter().map(|p| p.alu_ops).sum();
        if self.cycles == 0 {
            0.0
        } else {
            ops as f64 / self.cycles as f64
        }
    }

    pub fn one_line(&self) -> String {
        format!(
            "{}: {} cycles, util {:.1}%, {} pkts ({} defl), max ready {}",
            self.scheduler.name(),
            self.cycles,
            100.0 * self.avg_pe_utilization,
            self.net.delivered,
            self.net.deflections,
            self.max_ready_occupancy
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let pe = vec![
            PeStats { busy_cycles: 50, alu_ops: 10, max_ready: 3, ..Default::default() },
            PeStats { busy_cycles: 100, alu_ops: 30, max_ready: 7, ..Default::default() },
        ];
        let s = SimStats::collect(
            100,
            64,
            64,
            SchedulerKind::OutOfOrder,
            NetworkStats::default(),
            pe,
        );
        assert!((s.avg_pe_utilization - 0.75).abs() < 1e-12);
        assert_eq!(s.max_ready_occupancy, 7);
        assert!((s.ops_per_cycle() - 0.4).abs() < 1e-12);
        assert!((s.runtime_us(250.0) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn zero_cycles_safe() {
        let s = SimStats::collect(
            0,
            0,
            0,
            SchedulerKind::InOrder,
            NetworkStats::default(),
            vec![],
        );
        assert_eq!(s.avg_pe_utilization, 0.0);
        assert_eq!(s.ops_per_cycle(), 0.0);
    }
}
