//! Simulation statistics: per-PE and aggregate, with a strict JSON
//! round-trip ([`SimStats::to_json`] / [`SimStats::from_json`]) — the
//! response format of the service layer ([`crate::service`]) and the
//! CLI's `--format json`.

use crate::noc::NetworkStats;
use crate::sched::SchedulerKind;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// Per-PE counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeStats {
    pub busy_cycles: u64,
    pub alu_ops: u64,
    /// packets consumed off the network (operand deliveries); sums to
    /// `net.delivered` across the fabric
    pub ejects: u64,
    pub picks: u64,
    pub pg_busy: u64,
    pub pg_stalls: u64,
    /// BRAM port-arbitration stalls (0 with the paper's 2x multipump)
    pub port_stalls: u64,
    pub max_ready: usize,
    pub sched_mem_words: usize,
    pub fifo_overflows: u64,
}

impl PeStats {
    fn to_json_value(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("busy_cycles".to_string(), Json::Num(self.busy_cycles as f64));
        m.insert("alu_ops".to_string(), Json::Num(self.alu_ops as f64));
        m.insert("ejects".to_string(), Json::Num(self.ejects as f64));
        m.insert("picks".to_string(), Json::Num(self.picks as f64));
        m.insert("pg_busy".to_string(), Json::Num(self.pg_busy as f64));
        m.insert("pg_stalls".to_string(), Json::Num(self.pg_stalls as f64));
        m.insert("port_stalls".to_string(), Json::Num(self.port_stalls as f64));
        m.insert("max_ready".to_string(), Json::Num(self.max_ready as f64));
        m.insert("sched_mem_words".to_string(), Json::Num(self.sched_mem_words as f64));
        m.insert("fifo_overflows".to_string(), Json::Num(self.fifo_overflows as f64));
        Json::Obj(m)
    }

    fn from_json_value(j: &Json) -> Result<Self, String> {
        let obj = j.as_obj().ok_or("pe: expected object")?;
        let mut s = PeStats::default();
        for (key, v) in obj {
            let n = v
                .as_u64()
                .ok_or_else(|| format!("pe.{key}: expected non-negative integer"))?;
            match key.as_str() {
                "busy_cycles" => s.busy_cycles = n,
                "alu_ops" => s.alu_ops = n,
                "ejects" => s.ejects = n,
                "picks" => s.picks = n,
                "pg_busy" => s.pg_busy = n,
                "pg_stalls" => s.pg_stalls = n,
                "port_stalls" => s.port_stalls = n,
                "max_ready" => s.max_ready = n as usize,
                "sched_mem_words" => s.sched_mem_words = n as usize,
                "fifo_overflows" => s.fifo_overflows = n,
                other => return Err(format!("unknown pe counter '{other}'")),
            }
        }
        Ok(s)
    }
}

/// Aggregate result of one simulation run.
///
/// `PartialEq` compares every counter (completion cycle, network stats,
/// all per-PE counters) — the equality the `engine::parity` harness
/// asserts between the lockstep and skip-ahead backends.
#[derive(Debug, Clone, PartialEq)]
pub struct SimStats {
    pub cycles: u64,
    pub total_nodes: usize,
    pub completed: usize,
    pub scheduler: SchedulerKind,
    pub net: NetworkStats,
    pub pe: Vec<PeStats>,
    // aggregates
    pub avg_pe_utilization: f64,
    pub max_ready_occupancy: usize,
    pub total_fifo_overflows: u64,
    pub total_pg_stalls: u64,
}

impl SimStats {
    pub fn collect(
        cycles: u64,
        total_nodes: usize,
        completed: usize,
        scheduler: SchedulerKind,
        net: NetworkStats,
        pe: Vec<PeStats>,
    ) -> Self {
        let busy: u64 = pe.iter().map(|p| p.busy_cycles).sum();
        let avg_pe_utilization = if cycles == 0 || pe.is_empty() {
            0.0
        } else {
            busy as f64 / (cycles as f64 * pe.len() as f64)
        };
        let max_ready_occupancy = pe.iter().map(|p| p.max_ready).max().unwrap_or(0);
        let total_fifo_overflows = pe.iter().map(|p| p.fifo_overflows).sum();
        let total_pg_stalls = pe.iter().map(|p| p.pg_stalls).sum();
        Self {
            cycles,
            total_nodes,
            completed,
            scheduler,
            net,
            pe,
            avg_pe_utilization,
            max_ready_occupancy,
            total_fifo_overflows,
            total_pg_stalls,
        }
    }

    /// Wall-clock estimate at `freq_mhz` (resource model supplies Fmax).
    pub fn runtime_us(&self, freq_mhz: f64) -> f64 {
        self.cycles as f64 / freq_mhz
    }

    /// ALU operations per cycle across the overlay (throughput metric).
    pub fn ops_per_cycle(&self) -> f64 {
        let ops: u64 = self.pe.iter().map(|p| p.alu_ops).sum();
        if self.cycles == 0 {
            0.0
        } else {
            ops as f64 / self.cycles as f64
        }
    }

    /// JSON object with every counter: top-level scalars, the network
    /// stats under `net`, and the per-PE counter array under `pe`.
    /// Aggregates are serialized as-is (not recomputed on load), so the
    /// round-trip is bit-identical — `PartialEq` on the reloaded value
    /// compares equal to the original.
    pub fn to_json_value(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("cycles".to_string(), Json::Num(self.cycles as f64));
        m.insert("total_nodes".to_string(), Json::Num(self.total_nodes as f64));
        m.insert("completed".to_string(), Json::Num(self.completed as f64));
        m.insert(
            "scheduler".to_string(),
            Json::Str(self.scheduler.toml_name().to_string()),
        );
        m.insert("net".to_string(), self.net.to_json_value());
        m.insert(
            "pe".to_string(),
            Json::Arr(self.pe.iter().map(PeStats::to_json_value).collect()),
        );
        m.insert(
            "avg_pe_utilization".to_string(),
            Json::Num(self.avg_pe_utilization),
        );
        m.insert(
            "max_ready_occupancy".to_string(),
            Json::Num(self.max_ready_occupancy as f64),
        );
        m.insert(
            "total_fifo_overflows".to_string(),
            Json::Num(self.total_fifo_overflows as f64),
        );
        m.insert("total_pg_stalls".to_string(), Json::Num(self.total_pg_stalls as f64));
        Json::Obj(m)
    }

    /// Compact JSON text (see [`SimStats::to_json_value`]).
    pub fn to_json(&self) -> String {
        json::write(&self.to_json_value())
    }

    /// Strict inverse of [`SimStats::to_json_value`]: every counter
    /// recovered exactly, unknown keys rejected.
    pub fn from_json_value(j: &Json) -> Result<Self, String> {
        let obj = j.as_obj().ok_or("stats: expected object")?;
        let u = |key: &str, v: &Json| -> Result<u64, String> {
            v.as_u64()
                .ok_or_else(|| format!("{key}: expected non-negative integer"))
        };
        let mut s = SimStats {
            cycles: 0,
            total_nodes: 0,
            completed: 0,
            scheduler: SchedulerKind::OutOfOrder,
            net: NetworkStats::default(),
            pe: Vec::new(),
            avg_pe_utilization: 0.0,
            max_ready_occupancy: 0,
            total_fifo_overflows: 0,
            total_pg_stalls: 0,
        };
        for (key, v) in obj {
            match key.as_str() {
                "cycles" => s.cycles = u(key, v)?,
                "total_nodes" => s.total_nodes = u(key, v)? as usize,
                "completed" => s.completed = u(key, v)? as usize,
                "scheduler" => {
                    s.scheduler = v
                        .as_str()
                        .ok_or("scheduler: expected string")?
                        .parse()?
                }
                "net" => s.net = NetworkStats::from_json_value(v)?,
                "pe" => {
                    s.pe = v
                        .as_arr()
                        .ok_or("pe: expected array")?
                        .iter()
                        .map(PeStats::from_json_value)
                        .collect::<Result<_, _>>()?
                }
                "avg_pe_utilization" => {
                    s.avg_pe_utilization =
                        v.as_f64().ok_or("avg_pe_utilization: expected number")?
                }
                "max_ready_occupancy" => s.max_ready_occupancy = u(key, v)? as usize,
                "total_fifo_overflows" => s.total_fifo_overflows = u(key, v)?,
                "total_pg_stalls" => s.total_pg_stalls = u(key, v)?,
                other => return Err(format!("unknown stats key '{other}'")),
            }
        }
        Ok(s)
    }

    /// Parse from JSON text (see [`SimStats::from_json_value`]).
    pub fn from_json(text: &str) -> Result<Self, String> {
        Self::from_json_value(&json::parse(text).map_err(|e| e.to_string())?)
    }

    pub fn one_line(&self) -> String {
        format!(
            "{}: {} cycles, util {:.1}%, {} pkts ({} defl), max ready {}",
            self.scheduler.name(),
            self.cycles,
            100.0 * self.avg_pe_utilization,
            self.net.delivered,
            self.net.deflections,
            self.max_ready_occupancy
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let pe = vec![
            PeStats { busy_cycles: 50, alu_ops: 10, max_ready: 3, ..Default::default() },
            PeStats { busy_cycles: 100, alu_ops: 30, max_ready: 7, ..Default::default() },
        ];
        let s = SimStats::collect(
            100,
            64,
            64,
            SchedulerKind::OutOfOrder,
            NetworkStats::default(),
            pe,
        );
        assert!((s.avg_pe_utilization - 0.75).abs() < 1e-12);
        assert_eq!(s.max_ready_occupancy, 7);
        assert!((s.ops_per_cycle() - 0.4).abs() < 1e-12);
        assert!((s.runtime_us(250.0) - 0.4).abs() < 1e-12);
    }

    /// The satellite acceptance: `util::json` parse of the emitted
    /// object recovers every counter — checked on a real simulation
    /// result (non-trivial per-PE and network counters), bit-identical
    /// under `PartialEq`.
    #[test]
    fn json_roundtrip_recovers_every_counter() {
        let g = crate::workload::layered_random(8, 4, 16, 2, 3);
        let cfg = crate::config::OverlayConfig::default().with_dims(2, 2);
        let mut sim = crate::sim::Simulator::new(&g, cfg).unwrap();
        let stats = sim.run().unwrap();
        assert!(stats.cycles > 0 && stats.net.delivered > 0, "non-trivial run");
        let text = stats.to_json();
        let back = SimStats::from_json(&text).unwrap();
        assert_eq!(back, stats, "every counter must round-trip bit-identically");
        // the new per-PE activity counter survives the trip with a
        // non-trivial value (every delivered packet was ejected somewhere)
        assert_eq!(
            back.pe.iter().map(|p| p.ejects).sum::<u64>(),
            stats.net.delivered
        );
        assert!(back.pe.iter().any(|p| p.ejects > 0));
        // and the emitted object is plain JSON util::json can re-emit
        let reparsed = json::parse(&text).unwrap();
        assert_eq!(json::write(&reparsed), text);
        assert_eq!(reparsed.get("pe").unwrap().as_arr().unwrap().len(), 4);
        let pe0 = &reparsed.get("pe").unwrap().as_arr().unwrap()[0];
        assert!(pe0.get("ejects").is_some(), "activity field serialized");
    }

    #[test]
    fn json_rejects_unknown_and_malformed_keys() {
        assert!(SimStats::from_json("{\"bogus\": 1}").is_err());
        assert!(SimStats::from_json("{\"cycles\": -4}").is_err());
        assert!(SimStats::from_json("{\"scheduler\": \"nope\"}").is_err());
        assert!(SimStats::from_json("[1]").is_err());
        // per-PE objects are just as strict: unknown or malformed
        // activity counters are rejected, not ignored
        assert!(SimStats::from_json("{\"pe\": [{\"bogus\": 1}]}").is_err());
        assert!(SimStats::from_json("{\"pe\": [{\"ejects\": -1}]}").is_err());
        assert!(SimStats::from_json("{\"pe\": [{\"ejects\": 2}]}").is_ok());
    }

    #[test]
    fn zero_cycles_safe() {
        let s = SimStats::collect(
            0,
            0,
            0,
            SchedulerKind::InOrder,
            NetworkStats::default(),
            vec![],
        );
        assert_eq!(s.avg_pe_utilization, 0.0);
        assert_eq!(s.ops_per_cycle(), 0.0);
    }
}
