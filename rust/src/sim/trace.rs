//! Execution tracing: sampled time series of the quantities that explain
//! Fig. 1 — ready-queue occupancy (the regime detector), PE busyness and
//! network load — plus a completion (retired-nodes) curve. Backs the
//! `tdp analyze` subcommand.

/// One sampled point of the overlay state.
#[derive(Debug, Clone, Copy, Default)]
pub struct Sample {
    pub cycle: u64,
    /// total ready nodes queued across all PEs
    pub ready_total: usize,
    /// deepest single-PE ready queue
    pub ready_max: usize,
    /// PEs with non-idle packet-gen or ALU
    pub busy_pes: usize,
    /// packets on network links
    pub in_flight: usize,
    /// nodes fully completed (fanout done)
    pub completed: usize,
}

/// Sampling trace with a fixed stride (cycles between samples).
#[derive(Debug, Clone)]
pub struct Trace {
    pub stride: u64,
    pub samples: Vec<Sample>,
}

impl Trace {
    pub fn new(stride: u64) -> Self {
        assert!(stride >= 1);
        Self {
            stride,
            samples: Vec::new(),
        }
    }

    #[inline]
    pub fn due(&self, cycle: u64) -> bool {
        cycle % self.stride == 0
    }

    pub fn push(&mut self, s: Sample) {
        self.samples.push(s);
    }

    /// Cycle of the most recent sample, if any. Used by the simulator's
    /// termination path to avoid double-sampling the final cycle when it
    /// happens to be stride-aligned.
    pub fn last_cycle(&self) -> Option<u64> {
        self.samples.last().map(|s| s.cycle)
    }

    /// Peak total ready occupancy over the run.
    pub fn peak_ready(&self) -> usize {
        self.samples.iter().map(|s| s.ready_total).max().unwrap_or(0)
    }

    /// Mean PE busyness over sampled points (fraction of `num_pes`).
    pub fn mean_busy(&self, num_pes: usize) -> f64 {
        if self.samples.is_empty() || num_pes == 0 {
            return 0.0;
        }
        self.samples.iter().map(|s| s.busy_pes).sum::<usize>() as f64
            / (self.samples.len() * num_pes) as f64
    }

    /// Render a coarse ASCII sparkline of a series (reports/CLI).
    pub fn sparkline<F: Fn(&Sample) -> usize>(&self, f: F, width: usize) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        if self.samples.is_empty() {
            return String::new();
        }
        let series: Vec<usize> = self.samples.iter().map(|s| f(s)).collect();
        let max = *series.iter().max().unwrap();
        let bucket = series.len().div_ceil(width.max(1));
        let mut out = String::new();
        for chunk in series.chunks(bucket) {
            let avg = chunk.iter().sum::<usize>() / chunk.len();
            let idx = if max == 0 {
                0
            } else {
                (avg * (GLYPHS.len() - 1)) / max
            };
            out.push(GLYPHS[idx]);
        }
        out
    }

    /// CSV dump (cycle, ready_total, ready_max, busy_pes, in_flight,
    /// completed).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("cycle,ready_total,ready_max,busy_pes,in_flight,completed\n");
        for s in &self.samples {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                s.cycle, s.ready_total, s.ready_max, s.busy_pes, s.in_flight, s.completed
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(cycle: u64, ready: usize, busy: usize) -> Sample {
        Sample {
            cycle,
            ready_total: ready,
            ready_max: ready / 2,
            busy_pes: busy,
            in_flight: 1,
            completed: cycle as usize,
        }
    }

    #[test]
    fn stride_gates_sampling() {
        let t = Trace::new(10);
        assert!(t.due(0));
        assert!(!t.due(5));
        assert!(t.due(20));
    }

    #[test]
    fn aggregates() {
        let mut t = Trace::new(1);
        t.push(sample(0, 4, 2));
        t.push(sample(1, 10, 4));
        t.push(sample(2, 6, 0));
        assert_eq!(t.peak_ready(), 10);
        assert!((t.mean_busy(4) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sparkline_shapes() {
        let mut t = Trace::new(1);
        for i in 0..100u64 {
            t.push(sample(i, i as usize, 0));
        }
        let s = t.sparkline(|s| s.ready_total, 10);
        assert_eq!(s.chars().count(), 10);
        let first = s.chars().next().unwrap();
        let last = s.chars().last().unwrap();
        assert!(last > first, "rising series: {s}");
    }

    #[test]
    fn csv_header_and_rows() {
        let mut t = Trace::new(1);
        t.push(sample(5, 1, 1));
        let csv = t.to_csv();
        assert!(csv.starts_with("cycle,"));
        assert!(csv.contains("5,1,0,1,1,5"));
    }

    #[test]
    fn empty_trace_safe() {
        let t = Trace::new(4);
        assert_eq!(t.peak_ready(), 0);
        assert_eq!(t.mean_busy(8), 0.0);
        assert_eq!(t.sparkline(|s| s.ready_total, 10), "");
        assert_eq!(t.last_cycle(), None);
    }

    #[test]
    fn last_cycle_tracks_most_recent_sample() {
        let mut t = Trace::new(10);
        t.push(sample(0, 1, 1));
        assert_eq!(t.last_cycle(), Some(0));
        t.push(sample(20, 1, 1));
        assert_eq!(t.last_cycle(), Some(20));
    }
}
