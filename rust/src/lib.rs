//! # tdp-overlay
//!
//! A cycle-level reproduction of *"Out-of-Order Dataflow Scheduling for
//! FPGA Overlays"* (Siddhartha & Kapre, 2017): a token-dataflow soft
//! processor overlay for the Arria 10, with hundreds of PEs on a Hoplite
//! 2-D torus NoC, comparing the paper's hierarchical leading-one-detector
//! (LOD) out-of-order ready-node scheduler against the classical
//! FIFO-based in-order scheduler.
//!
//! Layering (see DESIGN.md):
//! * **L3 (this crate)** — the overlay simulator behind the pluggable
//!   [`engine::SimBackend`] engines (lockstep reference + bit-exact
//!   skip-ahead event backend), schedulers, NoC, workload generators,
//!   criticality labeling, resource model and the experiment coordinator.
//! * **L2/L1 (python, build-time only)** — a JAX levelized graph
//!   evaluator calling a Pallas ALU kernel, AOT-lowered to HLO text in
//!   `artifacts/`; loaded at runtime through [`runtime::XlaRuntime`]
//!   (PJRT CPU) as the numerics oracle. Python never runs on the request
//!   path.
//!
//! Execution API (DESIGN.md §8) — compile once, run many times:
//! ```no_run
//! use tdp::{Overlay, Program, SchedulerKind};
//! # fn demo(g: &tdp::DataflowGraph) -> Result<(), tdp::Error> {
//! let overlay = Overlay::builder().dims(4, 4).build()?;   // validated hardware
//! let program = Program::compile(g, &overlay)?;           // place + label once
//! let ooo = program.session().run()?;                     // cheap repeatable runs
//! let fifo = program.session().with_scheduler(SchedulerKind::InOrder).run()?;
//! # let _ = (ooo, fifo); Ok(()) }
//! ```
//!
//! Service API (DESIGN.md §9) — jobs in, results out, compiles cached:
//! ```no_run
//! use tdp::service::{Engine, JobSpec};
//! # fn demo() -> Result<(), tdp::Error> {
//! let engine = Engine::new();                         // long-lived; owns the Program cache
//! let job = JobSpec::new("chain:4096:seed=7");        // workload spec string + variant
//! let cold = engine.submit(&job)?;                    // compiles once...
//! let warm = engine.submit(&job)?;                    // ...then every duplicate is a cache hit
//! assert!(warm.cache_hit && warm.stats == cold.stats);
//! # Ok(()) }
//! ```
//!
//! Daemon (DESIGN.md §13) — the same engine behind a socket: `tdp
//! serve` runs a [`serve::Daemon`] (bounded fair admission queue,
//! worker pool, graceful drain, `stats` endpoint) so the Program cache
//! amortizes across many clients; `tdp batch --connect` and `tdp top`
//! are its clients.
//!
//! Sharding (DESIGN.md §14) — graphs too big for one fabric partition
//! across N simulated overlays joined by boundary channels under
//! epoch-barrier cycle sync; the [`Engine`](service::Engine)
//! auto-shards when [`Program::fits`] fails and capacity is
//! unenforced, or `shards = N` forces it:
//! ```no_run
//! use std::sync::Arc;
//! use tdp::{Overlay, ShardedProgram};
//! # fn demo(g: Arc<tdp::DataflowGraph>) -> Result<(), tdp::Error> {
//! let overlay = Overlay::builder().dims(2, 2).build()?;
//! let sharded = ShardedProgram::compile(g, &overlay, 2)?;  // forced 2-way cut
//! let run = sharded.session().run()?;                      // deterministic for any
//! # let _ = run; Ok(()) }                                  // host thread count
//! ```

pub mod config;
pub mod coordinator;
pub mod criticality;
pub mod engine;
pub mod error;
pub mod faultinject;
pub mod graph;
pub mod lod;
pub mod noc;
pub mod passes;
pub mod pe;
pub mod place;
pub mod program;
pub mod resource;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod service;
pub mod shard;
pub mod sim;
pub mod telemetry;
pub mod util;
pub mod workload;

pub use config::{ConfigError, Overlay, OverlayBuilder, OverlayConfig};
pub use engine::{BackendKind, SimBackend};
pub use error::{panic_message, Error, Partial};
pub use faultinject::{BarrierDrop, FaultPlan};
pub use graph::{DataflowGraph, NodeId, Op};
pub use passes::{Diagnostic, PassManager, Severity};
pub use program::{
    run_batch, CompileError, Program, RunVariant, RuntimeTables, Session, SharedProgram,
};
pub use sched::SchedulerKind;
pub use serve::{Daemon, DaemonHandle, ServeConfig};
pub use service::{Engine, JobResult, JobSpec};
pub use shard::{ShardSession, ShardedProgram, ShardedRun};
pub use sim::{CancelCause, CancelToken, SimError, SimStats, Simulator};
pub use telemetry::{Registry, Telemetry};
