//! Best-effort SIGTERM/SIGINT hookup for the graceful drain — the
//! process-manager path to the same state machine the `shutdown`
//! control line drives.
//!
//! Zero-dependency by design: the handler is registered through the C
//! library's `signal()` (which `std` already links on unix) and does
//! nothing but store into a static `AtomicBool` — the only
//! async-signal-safe action we need. The CLI polls the flag from an
//! ordinary thread and calls [`crate::serve::DaemonHandle::drain`].
//! On non-unix targets installation is a no-op and the control-line
//! path remains the only shutdown trigger.

use std::sync::atomic::AtomicBool;

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    use std::sync::atomic::Ordering;

    extern "C" fn on_signal(_signum: i32) {
        super::SHUTDOWN.store(true, Ordering::SeqCst);
    }

    extern "C" {
        // from the C library std links anyway; usize holds the handler
        // function pointer (sighandler_t)
        fn signal(signum: i32, handler: usize) -> usize;
    }

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    pub fn install() {
        unsafe {
            signal(SIGTERM, on_signal as usize);
            signal(SIGINT, on_signal as usize);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn install() {}
}

/// Install the SIGTERM/SIGINT handler (idempotent) and return the flag
/// it sets. The caller polls the flag; nothing else ever clears it.
pub fn install_shutdown_flag() -> &'static AtomicBool {
    imp::install();
    &SHUTDOWN
}
