//! The network daemon (DESIGN.md §13): `tdp serve` keeps one
//! [`crate::service::Engine`] — and therefore the content-addressed
//! Program cache and single-flight compilation — alive across a stream
//! of clients, turning the paper's compile-once economics into a
//! request server instead of a one-shot CLI.
//!
//! * [`protocol`] — line-delimited JSON over TCP: job lines are the
//!   exact strict [`crate::service::JobSpec`] documents `tdp batch`
//!   reads; control lines (`stats` / `ping` / `shutdown`) drive
//!   observability and the drain; every response is seq-tagged so
//!   clients pipeline freely. Errors are structured (`queue_full`,
//!   `draining`, `bad_request`, `job_failed`) and never cost a client
//!   its connection.
//! * [`queue`] — the bounded admission queue with round-robin
//!   per-client fairness: one slot per client per turn, so a firehose
//!   client cannot starve the rest; the global bound is the
//!   backpressure signal.
//! * [`daemon`] — [`Daemon`]: accept loop, per-connection readers, the
//!   worker pool over the shared engine, the graceful drain state
//!   machine, and the `stats` document
//!   ([`crate::service::Engine::metrics_snapshot`] + daemon gauges,
//!   the gauges also registered on the passed-in
//!   [`crate::telemetry::Registry`] as `serve.*`).
//! * [`client`] — the other end: `tdp batch --connect` job streaming
//!   (pipelined, reassembled into input order) and the `tdp top`
//!   stats poll/renderer.
//! * [`signal`] — SIGTERM/SIGINT → the same drain path, dependency-free.

pub mod client;
pub mod protocol;
pub mod queue;
pub mod signal;

mod daemon;

pub use daemon::{Daemon, DaemonHandle, ServeConfig, DEFAULT_QUEUE_CAPACITY};
pub use queue::{FairQueue, PushError};
