//! The `tdp serve` wire protocol (DESIGN.md §13): line-delimited JSON
//! over TCP, one request object per line in, one response object per
//! line out.
//!
//! A request line is either a job — the exact [`JobSpec`] JSON `tdp
//! batch` already reads, parsed strictly so protocol typos fail loudly
//! at the daemon boundary — or a control object `{"control": "stats" |
//! "ping" | "shutdown"}`. Every response carries `"seq"`, the 1-based
//! index of the request among the *non-empty* lines of that connection,
//! so a client may pipeline requests and reassemble responses in any
//! completion order. Errors are structured (`{"seq", "code", "error"}`)
//! and never cost the client its connection.

use crate::service::{JobResult, JobSpec};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// Protocol revision carried in every `stats` response. Bump only when
/// an existing key changes meaning; new keys are added freely.
pub const PROTOCOL_VERSION: u64 = 1;

/// One parsed request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// a job submission (the `tdp batch` [`JobSpec`] document)
    Job(Box<JobSpec>),
    /// a daemon control message
    Control(Control),
}

/// The control verbs of the protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Control {
    /// engine metrics snapshot + daemon gauges
    Stats,
    /// liveness probe
    Ping,
    /// begin graceful drain: stop admitting, finish in-flight, exit
    Shutdown,
}

impl Control {
    pub fn name(&self) -> &'static str {
        match self {
            Control::Stats => "stats",
            Control::Ping => "ping",
            Control::Shutdown => "shutdown",
        }
    }
}

/// Machine-readable error codes of structured error responses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// the line did not parse as a job or control object
    BadRequest,
    /// the bounded admission queue is at capacity — retry later
    QueueFull,
    /// the daemon is draining and admits no new work
    Draining,
    /// the job was admitted and executed, but failed
    JobFailed,
}

impl ErrorCode {
    pub fn name(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::Draining => "draining",
            ErrorCode::JobFailed => "job_failed",
        }
    }
}

/// Parse one request line. A JSON object containing the key `"control"`
/// is a control message (that key must be its only key); anything else
/// must be a strict [`JobSpec`] document.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = json::parse(line).map_err(|e| e.to_string())?;
    let obj = j.as_obj().ok_or("request must be a JSON object")?;
    if let Some(verb) = obj.get("control") {
        if obj.len() != 1 {
            return Err("control request takes no other keys".to_string());
        }
        let verb = verb.as_str().ok_or("control: expected string")?;
        let control = match verb {
            "stats" => Control::Stats,
            "ping" => Control::Ping,
            "shutdown" => Control::Shutdown,
            other => {
                return Err(format!("unknown control verb '{other}' (stats | ping | shutdown)"))
            }
        };
        return Ok(Request::Control(control));
    }
    Ok(Request::Job(Box::new(JobSpec::from_json_value(&j)?)))
}

fn base(seq: u64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("seq".to_string(), Json::Num(seq as f64));
    m
}

/// A successful job response: `{"seq": N, "result": <JobResult>}`.
pub fn result_response(seq: u64, result: &JobResult) -> String {
    let mut m = base(seq);
    m.insert("result".to_string(), result.to_json_value());
    json::write(&Json::Obj(m))
}

/// A structured error response: `{"seq": N, "code": ..., "error": ...}`.
pub fn error_response(seq: u64, code: ErrorCode, msg: &str) -> String {
    let mut m = base(seq);
    m.insert("code".to_string(), Json::Str(code.name().to_string()));
    m.insert("error".to_string(), Json::Str(msg.to_string()));
    json::write(&Json::Obj(m))
}

/// A failed-job response carrying the engine's typed failure class
/// ([`crate::error::Error::code`]: `deadline_exceeded`, `cancelled`,
/// `cycles_exhausted`, `compile_poisoned`, `panicked`, ...) as its
/// `code`, plus — for mid-run stops — the partial progress under
/// `"partial"`, so a timed-out job still reports how far it got.
pub fn job_error_response(seq: u64, err: &crate::error::Error) -> String {
    let mut m = base(seq);
    m.insert("code".to_string(), Json::Str(err.code().to_string()));
    m.insert("error".to_string(), Json::Str(err.to_string()));
    if let Some(p) = err.partial() {
        let mut partial = BTreeMap::new();
        partial.insert("cycles".to_string(), Json::Num(p.cycles as f64));
        partial.insert("completed".to_string(), Json::Num(p.completed as f64));
        partial.insert("total".to_string(), Json::Num(p.total as f64));
        m.insert("partial".to_string(), Json::Obj(partial));
    }
    json::write(&Json::Obj(m))
}

/// The queue-shed response (DESIGN.md §15): the job's `timeout_ms`
/// expired while it was still queued, so the daemon answers
/// `deadline_exceeded` without ever occupying a worker on it.
pub fn shed_response(seq: u64) -> String {
    let mut m = base(seq);
    m.insert("code".to_string(), Json::Str("deadline_exceeded".to_string()));
    m.insert(
        "error".to_string(),
        Json::Str("deadline expired while queued; job was never started".to_string()),
    );
    json::write(&Json::Obj(m))
}

/// The `ping` response: `{"seq": N, "control": "ping", "ok": true}`.
pub fn ping_response(seq: u64) -> String {
    let mut m = base(seq);
    m.insert("control".to_string(), Json::Str("ping".to_string()));
    m.insert("ok".to_string(), Json::Bool(true));
    json::write(&Json::Obj(m))
}

/// The `shutdown` acknowledgement, sent before the drain begins.
pub fn shutdown_response(seq: u64) -> String {
    let mut m = base(seq);
    m.insert("control".to_string(), Json::Str("shutdown".to_string()));
    m.insert("state".to_string(), Json::Str("draining".to_string()));
    json::write(&Json::Obj(m))
}

/// The `stats` response: the versioned engine snapshot under `"engine"`
/// plus the daemon-level document under `"daemon"`.
pub fn stats_response(seq: u64, engine: Json, daemon: Json, state: &str) -> String {
    let mut m = base(seq);
    m.insert("control".to_string(), Json::Str("stats".to_string()));
    m.insert("version".to_string(), Json::Num(PROTOCOL_VERSION as f64));
    m.insert("state".to_string(), Json::Str(state.to_string()));
    m.insert("engine".to_string(), engine);
    m.insert("daemon".to_string(), daemon);
    json::write(&Json::Obj(m))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_lines_parse_strictly() {
        match parse_request("{\"workload\": \"chain:8\", \"cols\": 2, \"rows\": 2}").unwrap() {
            Request::Job(job) => assert_eq!(job.workload, "chain:8"),
            other => panic!("expected job, got {other:?}"),
        }
        // a misspelled field is a hard parse error at the boundary, not
        // a silently-defaulted job
        let err = parse_request("{\"workload\": \"chain:8\", \"schedular\": \"ooo\"}")
            .unwrap_err();
        assert!(err.contains("unknown job key 'schedular'"), "{err}");
        assert!(parse_request("not json").is_err());
        assert!(parse_request("[1, 2]").is_err());
    }

    #[test]
    fn control_lines_parse() {
        for (text, want) in [
            ("{\"control\": \"stats\"}", Control::Stats),
            ("{\"control\": \"ping\"}", Control::Ping),
            ("{\"control\": \"shutdown\"}", Control::Shutdown),
        ] {
            assert_eq!(parse_request(text).unwrap(), Request::Control(want));
        }
        assert!(parse_request("{\"control\": \"reboot\"}").is_err());
        // control + extra keys is ambiguous — rejected, not guessed at
        assert!(parse_request("{\"control\": \"stats\", \"workload\": \"x\"}").is_err());
        // "control" is not a JobSpec key, so there is no grammar overlap
    }

    #[test]
    fn job_errors_carry_typed_codes_and_partial_progress() {
        use crate::error::{Error, Partial};
        let e = Error::Deadline(Partial { cycles: 2048, completed: 5, total: 10 });
        let j = json::parse(&job_error_response(4, &e)).unwrap();
        assert_eq!(j.get("seq").unwrap().as_u64(), Some(4));
        assert_eq!(j.get("code").unwrap().as_str(), Some("deadline_exceeded"));
        let p = j.get("partial").expect("mid-run stops carry partial progress");
        assert_eq!(p.get("cycles").unwrap().as_u64(), Some(2048));
        assert_eq!(p.get("completed").unwrap().as_u64(), Some(5));
        assert_eq!(p.get("total").unwrap().as_u64(), Some(10));
        let e = Error::Panicked { stage: "compile", message: "boom".into() };
        let j = json::parse(&job_error_response(5, &e)).unwrap();
        assert_eq!(j.get("code").unwrap().as_str(), Some("panicked"));
        assert!(j.get("partial").is_none());
        let shed = json::parse(&shed_response(6)).unwrap();
        assert_eq!(shed.get("code").unwrap().as_str(), Some("deadline_exceeded"));
        assert!(shed.get("error").unwrap().as_str().unwrap().contains("queued"));
    }

    #[test]
    fn responses_are_seq_tagged_json() {
        let line = error_response(7, ErrorCode::QueueFull, "queue full (capacity 4)");
        let j = json::parse(&line).unwrap();
        assert_eq!(j.get("seq").unwrap().as_u64(), Some(7));
        assert_eq!(j.get("code").unwrap().as_str(), Some("queue_full"));
        let pong = json::parse(&ping_response(1)).unwrap();
        assert_eq!(pong.get("control").unwrap().as_str(), Some("ping"));
        assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));
        let ack = json::parse(&shutdown_response(2)).unwrap();
        assert_eq!(ack.get("state").unwrap().as_str(), Some("draining"));
        let stats = json::parse(&stats_response(
            3,
            Json::Obj(Default::default()),
            Json::Obj(Default::default()),
            "serving",
        ))
        .unwrap();
        assert_eq!(stats.get("version").unwrap().as_u64(), Some(PROTOCOL_VERSION));
        assert_eq!(stats.get("state").unwrap().as_str(), Some("serving"));
        assert!(stats.get("engine").is_some() && stats.get("daemon").is_some());
    }
}
