//! Client helpers for the `tdp serve` protocol: submit a JSONL job
//! stream over a socket (`tdp batch --connect`), fetch or request
//! daemon state (`tdp top`, shutdown), and render the `tdp top` text
//! frame.
//!
//! The submitter pipelines: a reader thread collects seq-tagged
//! responses while the writer is still sending, so a large job file
//! can never deadlock on full kernel socket buffers, and responses are
//! reassembled into input order before they are returned.

use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn invalid<E: std::fmt::Display>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

/// Send `lines` (one request per element, verbatim — the daemon does
/// all parsing and validation) and return one response per line, in
/// input order regardless of the daemon's completion order.
pub fn submit_raw_lines(addr: &str, lines: &[String]) -> std::io::Result<Vec<Json>> {
    let stream = TcpStream::connect(addr)?;
    let mut write_half = stream.try_clone()?;
    let n = lines.len();
    // reader first: responses stream back while we are still sending
    let reader = std::thread::spawn(move || -> std::io::Result<Vec<Json>> {
        let mut input = BufReader::new(stream);
        let mut got: Vec<Option<Json>> = (0..n).map(|_| None).collect();
        let mut remaining = n;
        let mut line = String::new();
        while remaining > 0 {
            line.clear();
            if input.read_line(&mut line)? == 0 {
                return Err(invalid(format!(
                    "daemon closed the connection with {remaining} responses outstanding"
                )));
            }
            let text = line.trim();
            if text.is_empty() {
                continue;
            }
            let j = json::parse(text).map_err(invalid)?;
            let seq = j
                .get("seq")
                .and_then(Json::as_u64)
                .ok_or_else(|| invalid(format!("response without seq: {text}")))?;
            let idx = (seq as usize)
                .checked_sub(1)
                .filter(|i| *i < n)
                .ok_or_else(|| invalid(format!("response seq {seq} out of range 1..={n}")))?;
            if got[idx].is_none() {
                got[idx] = Some(j);
                remaining -= 1;
            }
        }
        Ok(got.into_iter().map(|j| j.expect("all seqs answered")).collect())
    });
    for line in lines {
        write_half.write_all(line.as_bytes())?;
        write_half.write_all(b"\n")?;
    }
    write_half.flush()?;
    reader.join().map_err(|_| invalid("response reader panicked"))?
}

/// One request/response exchange on a fresh connection.
fn roundtrip(addr: &str, request: &str) -> std::io::Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(request.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    if line.trim().is_empty() {
        return Err(invalid("daemon closed the connection without a response"));
    }
    json::parse(line.trim()).map_err(invalid)
}

/// Fetch the full stats document (`{version, state, engine, daemon}`
/// plus the seq/control envelope).
pub fn fetch_stats(addr: &str) -> std::io::Result<Json> {
    roundtrip(addr, "{\"control\": \"stats\"}")
}

/// Request a graceful drain; returns the acknowledgement line.
pub fn request_shutdown(addr: &str) -> std::io::Result<Json> {
    roundtrip(addr, "{\"control\": \"shutdown\"}")
}

fn u(j: Option<&Json>) -> u64 {
    j.and_then(Json::as_u64).unwrap_or(0)
}

fn pct(hits: u64, misses: u64) -> f64 {
    if hits + misses == 0 {
        0.0
    } else {
        100.0 * hits as f64 / (hits + misses) as f64
    }
}

fn latency_line(h: Option<&Json>) -> String {
    let g = |k: &str| u(h.and_then(|h| h.get(k)));
    format!(
        "p50 {:>7} µs  p90 {:>7} µs  p99 {:>7} µs  (n={})",
        g("p50"),
        g("p90"),
        g("p99"),
        g("count")
    )
}

/// Render one `tdp top` text frame from a stats document.
pub fn render_top(addr: &str, stats: &Json) -> String {
    let state = stats.get("state").and_then(Json::as_str).unwrap_or("?");
    let d = stats.get("daemon");
    let e = stats.get("engine");
    let dg = |k: &str| u(d.and_then(|d| d.get(k)));
    let cache = e.and_then(|e| e.get("cache"));
    let cg = |k: &str| u(cache.and_then(|c| c.get(k)));
    let flight = e.and_then(|e| e.get("flight"));
    let latency = e.and_then(|e| e.get("latency"));
    let uptime = d
        .and_then(|d| d.get("uptime_secs"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    let mut out = String::new();
    out.push_str(&format!(
        "tdp top — {addr}   state: {state}   uptime: {uptime:.1}s\n"
    ));
    out.push_str(&format!(
        "queue    depth {}/{}   inflight {}   workers {}   clients {} ({} total conns)\n",
        dg("queue_depth"),
        dg("queue_capacity"),
        dg("inflight"),
        dg("workers"),
        dg("clients_connected"),
        dg("connections"),
    ));
    out.push_str(&format!(
        "jobs     accepted {}  completed {}  failed {}  rejected {} (full {}, draining {})  drained {}\n",
        dg("accepted"),
        dg("completed"),
        dg("failed"),
        dg("rejected"),
        dg("rejected_full"),
        dg("rejected_draining"),
        dg("drained"),
    ));
    out.push_str(&format!(
        "cache    hits {}  misses {}  evictions {}  entries {}  hit-rate {:.1}%\n",
        cg("hits"),
        cg("misses"),
        cg("evictions"),
        cg("entries"),
        pct(cg("hits"), cg("misses")),
    ));
    out.push_str(&format!(
        "flight   program-waits {}  graph-waits {}\n",
        u(flight.and_then(|f| f.get("program_waits"))),
        u(flight.and_then(|f| f.get("graph_waits"))),
    ));
    out.push_str(&format!(
        "compile  {}\n",
        latency_line(latency.and_then(|l| l.get("compile_micros")))
    ));
    out.push_str(&format!(
        "run      {}\n",
        latency_line(latency.and_then(|l| l.get("run_micros")))
    ));
    // per-client outstanding work (the fairness picture)
    if let Some(per) = d.and_then(|d| d.get("per_client")).and_then(Json::as_obj) {
        if !per.is_empty() {
            let cells: Vec<String> = per
                .iter()
                .map(|(id, v)| {
                    format!("#{id} q={} f={}", u(v.get("queued")), u(v.get("inflight")))
                })
                .collect();
            out.push_str(&format!("clients  {}\n", cells.join("  ")));
        }
    }
    // busiest workloads by job count, run p50 alongside
    if let Some(per) = e.and_then(|e| e.get("workloads")).and_then(Json::as_obj) {
        let mut rows: Vec<(&String, u64, u64)> = per
            .iter()
            .map(|(k, v)| {
                (
                    k,
                    u(v.get("jobs")),
                    u(v.get("run_micros").and_then(|h| h.get("p50"))),
                )
            })
            .collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(b.0)));
        for (k, jobs, p50) in rows.into_iter().take(5) {
            out.push_str(&format!("  {k:<40} jobs {jobs:>6}   run p50 {p50:>7} µs\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_frame_renders_the_load_bearing_fields() {
        // a miniature stats doc shaped like the daemon's
        let doc = json::parse(
            "{\"state\": \"serving\", \
              \"daemon\": {\"queue_depth\": 3, \"queue_capacity\": 256, \"inflight\": 2, \
                           \"workers\": 8, \"clients_connected\": 2, \"connections\": 5, \
                           \"accepted\": 10, \"completed\": 7, \"failed\": 1, \"rejected\": 2, \
                           \"rejected_full\": 2, \"rejected_draining\": 0, \"drained\": 0, \
                           \"uptime_secs\": 1.5, \
                           \"per_client\": {\"1\": {\"queued\": 3, \"inflight\": 2}}}, \
              \"engine\": {\"cache\": {\"hits\": 6, \"misses\": 2, \"evictions\": 0, \"entries\": 2}, \
                           \"flight\": {\"program_waits\": 1, \"graph_waits\": 0}, \
                           \"latency\": {\"compile_micros\": {\"count\": 2, \"p50\": 100, \"p90\": 100, \"p99\": 100}, \
                                          \"run_micros\": {\"count\": 8, \"p50\": 40, \"p90\": 60, \"p99\": 60}}, \
                           \"workloads\": {\"reduction:32\": {\"jobs\": 8, \
                                            \"run_micros\": {\"p50\": 40}}}}}",
        )
        .unwrap();
        let frame = render_top("127.0.0.1:7411", &doc);
        assert!(frame.contains("state: serving"), "{frame}");
        assert!(frame.contains("depth 3/256"), "{frame}");
        assert!(frame.contains("hit-rate 75.0%"), "{frame}");
        assert!(frame.contains("#1 q=3 f=2"), "{frame}");
        assert!(frame.contains("reduction:32"), "{frame}");
        // a degenerate doc still renders (every field defaults to 0)
        let empty = render_top("x", &Json::Obj(Default::default()));
        assert!(empty.contains("hit-rate 0.0%"), "{empty}");
    }
}
