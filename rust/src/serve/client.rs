//! Client helpers for the `tdp serve` protocol: submit a JSONL job
//! stream over a socket (`tdp batch --connect`), fetch or request
//! daemon state (`tdp top`, shutdown), and render the `tdp top` text
//! frame.
//!
//! The submitter pipelines: a reader thread collects seq-tagged
//! responses while the writer is still sending, so a large job file
//! can never deadlock on full kernel socket buffers, and responses are
//! reassembled into input order before they are returned.

use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

fn invalid<E: std::fmt::Display>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

/// One submission attempt over one fresh connection. The outer `Err` is
/// a connect failure (nothing was sent — safe to back off and redial);
/// `Ok((responses, stream_err))` carries whatever answers arrived
/// before the stream died, slot `i` holding the response to `lines[i]`,
/// plus the stream error if the connection was lost mid-exchange. A
/// dead daemon therefore yields a typed error naming the outstanding
/// count — never a hung reader thread.
fn submit_once(
    addr: &str,
    lines: &[String],
) -> std::io::Result<(Vec<Option<Json>>, Option<std::io::Error>)> {
    let stream = TcpStream::connect(addr)?;
    let mut write_half = stream.try_clone()?;
    let n = lines.len();
    // reader first: responses stream back while we are still sending,
    // so a large job file cannot deadlock on full kernel buffers
    let reader = std::thread::spawn(move || {
        let mut input = BufReader::new(stream);
        let mut got: Vec<Option<Json>> = (0..n).map(|_| None).collect();
        let mut remaining = n;
        let mut line = String::new();
        let mut failure: Option<std::io::Error> = None;
        while remaining > 0 {
            line.clear();
            match input.read_line(&mut line) {
                Ok(0) => {
                    failure = Some(invalid(format!(
                        "daemon closed the connection with {remaining} responses outstanding"
                    )));
                    break;
                }
                Ok(_) => {}
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
            let text = line.trim();
            if text.is_empty() {
                continue;
            }
            let parsed = json::parse(text).map_err(invalid).and_then(|j| {
                let seq = j
                    .get("seq")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| invalid(format!("response without seq: {text}")))?;
                let idx = (seq as usize)
                    .checked_sub(1)
                    .filter(|i| *i < n)
                    .ok_or_else(|| invalid(format!("response seq {seq} out of range 1..={n}")))?;
                Ok((idx, j))
            });
            match parsed {
                Ok((idx, j)) => {
                    if got[idx].is_none() {
                        got[idx] = Some(j);
                        remaining -= 1;
                    }
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        (got, failure)
    });
    let mut write_err: Option<std::io::Error> = None;
    for line in lines {
        let sent = write_half
            .write_all(line.as_bytes())
            .and_then(|()| write_half.write_all(b"\n"));
        if let Err(e) = sent {
            // unsendable lines will never be answered: force the reader
            // awake (EOF) instead of letting it wait forever
            let _ = write_half.shutdown(Shutdown::Both);
            write_err = Some(e);
            break;
        }
    }
    let _ = write_half.flush();
    let (got, read_err) = reader.join().map_err(|_| invalid("response reader panicked"))?;
    Ok((got, read_err.or(write_err)))
}

/// Send `lines` (one request per element, verbatim — the daemon does
/// all parsing and validation) and return one response per line, in
/// input order regardless of the daemon's completion order, redialing
/// up to `retries` times on connect failure or a connection lost
/// mid-exchange. Each redial resubmits only the still-unanswered lines
/// — answered seqs are never re-run, and resubmission of unanswered
/// jobs is idempotent against the daemon's content-addressed Program
/// cache — and every returned response has its `seq` re-homed to the
/// line's 1-based position in the *original* input, whatever position
/// it held in the retry subset.
pub fn submit_raw_lines_with_retry(
    addr: &str,
    lines: &[String],
    retries: usize,
) -> std::io::Result<Vec<Json>> {
    let n = lines.len();
    let mut answers: Vec<Option<Json>> = (0..n).map(|_| None).collect();
    let mut failures = 0usize;
    loop {
        let unanswered: Vec<usize> = (0..n).filter(|&i| answers[i].is_none()).collect();
        if unanswered.is_empty() {
            return Ok(answers.into_iter().map(|j| j.expect("all seqs answered")).collect());
        }
        let subset: Vec<String> = unanswered.iter().map(|&i| lines[i].clone()).collect();
        let err = match submit_once(addr, &subset) {
            Ok((got, stream_err)) => {
                for (&slot, j) in unanswered.iter().zip(got) {
                    if let Some(mut j) = j {
                        if let Json::Obj(m) = &mut j {
                            m.insert("seq".to_string(), Json::Num((slot + 1) as f64));
                        }
                        answers[slot] = Some(j);
                    }
                }
                match stream_err {
                    None => continue, // fully answered; the next pass returns
                    Some(e) => e,
                }
            }
            Err(e) => e,
        };
        if failures >= retries {
            let left = answers.iter().filter(|a| a.is_none()).count();
            return Err(std::io::Error::new(
                err.kind(),
                format!(
                    "giving up after {} attempt(s) with {left} response(s) outstanding: {err}",
                    failures + 1
                ),
            ));
        }
        // exponential backoff: 50ms, 100ms, ... capped at 3.2s
        std::thread::sleep(Duration::from_millis(50u64 << failures.min(6)));
        failures += 1;
    }
}

/// [`submit_raw_lines_with_retry`] without the redials: one connection,
/// one shot, a typed error if the daemon disappears mid-exchange.
pub fn submit_raw_lines(addr: &str, lines: &[String]) -> std::io::Result<Vec<Json>> {
    submit_raw_lines_with_retry(addr, lines, 0)
}

/// One request/response exchange on a fresh connection.
fn roundtrip(addr: &str, request: &str) -> std::io::Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(request.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    if line.trim().is_empty() {
        return Err(invalid("daemon closed the connection without a response"));
    }
    json::parse(line.trim()).map_err(invalid)
}

/// Fetch the full stats document (`{version, state, engine, daemon}`
/// plus the seq/control envelope).
pub fn fetch_stats(addr: &str) -> std::io::Result<Json> {
    roundtrip(addr, "{\"control\": \"stats\"}")
}

/// Request a graceful drain; returns the acknowledgement line.
pub fn request_shutdown(addr: &str) -> std::io::Result<Json> {
    roundtrip(addr, "{\"control\": \"shutdown\"}")
}

fn u(j: Option<&Json>) -> u64 {
    j.and_then(Json::as_u64).unwrap_or(0)
}

fn pct(hits: u64, misses: u64) -> f64 {
    if hits + misses == 0 {
        0.0
    } else {
        100.0 * hits as f64 / (hits + misses) as f64
    }
}

fn latency_line(h: Option<&Json>) -> String {
    let g = |k: &str| u(h.and_then(|h| h.get(k)));
    format!(
        "p50 {:>7} µs  p90 {:>7} µs  p99 {:>7} µs  (n={})",
        g("p50"),
        g("p90"),
        g("p99"),
        g("count")
    )
}

/// Render one `tdp top` text frame from a stats document.
pub fn render_top(addr: &str, stats: &Json) -> String {
    let state = stats.get("state").and_then(Json::as_str).unwrap_or("?");
    let d = stats.get("daemon");
    let e = stats.get("engine");
    let dg = |k: &str| u(d.and_then(|d| d.get(k)));
    let cache = e.and_then(|e| e.get("cache"));
    let cg = |k: &str| u(cache.and_then(|c| c.get(k)));
    let flight = e.and_then(|e| e.get("flight"));
    let latency = e.and_then(|e| e.get("latency"));
    let uptime = d
        .and_then(|d| d.get("uptime_secs"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    let mut out = String::new();
    out.push_str(&format!(
        "tdp top — {addr}   state: {state}   uptime: {uptime:.1}s\n"
    ));
    out.push_str(&format!(
        "queue    depth {}/{}   inflight {}   workers {}   clients {} ({} total conns)\n",
        dg("queue_depth"),
        dg("queue_capacity"),
        dg("inflight"),
        dg("workers"),
        dg("clients_connected"),
        dg("connections"),
    ));
    out.push_str(&format!(
        "jobs     accepted {}  completed {}  failed {}  rejected {} (full {}, draining {})  drained {}\n",
        dg("accepted"),
        dg("completed"),
        dg("failed"),
        dg("rejected"),
        dg("rejected_full"),
        dg("rejected_draining"),
        dg("drained"),
    ));
    out.push_str(&format!(
        "cache    hits {}  misses {}  evictions {}  entries {}  hit-rate {:.1}%\n",
        cg("hits"),
        cg("misses"),
        cg("evictions"),
        cg("entries"),
        pct(cg("hits"), cg("misses")),
    ));
    out.push_str(&format!(
        "flight   program-waits {}  graph-waits {}\n",
        u(flight.and_then(|f| f.get("program_waits"))),
        u(flight.and_then(|f| f.get("graph_waits"))),
    ));
    out.push_str(&format!(
        "compile  {}\n",
        latency_line(latency.and_then(|l| l.get("compile_micros")))
    ));
    out.push_str(&format!(
        "run      {}\n",
        latency_line(latency.and_then(|l| l.get("run_micros")))
    ));
    // per-client outstanding work (the fairness picture)
    if let Some(per) = d.and_then(|d| d.get("per_client")).and_then(Json::as_obj) {
        if !per.is_empty() {
            let cells: Vec<String> = per
                .iter()
                .map(|(id, v)| {
                    format!("#{id} q={} f={}", u(v.get("queued")), u(v.get("inflight")))
                })
                .collect();
            out.push_str(&format!("clients  {}\n", cells.join("  ")));
        }
    }
    // busiest workloads by job count, run p50 alongside
    if let Some(per) = e.and_then(|e| e.get("workloads")).and_then(Json::as_obj) {
        let mut rows: Vec<(&String, u64, u64)> = per
            .iter()
            .map(|(k, v)| {
                (
                    k,
                    u(v.get("jobs")),
                    u(v.get("run_micros").and_then(|h| h.get("p50"))),
                )
            })
            .collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(b.0)));
        for (k, jobs, p50) in rows.into_iter().take(5) {
            out.push_str(&format!("  {k:<40} jobs {jobs:>6}   run p50 {p50:>7} µs\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// A server that answers one of two pipelined jobs and hangs up; the
    /// retry layer must redial, resubmit only the unanswered line, and
    /// re-home the retry connection's `seq 1` back to input position 2.
    #[test]
    fn retry_resubmits_only_unanswered_lines_and_rehomes_seq() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || -> String {
            // conn 1: read both lines (so the close is a clean FIN, not
            // an RST racing the response), answer only seq 1, hang up
            let (mut s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            line.clear();
            r.read_line(&mut line).unwrap();
            s.write_all(b"{\"seq\": 1, \"result\": {\"tag\": \"first\"}}\n").unwrap();
            drop((s, r));
            // conn 2: the redial carries exactly the unanswered line
            let (mut s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            s.write_all(b"{\"seq\": 1, \"result\": {\"tag\": \"second\"}}\n").unwrap();
            line.trim().to_string()
        });
        let lines = vec!["{\"a\": 1}".to_string(), "{\"b\": 2}".to_string()];
        let out = submit_raw_lines_with_retry(&addr, &lines, 3).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].get("seq").unwrap().as_u64(), Some(1));
        assert_eq!(out[0].get("result").unwrap().get("tag").unwrap().as_str(), Some("first"));
        assert_eq!(out[1].get("seq").unwrap().as_u64(), Some(2), "seq re-homed to input order");
        assert_eq!(out[1].get("result").unwrap().get("tag").unwrap().as_str(), Some("second"));
        assert_eq!(server.join().unwrap(), "{\"b\": 2}", "only the unanswered line was resent");
    }

    /// Without retries, a daemon that dies mid-exchange yields a typed
    /// error naming the outstanding count — never a hung reader.
    #[test]
    fn early_close_is_a_typed_error_not_a_hang() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut r = BufReader::new(s);
            let mut line = String::new();
            r.read_line(&mut line).unwrap(); // consume, answer nothing
        });
        let err = submit_raw_lines(&addr, &["{\"a\": 1}".to_string()]).unwrap_err();
        assert!(err.to_string().contains("outstanding"), "{err}");
        server.join().unwrap();

        // a dead address exhausts its retries with a connect error
        let gone = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = submit_raw_lines_with_retry(&gone, &["{}".to_string()], 1).unwrap_err();
        assert!(err.to_string().contains("giving up after 2 attempt(s)"), "{err}");
    }

    #[test]
    fn top_frame_renders_the_load_bearing_fields() {
        // a miniature stats doc shaped like the daemon's
        let doc = json::parse(
            "{\"state\": \"serving\", \
              \"daemon\": {\"queue_depth\": 3, \"queue_capacity\": 256, \"inflight\": 2, \
                           \"workers\": 8, \"clients_connected\": 2, \"connections\": 5, \
                           \"accepted\": 10, \"completed\": 7, \"failed\": 1, \"rejected\": 2, \
                           \"rejected_full\": 2, \"rejected_draining\": 0, \"drained\": 0, \
                           \"uptime_secs\": 1.5, \
                           \"per_client\": {\"1\": {\"queued\": 3, \"inflight\": 2}}}, \
              \"engine\": {\"cache\": {\"hits\": 6, \"misses\": 2, \"evictions\": 0, \"entries\": 2}, \
                           \"flight\": {\"program_waits\": 1, \"graph_waits\": 0}, \
                           \"latency\": {\"compile_micros\": {\"count\": 2, \"p50\": 100, \"p90\": 100, \"p99\": 100}, \
                                          \"run_micros\": {\"count\": 8, \"p50\": 40, \"p90\": 60, \"p99\": 60}}, \
                           \"workloads\": {\"reduction:32\": {\"jobs\": 8, \
                                            \"run_micros\": {\"p50\": 40}}}}}",
        )
        .unwrap();
        let frame = render_top("127.0.0.1:7411", &doc);
        assert!(frame.contains("state: serving"), "{frame}");
        assert!(frame.contains("depth 3/256"), "{frame}");
        assert!(frame.contains("hit-rate 75.0%"), "{frame}");
        assert!(frame.contains("#1 q=3 f=2"), "{frame}");
        assert!(frame.contains("reduction:32"), "{frame}");
        // a degenerate doc still renders (every field defaults to 0)
        let empty = render_top("x", &Json::Obj(Default::default()));
        assert!(empty.contains("hit-rate 0.0%"), "{empty}");
    }
}
