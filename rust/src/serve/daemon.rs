//! The long-lived job daemon: a TCP accept loop, per-connection reader
//! threads, a bounded fair admission queue, and a worker pool running
//! jobs on one shared [`Engine`] — so the content-addressed Program
//! cache and single-flight compilation amortize across every client of
//! the process, not just one `tdp batch` invocation.
//!
//! Threading model (DESIGN.md §13): `run()` owns the accept loop; each
//! connection gets a reader thread that parses request lines, answers
//! control messages inline, and admits jobs into the [`FairQueue`];
//! `workers` pool threads pop round-robin across clients, run
//! [`Engine::submit`], and write the seq-tagged response to the
//! submitting connection. Responses therefore complete out of order
//! under concurrency — the `seq` tag is the client's reassembly key.
//!
//! Drain state machine: `serving → draining → stopped`. A `shutdown`
//! control line or [`DaemonHandle::drain`] (the CLI's SIGTERM path)
//! flips the atomic `draining` flag: new jobs are refused with a
//! structured `draining` error, everything already admitted runs to
//! completion and its response is flushed, workers exit once the queue
//! is dry, and `run()` returns after the last in-flight job completes.
//! No socket is ever closed with an answer still owed.

use super::protocol::{self, Control, ErrorCode, Request, PROTOCOL_VERSION};
use super::queue::{FairQueue, PushError};
use crate::error::{panic_message, Error};
use crate::faultinject::FaultPlan;
use crate::service::{Engine, JobSpec, DEFAULT_CACHE_CAPACITY};
use crate::telemetry::Registry;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Default bound of the admission queue (jobs admitted but not yet
/// picked up by a worker).
pub const DEFAULT_QUEUE_CAPACITY: usize = 256;

/// Daemon sizing knobs (`tdp serve --workers/--queue/--cache`) plus the
/// optional chaos plan (`tdp serve --fault-plan`).
#[derive(Debug, Clone, Default)]
pub struct ServeConfig {
    /// worker pool size; 0 = one per available core
    pub workers: usize,
    /// admission queue bound ([`FairQueue`] global capacity); 0 = the
    /// default bound
    pub queue_capacity: usize,
    /// [`Engine`] cache bound (programs and graphs resident at once);
    /// 0 = the default bound
    pub cache_capacity: usize,
    /// deterministic fault-injection plan handed to the shared
    /// [`Engine`] (DESIGN.md §15); `None` in production daemons
    pub fault_plan: Option<Arc<FaultPlan>>,
}

/// The per-connection response writer: workers and the reader share it,
/// one whole line written and flushed per lock hold.
type Writer = Arc<Mutex<TcpStream>>;

/// One admitted job waiting for (or holding) a worker.
struct Work {
    seq: u64,
    job: Box<JobSpec>,
    out: Writer,
    /// admission time: a job whose `timeout_ms` has already expired by
    /// the time a worker pops it is shed with `deadline_exceeded`
    /// instead of occupying the worker (DESIGN.md §15)
    admitted: Instant,
}

/// Monotonic daemon counters (mirrored onto the telemetry registry as
/// `serve.*` counters at event time).
#[derive(Default)]
struct Counters {
    connections: AtomicU64,
    accepted: AtomicU64,
    rejected_full: AtomicU64,
    rejected_draining: AtomicU64,
    bad_lines: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    drained: AtomicU64,
    stats_served: AtomicU64,
    /// jobs that panicked inside a worker (caught; the worker survived)
    panics: AtomicU64,
    /// jobs answered `deadline_exceeded` straight from the queue
    shed_deadline: AtomicU64,
}

struct Shared {
    engine: Engine,
    registry: Arc<Registry>,
    addr: SocketAddr,
    workers: usize,
    started: Instant,
    queue: Mutex<FairQueue<Work>>,
    /// workers wait here for admissions (and the drain wake-up)
    work_cv: Condvar,
    /// `run()` waits here for `outstanding() == 0` during drain
    idle_cv: Condvar,
    draining: AtomicBool,
    next_client: AtomicU64,
    clients_connected: AtomicU64,
    counters: Counters,
}

impl Shared {
    fn bump(&self, counter: &AtomicU64, key: &'static str) {
        counter.fetch_add(1, Ordering::Relaxed);
        self.registry.count(key, 1);
    }

    /// Publish the queue gauges; call with the queue lock held so the
    /// gauge pair is a coherent snapshot (lock order: queue → registry).
    fn publish_gauges(&self, q: &FairQueue<Work>) {
        self.registry.gauge("serve.queue_depth", q.queued() as f64);
        self.registry.gauge("serve.inflight", q.inflight() as f64);
    }

    fn state_name(&self) -> &'static str {
        if self.draining.load(Ordering::SeqCst) {
            "draining"
        } else {
            "serving"
        }
    }

    /// Begin the graceful drain (idempotent): refuse new admissions,
    /// wake idle workers so they can exit once the queue is dry, and
    /// poke the accept loop awake with a loopback connection.
    fn begin_drain(&self) {
        if self.draining.swap(true, Ordering::SeqCst) {
            return;
        }
        self.registry.count("serve.drains", 1);
        self.work_cv.notify_all();
        self.idle_cv.notify_all();
        let _ = TcpStream::connect(self.addr);
    }

    /// The daemon half of the stats document: queue/fairness gauges,
    /// admission counters, and the per-client outstanding-work map.
    fn daemon_json(&self) -> Json {
        let (queued, capacity, inflight, per_client) = {
            let q = self.queue.lock().expect("serve queue lock");
            (q.queued(), q.capacity(), q.inflight(), q.per_client())
        };
        let c = &self.counters;
        let num = |v: u64| Json::Num(v as f64);
        let rejected_full = c.rejected_full.load(Ordering::Relaxed);
        let rejected_draining = c.rejected_draining.load(Ordering::Relaxed);
        let mut clients = BTreeMap::new();
        for (id, (queued, inflight)) in per_client {
            let mut m = BTreeMap::new();
            m.insert("queued".to_string(), num(queued as u64));
            m.insert("inflight".to_string(), num(inflight as u64));
            clients.insert(id.to_string(), Json::Obj(m));
        }
        let mut m = BTreeMap::new();
        m.insert("queue_depth".to_string(), num(queued as u64));
        m.insert("queue_capacity".to_string(), num(capacity as u64));
        m.insert("inflight".to_string(), num(inflight as u64));
        m.insert("workers".to_string(), num(self.workers as u64));
        m.insert(
            "clients_connected".to_string(),
            num(self.clients_connected.load(Ordering::Relaxed)),
        );
        m.insert("connections".to_string(), num(c.connections.load(Ordering::Relaxed)));
        m.insert("accepted".to_string(), num(c.accepted.load(Ordering::Relaxed)));
        m.insert("rejected_full".to_string(), num(rejected_full));
        m.insert("rejected_draining".to_string(), num(rejected_draining));
        m.insert("rejected".to_string(), num(rejected_full + rejected_draining));
        m.insert("bad_lines".to_string(), num(c.bad_lines.load(Ordering::Relaxed)));
        m.insert("completed".to_string(), num(c.completed.load(Ordering::Relaxed)));
        m.insert("failed".to_string(), num(c.failed.load(Ordering::Relaxed)));
        m.insert("drained".to_string(), num(c.drained.load(Ordering::Relaxed)));
        m.insert("stats_served".to_string(), num(c.stats_served.load(Ordering::Relaxed)));
        m.insert("panics".to_string(), num(c.panics.load(Ordering::Relaxed)));
        m.insert("shed_deadline".to_string(), num(c.shed_deadline.load(Ordering::Relaxed)));
        m.insert(
            "uptime_secs".to_string(),
            Json::Num(self.started.elapsed().as_secs_f64()),
        );
        m.insert("per_client".to_string(), Json::Obj(clients));
        Json::Obj(m)
    }

    /// The full stats document (`{version, state, engine, daemon}`) —
    /// what the `stats` control request returns and `tdp serve
    /// --metrics-out` writes at exit.
    fn stats_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("version".to_string(), Json::Num(PROTOCOL_VERSION as f64));
        m.insert("state".to_string(), Json::Str(self.state_name().to_string()));
        m.insert("engine".to_string(), self.engine.metrics_snapshot());
        m.insert("daemon".to_string(), self.daemon_json());
        Json::Obj(m)
    }
}

/// A handle for controlling a running daemon from outside its threads
/// (the CLI's signal watcher, tests).
#[derive(Clone)]
pub struct DaemonHandle {
    shared: Arc<Shared>,
}

impl DaemonHandle {
    /// Trigger the graceful drain, exactly as a `shutdown` control line
    /// would. Idempotent; returns immediately (drain completion is
    /// observed by [`Daemon::run`] returning).
    pub fn drain(&self) {
        self.shared.begin_drain();
    }

    /// True once a drain has been requested.
    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// The current full stats document (`{version, state, engine,
    /// daemon}`).
    pub fn stats_json(&self) -> Json {
        self.shared.stats_json()
    }
}

/// A bound-but-not-yet-running daemon. [`Daemon::bind`] reserves the
/// socket (so the caller can learn the ephemeral port before serving);
/// [`Daemon::run`] serves until drained.
pub struct Daemon {
    shared: Arc<Shared>,
    listener: TcpListener,
}

impl Daemon {
    /// Bind `addr` (e.g. `127.0.0.1:7411`, port 0 for ephemeral) and
    /// build the engine, queue, and worker sizing. Daemon gauges and
    /// counters register on `registry` under `serve.*`.
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        cfg: ServeConfig,
        registry: Arc<Registry>,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let workers = if cfg.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            cfg.workers
        };
        let queue_capacity = if cfg.queue_capacity == 0 {
            DEFAULT_QUEUE_CAPACITY
        } else {
            cfg.queue_capacity
        };
        let cache_capacity = if cfg.cache_capacity == 0 {
            DEFAULT_CACHE_CAPACITY
        } else {
            cfg.cache_capacity
        };
        let shared = Arc::new(Shared {
            engine: Engine::with_capacity_and_faults(cache_capacity, cfg.fault_plan.clone()),
            registry,
            addr,
            workers,
            started: Instant::now(),
            queue: Mutex::new(FairQueue::new(queue_capacity)),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
            draining: AtomicBool::new(false),
            next_client: AtomicU64::new(0),
            clients_connected: AtomicU64::new(0),
            counters: Counters::default(),
        });
        shared.registry.gauge("serve.queue_depth", 0.0);
        shared.registry.gauge("serve.inflight", 0.0);
        shared.registry.gauge("serve.clients", 0.0);
        Ok(Self { shared, listener })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// A control handle usable from other threads while `run()` blocks.
    pub fn handle(&self) -> DaemonHandle {
        DaemonHandle { shared: Arc::clone(&self.shared) }
    }

    /// Serve until drained: accept connections, run jobs, and return
    /// once a drain (control line, [`DaemonHandle::drain`]) has been
    /// requested *and* every admitted job's response has been written.
    pub fn run(self) -> std::io::Result<()> {
        let mut pool = Vec::with_capacity(self.shared.workers);
        for _ in 0..self.shared.workers {
            let shared = Arc::clone(&self.shared);
            pool.push(std::thread::spawn(move || worker_loop(&shared)));
        }
        for stream in self.listener.incoming() {
            if self.shared.draining.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let shared = Arc::clone(&self.shared);
            std::thread::spawn(move || reader_loop(&shared, stream));
        }
        // drain barrier: every admitted job answered before we return
        {
            let mut q = self.shared.queue.lock().expect("serve queue lock");
            while q.outstanding() > 0 {
                q = self.shared.idle_cv.wait(q).expect("serve queue lock");
            }
        }
        self.shared.work_cv.notify_all();
        for h in pool {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Write one response line; errors are ignored (the client may already
/// be gone, and its remaining jobs still run to completion).
fn write_line(out: &Writer, line: &str) {
    if let Ok(mut s) = out.lock() {
        let _ = s.write_all(line.as_bytes());
        let _ = s.write_all(b"\n");
        let _ = s.flush();
    }
}

/// One worker: pop round-robin, run on the shared engine, respond.
fn worker_loop(shared: &Shared) {
    loop {
        let popped = {
            let mut q = shared.queue.lock().expect("serve queue lock");
            loop {
                if let Some((client, work)) = q.pop() {
                    shared.publish_gauges(&q);
                    break Some((client, work));
                }
                if shared.draining.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.work_cv.wait(q).expect("serve queue lock");
            }
        };
        let Some((client, work)) = popped else { return };
        // deadline-aware shedding: a job already past its budget while
        // queued is answered without ever starting
        let shed = work
            .job
            .timeout_ms
            .is_some_and(|ms| work.admitted.elapsed() >= Duration::from_millis(ms));
        let line = if shed {
            shared.bump(&shared.counters.shed_deadline, "serve.shed_deadline");
            shared.bump(&shared.counters.failed, "serve.failed");
            protocol::shed_response(work.seq)
        } else {
            // unwind belt: a panic anywhere in submit fails this one
            // job with a structured response; the worker (and the
            // daemon) keep serving, and `complete` below still runs so
            // the drain predicate cannot wedge
            match catch_unwind(AssertUnwindSafe(|| shared.engine.submit(&work.job))) {
                Ok(Ok(result)) => {
                    shared.bump(&shared.counters.completed, "serve.completed");
                    protocol::result_response(work.seq, &result)
                }
                Ok(Err(e)) => {
                    shared.bump(&shared.counters.failed, "serve.failed");
                    protocol::job_error_response(work.seq, &e)
                }
                Err(payload) => {
                    shared.bump(&shared.counters.panics, "serve.panics");
                    shared.bump(&shared.counters.failed, "serve.failed");
                    let e = Error::Panicked {
                        stage: "worker",
                        message: panic_message(payload.as_ref()),
                    };
                    protocol::job_error_response(work.seq, &e)
                }
            }
        };
        if shared.draining.load(Ordering::SeqCst) {
            shared.bump(&shared.counters.drained, "serve.drained");
        }
        write_line(&work.out, &line);
        let outstanding = {
            let mut q = shared.queue.lock().expect("serve queue lock");
            q.complete(client);
            shared.publish_gauges(&q);
            q.outstanding()
        };
        if outstanding == 0 {
            shared.idle_cv.notify_all();
        }
    }
}

/// One connection: parse request lines, answer controls inline, admit
/// jobs (or refuse them with structured errors — never a disconnect).
fn reader_loop(shared: &Shared, stream: TcpStream) {
    let Ok(write_half) = stream.try_clone() else { return };
    let out: Writer = Arc::new(Mutex::new(write_half));
    let client = shared.next_client.fetch_add(1, Ordering::Relaxed) + 1;
    shared.bump(&shared.counters.connections, "serve.connections");
    let connected = shared.clients_connected.fetch_add(1, Ordering::Relaxed) + 1;
    shared.registry.gauge("serve.clients", connected as f64);
    let mut seq = 0u64;
    for line in BufReader::new(stream).lines() {
        let Ok(line) = line else { break };
        let text = line.trim();
        if text.is_empty() {
            continue;
        }
        seq += 1;
        match protocol::parse_request(text) {
            Err(msg) => {
                shared.bump(&shared.counters.bad_lines, "serve.bad_lines");
                write_line(&out, &protocol::error_response(seq, ErrorCode::BadRequest, &msg));
            }
            Ok(Request::Control(Control::Ping)) => {
                write_line(&out, &protocol::ping_response(seq));
            }
            Ok(Request::Control(Control::Stats)) => {
                shared.bump(&shared.counters.stats_served, "serve.stats_served");
                let line = protocol::stats_response(
                    seq,
                    shared.engine.metrics_snapshot(),
                    shared.daemon_json(),
                    shared.state_name(),
                );
                write_line(&out, &line);
            }
            Ok(Request::Control(Control::Shutdown)) => {
                // ack first, then flip the state: the requester always
                // sees the acknowledgement even if drain finishes fast
                write_line(&out, &protocol::shutdown_response(seq));
                shared.begin_drain();
            }
            Ok(Request::Job(job)) => {
                if shared.draining.load(Ordering::SeqCst) {
                    shared.bump(&shared.counters.rejected_draining, "serve.rejected");
                    let line = protocol::error_response(
                        seq,
                        ErrorCode::Draining,
                        "daemon is draining and admits no new jobs",
                    );
                    write_line(&out, &line);
                    continue;
                }
                let admitted = {
                    let mut q = shared.queue.lock().expect("serve queue lock");
                    let res = q.push(
                        client,
                        Work { seq, job, out: Arc::clone(&out), admitted: Instant::now() },
                    );
                    if res.is_ok() {
                        shared.publish_gauges(&q);
                    }
                    res.map_err(|PushError::Full| q.capacity())
                };
                match admitted {
                    Ok(()) => {
                        shared.bump(&shared.counters.accepted, "serve.accepted");
                        shared.work_cv.notify_one();
                    }
                    Err(capacity) => {
                        shared.bump(&shared.counters.rejected_full, "serve.rejected");
                        let line = protocol::error_response(
                            seq,
                            ErrorCode::QueueFull,
                            &format!("queue full (capacity {capacity})"),
                        );
                        write_line(&out, &line);
                    }
                }
            }
        }
    }
    let connected = shared.clients_connected.fetch_sub(1, Ordering::Relaxed) - 1;
    shared.registry.gauge("serve.clients", connected as f64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{self, Json};
    use std::io::{BufRead, BufReader, Write};

    fn send_line(stream: &mut TcpStream, line: &str) {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
    }

    fn read_json(reader: &mut BufReader<TcpStream>) -> Json {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response '{line}': {e}"))
    }

    /// Idle daemon lifecycle: bind an ephemeral port, answer ping and
    /// stats, drain via the control line, and join cleanly.
    #[test]
    fn ping_stats_and_drain_on_idle_daemon() {
        let registry = Arc::new(Registry::new());
        let daemon = Daemon::bind(
            "127.0.0.1:0",
            ServeConfig { workers: 2, ..Default::default() },
            Arc::clone(&registry),
        )
        .unwrap();
        let addr = daemon.local_addr();
        let handle = daemon.handle();
        let server = std::thread::spawn(move || daemon.run());

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        send_line(&mut stream, "{\"control\": \"ping\"}");
        let pong = read_json(&mut reader);
        assert_eq!(pong.get("seq").unwrap().as_u64(), Some(1));
        assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

        send_line(&mut stream, "{\"control\": \"stats\"}");
        let stats = read_json(&mut reader);
        assert_eq!(stats.get("state").unwrap().as_str(), Some("serving"));
        assert_eq!(stats.get("version").unwrap().as_u64(), Some(PROTOCOL_VERSION));
        let daemon_doc = stats.get("daemon").unwrap();
        assert_eq!(daemon_doc.get("queue_depth").unwrap().as_u64(), Some(0));
        assert_eq!(daemon_doc.get("workers").unwrap().as_u64(), Some(2));
        assert_eq!(daemon_doc.get("clients_connected").unwrap().as_u64(), Some(1));
        assert_eq!(
            stats.get("engine").unwrap().get("version").unwrap().as_u64(),
            Some(1),
            "engine snapshot nests intact"
        );
        // daemon gauges registered on the passed-in registry
        assert_eq!(registry.gauge_value("serve.queue_depth"), Some(0.0));
        assert_eq!(registry.gauge_value("serve.clients"), Some(1.0));

        send_line(&mut stream, "{\"control\": \"shutdown\"}");
        let ack = read_json(&mut reader);
        assert_eq!(ack.get("state").unwrap().as_str(), Some("draining"));
        assert!(handle.is_draining());
        server.join().unwrap().unwrap();
        assert_eq!(handle.stats_json().get("state").unwrap().as_str(), Some("draining"));
    }

    /// One job over the socket end-to-end, plus a structured error for
    /// a misspelled field — same connection, no disconnect.
    #[test]
    fn job_roundtrip_and_bad_request_share_a_connection() {
        let registry = Arc::new(Registry::new());
        let daemon =
            Daemon::bind("127.0.0.1:0", ServeConfig { workers: 1, ..Default::default() }, registry)
                .unwrap();
        let addr = daemon.local_addr();
        let handle = daemon.handle();
        let server = std::thread::spawn(move || daemon.run());

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        send_line(&mut stream, "{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}");
        let r1 = read_json(&mut reader);
        assert_eq!(r1.get("seq").unwrap().as_u64(), Some(1));
        let result = r1.get("result").expect("job succeeded");
        assert_eq!(result.get("workload").unwrap().as_str(), Some("reduction:32"));
        assert!(result.get("stats").unwrap().get("cycles").unwrap().as_u64().unwrap() > 0);

        // protocol typo → structured bad_request on the same connection
        send_line(&mut stream, "{\"workload\": \"reduction:32\", \"schedular\": \"ooo\"}");
        let r2 = read_json(&mut reader);
        assert_eq!(r2.get("seq").unwrap().as_u64(), Some(2));
        assert_eq!(r2.get("code").unwrap().as_str(), Some("bad_request"));
        assert!(r2.get("error").unwrap().as_str().unwrap().contains("schedular"));

        // the connection survived: a third request still answers
        send_line(&mut stream, "{\"workload\": \"reduction:32\", \"cols\": 2, \"rows\": 2}");
        let r3 = read_json(&mut reader);
        assert_eq!(r3.get("seq").unwrap().as_u64(), Some(3));
        assert!(r3.get("result").unwrap().get("cache_hit").unwrap() == &Json::Bool(true));

        handle.drain();
        server.join().unwrap().unwrap();
        let daemon_doc = handle.stats_json();
        let d = daemon_doc.get("daemon").unwrap();
        assert_eq!(d.get("accepted").unwrap().as_u64(), Some(2));
        assert_eq!(d.get("completed").unwrap().as_u64(), Some(2));
        assert_eq!(d.get("bad_lines").unwrap().as_u64(), Some(1));
    }

    /// Panic isolation (DESIGN.md §15): an injected compile panic is
    /// answered as a structured `panicked` response, the worker and
    /// connection survive, and — because the poisoned flight latch is
    /// cleared and injected panics fire once — resubmitting the same
    /// job succeeds.
    #[test]
    fn worker_survives_injected_compile_panic_and_recovers() {
        let plan = FaultPlan {
            compile_panics: vec!["reduction:24".to_string()],
            ..Default::default()
        };
        let registry = Arc::new(Registry::new());
        let daemon = Daemon::bind(
            "127.0.0.1:0",
            ServeConfig { workers: 1, fault_plan: Some(Arc::new(plan)), ..Default::default() },
            registry,
        )
        .unwrap();
        let addr = daemon.local_addr();
        let handle = daemon.handle();
        let server = std::thread::spawn(move || daemon.run());

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let job = "{\"workload\": \"reduction:24\", \"cols\": 2, \"rows\": 2}";
        send_line(&mut stream, job);
        let r1 = read_json(&mut reader);
        assert_eq!(r1.get("code").unwrap().as_str(), Some("panicked"));
        assert!(r1.get("error").unwrap().as_str().unwrap().contains("fault injection"), "{r1:?}");

        // same connection, same job: the retry compiles for real
        send_line(&mut stream, job);
        let r2 = read_json(&mut reader);
        assert_eq!(r2.get("seq").unwrap().as_u64(), Some(2));
        assert!(r2.get("result").is_some(), "retry after poison recovers: {r2:?}");

        handle.drain();
        server.join().unwrap().unwrap();
        let stats = handle.stats_json();
        let d = stats.get("daemon").unwrap();
        assert_eq!(d.get("failed").unwrap().as_u64(), Some(1));
        assert_eq!(d.get("completed").unwrap().as_u64(), Some(1));
        let faults = stats.get("engine").unwrap().get("faults").unwrap();
        assert_eq!(faults.get("injected_compile_panics").unwrap().as_u64(), Some(1));
    }

    /// Deadline-aware shedding: a job whose budget expired while it sat
    /// in the queue is answered `deadline_exceeded` without ever
    /// occupying a worker; the daemon stays healthy for the next job.
    #[test]
    fn expired_queued_jobs_are_shed_without_running() {
        let registry = Arc::new(Registry::new());
        let daemon =
            Daemon::bind("127.0.0.1:0", ServeConfig { workers: 1, ..Default::default() }, registry)
                .unwrap();
        let addr = daemon.local_addr();
        let handle = daemon.handle();
        let server = std::thread::spawn(move || daemon.run());

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        // timeout_ms 0: already expired by the time any worker pops it
        send_line(
            &mut stream,
            "{\"workload\": \"chain:32\", \"cols\": 2, \"rows\": 2, \"timeout_ms\": 0}",
        );
        let shed = read_json(&mut reader);
        assert_eq!(shed.get("code").unwrap().as_str(), Some("deadline_exceeded"));
        assert!(shed.get("error").unwrap().as_str().unwrap().contains("queued"), "{shed:?}");

        // the undeadlined duplicate runs normally afterwards
        send_line(&mut stream, "{\"workload\": \"chain:32\", \"cols\": 2, \"rows\": 2}");
        let ok = read_json(&mut reader);
        assert!(ok.get("result").is_some(), "{ok:?}");

        handle.drain();
        server.join().unwrap().unwrap();
        let d = handle.stats_json();
        let d = d.get("daemon").unwrap();
        assert_eq!(d.get("shed_deadline").unwrap().as_u64(), Some(1));
        assert_eq!(d.get("completed").unwrap().as_u64(), Some(1));
    }

    /// A client that vanishes with jobs queued and in flight must not
    /// wedge the drain: its jobs still run (responses are dropped on the
    /// floor), `outstanding()` reaches zero, and `run()` returns.
    #[test]
    fn abrupt_client_disconnect_does_not_wedge_the_drain() {
        let registry = Arc::new(Registry::new());
        let daemon =
            Daemon::bind("127.0.0.1:0", ServeConfig { workers: 1, ..Default::default() }, registry)
                .unwrap();
        let addr = daemon.local_addr();
        let handle = daemon.handle();
        let server = std::thread::spawn(move || daemon.run());

        let mut stream = TcpStream::connect(addr).unwrap();
        for _ in 0..3 {
            send_line(&mut stream, "{\"workload\": \"chain:24:seed=1\", \"cols\": 2, \"rows\": 2}");
        }
        // wait until all three are admitted, then hang up without
        // reading a single response
        loop {
            let d = handle.stats_json();
            if d.get("daemon").unwrap().get("accepted").unwrap().as_u64() == Some(3) {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(stream);

        handle.drain();
        server.join().unwrap().unwrap();
        let d = handle.stats_json();
        let d = d.get("daemon").unwrap();
        assert_eq!(d.get("completed").unwrap().as_u64(), Some(3), "orphaned jobs still ran");
        assert_eq!(d.get("clients_connected").unwrap().as_u64(), Some(0));
    }
}
