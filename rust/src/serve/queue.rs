//! The bounded, per-client-fair admission queue of the daemon.
//!
//! [`FairQueue`] holds one FIFO per client plus a round-robin rotation
//! of clients with pending work: each [`FairQueue::pop`] takes one item
//! from the client at the front of the rotation and sends that client
//! to the rear, so a firehose client gets exactly one slot per turn and
//! can never starve the others. Admission is bounded by a *global*
//! capacity — [`FairQueue::push`] returns [`PushError::Full`] instead
//! of growing, which the daemon turns into a structured `queue_full`
//! response (backpressure without disconnects).
//!
//! The queue is plain data: the daemon wraps it in a `Mutex` and pairs
//! it with condvars. In-flight accounting lives here too so the stats
//! endpoint reads one coherent picture under one lock.

use std::collections::{BTreeMap, VecDeque};

/// Why a push was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// the global bound is reached — retry after completions drain it
    Full,
}

/// A bounded multi-client queue with round-robin pop fairness.
#[derive(Debug)]
pub struct FairQueue<T> {
    capacity: usize,
    queues: BTreeMap<u64, VecDeque<T>>,
    rotation: VecDeque<u64>,
    queued: usize,
    inflight: BTreeMap<u64, usize>,
    inflight_total: usize,
}

impl<T> FairQueue<T> {
    /// An empty queue admitting at most `capacity` items at once.
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            queues: BTreeMap::new(),
            rotation: VecDeque::new(),
            queued: 0,
            inflight: BTreeMap::new(),
            inflight_total: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Items admitted but not yet popped.
    pub fn queued(&self) -> usize {
        self.queued
    }

    /// Items popped but not yet marked complete.
    pub fn inflight(&self) -> usize {
        self.inflight_total
    }

    /// `queued() + inflight()` — the work the daemon still owes answers
    /// for (the drain-completion predicate).
    pub fn outstanding(&self) -> usize {
        self.queued + self.inflight_total
    }

    /// Admit one item for `client`, or refuse at capacity.
    pub fn push(&mut self, client: u64, item: T) -> Result<(), PushError> {
        if self.queued >= self.capacity {
            return Err(PushError::Full);
        }
        let q = self.queues.entry(client).or_default();
        if q.is_empty() {
            self.rotation.push_back(client);
        }
        q.push_back(item);
        self.queued += 1;
        Ok(())
    }

    /// Take the next item round-robin across clients; the item moves to
    /// the in-flight set until [`FairQueue::complete`] is called for its
    /// client. Returns `None` when nothing is queued.
    pub fn pop(&mut self) -> Option<(u64, T)> {
        let client = self.rotation.pop_front()?;
        let q = self.queues.get_mut(&client).expect("rotation tracks queues");
        let item = q.pop_front().expect("rotated clients are non-empty");
        if q.is_empty() {
            self.queues.remove(&client);
        } else {
            self.rotation.push_back(client);
        }
        self.queued -= 1;
        *self.inflight.entry(client).or_insert(0) += 1;
        self.inflight_total += 1;
        Some((client, item))
    }

    /// Mark one popped item of `client` finished.
    pub fn complete(&mut self, client: u64) {
        let n = self.inflight.get_mut(&client).expect("complete matches a pop");
        *n -= 1;
        if *n == 0 {
            self.inflight.remove(&client);
        }
        self.inflight_total -= 1;
    }

    /// Per-client `(queued, inflight)` of every client with outstanding
    /// work, for the stats endpoint.
    pub fn per_client(&self) -> BTreeMap<u64, (usize, usize)> {
        let mut out: BTreeMap<u64, (usize, usize)> = BTreeMap::new();
        for (&c, q) in &self.queues {
            out.entry(c).or_insert((0, 0)).0 = q.len();
        }
        for (&c, &n) in &self.inflight {
            out.entry(c).or_insert((0, 0)).1 = n;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_interleaves_clients() {
        // client 1 floods 6 items before clients 2 and 3 enqueue 2 each;
        // pops must still alternate across clients, one slot per turn
        let mut q = FairQueue::new(64);
        for i in 0..6 {
            q.push(1, format!("a{i}")).unwrap();
        }
        for i in 0..2 {
            q.push(2, format!("b{i}")).unwrap();
            q.push(3, format!("c{i}")).unwrap();
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|(c, _)| c)).collect();
        assert_eq!(order, vec![1, 2, 3, 1, 2, 3, 1, 1, 1, 1], "firehose waits its turn");
        assert_eq!(q.queued(), 0);
        assert_eq!(q.inflight(), 10);
        for c in order {
            q.complete(c);
        }
        assert_eq!(q.outstanding(), 0);
    }

    #[test]
    fn fifo_within_one_client() {
        let mut q = FairQueue::new(8);
        for i in 0..3 {
            q.push(9, i).unwrap();
        }
        let items: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, v)| v)).collect();
        assert_eq!(items, vec![0, 1, 2]);
    }

    #[test]
    fn capacity_bounds_admission_globally() {
        let mut q = FairQueue::new(2);
        q.push(1, "a").unwrap();
        q.push(2, "b").unwrap();
        assert_eq!(q.push(3, "c"), Err(PushError::Full), "bound is global");
        // popping frees a slot (in-flight work does not count against
        // the *admission* bound — it already holds a worker)
        let (c, _) = q.pop().unwrap();
        q.push(3, "c").unwrap();
        q.complete(c);
        assert_eq!(q.queued(), 2);
        // a zero capacity still admits one job at a time
        assert_eq!(FairQueue::<u8>::new(0).capacity(), 1);
    }

    /// The disconnect scenario (DESIGN.md §15): a client vanishes with
    /// work both queued and in flight. The queue has no "disconnect"
    /// verb by design — its items still run and complete — so the only
    /// requirement is that the normal pop/complete protocol drives
    /// `outstanding()` to zero and the drain predicate terminates.
    #[test]
    fn orphaned_client_work_still_drains_to_zero() {
        let mut q = FairQueue::new(8);
        q.push(1, "a").unwrap();
        q.push(1, "b").unwrap();
        q.push(1, "c").unwrap();
        q.push(2, "d").unwrap();
        let (c, _) = q.pop().unwrap();
        assert_eq!(c, 1, "client 1 has one job in flight");
        // client 1's socket dies here: nothing is removed, the daemon
        // keeps owing the pops and completes
        assert_eq!(q.outstanding(), 4);
        q.complete(1);
        let mut popped = 0;
        while let Some((client, _)) = q.pop() {
            q.complete(client);
            popped += 1;
        }
        assert_eq!(popped, 3);
        assert_eq!(q.outstanding(), 0, "drain predicate terminates");
        assert!(q.per_client().is_empty());
    }

    #[test]
    fn per_client_snapshot_tracks_both_phases() {
        let mut q = FairQueue::new(8);
        q.push(1, "a").unwrap();
        q.push(1, "b").unwrap();
        q.push(2, "c").unwrap();
        let (c, _) = q.pop().unwrap();
        assert_eq!(c, 1);
        let snap = q.per_client();
        assert_eq!(snap.get(&1), Some(&(1, 1)), "one queued, one in flight");
        assert_eq!(snap.get(&2), Some(&(1, 0)));
        q.complete(1);
        assert_eq!(q.per_client().get(&1), Some(&(1, 0)));
    }
}
