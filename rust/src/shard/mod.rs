//! Sharded multi-fabric execution (DESIGN.md §14): run one dataflow
//! graph across N simulated overlays when it cannot fit — or should not
//! monopolize — a single fabric.
//!
//! ## Compile side
//!
//! [`ShardedProgram::compile`] runs a short pass pipeline (verify →
//! criticality → [`crate::passes::PartitionPass`]) over the original
//! graph, then extracts one subgraph per shard. Every boundary in-edge
//! (producer in another shard) becomes a **proxy input** in the consumer
//! shard — a placeholder `Input` node carrying no token until the
//! runtime injects the producer's value across a boundary channel. A
//! producer fanning out to many consumers in one shard gets a single
//! proxy there, so each `(producer, consumer shard)` pair crosses the
//! boundary exactly once. Proxies are interleaved at their producer's
//! original id, and members keep their relative order, so builder order
//! stays topological and each shard then compiles through the standard
//! per-fabric pipeline (place → bram_images → bake_tables) *unchanged*.
//! With one shard the extraction reproduces the original graph
//! node-for-node (same fingerprint), which is what makes the sharded
//! N=1 path bit-identical to single-fabric execution.
//!
//! ## Run side
//!
//! [`ShardSession::run`] builds one [`SimBackend`] per shard (boundary
//! proxies deferred, [`crate::engine::backend_with_tables_deferred`])
//! and advances all of them in lockstep **epochs** of E cycles on a
//! [`crate::util::par::run_parallel`] worker pool. At each epoch
//! barrier, every [`BoundaryChannel`] — a bounded queue modeling the
//! higher-latency inter-fabric link — harvests newly computed producer
//! values, promotes up to `capacity` of them in flight, and delivers
//! the previous barrier's in-flight values into the consumer shards'
//! proxies. A value computed at cycle `c` of epoch `k` becomes visible
//! at cycle `(k+2)·E`, i.e. after `E < latency ≤ 2E` cycles — never
//! less than the modeled link latency E ([`boundary_latency`]).
//!
//! **Determinism invariant**: shards interact *only* at barriers, and
//! every barrier walks channels, links and injections in one canonical
//! order (channels sorted by `(src, dst)` shard pair, links by producer
//! id) — worker threads never touch shared state mid-epoch. Results are
//! therefore invariant under thread count and scheduling interleaving;
//! `tests/sharding.rs` pins this.

use crate::config::{Overlay, OverlayConfig};
use crate::engine::{self, BackendKind, SimBackend};
use crate::graph::{DataflowGraph, NodeKind};
use crate::noc::NetworkStats;
use crate::passes::partition::Partition;
use crate::passes::{CriticalityPass, PartitionPass, PassCtx, PassManager, VerifyPass};
use crate::faultinject::FaultPlan;
use crate::program::{CompileError, Program, SharedProgram};
use crate::sched::SchedulerKind;
use crate::sim::{CancelToken, PeStats, SimError, SimStats};
use crate::telemetry::{self, Registry, Telemetry};
use crate::util::par::run_parallel;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// In-flight capacity of one directed boundary channel per epoch:
/// harvested values beyond this wait (counted as stalls) and drain on
/// later barriers.
pub const BOUNDARY_CHANNEL_CAPACITY: usize = 16;

/// The epoch watchdog's zero-progress window, in fabric cycles: when no
/// shard completes a node, no boundary value is harvested, promoted or
/// delivered, and no shard finishes for this many consecutive cycles
/// (rounded up to whole epochs), the run is declared stalled
/// ([`SimError::ShardStalled`]) instead of spinning to `max_cycles`.
/// Sized far above any legitimate quiet period (ALU latency, a
/// boundary round-trip of 2E) yet tiny next to a real cycle budget.
pub const WATCHDOG_STALL_CYCLES: u64 = 1024;

/// Modeled latency of an inter-fabric link, in fabric cycles — a
/// serialized off-fabric hop is never cheaper than crossing the torus
/// itself, so it scales with the fabric diameter plus a fixed
/// serialization cost. Also the epoch length E: syncing every E cycles
/// can only *add* latency (delivery lands at the next barrier), so the
/// channel model is honored for every thread interleaving.
pub fn boundary_latency(cfg: &OverlayConfig) -> u64 {
    (cfg.cols + cfg.rows) as u64 + 4
}

/// One value that crosses a boundary channel: original-graph `producer`,
/// its node id in the producing shard's subgraph (`src_local`) and the
/// proxy input standing in for it in the consuming shard (`dst_local`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoundaryLink {
    pub producer: u32,
    pub src_local: u32,
    pub dst_local: u32,
}

/// A directed inter-fabric channel: every boundary value flowing from
/// `src_shard` to `dst_shard`, links sorted by producer id (the
/// canonical barrier-processing order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChannelSpec {
    pub src_shard: u32,
    pub dst_shard: u32,
    pub links: Vec<BoundaryLink>,
}

/// One shard of a [`ShardedProgram`]: a compiled per-fabric program over
/// the extracted subgraph, plus the id maps tying it back to the
/// original graph.
pub struct ShardUnit {
    /// the shard's subgraph compiled through the standard per-fabric
    /// pipeline
    pub program: SharedProgram,
    /// subgraph node id → original graph node id (a proxy maps to the
    /// boundary producer it stands in for)
    pub orig_of_local: Vec<u32>,
    /// subgraph node ids of the boundary proxies, ascending (the
    /// deferred-seed list)
    pub deferred: Vec<u32>,
    /// executed-graph nodes standing in for proxies (equals
    /// `deferred.len()` unless an `opt` pipeline replicated or dropped
    /// some) — subtracted when merging per-shard completion counts
    exec_proxies: usize,
}

impl ShardUnit {
    /// Subgraph node count (members + proxies).
    pub fn len(&self) -> usize {
        self.orig_of_local.len()
    }

    pub fn is_empty(&self) -> bool {
        self.orig_of_local.is_empty()
    }

    /// Boundary-proxy inputs in this shard.
    pub fn proxies(&self) -> usize {
        self.deferred.len()
    }

    /// Original-graph nodes resident in this shard.
    pub fn members(&self) -> usize {
        self.len() - self.proxies()
    }

    fn is_proxy(&self, local: u32) -> bool {
        self.deferred.binary_search(&local).is_ok()
    }
}

/// A graph compiled for N overlay fabrics: the partition, one compiled
/// [`ShardUnit`] per shard, and the boundary-channel table. Immutable
/// and `Sync`, like [`SharedProgram`] — service caches hold it under the
/// same content address scheme (the `shards` knob is part of the
/// normalized overlay, so sharded and single-fabric artifacts never
/// collide).
pub struct ShardedProgram {
    graph: Arc<DataflowGraph>,
    overlay: Overlay,
    partition: Partition,
    units: Vec<ShardUnit>,
    channels: Vec<ChannelSpec>,
    /// epoch length E == modeled boundary-link latency
    epoch: u64,
}

impl ShardedProgram {
    /// Partition `graph` into `num_shards` subgraphs (clamped to the
    /// node count; `0` and `1` both mean one shard) and compile each for
    /// its own copy of `overlay`.
    pub fn compile(
        graph: Arc<DataflowGraph>,
        overlay: &Overlay,
        num_shards: usize,
    ) -> Result<Self, CompileError> {
        Self::compile_with(graph, overlay, num_shards, None)
    }

    /// [`ShardedProgram::compile`] with a telemetry registry attached:
    /// the partition pipeline and each per-shard compile record their
    /// pass spans on the `"compile"` track.
    pub fn compile_with(
        graph: Arc<DataflowGraph>,
        overlay: &Overlay,
        num_shards: usize,
        tel: Telemetry<'_>,
    ) -> Result<Self, CompileError> {
        let cfg = *overlay.config();
        // partition the *original* graph (per-shard `opt` transforms run
        // later, inside each shard's own pipeline)
        let mut cx = PassCtx::new(&graph, cfg);
        PassManager::new()
            .with(VerifyPass)
            .with(CriticalityPass)
            .with(PartitionPass::new(num_shards.max(1)))
            .run(&mut cx, tel)?;
        let partition = cx.partition.take().expect("partition pass ran");
        telemetry::count(tel, "shard.compiles", 1);

        let k = partition.num_shards;
        let n = graph.len();
        // subgraph extraction: members + proxies merged by original id
        let mut local_of: Vec<Vec<u32>> = vec![vec![u32::MAX; n]; k];
        let mut units = Vec::with_capacity(k);
        for s in 0..k as u32 {
            // boundary producers feeding this shard, deduped via the
            // local_of scratch (filled in ascending id order below)
            let mut sub = DataflowGraph::new();
            let mut orig_of_local = Vec::new();
            let mut deferred = Vec::new();
            // pass 1: which foreign producers does shard s consume?
            let mut wants_proxy = vec![false; n];
            for v in 0..n {
                if partition.shard_of[v] != s {
                    continue;
                }
                if let NodeKind::Operation { op, src } = graph.node(v as u32).kind {
                    for &u in &src[..op.arity()] {
                        if partition.shard_of[u as usize] != s {
                            wants_proxy[u as usize] = true;
                        }
                    }
                }
            }
            // pass 2: build the subgraph in original-id order; a proxy
            // sits at its producer's id slot, so it precedes every
            // consumer (builder order is topological)
            for v in 0..n {
                if wants_proxy[v] {
                    let local = sub.add_input(0.0);
                    local_of[s as usize][v] = local;
                    orig_of_local.push(v as u32);
                    deferred.push(local);
                } else if partition.shard_of[v] == s {
                    let local = match graph.node(v as u32).kind {
                        NodeKind::Input { value } => sub.add_input(value),
                        NodeKind::Operation { op, src } => {
                            let mut mapped = [0u32; 2];
                            for (slot, &u) in src[..op.arity()].iter().enumerate() {
                                mapped[slot] = local_of[s as usize][u as usize];
                            }
                            sub.add_op(op, &mapped[..op.arity()])
                                .expect("extraction preserves topological order")
                        }
                    };
                    local_of[s as usize][v] = local;
                    orig_of_local.push(v as u32);
                }
            }
            let program = SharedProgram::compile_with(Arc::new(sub), overlay, tel)?;
            let exec_proxies = match program.program().node_map() {
                None => deferred.len(),
                Some(map) => {
                    let proxy = |local: u32| deferred.binary_search(&local).is_ok();
                    map.orig_of.iter().filter(|&&o| proxy(o)).count()
                }
            };
            units.push(ShardUnit { program, orig_of_local, deferred, exec_proxies });
        }

        // boundary channels in canonical (src, dst) order, links in
        // producer-id order (insertion order already ascending)
        let mut channels: std::collections::BTreeMap<(u32, u32), Vec<BoundaryLink>> =
            std::collections::BTreeMap::new();
        for (t, unit) in units.iter().enumerate() {
            for &dst_local in &unit.deferred {
                let producer = unit.orig_of_local[dst_local as usize];
                let src_shard = partition.shard_of[producer as usize];
                let src_local = local_of[src_shard as usize][producer as usize];
                debug_assert_ne!(src_local, u32::MAX, "producer resident in its shard");
                channels.entry((src_shard, t as u32)).or_default().push(BoundaryLink {
                    producer,
                    src_local,
                    dst_local,
                });
            }
        }
        let channels = channels
            .into_iter()
            .map(|((src_shard, dst_shard), links)| ChannelSpec { src_shard, dst_shard, links })
            .collect();

        let epoch = boundary_latency(&cfg);
        Ok(Self { graph, overlay: *overlay, partition, units, channels, epoch })
    }

    /// The original (unpartitioned) graph.
    pub fn graph(&self) -> &Arc<DataflowGraph> {
        &self.graph
    }

    /// The per-fabric overlay every shard targets.
    pub fn overlay(&self) -> &Overlay {
        &self.overlay
    }

    /// The node→shard assignment and boundary-edge table.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    pub fn num_shards(&self) -> usize {
        self.units.len()
    }

    /// The compiled per-shard units, in shard order.
    pub fn units(&self) -> &[ShardUnit] {
        &self.units
    }

    /// The boundary channels, in canonical `(src, dst)` order.
    pub fn channels(&self) -> &[ChannelSpec] {
        &self.channels
    }

    /// Epoch length E (== modeled boundary-link latency, in cycles).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Total boundary values a full run carries across channels.
    pub fn boundary_values(&self) -> usize {
        self.channels.iter().map(|c| c.links.len()).sum()
    }

    /// Does every shard fit `kind`'s per-PE BRAM budget?
    pub fn fits(&self, kind: SchedulerKind) -> bool {
        self.units.iter().all(|u| u.program.program().fits(kind))
    }

    /// Open a run session at the overlay's default variant.
    pub fn session(&self) -> ShardSession<'_> {
        ShardSession {
            program: self,
            cfg: *self.overlay.config(),
            threads: self.units.len(),
            telemetry: None,
            cancel: None,
            faults: None,
        }
    }
}

/// The merged outcome of one sharded run.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedRun {
    /// fabric-cycle stats merged across shards: `cycles` is the max
    /// (shards advance in lockstep epochs), NoC counters sum
    /// ([`NetworkStats::merged`]), `pe` concatenates every fabric's PEs
    /// in shard order. Bit-identical to the single-fabric `SimStats`
    /// when there is one shard.
    pub stats: SimStats,
    /// final node values in original graph id order
    pub values: Vec<f32>,
    /// completion cycle of each shard
    pub shard_cycles: Vec<u64>,
    /// epoch barriers the run synchronized at
    pub epochs: u64,
    /// values carried across boundary channels
    pub boundary_values: u64,
    /// channel-capacity stall events (a harvested value waiting a full
    /// barrier because its channel was at capacity)
    pub boundary_stalls: u64,
}

/// A configured sharded run — the [`crate::program::Session`] analogue
/// over a [`ShardedProgram`] (pick variant, run, repeat; each run builds
/// fresh per-shard backends, so runs are independent).
#[derive(Clone, Copy)]
pub struct ShardSession<'p> {
    program: &'p ShardedProgram,
    cfg: OverlayConfig,
    threads: usize,
    telemetry: Telemetry<'p>,
    cancel: Option<&'p CancelToken>,
    faults: Option<&'p FaultPlan>,
}

impl<'p> ShardSession<'p> {
    pub fn with_scheduler(mut self, kind: SchedulerKind) -> Self {
        self.cfg.scheduler = kind;
        self
    }

    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.cfg.backend = backend;
        self
    }

    pub fn with_max_cycles(mut self, max_cycles: u64) -> Self {
        self.cfg.max_cycles = max_cycles;
        self
    }

    /// Worker threads for the per-epoch shard fan-out (results are
    /// thread-count invariant; this is purely a wall-clock knob).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Attach a telemetry registry: the run records one span per shard
    /// on the `"shard"` track (aggregate simulate time across epochs)
    /// plus boundary/epoch counters.
    pub fn with_telemetry(mut self, reg: &'p Registry) -> Self {
        self.telemetry = Some(reg);
        self
    }

    /// Attach a cooperative cancellation / deadline token (DESIGN.md
    /// §15): every per-shard backend polls it mid-epoch, and the epoch
    /// runner re-checks at each barrier, so a sharded run stops within
    /// one check interval like a single-fabric one. The error reports
    /// merged (original-graph) progress.
    pub fn with_cancel(mut self, token: &'p CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Attach a fault-injection plan: its `barrier_drop` sites silence
    /// the named boundary channels (canonical channel order, 0-based
    /// barrier index), which the epoch watchdog then detects.
    pub fn with_fault_plan(mut self, plan: &'p FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Run all shards to completion through the epoch-barrier protocol.
    pub fn run(&self) -> Result<ShardedRun, SimError> {
        let prog = self.program;
        let k = prog.units.len();
        let t0 = Instant::now();

        // per-shard backends over each unit's compiled artifact, with
        // boundary proxies deferred (no token until injection)
        let views: Vec<Program<'_>> = prog.units.iter().map(|u| u.program.program()).collect();
        let mut backends: Vec<Option<Box<dyn SimBackend + '_>>> = Vec::with_capacity(k);
        for (unit, view) in prog.units.iter().zip(&views) {
            let mut cfg = *view.overlay().config();
            cfg.scheduler = self.cfg.scheduler;
            cfg.backend = self.cfg.backend;
            cfg.max_cycles = self.cfg.max_cycles;
            let mut backend = engine::backend_with_tables_deferred(
                view.exec_graph(),
                view.runtime_tables(),
                cfg,
                &unit.deferred,
            )?;
            if let Some(token) = self.cancel {
                backend.set_cancel(token.clone());
            }
            backends.push(Some(backend));
        }

        let mut chans: Vec<BoundaryChannel> = prog
            .channels
            .iter()
            .map(|spec| BoundaryChannel::new(spec.links.len()))
            .collect();
        let mut done = vec![false; k];
        let mut sim_time = vec![Duration::ZERO; k];
        let mut epochs = 0u64;
        let mut boundary_values = 0u64;
        let mut boundary_stalls = 0u64;
        let mut bound = prog.epoch;
        // watchdog: consecutive epochs with zero progress anywhere —
        // trips once the quiet span covers WATCHDOG_STALL_CYCLES
        let watchdog_epochs = WATCHDOG_STALL_CYCLES.div_ceil(prog.epoch).max(2);
        let mut zero_epochs = 0u64;
        let mut last_completed: usize = 0;

        loop {
            // advance every live shard to the epoch bound, in parallel;
            // shards share nothing mid-epoch, so interleaving is free
            let jobs: Vec<(usize, Box<dyn SimBackend + '_>)> = backends
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| !done[*i])
                .map(|(i, slot)| (i, slot.take().expect("live shard has its backend")))
                .collect();
            let epoch_bound = bound;
            let out = run_parallel(jobs, self.threads, move |(i, mut b): (usize, Box<dyn SimBackend + '_>)| {
                let s0 = Instant::now();
                let r = b.run_until(epoch_bound);
                (i, b, r, s0.elapsed())
            });
            epochs += 1;
            let mut first_err: Option<SimError> = None;
            let mut finished_this_epoch = false;
            for (i, b, r, dt) in out {
                backends[i] = Some(b);
                sim_time[i] += dt;
                match r {
                    Ok(finished) => {
                        finished_this_epoch |= finished && !done[i];
                        done[i] = finished;
                    }
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e); // lowest shard index wins — deterministic
                        }
                    }
                }
            }
            if let Some(e) = first_err {
                return Err(self.remap_error(e, &backends));
            }
            if done.iter().all(|&d| d) {
                debug_assert!(
                    self.faults.is_some()
                        || chans.iter().all(|c| c.flying.is_empty() && c.pending.is_empty()),
                    "all shards complete implies all boundary values delivered"
                );
                break;
            }
            // cooperative cancellation re-check at the barrier (the
            // per-shard backends also poll mid-epoch; this covers
            // tokens fired between a shard's last check and the sync)
            if let Some(cause) = self.cancel.and_then(CancelToken::fired) {
                let (completed, total) = self.merged_progress(&backends);
                let cycle = bound;
                return Err(match cause {
                    crate::sim::CancelCause::Deadline => {
                        SimError::DeadlineExceeded { cycle, completed, total }
                    }
                    crate::sim::CancelCause::Cancelled => {
                        SimError::Cancelled { cycle, completed, total }
                    }
                });
            }
            // epoch barrier: deliver → harvest → promote, per channel, in
            // canonical order (the determinism invariant)
            let mut moved = 0u64;
            for (ci, (spec, chan)) in prog.channels.iter().zip(&mut chans).enumerate() {
                // fault injection: a dropped channel delivers nothing
                // from its arming epoch on — in-flight and queued values
                // are discarded, producers still count as harvested
                let dropped = self
                    .faults
                    .is_some_and(|plan| plan.barrier_dropped(ci, epochs - 1));
                let dst = backends[spec.dst_shard as usize].as_mut().expect("backend parked");
                for (li, v) in chan.flying.drain(..) {
                    if !dropped {
                        dst.inject_value(spec.links[li as usize].dst_local, v);
                        chan.delivered[li as usize] = true;
                        moved += 1;
                    }
                }
                let src = backends[spec.src_shard as usize].as_ref().expect("backend parked");
                for (li, link) in spec.links.iter().enumerate() {
                    if !chan.sent[li] && src.node_computed(link.src_local) {
                        chan.sent[li] = true;
                        chan.pending.push_back((li as u32, src.values()[link.src_local as usize]));
                        moved += 1;
                    }
                }
                if dropped {
                    chan.pending.clear();
                }
                while chan.flying.len() < BOUNDARY_CHANNEL_CAPACITY {
                    let Some(entry) = chan.pending.pop_front() else {
                        break;
                    };
                    chan.flying.push(entry);
                    boundary_values += 1;
                }
                boundary_stalls += chan.pending.len() as u64;
            }
            // zero-progress watchdog: nothing finished, nothing moved on
            // any boundary, and no shard completed a single node — for a
            // window of epochs covering WATCHDOG_STALL_CYCLES that is a
            // boundary livelock (e.g. a dropped channel), so fail fast
            // with a diagnostic instead of spinning to max_cycles.
            let completed_now: usize = backends
                .iter()
                .map(|b| b.as_ref().expect("backend parked").completed_nodes())
                .sum();
            if finished_this_epoch || moved > 0 || completed_now != last_completed {
                zero_epochs = 0;
            } else {
                zero_epochs += 1;
                if zero_epochs >= watchdog_epochs {
                    return Err(self.stall_error(epochs, bound, &done, &chans, &backends));
                }
            }
            last_completed = completed_now;
            bound += prog.epoch;
        }

        // merge: values in original id order (a producer's own shard is
        // canonical; proxies are skipped), stats across fabrics
        let mut values = vec![0f32; prog.graph.len()];
        let mut shard_cycles = Vec::with_capacity(k);
        let mut completed = 0usize;
        // executed-domain node count minus proxy stand-ins: equals the
        // original graph length on non-`opt` overlays, and equals the
        // single-fabric `total_nodes` when there is one shard
        let mut total = 0usize;
        let mut pe: Vec<PeStats> = Vec::new();
        let mut nets: Vec<NetworkStats> = Vec::with_capacity(k);
        for (unit, backend) in prog.units.iter().zip(&backends) {
            let backend = backend.as_ref().expect("backend parked");
            let vals = backend.values();
            for (local, &orig) in unit.orig_of_local.iter().enumerate() {
                if !unit.is_proxy(local as u32) {
                    values[orig as usize] = vals[local];
                }
            }
            let stats = backend.stats();
            shard_cycles.push(stats.cycles);
            completed += stats.completed - unit.exec_proxies;
            total += stats.total_nodes - unit.exec_proxies;
            nets.push(stats.net);
            pe.extend(stats.pe);
        }
        let cycles = shard_cycles.iter().copied().max().unwrap_or(0);
        let stats = SimStats::collect(
            cycles,
            total,
            completed,
            self.cfg.scheduler,
            NetworkStats::merged(nets),
            pe,
        );

        if let Some(reg) = self.telemetry {
            for dt in &sim_time {
                reg.record_span("shard", "simulate", t0, *dt);
            }
            reg.count("shard.runs", 1);
            reg.count("shard.epochs", epochs);
            reg.count("shard.boundary.values", boundary_values);
            reg.count("shard.boundary.stalls", boundary_stalls);
            reg.observe("shard.cycles", cycles);
        }
        Ok(ShardedRun {
            stats,
            values,
            shard_cycles,
            epochs,
            boundary_values,
            boundary_stalls,
        })
    }

    /// Merged (original-graph) progress across every shard: original
    /// nodes whose value was computed, over the original node count.
    fn merged_progress(&self, backends: &[Option<Box<dyn SimBackend + '_>>]) -> (usize, usize) {
        let mut computed = 0usize;
        for (unit, backend) in self.program.units.iter().zip(backends) {
            let Some(backend) = backend.as_ref() else { continue };
            computed += (0..unit.len() as u32)
                .filter(|&l| !unit.is_proxy(l) && backend.node_computed(l))
                .count();
        }
        (computed, self.program.graph.len())
    }

    /// A shard's error, re-homed to the merged domain. With one shard
    /// the subgraph *is* the graph, so the error passes through verbatim
    /// (the N=1 bit-identity guarantee covers error runs too); with
    /// several, the early-stop shapes (cycle limit, deadline, cancel)
    /// report merged progress over the original node count.
    fn remap_error(&self, e: SimError, backends: &[Option<Box<dyn SimBackend + '_>>]) -> SimError {
        if self.program.units.len() == 1 {
            return e;
        }
        match e {
            SimError::CycleLimitExceeded { cycle, .. } => {
                let (completed, total) = self.merged_progress(backends);
                SimError::CycleLimitExceeded { cycle, completed, total }
            }
            SimError::DeadlineExceeded { cycle, .. } => {
                let (completed, total) = self.merged_progress(backends);
                SimError::DeadlineExceeded { cycle, completed, total }
            }
            SimError::Cancelled { cycle, .. } => {
                let (completed, total) = self.merged_progress(backends);
                SimError::Cancelled { cycle, completed, total }
            }
            other => other,
        }
    }

    /// The watchdog's diagnostic: name the lowest-indexed stuck shard
    /// and the boundary channels it is still waiting on (channels
    /// feeding it that have undelivered links).
    fn stall_error(
        &self,
        epoch: u64,
        cycle: u64,
        done: &[bool],
        chans: &[BoundaryChannel],
        backends: &[Option<Box<dyn SimBackend + '_>>],
    ) -> SimError {
        let (completed, total) = self.merged_progress(backends);
        let stuck_shard = done.iter().position(|&d| !d).unwrap_or(0);
        let waiting: Vec<(usize, usize)> = self
            .program
            .channels
            .iter()
            .zip(chans)
            .filter(|(spec, chan)| {
                spec.dst_shard as usize == stuck_shard && chan.delivered.iter().any(|&d| !d)
            })
            .map(|(spec, _)| (spec.src_shard as usize, spec.dst_shard as usize))
            .collect();
        SimError::ShardStalled { epoch, cycle, completed, total, stuck_shard, waiting }
    }
}

/// Runtime state of one directed inter-fabric link: `sent` marks
/// harvested producers, `delivered` marks values injected at the
/// destination (so the watchdog can name links lost to a dropped
/// channel), `pending` holds values waiting for channel capacity,
/// `flying` holds the values delivered at the next barrier.
struct BoundaryChannel {
    sent: Vec<bool>,
    delivered: Vec<bool>,
    pending: VecDeque<(u32, f32)>,
    flying: Vec<(u32, f32)>,
}

impl BoundaryChannel {
    fn new(links: usize) -> Self {
        Self {
            sent: vec![false; links],
            delivered: vec![false; links],
            pending: VecDeque::new(),
            flying: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{layered_random, lu_factorization_graph, SparseMatrix};

    fn overlay(cols: usize, rows: usize) -> Overlay {
        Overlay::builder().dims(cols, rows).build().unwrap()
    }

    #[test]
    fn one_shard_extraction_is_the_original_graph() {
        let g = Arc::new(layered_random(8, 4, 12, 2, 1));
        let sp = ShardedProgram::compile(Arc::clone(&g), &overlay(2, 2), 1).unwrap();
        assert_eq!(sp.num_shards(), 1);
        assert!(sp.channels().is_empty());
        let unit = &sp.units()[0];
        assert_eq!(unit.proxies(), 0);
        assert_eq!(unit.program.graph().fingerprint(), g.fingerprint());
    }

    #[test]
    fn sharded_n1_matches_single_fabric_bit_for_bit() {
        let g = Arc::new(layered_random(10, 5, 16, 2, 3));
        let ov = overlay(2, 2);
        let single = SharedProgram::compile(Arc::clone(&g), &ov).unwrap();
        let want = single.program().session().run().unwrap();
        let sp = ShardedProgram::compile(Arc::clone(&g), &ov, 1).unwrap();
        let run = sp.session().run().unwrap();
        assert_eq!(run.stats, want, "N=1 sharded stats == single-fabric stats");
        assert_eq!(run.values, g.evaluate());
        assert_eq!(run.boundary_values, 0);
        assert_eq!(run.boundary_stalls, 0);
    }

    #[test]
    fn multi_shard_run_computes_correct_values() {
        let m = SparseMatrix::banded(48, 3, 0.9, 7);
        let (g, _) = lu_factorization_graph(&m);
        let g = Arc::new(g);
        let want = g.evaluate();
        for k in [2, 3, 4] {
            let sp = ShardedProgram::compile(Arc::clone(&g), &overlay(2, 2), k).unwrap();
            assert_eq!(sp.num_shards(), k);
            assert!(sp.boundary_values() > 0, "a real cut crosses the boundary");
            let run = sp.session().run().unwrap();
            for (i, (a, b)) in run.values.iter().zip(&want).enumerate() {
                assert!(
                    (a == b) || (a.is_nan() && b.is_nan()),
                    "k={k} node {i}: sharded={a}, ref={b}"
                );
            }
            assert_eq!(run.stats.completed, g.len());
            assert_eq!(run.boundary_values, sp.boundary_values() as u64);
            assert_eq!(run.shard_cycles.len(), k);
            assert_eq!(run.stats.cycles, run.shard_cycles.iter().copied().max().unwrap());
        }
    }

    #[test]
    fn results_invariant_under_thread_count_and_backend() {
        let g = Arc::new(layered_random(16, 6, 24, 2, 9));
        let sp = ShardedProgram::compile(Arc::clone(&g), &overlay(2, 2), 3).unwrap();
        let base = sp.session().with_threads(1).run().unwrap();
        for threads in [2, 3, 8] {
            let run = sp.session().with_threads(threads).run().unwrap();
            assert_eq!(run, base, "threads={threads}");
        }
        for backend in BackendKind::ALL {
            let run = sp.session().with_backend(backend).run().unwrap();
            assert_eq!(run.values, base.values, "{backend:?} values");
            assert_eq!(run.stats.cycles, base.stats.cycles, "{backend:?} cycles");
        }
    }

    #[test]
    fn boundary_latency_is_at_least_the_epoch() {
        // a value computed at cycle c is visible at the second barrier
        // after it: latency in (E, 2E] — never below the link latency
        let cfg = *overlay(2, 2).config();
        let e = boundary_latency(&cfg);
        assert_eq!(e, 8);
        let g = Arc::new(layered_random(8, 4, 12, 2, 5));
        let sp = ShardedProgram::compile(Arc::clone(&g), &overlay(2, 2), 2).unwrap();
        assert_eq!(sp.epoch(), e);
        let run = sp.session().run().unwrap();
        assert!(run.epochs >= run.stats.cycles / e, "one barrier per epoch");
    }

    #[test]
    fn cycle_limit_error_reports_merged_domain() {
        let g = Arc::new(layered_random(16, 6, 24, 2, 9));
        let sp = ShardedProgram::compile(Arc::clone(&g), &overlay(2, 2), 2).unwrap();
        match sp.session().with_max_cycles(3).run() {
            Err(SimError::CycleLimitExceeded { cycle, completed, total }) => {
                assert_eq!(cycle, 3);
                assert_eq!(total, g.len());
                assert!(completed < total);
            }
            other => panic!("expected cycle limit, got {other:?}"),
        }
    }

    /// A dropped boundary channel starves its destination shard; the
    /// epoch watchdog fails fast (long before `max_cycles`) naming the
    /// stuck shard and the channels it is waiting on.
    #[test]
    fn watchdog_names_stuck_shard_on_dropped_channel() {
        use crate::faultinject::BarrierDrop;
        let g = Arc::new(layered_random(16, 6, 24, 2, 9));
        let sp = ShardedProgram::compile(Arc::clone(&g), &overlay(2, 2), 2).unwrap();
        assert!(!sp.channels().is_empty(), "a real cut has boundary channels");
        let plan = FaultPlan {
            barrier_drops: (0..sp.channels().len())
                .map(|channel| BarrierDrop { channel, from_epoch: 0 })
                .collect(),
            ..FaultPlan::default()
        };
        match sp.session().with_fault_plan(&plan).run() {
            Err(SimError::ShardStalled { epoch, completed, total, stuck_shard, waiting, .. }) => {
                assert!(epoch > 0);
                assert_eq!(total, g.len());
                assert!(completed < total, "starved run cannot complete");
                assert!(stuck_shard < sp.num_shards());
                assert!(!waiting.is_empty(), "diagnostic must name waiting channels");
                for (src, dst) in &waiting {
                    assert_eq!(*dst, stuck_shard);
                    assert!(*src < sp.num_shards());
                }
            }
            other => panic!("expected shard stall, got {other:?}"),
        }
    }

    /// Cancellation and deadlines stop a sharded run with the merged
    /// (original-graph) progress in the error, on any backend.
    #[test]
    fn cancel_and_deadline_stop_sharded_runs() {
        let g = Arc::new(layered_random(16, 6, 24, 2, 9));
        let sp = ShardedProgram::compile(Arc::clone(&g), &overlay(2, 2), 2).unwrap();
        let token = CancelToken::new();
        token.cancel();
        match sp.session().with_cancel(&token).run() {
            Err(SimError::Cancelled { completed, total, .. }) => {
                assert_eq!(total, g.len());
                assert!(completed < total);
            }
            other => panic!("expected cancelled, got {other:?}"),
        }
        for backend in BackendKind::ALL {
            let expired = CancelToken::already_expired();
            match sp.session().with_backend(backend).with_cancel(&expired).run() {
                Err(SimError::DeadlineExceeded { total, .. }) => assert_eq!(total, g.len()),
                other => panic!("{backend:?}: expected deadline, got {other:?}"),
            }
        }
    }
}
