//! Pluggable simulation engines (DESIGN.md §6).
//!
//! Every experiment — CLI runs, coordinator sweeps, benches — routes
//! through the [`SimBackend`] trait instead of constructing
//! [`crate::sim::Simulator`] directly, so the stepping strategy is a
//! configuration choice ([`crate::config::OverlayConfig::backend`]):
//!
//! * [`LockstepBackend`] — the reference cycle-level simulator: every PE
//!   and every Hoplite router stepped once per fabric cycle,
//!   O(PEs × cycles) even when the fabric is idle.
//! * [`SkipAheadBackend`] — an event-horizon engine. Whenever the overlay
//!   is *quiescent* (zero packets in flight, no packet-gen unit
//!   mid-drain) it computes the earliest next event — ALU retirement,
//!   scheduling-pass completion, pending pick or adoption — and advances
//!   the clock there in one jump. While any packet is routing it falls
//!   back to cycle-accurate stepping: Hoplite's deflection routing makes
//!   in-flight cycles irreducible.
//!
//! Both backends are bit-exact: identical node values, identical
//! completion cycles, identical [`crate::sim::SimStats`] down to every
//! per-PE counter. [`parity::check_parity`] runs both on the same
//! (graph, config) and asserts exactly that; `tests/engine_parity.rs`
//! sweeps it across workload families, and `benches/engine_speedup.rs`
//! measures what the jumps buy in wall-clock.

mod lockstep;
pub mod parity;
mod skipahead;

pub use lockstep::LockstepBackend;
pub use parity::{check_parity, ParityError, ParityReport};
pub use skipahead::SkipAheadBackend;

use crate::config::OverlayConfig;
use crate::graph::DataflowGraph;
use crate::place::Placement;
use crate::program::RuntimeTables;
use crate::sim::{ActivityReport, CancelToken, SimError, SimStats, Trace};
use std::sync::Arc;

/// Which stepping engine a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// reference simulator, one step per fabric cycle
    #[default]
    Lockstep,
    /// event-horizon engine, jumps over quiescent regions
    SkipAhead,
}

impl BackendKind {
    pub const ALL: [BackendKind; 2] = [BackendKind::Lockstep, BackendKind::SkipAhead];

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Lockstep => "lockstep",
            BackendKind::SkipAhead => "skip-ahead",
        }
    }
}

/// Common interface of the simulation engines. One backend instance
/// simulates one (graph, placement, config) run to completion.
/// (`Send` so the sharded runtime ([`crate::shard`]) can move per-shard
/// backends across its epoch worker threads.)
pub trait SimBackend: Send {
    fn kind(&self) -> BackendKind;

    /// Run to completion (or until the cycle limit).
    fn run(&mut self) -> Result<SimStats, SimError>;

    /// Run until the graph completes (`Ok(true)`) or the clock reaches
    /// `bound` (`Ok(false)`) — the sharded runtime's epoch slice.
    /// Bit-exact with [`SimBackend::run`]: a run chopped into epochs
    /// reaches the same completion cycle, values and error.
    fn run_until(&mut self, bound: u64) -> Result<bool, SimError>;

    /// Attach a cooperative cancellation / deadline token
    /// (DESIGN.md §15). The run loops poll it at least once every
    /// [`crate::sim::CANCEL_CHECK_INTERVAL`] fabric cycles and stop
    /// with a typed [`SimError::Cancelled`] / [`SimError::DeadlineExceeded`]
    /// carrying the partial progress.
    fn set_cancel(&mut self, token: CancelToken);

    /// Deliver a token to a deferred-seed input (graph node id) — the
    /// sharded runtime's boundary injection. No-op unless the node was
    /// deferred at construction and not yet injected.
    fn inject_value(&mut self, node: u32, value: f32);

    /// Has graph node `node` produced its value yet? (The sharded
    /// runtime's boundary-harvest predicate.)
    fn node_computed(&self, node: u32) -> bool;

    /// Count of graph nodes whose fanout processing has completed — an
    /// O(1) read-out the sharded runtime's zero-progress watchdog polls
    /// at every epoch barrier (DESIGN.md §15).
    fn completed_nodes(&self) -> usize;

    /// Statistics of the current (usually final) state.
    fn stats(&self) -> SimStats;

    /// Final (or current) node values — bit-exact across backends.
    fn values(&self) -> &[f32];

    /// Current fabric cycle.
    fn cycle(&self) -> u64;

    /// Per-PE / per-router activity counters (telemetry heatmaps,
    /// DESIGN.md §11) — a pure read-out, valid at any point of a run.
    fn activity(&self) -> ActivityReport;

    /// Record a per-cycle [`Trace`] (one sample every `stride` cycles,
    /// plus the final cycle). On the skip-ahead backend tracing pins the
    /// run to cycle-accurate stepping — samples are per-cycle
    /// observations, so quiescent regions cannot be jumped — while
    /// results stay bit-exact.
    fn enable_trace(&mut self, stride: u64);

    /// The recorded trace, if tracing was enabled.
    fn trace(&self) -> Option<&Trace>;
}

/// Construct the backend selected by `cfg.backend`. Places the graph as
/// part of construction; for repeated runs of the same workload prefer
/// compiling a [`crate::program::Program`] once and opening
/// [`crate::program::Session`]s (which route through
/// [`backend_with_tables`]).
pub fn make_backend<'g>(
    g: &'g DataflowGraph,
    cfg: OverlayConfig,
) -> Result<Box<dyn SimBackend + 'g>, SimError> {
    Ok(match cfg.backend {
        BackendKind::Lockstep => Box::new(LockstepBackend::new(g, cfg)?),
        BackendKind::SkipAhead => Box::new(SkipAheadBackend::new(g, cfg)?),
    })
}

/// Construct the backend selected by `cfg.backend` over an
/// already-compiled, shared placement. Bakes the runtime tables from
/// the placement; the artifact path ([`backend_with_tables`]) skips
/// even that.
pub fn backend_for<'g>(
    g: &'g DataflowGraph,
    place: Arc<Placement>,
    cfg: OverlayConfig,
) -> Result<Box<dyn SimBackend + 'g>, SimError> {
    Ok(match cfg.backend {
        BackendKind::Lockstep => Box::new(LockstepBackend::with_shared_placement(g, place, cfg)?),
        BackendKind::SkipAhead => {
            Box::new(SkipAheadBackend::with_shared_placement(g, place, cfg)?)
        }
    })
}

/// Construct the backend selected by `cfg.backend` over a compiled
/// artifact's baked [`RuntimeTables`] — the [`crate::program::Session`]
/// execution path: no placement, labeling or flattening work at all.
pub fn backend_with_tables<'g>(
    g: &'g DataflowGraph,
    tables: Arc<RuntimeTables>,
    cfg: OverlayConfig,
) -> Result<Box<dyn SimBackend + 'g>, SimError> {
    Ok(match cfg.backend {
        BackendKind::Lockstep => Box::new(LockstepBackend::with_tables(g, tables, cfg)?),
        BackendKind::SkipAhead => Box::new(SkipAheadBackend::with_tables(g, tables, cfg)?),
    })
}

/// [`backend_with_tables`] with some inputs left unseeded, awaiting
/// [`SimBackend::inject_value`] — the sharded runtime's per-shard
/// constructor (`deferred` lists the boundary-proxy node ids).
pub fn backend_with_tables_deferred<'g>(
    g: &'g DataflowGraph,
    tables: Arc<RuntimeTables>,
    cfg: OverlayConfig,
    deferred: &[u32],
) -> Result<Box<dyn SimBackend + 'g>, SimError> {
    Ok(match cfg.backend {
        BackendKind::Lockstep => {
            Box::new(LockstepBackend::with_tables_deferred(g, tables, cfg, deferred)?)
        }
        BackendKind::SkipAhead => {
            Box::new(SkipAheadBackend::with_tables_deferred(g, tables, cfg, deferred)?)
        }
    })
}

/// Build the configured backend and run it to completion.
#[deprecated(
    note = "compile once with `Program::compile` and run through `Session` — \
            this shim re-places and re-labels the graph on every call"
)]
pub fn run_with_backend(g: &DataflowGraph, cfg: OverlayConfig) -> Result<SimStats, SimError> {
    let overlay = crate::config::Overlay::trusted(cfg);
    let program = crate::program::Program::compile(g, &overlay).map_err(SimError::from)?;
    program.session().run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::layered_random;

    #[test]
    fn make_backend_honors_config() {
        let g = layered_random(8, 4, 12, 2, 1);
        for kind in BackendKind::ALL {
            let cfg = OverlayConfig::default().with_dims(2, 2).with_backend(kind);
            let be = make_backend(&g, cfg).unwrap();
            assert_eq!(be.kind(), kind);
        }
    }

    #[test]
    #[allow(deprecated)]
    fn run_with_backend_completes_on_both() {
        let g = layered_random(8, 4, 12, 2, 1);
        let mut cycles = Vec::new();
        for kind in BackendKind::ALL {
            let cfg = OverlayConfig::default().with_dims(2, 2).with_backend(kind);
            let stats = run_with_backend(&g, cfg).unwrap();
            assert_eq!(stats.completed, g.len());
            cycles.push(stats.cycles);
        }
        assert_eq!(cycles[0], cycles[1], "backends must agree on completion cycle");
    }

    /// The deprecated shim and the compile-once path must be
    /// bit-identical — the migration guarantee of the API redesign.
    #[test]
    #[allow(deprecated)]
    fn shim_matches_program_session_path() {
        use crate::config::Overlay;
        use crate::program::Program;
        let g = layered_random(10, 5, 16, 2, 3);
        for kind in BackendKind::ALL {
            let cfg = OverlayConfig::default().with_dims(3, 3).with_backend(kind);
            let shim = run_with_backend(&g, cfg).unwrap();
            let program = Program::compile(&g, &Overlay::from_config(cfg).unwrap()).unwrap();
            let fresh = program.session().run().unwrap();
            assert_eq!(shim, fresh, "{kind:?}");
        }
    }

    #[test]
    fn backend_names() {
        assert_eq!(BackendKind::Lockstep.name(), "lockstep");
        assert_eq!(BackendKind::SkipAhead.name(), "skip-ahead");
        assert_eq!(BackendKind::default(), BackendKind::Lockstep);
    }
}
