//! The skip-ahead event backend: cycle-accurate stepping while traffic is
//! in flight, single-jump clock advances through quiescent regions.
//!
//! ## Why this is exact
//!
//! Between two consecutive events, a *quiescent* overlay (no packets on
//! Hoplite links, no packet-gen unit mid-drain) executes only no-op
//! lockstep cycles: the network switches nothing, no operands arrive, no
//! node fires, no packet is injected. The only per-cycle state change is
//! utilization accounting (a PE with results in its ALU pipeline counts
//! as busy). `Simulator::jump_to` applies exactly that accounting for the
//! skipped span, so the post-jump state is bit-identical to having
//! stepped cycle by cycle.
//!
//! The events that end a quiescent region are all scheduled at known
//! cycles — ALU retirements ([`crate::pe::AluPipeline::next_retire_cycle`])
//! and scheduling-pass completions ([`crate::sched::ReadyScheduler::pick_completion`]) —
//! so the horizon is their minimum. While any packet is routing
//! ([`crate::noc::Network::in_flight`] > 0) the backend steps
//! cycle-accurately: deflection routing makes those cycles irreducible.
//!
//! One observable difference to lockstep remains, by design: the
//! network's *internal* clock is not advanced across jumps. It is only
//! ever used for latency deltas within a single routing episode, and no
//! packet exists across a quiescent region, so all [`crate::sim::SimStats`]
//! — including packet latencies — are unaffected.

use super::{BackendKind, SimBackend};
use crate::config::OverlayConfig;
use crate::graph::DataflowGraph;
use crate::place::Placement;
use crate::program::RuntimeTables;
use crate::sim::{
    ActivityReport, CancelToken, SimError, SimStats, Simulator, Trace, CANCEL_CHECK_INTERVAL,
};
use std::sync::Arc;

/// Event-horizon engine over the reference simulator.
pub struct SkipAheadBackend<'g> {
    sim: Simulator<'g>,
    jumps: u64,
    cycles_skipped: u64,
}

impl<'g> SkipAheadBackend<'g> {
    pub fn new(g: &'g DataflowGraph, cfg: OverlayConfig) -> Result<Self, SimError> {
        Ok(Self {
            sim: Simulator::new(g, cfg)?,
            jumps: 0,
            cycles_skipped: 0,
        })
    }

    /// Build over a compiled, shared placement (the
    /// [`crate::program::Session`] path — no placement work here).
    pub fn with_shared_placement(
        g: &'g DataflowGraph,
        place: Arc<Placement>,
        cfg: OverlayConfig,
    ) -> Result<Self, SimError> {
        Ok(Self {
            sim: Simulator::with_shared_placement(g, place, cfg)?,
            jumps: 0,
            cycles_skipped: 0,
        })
    }

    /// Build over a compiled artifact's baked runtime tables (the
    /// [`crate::program::Session`] path — no placement, labeling or
    /// flattening work here).
    pub fn with_tables(
        g: &'g DataflowGraph,
        tables: Arc<RuntimeTables>,
        cfg: OverlayConfig,
    ) -> Result<Self, SimError> {
        Ok(Self {
            sim: Simulator::with_tables(g, tables, cfg)?,
            jumps: 0,
            cycles_skipped: 0,
        })
    }

    /// [`SkipAheadBackend::with_tables`] with some inputs left unseeded
    /// (sharded execution's boundary proxies).
    pub fn with_tables_deferred(
        g: &'g DataflowGraph,
        tables: Arc<RuntimeTables>,
        cfg: OverlayConfig,
        deferred: &[u32],
    ) -> Result<Self, SimError> {
        Ok(Self {
            sim: Simulator::with_tables_deferred(g, tables, cfg, deferred)?,
            jumps: 0,
            cycles_skipped: 0,
        })
    }

    /// Wrap an already-constructed simulator — the composition hook for
    /// ablations that pair a custom scheduler factory with either
    /// engine (e.g. `tests/artifact_tables.rs`).
    pub fn from_simulator(sim: Simulator<'g>) -> Self {
        Self {
            sim,
            jumps: 0,
            cycles_skipped: 0,
        }
    }

    /// Clock jumps taken so far.
    pub fn jumps(&self) -> u64 {
        self.jumps
    }

    /// Fabric cycles skipped (not stepped) so far.
    pub fn cycles_skipped(&self) -> u64 {
        self.cycles_skipped
    }

    fn cycle_limit_error(&self) -> SimError {
        SimError::CycleLimitExceeded {
            cycle: self.sim.cycle(),
            completed: self.sim.completed_nodes(),
            total: self.sim.total_nodes(),
        }
    }

    /// Poll the attached cancel token — the skip-ahead analog of the
    /// lockstep cycle-mask check. The run loops call this every
    /// [`CANCEL_CHECK_INTERVAL`] iterations (each iteration advances at
    /// least one cycle) and immediately after every jump (one jump can
    /// cross many intervals), so detection lag stays within one
    /// interval of the budget here too.
    fn check_cancel(&self) -> Option<SimError> {
        let cause = self.sim.cancel_token()?.fired()?;
        Some(self.sim.cancel_error(cause))
    }
}

impl<'g> SimBackend for SkipAheadBackend<'g> {
    fn kind(&self) -> BackendKind {
        BackendKind::SkipAhead
    }

    fn run(&mut self) -> Result<SimStats, SimError> {
        let max_cycles = self.sim.max_cycles();
        let mut ticks: u64 = 0;
        // entry poll, mirroring the lockstep engine: a pre-fired token
        // stops even a run short enough to never reach a check interval
        if let Some(e) = self.check_cancel() {
            return Err(e);
        }
        loop {
            let mut jumped = false;
            // Jump only through quiescent, incomplete states. The horizon
            // is clamped to the cycle limit so a livelocked or overlong
            // run reports the same `CycleLimitExceeded { cycle }` the
            // lockstep backend would (lockstep checks the limit *before*
            // executing the step at `max_cycles`, so an event scheduled
            // exactly there never runs under either backend).
            if self.sim.quiescent() && !self.sim.is_complete() {
                let target = self
                    .sim
                    .next_event_cycle()
                    .map_or(max_cycles, |t| t.min(max_cycles));
                if target > self.sim.cycle() {
                    self.jumps += 1;
                    self.cycles_skipped += target - self.sim.cycle();
                    self.sim.jump_to(target);
                    if target >= max_cycles {
                        return Err(self.cycle_limit_error());
                    }
                    jumped = true;
                }
            }
            if self.sim.step() {
                return Ok(self.sim.stats());
            }
            if self.sim.cycle() >= max_cycles {
                return Err(self.cycle_limit_error());
            }
            ticks += 1;
            if jumped || ticks & (CANCEL_CHECK_INTERVAL - 1) == 0 {
                if let Some(e) = self.check_cancel() {
                    return Err(e);
                }
            }
        }
    }

    /// Epoch-sliced run: identical jump logic to [`SkipAheadBackend::run`]
    /// with the horizon additionally clamped to `bound`. A quiescent
    /// state with *no* scheduled event is not reported as a livelock
    /// here — under sharded execution the shard may simply be waiting
    /// for a boundary injection at the next barrier — so the clock parks
    /// at `bound` and control returns to the epoch runner (the cycle
    /// limit still bounds a genuinely livelocked system with the same
    /// error as lockstep).
    fn run_until(&mut self, bound: u64) -> Result<bool, SimError> {
        let max_cycles = self.sim.max_cycles();
        let mut ticks: u64 = 0;
        // same entry order as the lockstep `run_until`: completion wins
        // over a fired token, then each epoch slice re-polls on entry
        if self.sim.is_complete() {
            return Ok(true);
        }
        if let Some(e) = self.check_cancel() {
            return Err(e);
        }
        loop {
            if self.sim.is_complete() {
                return Ok(true);
            }
            if self.sim.cycle() >= bound {
                return Ok(false);
            }
            let mut jumped = false;
            if self.sim.quiescent() {
                let target = self
                    .sim
                    .next_event_cycle()
                    .map_or(max_cycles.min(bound), |t| t.min(max_cycles).min(bound));
                if target > self.sim.cycle() {
                    self.jumps += 1;
                    self.cycles_skipped += target - self.sim.cycle();
                    self.sim.jump_to(target);
                    if target >= max_cycles {
                        return Err(self.cycle_limit_error());
                    }
                    if target >= bound {
                        return Ok(false);
                    }
                    jumped = true;
                }
            }
            if self.sim.step() {
                return Ok(true);
            }
            if self.sim.cycle() >= max_cycles {
                return Err(self.cycle_limit_error());
            }
            ticks += 1;
            if jumped || ticks & (CANCEL_CHECK_INTERVAL - 1) == 0 {
                if let Some(e) = self.check_cancel() {
                    return Err(e);
                }
            }
        }
    }

    fn set_cancel(&mut self, token: CancelToken) {
        self.sim.set_cancel(token);
    }

    fn inject_value(&mut self, node: u32, value: f32) {
        self.sim.inject_value(node, value);
    }

    fn node_computed(&self, node: u32) -> bool {
        self.sim.node_computed(node)
    }

    fn completed_nodes(&self) -> usize {
        self.sim.completed_nodes()
    }

    fn stats(&self) -> SimStats {
        self.sim.stats()
    }

    fn values(&self) -> &[f32] {
        self.sim.values()
    }

    fn cycle(&self) -> u64 {
        self.sim.cycle()
    }

    fn activity(&self) -> ActivityReport {
        self.sim.activity()
    }

    /// Tracing demotes this backend to cycle-accurate stepping for the
    /// whole run: `Simulator::quiescent` reports false while a trace is
    /// attached, so the jump gate in [`SkipAheadBackend::run`] never
    /// opens — per-cycle samples stay exact and results stay bit-equal
    /// to lockstep.
    fn enable_trace(&mut self, stride: u64) {
        self.sim.enable_trace(stride);
    }

    fn trace(&self) -> Option<&Trace> {
        self.sim.trace()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;
    use crate::sched::SchedulerKind;

    /// A dependency chain on one PE: every ALU-latency and pick-latency
    /// window is quiescent, so the engine must take many jumps.
    #[test]
    fn sequential_chain_skips() {
        let mut g = DataflowGraph::new();
        let mut prev = g.add_input(1.5);
        for _ in 0..100 {
            prev = g.op(Op::Neg, &[prev]);
        }
        let mut cfg = OverlayConfig::paper_1x1().with_scheduler(SchedulerKind::OutOfOrder);
        cfg.alu_latency = 8;
        let mut be = SkipAheadBackend::new(&g, cfg).unwrap();
        let stats = be.run().unwrap();
        assert_eq!(stats.completed, g.len());
        assert!(be.jumps() > 50, "chain must jump often, got {}", be.jumps());
        assert!(
            be.cycles_skipped() > stats.cycles / 2,
            "most chain cycles are quiescent: skipped {} of {}",
            be.cycles_skipped(),
            stats.cycles
        );
        assert_eq!(be.values()[100], 1.5 * (-1f32).powi(100));
    }

    /// Tracing is per-cycle observation: the jump gate must stay closed
    /// (zero jumps) while stats remain bit-equal to the untraced run,
    /// and the trace must end on the final cycle.
    #[test]
    fn tracing_disables_jumps_but_stays_bit_exact() {
        let mut g = DataflowGraph::new();
        let mut prev = g.add_input(1.5);
        for _ in 0..50 {
            prev = g.op(Op::Neg, &[prev]);
        }
        let cfg = OverlayConfig::paper_1x1().with_scheduler(SchedulerKind::OutOfOrder);
        let mut plain = SkipAheadBackend::new(&g, cfg).unwrap();
        let want = plain.run().unwrap();
        assert!(plain.jumps() > 0, "chain workload must jump when untraced");

        let mut traced = SkipAheadBackend::new(&g, cfg).unwrap();
        traced.enable_trace(64);
        let got = traced.run().unwrap();
        assert_eq!(got, want, "tracing must not perturb results");
        assert_eq!(traced.jumps(), 0, "tracing pins cycle-accurate stepping");
        let trace = traced.trace().unwrap();
        assert_eq!(trace.last_cycle(), Some(want.cycles - 1));
    }

    #[test]
    fn cycle_limit_reported_like_lockstep() {
        let g = crate::workload::layered_random(8, 4, 8, 1, 0);
        let mut cfg = OverlayConfig::default().with_dims(2, 2);
        cfg.max_cycles = 3;
        let mut lock = Simulator::new(&g, cfg).unwrap();
        let want = lock.run().unwrap_err();
        let mut skip = SkipAheadBackend::new(&g, cfg).unwrap();
        let got = skip.run().unwrap_err();
        assert_eq!(got, want, "identical error on the cycle limit");
    }
}
