//! Cross-backend validation: run the same (graph, config) on the
//! lockstep reference and the skip-ahead engine and assert they are
//! indistinguishable — bit-exact node values and [`SimStats`] equality
//! down to every per-PE counter (completion cycle, busy cycles, packet
//! and deflection counts, port stalls, occupancy high-water marks).
//!
//! This is the safety net that lets sweeps default to the fast backend:
//! `tests/engine_parity.rs` runs it across workload families × both
//! schedulers, and the speedup bench re-checks it before timing.

use super::{LockstepBackend, SimBackend, SkipAheadBackend};
use crate::config::OverlayConfig;
use crate::graph::DataflowGraph;
use crate::sim::{SimError, SimStats};

/// Outcome of a successful parity check.
#[derive(Debug, Clone)]
pub struct ParityReport {
    /// the (identical) statistics of both runs
    pub stats: SimStats,
    /// clock jumps the skip-ahead backend took
    pub jumps: u64,
    /// fabric cycles it skipped instead of stepping
    pub cycles_skipped: u64,
}

impl ParityReport {
    /// Fraction of fabric cycles skipped, in [0, 1].
    pub fn skip_fraction(&self) -> f64 {
        if self.stats.cycles == 0 {
            0.0
        } else {
            self.cycles_skipped as f64 / self.stats.cycles as f64
        }
    }
}

/// A parity violation (or a shared simulation failure).
#[derive(Debug, Clone)]
pub enum ParityError {
    /// both backends failed with the same simulation error
    Sim(SimError),
    /// one backend failed (or they failed differently)
    ErrorMismatch {
        lockstep: Option<SimError>,
        skip_ahead: Option<SimError>,
    },
    /// statistics diverged; `field` names the first differing counter
    StatsMismatch {
        field: String,
        lockstep: String,
        skip_ahead: String,
    },
    /// a node value diverged
    ValueMismatch {
        node: usize,
        lockstep: f32,
        skip_ahead: f32,
    },
}

impl std::fmt::Display for ParityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParityError::Sim(e) => write!(f, "both backends failed: {e}"),
            ParityError::ErrorMismatch { lockstep, skip_ahead } => write!(
                f,
                "backends disagree on failure: lockstep={lockstep:?}, skip-ahead={skip_ahead:?}"
            ),
            ParityError::StatsMismatch { field, lockstep, skip_ahead } => write!(
                f,
                "stats diverge at {field}: lockstep={lockstep}, skip-ahead={skip_ahead}"
            ),
            ParityError::ValueMismatch { node, lockstep, skip_ahead } => write!(
                f,
                "node {node} value diverges: lockstep={lockstep}, skip-ahead={skip_ahead}"
            ),
        }
    }
}

impl std::error::Error for ParityError {}

/// First differing statistic, as (field, lockstep, skip-ahead) strings.
fn diff_stats(a: &SimStats, b: &SimStats) -> Option<(String, String, String)> {
    if a.cycles != b.cycles {
        return Some(("cycles".into(), a.cycles.to_string(), b.cycles.to_string()));
    }
    if a.completed != b.completed {
        return Some(("completed".into(), a.completed.to_string(), b.completed.to_string()));
    }
    if a.net != b.net {
        return Some(("net".into(), format!("{:?}", a.net), format!("{:?}", b.net)));
    }
    if a.pe.len() != b.pe.len() {
        return Some(("pe.len".into(), a.pe.len().to_string(), b.pe.len().to_string()));
    }
    for (i, (pa, pb)) in a.pe.iter().zip(&b.pe).enumerate() {
        if pa != pb {
            return Some((format!("pe[{i}]"), format!("{pa:?}"), format!("{pb:?}")));
        }
    }
    if a != b {
        return Some(("aggregate".into(), format!("{a:?}"), format!("{b:?}")));
    }
    None
}

/// Run `g` under `cfg` on both backends and assert equivalence.
///
/// `cfg.backend` is ignored — both engines always run. Returns the
/// shared statistics plus the skip-ahead jump counters on success.
pub fn check_parity(g: &DataflowGraph, cfg: OverlayConfig) -> Result<ParityReport, ParityError> {
    let mut lock = LockstepBackend::new(g, cfg).map_err(ParityError::Sim)?;
    let mut skip = SkipAheadBackend::new(g, cfg).map_err(ParityError::Sim)?;
    let lock_res = lock.run();
    let skip_res = skip.run();
    match (lock_res, skip_res) {
        (Ok(lock_stats), Ok(skip_stats)) => {
            if let Some((field, l, s)) = diff_stats(&lock_stats, &skip_stats) {
                return Err(ParityError::StatsMismatch {
                    field,
                    lockstep: l,
                    skip_ahead: s,
                });
            }
            for (node, (x, y)) in lock.values().iter().zip(skip.values()).enumerate() {
                if x.to_bits() != y.to_bits() && !(x.is_nan() && y.is_nan()) {
                    return Err(ParityError::ValueMismatch {
                        node,
                        lockstep: *x,
                        skip_ahead: *y,
                    });
                }
            }
            Ok(ParityReport {
                stats: lock_stats,
                jumps: skip.jumps(),
                cycles_skipped: skip.cycles_skipped(),
            })
        }
        (Err(le), Err(se)) if le == se => Err(ParityError::Sim(le)),
        (lock_res, skip_res) => Err(ParityError::ErrorMismatch {
            lockstep: lock_res.err(),
            skip_ahead: skip_res.err(),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;
    use crate::sched::SchedulerKind;
    use crate::workload::layered_random;

    #[test]
    fn diamond_parity_both_schedulers() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(3.0);
        let b = g.add_input(4.0);
        let s = g.op(Op::Add, &[a, b]);
        let p = g.op(Op::Mul, &[a, b]);
        g.op(Op::Div, &[s, p]);
        for kind in [SchedulerKind::InOrder, SchedulerKind::OutOfOrder] {
            let cfg = OverlayConfig::paper_1x1().with_scheduler(kind);
            let rep = check_parity(&g, cfg).unwrap();
            assert_eq!(rep.stats.completed, g.len());
        }
    }

    #[test]
    fn layered_parity_reports_skips() {
        let g = layered_random(8, 6, 16, 2, 7);
        let cfg = OverlayConfig::default().with_dims(2, 2);
        let rep = check_parity(&g, cfg).unwrap();
        assert!(rep.skip_fraction() >= 0.0 && rep.skip_fraction() <= 1.0);
    }

    #[test]
    fn shared_cycle_limit_is_sim_error() {
        let g = layered_random(8, 4, 8, 1, 0);
        let mut cfg = OverlayConfig::default().with_dims(2, 2);
        cfg.max_cycles = 3;
        match check_parity(&g, cfg) {
            Err(ParityError::Sim(SimError::CycleLimitExceeded { cycle, .. })) => {
                assert_eq!(cycle, 3);
            }
            other => panic!("expected shared cycle-limit error, got {other:?}"),
        }
    }
}
