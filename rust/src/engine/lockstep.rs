//! The reference backend: a thin wrapper around [`Simulator`], stepping
//! every PE and router once per fabric cycle.

use super::{BackendKind, SimBackend};
use crate::config::OverlayConfig;
use crate::graph::DataflowGraph;
use crate::place::Placement;
use crate::program::RuntimeTables;
use crate::sim::{ActivityReport, CancelToken, SimError, SimStats, Simulator, Trace};
use std::sync::Arc;

/// Cycle-by-cycle reference engine. This is the seed simulator moved
/// behind the [`SimBackend`] trait; its behavior defines correctness for
/// every other backend (see [`crate::engine::parity`]).
pub struct LockstepBackend<'g> {
    sim: Simulator<'g>,
}

impl<'g> LockstepBackend<'g> {
    pub fn new(g: &'g DataflowGraph, cfg: OverlayConfig) -> Result<Self, SimError> {
        Ok(Self {
            sim: Simulator::new(g, cfg)?,
        })
    }

    /// Build over a compiled, shared placement (the
    /// [`crate::program::Session`] path — no placement work here).
    pub fn with_shared_placement(
        g: &'g DataflowGraph,
        place: Arc<Placement>,
        cfg: OverlayConfig,
    ) -> Result<Self, SimError> {
        Ok(Self {
            sim: Simulator::with_shared_placement(g, place, cfg)?,
        })
    }

    /// Build over a compiled artifact's baked runtime tables (the
    /// [`crate::program::Session`] path — no placement, labeling or
    /// flattening work here).
    pub fn with_tables(
        g: &'g DataflowGraph,
        tables: Arc<RuntimeTables>,
        cfg: OverlayConfig,
    ) -> Result<Self, SimError> {
        Ok(Self {
            sim: Simulator::with_tables(g, tables, cfg)?,
        })
    }

    /// [`LockstepBackend::with_tables`] with some inputs left unseeded
    /// (sharded execution's boundary proxies).
    pub fn with_tables_deferred(
        g: &'g DataflowGraph,
        tables: Arc<RuntimeTables>,
        cfg: OverlayConfig,
        deferred: &[u32],
    ) -> Result<Self, SimError> {
        Ok(Self {
            sim: Simulator::with_tables_deferred(g, tables, cfg, deferred)?,
        })
    }

    /// Wrap an already-constructed simulator — the composition hook for
    /// ablations that pair a custom scheduler factory with either
    /// engine (e.g. `tests/artifact_tables.rs`).
    pub fn from_simulator(sim: Simulator<'g>) -> Self {
        Self { sim }
    }

    /// The wrapped reference simulator — for tracing and ablation hooks
    /// that only make sense cycle-by-cycle (e.g. `tdp analyze`).
    pub fn simulator_mut(&mut self) -> &mut Simulator<'g> {
        &mut self.sim
    }
}

impl<'g> SimBackend for LockstepBackend<'g> {
    fn kind(&self) -> BackendKind {
        BackendKind::Lockstep
    }

    fn run(&mut self) -> Result<SimStats, SimError> {
        self.sim.run()
    }

    fn run_until(&mut self, bound: u64) -> Result<bool, SimError> {
        self.sim.run_until(bound)
    }

    fn set_cancel(&mut self, token: CancelToken) {
        self.sim.set_cancel(token);
    }

    fn inject_value(&mut self, node: u32, value: f32) {
        self.sim.inject_value(node, value);
    }

    fn node_computed(&self, node: u32) -> bool {
        self.sim.node_computed(node)
    }

    fn completed_nodes(&self) -> usize {
        self.sim.completed_nodes()
    }

    fn stats(&self) -> SimStats {
        self.sim.stats()
    }

    fn values(&self) -> &[f32] {
        self.sim.values()
    }

    fn cycle(&self) -> u64 {
        self.sim.cycle()
    }

    fn activity(&self) -> ActivityReport {
        self.sim.activity()
    }

    fn enable_trace(&mut self, stride: u64) {
        self.sim.enable_trace(stride);
    }

    fn trace(&self) -> Option<&Trace> {
        self.sim.trace()
    }
}
