//! Sparse LU factorization → dataflow graph extraction.
//!
//! The paper's evaluation workloads are "dataflow graphs extracted from
//! sparse matrix factorization kernels". This module performs a symbolic +
//! numeric right-looking LU (no pivoting; inputs are made diagonally
//! dominant) and records every floating-point operation as a dataflow
//! node:
//!
//! ```text
//! for k in 0..n:
//!   for each i > k with A[i,k] != 0:
//!     L[i,k] = A[i,k] / A[k,k]                      -- DIV node
//!     for each j > k with A[k,j] != 0:
//!       A[i,j] = A[i,j] - L[i,k] * A[k,j]           -- MUL + SUB nodes
//!       (fill-in if A[i,j] was structurally zero -> NEG(MUL) node)
//! ```
//!
//! The resulting DAG has the classic elimination-tree shape: wide early
//! levels, a narrowing critical path through the pivots — exactly the
//! regime where criticality-aware out-of-order issue pays off.

use super::patterns::SparseMatrix;
use crate::graph::{DataflowGraph, NodeId, Op};
use std::collections::HashMap;

/// Bookkeeping from graph extraction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FactorizationStats {
    pub matrix_n: usize,
    pub nnz_in: usize,
    pub div_ops: usize,
    pub mul_ops: usize,
    pub sub_ops: usize,
    pub fill_in: usize,
}

/// Extract the LU elimination dataflow graph of `m`.
///
/// Returns the graph plus stats. Node values are real: evaluating the
/// graph performs the factorization, and tests check the L/U factors
/// against a dense reference.
pub fn lu_factorization_graph(m: &SparseMatrix) -> (DataflowGraph, FactorizationStats) {
    let n = m.n;
    let mut g = DataflowGraph::with_capacity(m.nnz() * 3);
    // cur[(i,j)] = node currently holding the value of entry (i,j)
    let mut cur: HashMap<(u32, u32), NodeId> = HashMap::with_capacity(m.nnz() * 2);
    for (i, row) in m.rows.iter().enumerate() {
        for &(j, v) in row {
            let id = g.add_input(v);
            cur.insert((i as u32, j as u32), id);
        }
    }
    let mut stats = FactorizationStats {
        matrix_n: n,
        nnz_in: m.nnz(),
        div_ops: 0,
        mul_ops: 0,
        sub_ops: 0,
        fill_in: 0,
    };

    // Working sparsity: row -> sorted cols (evolves with fill-in).
    let mut cols: Vec<Vec<u32>> = m
        .rows
        .iter()
        .map(|r| r.iter().map(|&(c, _)| c as u32).collect())
        .collect();
    // column -> rows with a nonzero in that column below the diagonal
    let mut rows_in_col: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (i, r) in cols.iter().enumerate() {
        for &c in r {
            if (c as usize) < i {
                // will be updated as elimination proceeds; initial subdiag
            }
            if i > c as usize {
                rows_in_col[c as usize].push(i as u32);
            }
        }
    }

    for k in 0..n as u32 {
        let pivot = *cur
            .get(&(k, k))
            .expect("diagonal entry exists (diagonally dominant input)");
        // snapshot: the update row entries A[k, j>k]
        let urow: Vec<u32> = cols[k as usize]
            .iter()
            .copied()
            .filter(|&j| j > k)
            .collect();
        // rows below k with nonzero in column k (may have grown via fill-in)
        let targets = std::mem::take(&mut rows_in_col[k as usize]);
        for &i in targets.iter().filter(|&&i| i > k) {
            let aik = match cur.get(&(i, k)) {
                Some(&v) => v,
                None => continue, // cancelled structurally (shouldn't happen)
            };
            let lik = g.op(Op::Div, &[aik, pivot]);
            stats.div_ops += 1;
            cur.insert((i, k), lik); // L factor stored in place
            for &j in &urow {
                let akj = *cur.get(&(k, j)).expect("update-row entry");
                let prod = g.op(Op::Mul, &[lik, akj]);
                stats.mul_ops += 1;
                match cur.get(&(i, j)) {
                    Some(&aij) => {
                        let upd = g.op(Op::Sub, &[aij, prod]);
                        stats.sub_ops += 1;
                        cur.insert((i, j), upd);
                    }
                    None => {
                        // fill-in: 0 - prod
                        let fill = g.op(Op::Neg, &[prod]);
                        stats.fill_in += 1;
                        cur.insert((i, j), fill);
                        // insert into working sparsity
                        let row = &mut cols[i as usize];
                        if let Err(pos) = row.binary_search(&j) {
                            row.insert(pos, j);
                        }
                        if i > j {
                            rows_in_col[j as usize].push(i);
                        }
                    }
                }
            }
        }
    }
    (g, stats)
}

/// Dense LU reference (no pivoting) — tests only.
#[cfg(test)]
pub fn dense_lu(a: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = a.len();
    let mut m: Vec<Vec<f32>> = a.to_vec();
    for k in 0..n {
        for i in k + 1..n {
            if m[i][k] != 0.0 {
                m[i][k] /= m[k][k];
                let lik = m[i][k];
                for j in k + 1..n {
                    let akj = m[k][j];
                    if akj != 0.0 {
                        m[i][j] -= lik * akj;
                    }
                }
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_against_dense(m: &SparseMatrix) {
        let (g, _) = lu_factorization_graph(m);
        let vals = g.evaluate();
        let want = dense_lu(&m.to_dense());

        // Rebuild cur map by re-running extraction bookkeeping: simplest is
        // to re-extract and track final node per entry.
        let (_, _stats) = lu_factorization_graph(m);
        // Instead of replicating bookkeeping, verify through a fresh
        // extraction that returns the map:
        let finals = final_entry_nodes(m);
        for ((i, j), node) in finals {
            let got = vals[node as usize];
            let exp = want[i as usize][j as usize];
            let tol = 1e-4 * (1.0 + exp.abs());
            assert!(
                (got - exp).abs() <= tol,
                "entry ({i},{j}): got {got}, want {exp}"
            );
        }
    }

    /// Test helper: final node per matrix entry (duplicates the module's
    /// bookkeeping; kept in tests to keep the public API lean).
    fn final_entry_nodes(m: &SparseMatrix) -> HashMap<(u32, u32), NodeId> {
        let n = m.n;
        let mut g = DataflowGraph::new();
        let mut cur: HashMap<(u32, u32), NodeId> = HashMap::new();
        for (i, row) in m.rows.iter().enumerate() {
            for &(j, v) in row {
                let id = g.add_input(v);
                cur.insert((i as u32, j as u32), id);
            }
        }
        let mut cols: Vec<Vec<u32>> = m
            .rows
            .iter()
            .map(|r| r.iter().map(|&(c, _)| c as u32).collect())
            .collect();
        let mut rows_in_col: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (i, r) in cols.iter().enumerate() {
            for &c in r {
                if i > c as usize {
                    rows_in_col[c as usize].push(i as u32);
                }
            }
        }
        for k in 0..n as u32 {
            let pivot = *cur.get(&(k, k)).unwrap();
            let urow: Vec<u32> = cols[k as usize].iter().copied().filter(|&j| j > k).collect();
            let targets = std::mem::take(&mut rows_in_col[k as usize]);
            for &i in targets.iter().filter(|&&i| i > k) {
                let aik = *cur.get(&(i, k)).unwrap();
                let lik = g.op(Op::Div, &[aik, pivot]);
                cur.insert((i, k), lik);
                for &j in &urow {
                    let akj = *cur.get(&(k, j)).unwrap();
                    let prod = g.op(Op::Mul, &[lik, akj]);
                    match cur.get(&(i, j)) {
                        Some(&aij) => {
                            let upd = g.op(Op::Sub, &[aij, prod]);
                            cur.insert((i, j), upd);
                        }
                        None => {
                            let fill = g.op(Op::Neg, &[prod]);
                            cur.insert((i, j), fill);
                            let row = &mut cols[i as usize];
                            if let Err(pos) = row.binary_search(&j) {
                                row.insert(pos, j);
                            }
                            if i > j {
                                rows_in_col[j as usize].push(i);
                            }
                        }
                    }
                }
            }
        }
        cur
    }

    #[test]
    fn lu_graph_matches_dense_reference_banded() {
        let m = SparseMatrix::banded(24, 3, 0.9, 7);
        check_against_dense(&m);
    }

    #[test]
    fn lu_graph_matches_dense_reference_random() {
        let m = SparseMatrix::random(16, 0.25, 3);
        check_against_dense(&m);
    }

    #[test]
    fn lu_graph_matches_dense_reference_power_law() {
        let m = SparseMatrix::power_law(20, 3, 11);
        check_against_dense(&m);
    }

    #[test]
    fn stats_are_consistent() {
        let m = SparseMatrix::banded(64, 4, 0.8, 5);
        let (g, s) = lu_factorization_graph(&m);
        assert_eq!(s.nnz_in, m.nnz());
        assert_eq!(
            g.len(),
            s.nnz_in + s.div_ops + s.mul_ops + s.sub_ops + s.fill_in
        );
        assert!(s.div_ops > 0 && s.mul_ops > 0);
        // every SUB pairs with a MUL; fill-ins replace SUBs
        assert_eq!(s.mul_ops, s.sub_ops + s.fill_in);
    }

    #[test]
    fn tridiagonal_has_linear_critical_path() {
        let m = SparseMatrix::banded(50, 1, 1.0, 2);
        let (g, _) = lu_factorization_graph(&m);
        let depth = g.stats().depth;
        // elimination of a tridiagonal is inherently sequential: depth ~ 3n
        assert!(depth >= 50, "depth {depth} too shallow for tridiagonal");
    }

    #[test]
    fn graph_is_valid_and_nontrivial() {
        let m = SparseMatrix::banded(100, 5, 0.8, 1);
        let (g, _) = lu_factorization_graph(&m);
        g.validate().unwrap();
        assert!(g.len() > 1000);
        assert!(g.num_edges() >= g.len() - g.num_inputs());
    }
}
