//! Synthetic DAG families — controlled shapes for tests, microbenches and
//! ablations (width/depth/fanout knobs independent of sparsity patterns).

use crate::graph::{DataflowGraph, NodeId, Op};
use crate::util::rng::Rng;

/// Random layered DAG: `levels` levels of `width` nodes each; every node
/// draws its operands uniformly from the previous `lookback` levels.
pub fn layered_random(
    inputs: usize,
    levels: usize,
    width: usize,
    lookback: usize,
    seed: u64,
) -> DataflowGraph {
    assert!(inputs > 0 && lookback > 0);
    let mut rng = Rng::seed_from_u64(seed);
    let mut g = DataflowGraph::with_capacity(inputs + levels * width);
    let mut prev: Vec<Vec<NodeId>> = Vec::with_capacity(levels + 1);
    let layer0: Vec<NodeId> = (0..inputs)
        .map(|_| g.add_input(rng.gen_f32_in(-1.0, 1.0)))
        .collect();
    prev.push(layer0);
    let safe_ops = [Op::Add, Op::Mul, Op::Sub, Op::Max, Op::Min];
    for _ in 0..levels {
        let lo = prev.len().saturating_sub(lookback);
        let pool: Vec<NodeId> = prev[lo..].iter().flatten().copied().collect();
        let mut layer = Vec::with_capacity(width);
        for _ in 0..width {
            let op = safe_ops[rng.gen_range(safe_ops.len())];
            let a = pool[rng.gen_range(pool.len())];
            let b = pool[rng.gen_range(pool.len())];
            layer.push(g.op(op, &[a, b]));
        }
        prev.push(layer);
    }
    g
}

/// Balanced binary reduction tree over `width` inputs (width rounded up to
/// a power of two by repeating the last input).
pub fn reduction_tree(width: usize, op: Op, seed: u64) -> DataflowGraph {
    assert!(width >= 2 && op.arity() == 2);
    let mut rng = Rng::seed_from_u64(seed);
    let mut g = DataflowGraph::new();
    let mut layer: Vec<NodeId> = (0..width)
        .map(|_| g.add_input(rng.gen_f32_in(0.5, 1.5)))
        .collect();
    while layer.len() > 1 {
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        for pair in layer.chunks(2) {
            if pair.len() == 2 {
                next.push(g.op(op, &[pair[0], pair[1]]));
            } else {
                next.push(pair[0]);
            }
        }
        layer = next;
    }
    g
}

/// 1-D 3-point stencil iterated `steps` times over `width` cells
/// (boundaries clamp). Each step: cell' = (left + cell) + right.
pub fn stencil_1d(width: usize, steps: usize, seed: u64) -> DataflowGraph {
    assert!(width >= 3);
    let mut rng = Rng::seed_from_u64(seed);
    let mut g = DataflowGraph::new();
    let mut cells: Vec<NodeId> = (0..width)
        .map(|_| g.add_input(rng.gen_f32_in(-1.0, 1.0)))
        .collect();
    for _ in 0..steps {
        let mut next = Vec::with_capacity(width);
        for i in 0..width {
            let l = cells[i.saturating_sub(1)];
            let c = cells[i];
            let r = cells[(i + 1).min(width - 1)];
            let lc = g.op(Op::Add, &[l, c]);
            next.push(g.op(Op::Add, &[lc, r]));
        }
        cells = next;
    }
    g
}

/// FFT-style butterfly network over `width` (power of two) inputs:
/// log2(width) levels, each pairing nodes at stride 2^l into (a+b, a−b).
pub fn butterfly_graph(width: usize, seed: u64) -> DataflowGraph {
    assert!(width.is_power_of_two() && width >= 2);
    let mut rng = Rng::seed_from_u64(seed);
    let mut g = DataflowGraph::new();
    let mut layer: Vec<NodeId> = (0..width)
        .map(|_| g.add_input(rng.gen_f32_in(-1.0, 1.0)))
        .collect();
    let mut stride = 1;
    while stride < width {
        let mut next = layer.clone();
        for base in (0..width).step_by(stride * 2) {
            for k in 0..stride {
                let a = layer[base + k];
                let b = layer[base + k + stride];
                next[base + k] = g.op(Op::Add, &[a, b]);
                next[base + k + stride] = g.op(Op::Sub, &[a, b]);
            }
        }
        layer = next;
        stride *= 2;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layered_random_shape() {
        let g = layered_random(16, 10, 32, 2, 1);
        assert_eq!(g.len(), 16 + 10 * 32);
        assert_eq!(g.stats().depth, 10);
        g.validate().unwrap();
    }

    #[test]
    fn layered_random_deterministic_per_seed() {
        let a = layered_random(8, 4, 8, 1, 42).evaluate();
        let b = layered_random(8, 4, 8, 1, 42).evaluate();
        let c = layered_random(8, 4, 8, 1, 43).evaluate();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn reduction_tree_sums() {
        let g = reduction_tree(8, Op::Add, 3);
        let vals = g.evaluate();
        let inputs: f32 = vals[..8].iter().sum();
        let root = *vals.last().unwrap();
        assert!((root - inputs).abs() < 1e-4);
        assert_eq!(g.stats().depth, 3);
    }

    #[test]
    fn reduction_tree_odd_width() {
        let g = reduction_tree(7, Op::Max, 3);
        let vals = g.evaluate();
        let want = vals[..7].iter().copied().fold(f32::MIN, f32::max);
        assert_eq!(*vals.last().unwrap(), want);
    }

    #[test]
    fn stencil_shape_and_depth() {
        let g = stencil_1d(10, 4, 0);
        assert_eq!(g.len(), 10 + 4 * 10 * 2);
        assert_eq!(g.stats().depth, 8); // 2 adds per step
    }

    #[test]
    fn butterfly_depth_is_log2() {
        let g = butterfly_graph(16, 0);
        assert_eq!(g.stats().depth, 4);
        assert_eq!(g.len(), 16 + 4 * 16);
    }

    #[test]
    fn butterfly_first_output_is_sum() {
        let g = butterfly_graph(8, 5);
        let vals = g.evaluate();
        let sum: f32 = vals[..8].iter().sum();
        // node holding position 0 after the last level is the total sum
        // find it: last level writes 'next[0]' as one of the final nodes.
        // The DC term of an FFT butterfly == sum of inputs.
        let got = vals
            .iter()
            .copied()
            .filter(|v| (v - sum).abs() < 1e-4)
            .count();
        assert!(got >= 1, "sum {sum} not found among node values");
    }

    #[test]
    #[should_panic]
    fn butterfly_requires_power_of_two() {
        butterfly_graph(12, 0);
    }
}
