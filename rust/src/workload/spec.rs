//! The workload registry: compact, round-trippable spec strings.
//!
//! Every workload the generators can produce has a one-line name —
//! `chain:4096:seed=7`, `lu_pl:330:3`, `mix:100:60:2`, `mtx:path.mtx` —
//! which is the unit of request addressing in the service layer
//! ([`crate::service::JobSpec`] carries one) and the graph-cache key.
//! [`Spec`] parses the grammar, builds the graph, and `Display`s back
//! the canonical form, so specs survive CLI → JSON → engine round trips.
//!
//! Grammar: `kind[:arg]*[:key=value]*` — positional args are
//! kind-specific (see the table below), trailing `key=value` segments
//! are options, in any order: `seed=N` (generation seed) and `scale=K`
//! (tile K disjoint copies of the generated graph — the cheap way to
//! grow any workload past one fabric's BRAM budget for sharded-execution
//! testing). `mtx:` is special: everything after the first colon is the
//! file path, verbatim.
//!
//! | kind        | args                          | generator |
//! |-------------|-------------------------------|-----------|
//! | `lu_banded` | n, half_bw, fill              | sparse-LU of a banded matrix |
//! | `lu_random` | n, density                    | sparse-LU, uniform random |
//! | `lu_pl`     | n, avg_degree                 | sparse-LU, power-law (Fig. 1 ladder) |
//! | `chain`     | n                             | sequential pivot chain (tridiagonal LU) |
//! | `mix`       | chain_n, bulk_n, bulk_deg     | chain ∪ power-law bulk updates |
//! | `layered`   | inputs, levels, width, lookback | random layered DAG |
//! | `reduction` | width                         | binary reduction tree |
//! | `stencil`   | width, steps                  | 1-D 3-point stencil |
//! | `butterfly` | width                         | FFT butterfly |
//! | `mtx`       | path (rest of string)         | Matrix Market file |

use crate::config::WorkloadSpec;
use crate::graph::{DataflowGraph, NodeKind};
use std::fmt;
use std::str::FromStr;

/// A parsed workload spec string: the generator parameters plus the
/// generation seed. `FromStr` and `Display` round-trip; [`Spec::canonical`]
/// is the normalized form used as a cache key (aliases and a redundant
/// `seed=0` normalize away).
#[derive(Debug, Clone, PartialEq)]
pub struct Spec {
    /// which generator, with its parameters
    pub workload: WorkloadSpec,
    /// generation seed (`seed=N` option; 0 when absent)
    pub seed: u64,
    /// size multiplier (`scale=K` option; 1 when absent): the built
    /// graph is K disjoint copies of the generated one
    pub scale: usize,
}

impl Spec {
    /// Wrap a parsed [`WorkloadSpec`] with a seed (and no scaling).
    pub fn new(workload: WorkloadSpec, seed: u64) -> Self {
        Self { workload, seed, scale: 1 }
    }

    /// The normalized spec string (what `Display` prints) — equal specs
    /// canonicalize equal, so this is a sound graph-cache key.
    pub fn canonical(&self) -> String {
        self.to_string()
    }

    /// Materialize the dataflow graph: generate once, then tile
    /// `scale` disjoint copies.
    pub fn build(&self) -> Result<DataflowGraph, String> {
        let base = self.workload.build(self.seed)?;
        if self.scale <= 1 {
            return Ok(base);
        }
        Ok(tile(&base, self.scale))
    }
}

/// `copies` disjoint copies of `base` in one graph, copy-major: node
/// `id` of copy `c` lands at `c * base.len() + id`, so each copy
/// preserves the base's (topological) builder order and the result
/// needs no remapping pass.
fn tile(base: &DataflowGraph, copies: usize) -> DataflowGraph {
    let n = base.len() as u32;
    let mut out = DataflowGraph::new();
    for c in 0..copies as u32 {
        let off = c * n;
        for id in 0..n {
            match base.node(id).kind {
                NodeKind::Input { value } => {
                    out.add_input(value);
                }
                NodeKind::Operation { op, src } => {
                    let mapped: Vec<u32> =
                        src[..op.arity()].iter().map(|&s| s + off).collect();
                    out.add_op(op, &mapped).expect("tiled copy of a valid graph");
                }
            }
        }
    }
    out
}

impl fmt::Display for Spec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.workload {
            WorkloadSpec::LuBanded { n, half_bw, fill } => {
                write!(f, "lu_banded:{n}:{half_bw}:{fill}")?
            }
            WorkloadSpec::LuRandom { n, density } => write!(f, "lu_random:{n}:{density}")?,
            WorkloadSpec::LuPowerLaw { n, avg_degree } => write!(f, "lu_pl:{n}:{avg_degree}")?,
            WorkloadSpec::Layered { inputs, levels, width, lookback } => {
                write!(f, "layered:{inputs}:{levels}:{width}:{lookback}")?
            }
            WorkloadSpec::Reduction { width } => write!(f, "reduction:{width}")?,
            WorkloadSpec::Stencil { width, steps } => write!(f, "stencil:{width}:{steps}")?,
            WorkloadSpec::Butterfly { width } => write!(f, "butterfly:{width}")?,
            WorkloadSpec::Chain { n } => write!(f, "chain:{n}")?,
            WorkloadSpec::Mix { chain_n, bulk_n, bulk_deg } => {
                write!(f, "mix:{chain_n}:{bulk_n}:{bulk_deg}")?
            }
            // mtx consumes the rest of the string: no option suffix
            WorkloadSpec::MatrixMarket { path } => return write!(f, "mtx:{path}"),
        }
        if self.scale > 1 {
            write!(f, ":scale={}", self.scale)?;
        }
        if self.seed != 0 {
            write!(f, ":seed={}", self.seed)?;
        }
        Ok(())
    }
}

impl FromStr for Spec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if s.is_empty() {
            return Err("empty workload spec".to_string());
        }
        let (kind, rest) = match s.split_once(':') {
            Some((k, r)) => (k, r),
            None => (s, ""),
        };
        // mtx: the remainder is the path, verbatim (paths may contain ':')
        if kind == "mtx" || kind == "matrix_market" {
            if rest.is_empty() {
                return Err("mtx needs a path: mtx:<file.mtx>".to_string());
            }
            return Ok(Spec::new(WorkloadSpec::MatrixMarket { path: rest.to_string() }, 0));
        }
        let mut parts: Vec<&str> = if rest.is_empty() {
            Vec::new()
        } else {
            rest.split(':').collect()
        };
        // peel trailing key=value options (each at most once — silently
        // letting a duplicate win would run a different graph than the
        // one the user appended)
        let mut seed: Option<u64> = None;
        let mut scale: Option<usize> = None;
        while let Some(last) = parts.last() {
            let Some((key, value)) = last.split_once('=') else { break };
            match key {
                "seed" => {
                    if seed.is_some() {
                        return Err("duplicate spec option 'seed='".to_string());
                    }
                    seed = Some(
                        value
                            .parse()
                            .map_err(|_| format!("seed: cannot parse '{value}'"))?,
                    );
                }
                "scale" => {
                    if scale.is_some() {
                        return Err("duplicate spec option 'scale='".to_string());
                    }
                    let k: usize = value
                        .parse()
                        .map_err(|_| format!("scale: cannot parse '{value}'"))?;
                    if k == 0 {
                        return Err("scale: must be >= 1".to_string());
                    }
                    scale = Some(k);
                }
                other => return Err(format!("unknown spec option '{other}='")),
            }
            parts.pop();
        }
        let seed = seed.unwrap_or(0);
        let scale = scale.unwrap_or(1);
        let arity = |want: usize| -> Result<(), String> {
            if parts.len() == want {
                Ok(())
            } else {
                Err(format!(
                    "workload '{kind}' takes {want} argument(s), got {}",
                    parts.len()
                ))
            }
        };
        let usz = |i: usize| -> Result<usize, String> {
            parts[i]
                .parse()
                .map_err(|_| format!("{kind}: cannot parse '{}' as integer", parts[i]))
        };
        let flt = |i: usize| -> Result<f64, String> {
            parts[i]
                .parse()
                .map_err(|_| format!("{kind}: cannot parse '{}' as number", parts[i]))
        };
        let workload = match kind {
            "lu_banded" => {
                arity(3)?;
                WorkloadSpec::LuBanded { n: usz(0)?, half_bw: usz(1)?, fill: flt(2)? }
            }
            "lu_random" => {
                arity(2)?;
                WorkloadSpec::LuRandom { n: usz(0)?, density: flt(1)? }
            }
            "lu_pl" | "lu_power_law" => {
                arity(2)?;
                WorkloadSpec::LuPowerLaw { n: usz(0)?, avg_degree: usz(1)? }
            }
            "layered" => {
                arity(4)?;
                WorkloadSpec::Layered {
                    inputs: usz(0)?,
                    levels: usz(1)?,
                    width: usz(2)?,
                    lookback: usz(3)?,
                }
            }
            "reduction" => {
                arity(1)?;
                WorkloadSpec::Reduction { width: usz(0)? }
            }
            "stencil" => {
                arity(2)?;
                WorkloadSpec::Stencil { width: usz(0)?, steps: usz(1)? }
            }
            "butterfly" => {
                arity(1)?;
                WorkloadSpec::Butterfly { width: usz(0)? }
            }
            "chain" => {
                arity(1)?;
                WorkloadSpec::Chain { n: usz(0)? }
            }
            "mix" => {
                arity(3)?;
                WorkloadSpec::Mix { chain_n: usz(0)?, bulk_n: usz(1)?, bulk_deg: usz(2)? }
            }
            other => {
                return Err(format!(
                    "unknown workload kind '{other}' (lu_banded | lu_random | lu_pl | chain \
                     | mix | layered | reduction | stencil | butterfly | mtx)"
                ))
            }
        };
        Ok(Spec { workload, seed, scale })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        for s in [
            "chain:4096:seed=7",
            "reduction:64:scale=4",
            "layered:8:4:16:2:scale=3:seed=5",
            "lu_banded:100:4:0.8",
            "lu_random:64:0.1:seed=3",
            "lu_pl:330:3:seed=42",
            "mix:100:60:2:seed=1",
            "layered:8:4:16:2",
            "reduction:256",
            "stencil:32:4:seed=9",
            "butterfly:64",
            "mtx:/data/west0479.mtx",
        ] {
            let spec: Spec = s.parse().unwrap();
            assert_eq!(spec.to_string(), s, "canonical form is stable");
            let again: Spec = spec.to_string().parse().unwrap();
            assert_eq!(again, spec, "round trip");
        }
    }

    #[test]
    fn aliases_and_defaults_normalize() {
        let a: Spec = "lu_power_law:40:2".parse().unwrap();
        assert_eq!(a.canonical(), "lu_pl:40:2");
        // seed=0 is the default and normalizes away
        let b: Spec = "reduction:64:seed=0".parse().unwrap();
        assert_eq!(b.canonical(), "reduction:64");
        assert_eq!(b.seed, 0);
        // scale=1 is the default and normalizes away; options in either
        // order canonicalize to scale-then-seed
        let c: Spec = "reduction:64:scale=1".parse().unwrap();
        assert_eq!(c.canonical(), "reduction:64");
        let d: Spec = "reduction:64:seed=2:scale=3".parse().unwrap();
        assert_eq!(d.canonical(), "reduction:64:scale=3:seed=2");
    }

    #[test]
    fn scale_tiles_disjoint_copies() {
        let base: Spec = "reduction:32:seed=4".parse().unwrap();
        let scaled: Spec = "reduction:32:scale=3:seed=4".parse().unwrap();
        let g1 = base.build().unwrap();
        let g3 = scaled.build().unwrap();
        assert_eq!(g3.len(), 3 * g1.len());
        g3.validate().unwrap();
        assert_ne!(g1.fingerprint(), g3.fingerprint());
        // each copy computes the same values as the base graph
        let v1 = g1.evaluate();
        let v3 = g3.evaluate();
        for c in 0..3 {
            assert_eq!(&v3[c * g1.len()..(c + 1) * g1.len()], &v1[..], "copy {c}");
        }
        // depth is unchanged: copies are parallel, not stacked
        assert_eq!(g1.stats().depth, g3.stats().depth);
    }

    #[test]
    fn specs_build_real_graphs() {
        for s in ["chain:24", "mix:20:30:2:seed=1", "reduction:32", "lu_pl:40:2:seed=5"] {
            let spec: Spec = s.parse().unwrap();
            let g = spec.build().unwrap();
            assert!(g.len() > 0, "{s}");
            g.validate().unwrap();
        }
        // chain is depth-dominated: the pivot recurrence serializes
        let chain: Spec = "chain:24".parse().unwrap();
        let stats = chain.build().unwrap().stats();
        assert!(stats.depth >= 24, "chain depth {}", stats.depth);
    }

    #[test]
    fn same_spec_same_fingerprint_different_seed_differs() {
        let a: Spec = "layered:8:4:16:2:seed=5".parse().unwrap();
        let b: Spec = "layered:8:4:16:2:seed=5".parse().unwrap();
        let c: Spec = "layered:8:4:16:2:seed=6".parse().unwrap();
        assert_eq!(a.build().unwrap().fingerprint(), b.build().unwrap().fingerprint());
        assert_ne!(a.build().unwrap().fingerprint(), c.build().unwrap().fingerprint());
    }

    #[test]
    fn malformed_specs_rejected() {
        for s in [
            "",
            "bogus:4",
            "chain",            // missing arg
            "chain:x",          // non-numeric
            "chain:4:5",        // too many args
            "chain:4:speed=7",  // unknown option
            "chain:4:seed=1:seed=2", // duplicate option
            "chain:4:scale=2:scale=3", // duplicate option
            "chain:4:scale=0",  // zero copies is meaningless
            "chain:4:scale=x",
            "mtx:",             // missing path
            "reduction:64:seed=abc",
        ] {
            assert!(s.parse::<Spec>().is_err(), "'{s}' must not parse");
        }
    }
}
