//! Workload generation — the application dataflow graphs of the paper's
//! evaluation ("dataflow graphs extracted from sparse matrix factorization
//! kernels", hundreds to >100 K nodes/edges), plus synthetic DAG families
//! used by tests, benches and ablations.
//!
//! Substitution note (DESIGN.md §2): we do not have the authors' matrices;
//! the generators here produce sparse-LU elimination DAGs over synthetic
//! sparsity patterns (banded / uniform random / power-law) whose DAG
//! *shapes* — fanout skew, width-vs-depth profile — span the same regimes.
//! `patterns::parse_matrix_market` ingests real matrices when available.

mod factorization;
mod patterns;
mod profile;
mod spec;
mod synthetic;

pub use factorization::{lu_factorization_graph, FactorizationStats};
pub use patterns::{parse_matrix_market, SparseMatrix};
pub use profile::{profile, WorkloadProfile};
pub use spec::Spec;
pub use synthetic::{butterfly_graph, layered_random, reduction_tree, stencil_1d};

#[cfg(test)]
mod union_tests {
    use super::*;
    use crate::graph::Op;

    #[test]
    fn union_preserves_values() {
        let mut a = DataflowGraph::new();
        let x = a.add_input(2.0);
        a.op(Op::Neg, &[x]);
        let mut b = DataflowGraph::new();
        let y = b.add_input(5.0);
        let z = b.add_input(3.0);
        b.op(Op::Mul, &[y, z]);
        let u = union(&[a.clone(), b.clone()]);
        assert_eq!(u.len(), a.len() + b.len());
        let vals = u.evaluate();
        assert_eq!(vals[1], -2.0);
        assert_eq!(vals[4], 15.0);
        u.validate().unwrap();
    }

    #[test]
    fn mix_has_chain_and_bulk() {
        let g = factorization_mix(100, 60, 2, 1);
        let s = g.stats();
        // chain part forces depth ~ O(chain_n); bulk part dominates size
        assert!(s.depth >= 100, "depth {}", s.depth);
        assert!(s.nodes > 1000);
    }
}

use crate::graph::{DataflowGraph, NodeKind};

/// Disjoint union of dataflow graphs (independent subgraphs evaluated on
/// the same overlay — the multi-kernel workloads of real factorization
/// runs: a sequential pivot chain coupled with bulk update work).
pub fn union(graphs: &[DataflowGraph]) -> DataflowGraph {
    let total: usize = graphs.iter().map(|g| g.len()).sum();
    let mut out = DataflowGraph::with_capacity(total);
    for g in graphs {
        let base = out.len() as u32;
        for node in g.nodes() {
            match node.kind {
                NodeKind::Input { value } => {
                    out.add_input(value);
                }
                NodeKind::Operation { op, src } => {
                    let srcs: Vec<u32> = src[..op.arity()].iter().map(|&s| s + base).collect();
                    out.add_op(op, &srcs).expect("union preserves topology");
                }
            }
        }
    }
    out
}

/// One Fig.-1-style workload: a sparse factorization DAG with both a deep
/// pivot chain (tridiagonal block) and wide bulk updates (power-law
/// block) — the structure of real elimination DAGs, where out-of-order
/// criticality scheduling pays (paper §III).
pub fn factorization_mix(chain_n: usize, bulk_n: usize, bulk_deg: usize, seed: u64) -> DataflowGraph {
    let chain = {
        let m = SparseMatrix::banded(chain_n, 1, 1.0, seed);
        lu_factorization_graph(&m).0
    };
    let bulk = {
        let m = SparseMatrix::power_law(bulk_n, bulk_deg, seed.wrapping_add(1));
        lu_factorization_graph(&m).0
    };
    union(&[chain, bulk])
}

/// The standard Fig. 1 workload ladder as registry [`Spec`]s, smallest
/// matrix first: sparse-LU elimination DAGs of increasing size
/// (≈1 K → >1 M nodes+edges) from power-law sparsity patterns — the
/// skewed-criticality, bushy-elimination-tree regime of real
/// factorization matrices. Returns `(label, spec)` pairs; graph
/// generation happens inside the [`crate::service::Engine`] the sweep
/// runs on ([`crate::coordinator::fig1_sweep`] presents rows in
/// footprint order).
///
/// Run these with [`crate::config::OverlayConfig`] placement =
/// `Chunked` (the locality-preserving toolflow default): that is the
/// regime the paper measures, where per-PE ready queues form and the
/// scheduler decides completion time (see EXPERIMENTS.md §Fig1 for the
/// placement sensitivity study).
pub fn fig1_specs(seed: u64) -> Vec<(String, Spec)> {
    // (matrix dim, avg degree)
    let points: &[(usize, usize)] = &[
        (40, 2),
        (80, 2),
        (140, 3),
        (220, 3),
        (330, 3),
        (470, 3),
        (650, 3),
        (900, 3),
    ];
    points
        .iter()
        .enumerate()
        .map(|(i, &(n, deg))| {
            let spec: Spec = format!("lu_pl:{n}:{deg}:seed={}", seed.wrapping_add(i as u64))
                .parse()
                .expect("ladder specs are well-formed");
            (format!("lu_pl_n{n}"), spec)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_ladder_spans_the_paper_range() {
        let ws = fig1_specs(42);
        assert!(ws.len() >= 6);
        let mut sizes: Vec<usize> = ws
            .iter()
            .map(|(_, spec)| spec.build().unwrap().footprint())
            .collect();
        // spans hundreds to ~100K+ nodes+edges as in the paper (fill-in
        // makes footprint noisy across seeds, so size order is restored
        // at presentation time by fig1_sweep, not guaranteed here)
        sizes.sort_unstable();
        assert!(sizes[0] < 20_000, "{sizes:?}");
        assert!(*sizes.last().unwrap() > 100_000, "{sizes:?}");
        // every ladder spec round-trips through the registry grammar
        for (_, spec) in &ws {
            assert_eq!(spec.canonical().parse::<Spec>().unwrap(), *spec);
        }
    }
}
