//! Workload characterization: the graph-shape metrics that determine
//! which scheduling regime (Fig. 1) a workload lands in — per-level
//! parallelism (width profile), fanout skew and criticality spread.

use crate::criticality;
use crate::graph::DataflowGraph;

/// Shape profile of a dataflow graph.
#[derive(Debug, Clone)]
pub struct WorkloadProfile {
    pub nodes: usize,
    pub edges: usize,
    pub depth: usize,
    /// nodes per ASAP level (level 0 = inputs)
    pub width_per_level: Vec<usize>,
    pub max_width: usize,
    /// mean nodes per level — the average parallelism
    pub avg_width: f64,
    /// fanout histogram: count of nodes with fanout 0,1,2,3,4+,
    pub fanout_hist: [usize; 5],
    pub max_fanout: usize,
    /// fraction of nodes with zero slack (on a critical path)
    pub critical_fraction: f64,
}

/// Profile `g` (one pass each over levels/fanouts/slack).
pub fn profile(g: &DataflowGraph) -> WorkloadProfile {
    let levels = criticality::asap(g);
    let depth = levels.iter().copied().max().unwrap_or(0) as usize;
    let mut width = vec![0usize; depth + 1];
    for &l in &levels {
        width[l as usize] += 1;
    }
    let mut fanout_hist = [0usize; 5];
    let mut max_fanout = 0;
    for node in g.nodes() {
        let f = node.fanout.len();
        fanout_hist[f.min(4)] += 1;
        max_fanout = max_fanout.max(f);
    }
    let slack = criticality::slack(g);
    let critical = slack.iter().filter(|&&s| s == 0).count();
    WorkloadProfile {
        nodes: g.len(),
        edges: g.num_edges(),
        depth,
        max_width: width.iter().copied().max().unwrap_or(0),
        avg_width: g.len() as f64 / (depth + 1) as f64,
        width_per_level: width,
        fanout_hist,
        max_fanout,
        critical_fraction: critical as f64 / g.len() as f64,
    }
}

impl WorkloadProfile {
    /// Does this graph saturate an overlay of `num_pes` PEs? (The Fig. 1
    /// crossover condition: average parallelism well beyond PE count.)
    pub fn saturates(&self, num_pes: usize) -> bool {
        self.avg_width > num_pes as f64
    }

    /// Render the width profile as an ASCII sparkline.
    pub fn width_sparkline(&self, width: usize) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        if self.width_per_level.is_empty() {
            return String::new();
        }
        let max = self.max_width.max(1);
        let bucket = self.width_per_level.len().div_ceil(width.max(1));
        let mut out = String::new();
        for chunk in self.width_per_level.chunks(bucket) {
            let avg = chunk.iter().sum::<usize>() / chunk.len();
            out.push(GLYPHS[(avg * (GLYPHS.len() - 1)) / max]);
        }
        out
    }

    pub fn report(&self) -> String {
        format!(
            "nodes {}  edges {}  depth {}\n\
             parallelism: avg {:.1} / max {} per level\n\
             width profile: {}\n\
             fanout histogram (0/1/2/3/4+): {:?} (max {})\n\
             critical-path nodes: {:.1}%",
            self.nodes,
            self.edges,
            self.depth,
            self.avg_width,
            self.max_width,
            self.width_sparkline(48),
            self.fanout_hist,
            self.max_fanout,
            100.0 * self.critical_fraction
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;
    use crate::workload::{layered_random, lu_factorization_graph, reduction_tree, SparseMatrix};

    #[test]
    fn layered_profile() {
        let g = layered_random(10, 5, 20, 1, 1);
        let p = profile(&g);
        assert_eq!(p.depth, 5);
        assert_eq!(p.width_per_level[0], 10);
        assert_eq!(p.width_per_level[3], 20);
        assert_eq!(p.max_width, 20);
        assert!(p.saturates(4));
        assert!(!p.saturates(64));
    }

    #[test]
    fn reduction_tree_profile() {
        let g = reduction_tree(64, Op::Add, 1);
        let p = profile(&g);
        assert_eq!(p.depth, 6);
        assert_eq!(p.width_per_level[0], 64);
        assert_eq!(p.width_per_level[6], 1);
        // interior nodes have fanout 1, root 0
        assert_eq!(p.fanout_hist[0], 1);
    }

    #[test]
    fn lu_profile_is_skewed() {
        let m = SparseMatrix::power_law(60, 3, 2);
        let (g, _) = lu_factorization_graph(&m);
        let p = profile(&g);
        assert!(p.max_fanout > 4, "power-law LU has hub nodes");
        assert!(p.critical_fraction < 0.5, "most nodes off the critical path");
        assert_eq!(p.width_per_level.iter().sum::<usize>(), p.nodes);
    }

    #[test]
    fn sparkline_width() {
        let g = layered_random(8, 20, 8, 1, 0);
        let p = profile(&g);
        // bucketing may undershoot the target width, never overshoot
        let n = p.width_sparkline(12).chars().count();
        assert!(n >= 6 && n <= 12, "sparkline width {n}");
        assert!(!p.report().is_empty());
    }
}
