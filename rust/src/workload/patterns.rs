//! Sparse matrix substrate: patterns + values, generators, Matrix Market.

use crate::util::rng::Rng;

/// A square sparse matrix in row-major coordinate form with values.
/// Rows are kept sorted by column; duplicate entries are not allowed.
#[derive(Debug, Clone)]
pub struct SparseMatrix {
    pub n: usize,
    /// per-row sorted (col, value)
    pub rows: Vec<Vec<(usize, f32)>>,
}

impl SparseMatrix {
    pub fn empty(n: usize) -> Self {
        Self {
            n,
            rows: vec![Vec::new(); n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.rows.iter().map(|r| r.len()).sum()
    }

    pub fn get(&self, i: usize, j: usize) -> Option<f32> {
        self.rows[i]
            .binary_search_by_key(&j, |&(c, _)| c)
            .ok()
            .map(|k| self.rows[i][k].1)
    }

    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        match self.rows[i].binary_search_by_key(&j, |&(c, _)| c) {
            Ok(k) => self.rows[i][k].1 = v,
            Err(k) => self.rows[i].insert(k, (j, v)),
        }
    }

    /// Make the matrix strictly diagonally dominant (so LU without
    /// pivoting is numerically stable — the paper's dataflow graphs are
    /// pre-pivoted factorization traces).
    pub fn make_diagonally_dominant(&mut self) {
        for i in 0..self.n {
            let off: f32 = self.rows[i]
                .iter()
                .filter(|&&(c, _)| c != i)
                .map(|&(_, v)| v.abs())
                .sum();
            self.set(i, i, off + 1.0 + (i % 7) as f32 * 0.25);
        }
    }

    /// Banded matrix: entries within `half_bw` of the diagonal, each
    /// present with probability `fill` (diagonal always present).
    pub fn banded(n: usize, half_bw: usize, fill: f64, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut m = Self::empty(n);
        for i in 0..n {
            let lo = i.saturating_sub(half_bw);
            let hi = (i + half_bw).min(n - 1);
            for j in lo..=hi {
                if j == i || rng.gen_bool(fill) {
                    let v = rng.gen_f32_in(-1.0, 1.0);
                    m.set(i, j, v);
                }
            }
        }
        m.make_diagonally_dominant();
        m
    }

    /// Uniform random sparsity with expected `density` off-diagonal fill.
    pub fn random(n: usize, density: f64, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut m = Self::empty(n);
        for i in 0..n {
            for j in 0..n {
                if j == i || rng.gen_bool(density) {
                    m.set(i, j, rng.gen_f32_in(-1.0, 1.0));
                }
            }
        }
        m.make_diagonally_dominant();
        m
    }

    /// Power-law column degrees (a few dense columns, many sparse) — the
    /// skewed-fanout regime of circuit/graph matrices.
    pub fn power_law(n: usize, avg_degree: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut m = Self::empty(n);
        // zipf-ish column weights
        let weights: Vec<f64> = (0..n).map(|j| 1.0 / ((j + 1) as f64)).collect();
        let wsum: f64 = weights.iter().sum();
        let total = n * avg_degree;
        for _ in 0..total {
            let i = rng.gen_range(n);
            // inverse-CDF sample a column
            let mut t = rng.gen_f64() * wsum;
            let mut j = 0;
            for (idx, &w) in weights.iter().enumerate() {
                if t < w {
                    j = idx;
                    break;
                }
                t -= w;
            }
            m.set(i, j, rng.gen_f32_in(-1.0, 1.0));
        }
        for i in 0..n {
            if m.get(i, i).is_none() {
                m.set(i, i, 1.0);
            }
        }
        m.make_diagonally_dominant();
        m
    }

    /// Dense representation (tests only; O(n^2)).
    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        let mut d = vec![vec![0f32; self.n]; self.n];
        for (i, row) in self.rows.iter().enumerate() {
            for &(j, v) in row {
                d[i][j] = v;
            }
        }
        d
    }
}

/// Parse a Matrix Market file (`coordinate real/integer/pattern`,
/// `general` or `symmetric`). Pattern entries get pseudorandom values.
pub fn parse_matrix_market(text: &str) -> Result<SparseMatrix, String> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or("empty file")?;
    if !header.starts_with("%%MatrixMarket") {
        return Err("missing %%MatrixMarket header".into());
    }
    let h = header.to_ascii_lowercase();
    if !h.contains("coordinate") {
        return Err("only coordinate format supported".into());
    }
    if h.contains("complex") {
        return Err(
            "complex matrices are not supported: the overlay datapath is f32-only \
             (field must be real, integer or pattern)"
                .into(),
        );
    }
    let pattern = h.contains("pattern");
    if !pattern && !h.contains("real") && !h.contains("integer") {
        return Err(format!(
            "unsupported field in header '{}' (real | integer | pattern)",
            header.trim()
        ));
    }
    let symmetric = h.contains("symmetric");
    let mut body = lines.filter(|l| !l.trim_start().starts_with('%'));
    let dims = body.next().ok_or("missing size line")?;
    let mut it = dims.split_whitespace();
    let nr: usize = it.next().ok_or("bad size")?.parse().map_err(|e| format!("{e}"))?;
    let nc: usize = it.next().ok_or("bad size")?.parse().map_err(|e| format!("{e}"))?;
    let nnz: usize = it.next().ok_or("bad size")?.parse().map_err(|e| format!("{e}"))?;
    if nr != nc {
        return Err(format!("matrix must be square, got {nr}x{nc}"));
    }
    // Node ids are u32 throughout the stack (graph IR, NoC packets,
    // route tables). The elimination DAG emits several nodes per stored
    // entry plus one per row, so reject anything that could not derive
    // an addressable graph instead of silently truncating ids later.
    const MAX_ITEMS: usize = (u32::MAX / 4) as usize;
    if nr > MAX_ITEMS || nnz > MAX_ITEMS {
        return Err(format!(
            "matrix too large for u32 node ids: {nr} rows / {nnz} nonzeros \
             exceeds the {MAX_ITEMS}-item ceiling of the derived dataflow graph"
        ));
    }
    let mut m = SparseMatrix::empty(nr);
    let mut count = 0usize;
    let mut rng = Rng::seed_from_u64(0x4d4d);
    for line in body {
        let mut f = line.split_whitespace();
        let i: usize = f.next().ok_or("bad entry")?.parse().map_err(|e| format!("{e}"))?;
        let j: usize = f.next().ok_or("bad entry")?.parse().map_err(|e| format!("{e}"))?;
        if i == 0 || j == 0 || i > nr || j > nc {
            return Err(format!(
                "entry index ({i}, {j}) out of range for {nr}x{nc} matrix \
                 (Matrix Market indices are 1-based)"
            ));
        }
        let v: f32 = if pattern {
            rng.gen_f32_in(-1.0, 1.0)
        } else {
            f.next().ok_or("missing value")?.parse().map_err(|e| format!("{e}"))?
        };
        m.set(i - 1, j - 1, v);
        if symmetric && i != j {
            m.set(j - 1, i - 1, v);
        }
        count += 1;
    }
    if count != nnz {
        return Err(format!("expected {nnz} entries, got {count}"));
    }
    m.make_diagonally_dominant();
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banded_has_diagonal_and_band() {
        let m = SparseMatrix::banded(32, 2, 1.0, 1);
        assert_eq!(m.n, 32);
        for i in 0..32 {
            assert!(m.get(i, i).is_some());
            assert!(m.get(i, (i + 3).min(31)).is_none() || i + 3 > 31);
        }
        // full band: row 10 has cols 8..=12
        assert_eq!(m.rows[10].len(), 5);
    }

    #[test]
    fn diagonal_dominance_holds() {
        for seed in 0..3 {
            let m = SparseMatrix::random(24, 0.2, seed);
            for i in 0..m.n {
                let d = m.get(i, i).unwrap().abs();
                let off: f32 = m.rows[i]
                    .iter()
                    .filter(|&&(c, _)| c != i)
                    .map(|&(_, v)| v.abs())
                    .sum();
                assert!(d > off, "row {i}: |d|={d} <= sum|off|={off}");
            }
        }
    }

    #[test]
    fn power_law_is_skewed() {
        let m = SparseMatrix::power_law(100, 4, 9);
        let mut coldeg = vec![0usize; m.n];
        for row in &m.rows {
            for &(j, _) in row {
                coldeg[j] += 1;
            }
        }
        // column 0 should be much denser than the median column
        let mut sorted = coldeg.clone();
        sorted.sort_unstable();
        assert!(coldeg[0] >= 3 * sorted[m.n / 2].max(1));
    }

    #[test]
    fn matrix_market_general() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % comment\n\
                    3 3 4\n1 1 2.0\n2 2 3.0\n3 3 4.0\n3 1 -1.0\n";
        let m = parse_matrix_market(text).unwrap();
        assert_eq!(m.n, 3);
        assert!(m.get(2, 0).is_some());
        assert!(m.get(0, 2).is_none());
    }

    #[test]
    fn matrix_market_symmetric_and_pattern() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n\
                    3 3 3\n1 1\n3 1\n3 3\n";
        let m = parse_matrix_market(text).unwrap();
        assert!(m.get(2, 0).is_some());
        assert!(m.get(0, 2).is_some(), "symmetric mirror");
    }

    #[test]
    fn matrix_market_complex_rejected() {
        let text = "%%MatrixMarket matrix coordinate complex general\n\
                    2 2 1\n1 1 1.0 0.0\n";
        let err = parse_matrix_market(text).unwrap_err();
        assert!(err.contains("complex"), "error must name the field: {err}");
        // hermitian files are complex-by-definition in practice; the
        // explicit complex check fires before any entry parsing
        let text = "%%MatrixMarket matrix coordinate complex hermitian\n\
                    2 2 1\n1 1 1.0 0.0\n";
        assert!(parse_matrix_market(text).is_err());
    }

    #[test]
    fn matrix_market_unknown_field_rejected() {
        let text = "%%MatrixMarket matrix coordinate quaternion general\n2 2 1\n1 1 1.0\n";
        let err = parse_matrix_market(text).unwrap_err();
        assert!(err.contains("field"), "{err}");
    }

    #[test]
    fn matrix_market_index_range_validated() {
        let base = "%%MatrixMarket matrix coordinate real general\n3 3 1\n";
        for entry in ["4 1 1.0", "1 4 1.0", "0 1 1.0", "1 0 1.0", "7 9 1.0"] {
            let err = parse_matrix_market(&format!("{base}{entry}\n")).unwrap_err();
            assert!(err.contains("out of range"), "entry '{entry}': {err}");
        }
        // boundary indices are valid
        let m = parse_matrix_market(&format!("{base}3 3 1.0\n")).unwrap();
        assert!(m.get(2, 2).is_some());
    }

    #[test]
    fn matrix_market_integer_field_accepted() {
        let text = "%%MatrixMarket matrix coordinate integer general\n2 2 2\n1 1 3\n2 2 -2\n";
        let m = parse_matrix_market(text).unwrap();
        assert_eq!(m.n, 2);
        assert!(m.get(1, 1).is_some());
    }

    #[test]
    fn matrix_market_u32_range_guarded() {
        // a size line promising more items than u32 node ids can address
        // is rejected up front, before any entry parsing
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    4000000000 4000000000 1\n1 1 1.0\n";
        let err = parse_matrix_market(text).unwrap_err();
        assert!(err.contains("u32"), "error must name the id range: {err}");
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    3 3 4000000000\n1 1 1.0\n";
        assert!(parse_matrix_market(text).unwrap_err().contains("u32"));
    }

    #[test]
    fn matrix_market_errors() {
        assert!(parse_matrix_market("").is_err());
        assert!(parse_matrix_market("%%MatrixMarket matrix array real general\n2 2\n").is_err());
        assert!(parse_matrix_market("%%MatrixMarket matrix coordinate real general\n2 3 1\n1 1 1.0\n").is_err());
        assert!(parse_matrix_market("%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n").is_err());
        assert!(parse_matrix_market("%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n").is_err());
    }
}
