//! Stub PJRT bridge, compiled when the `xla` feature is **off** (the
//! default — the `xla` crate is not in the offline crate universe, see
//! Cargo.toml). [`XlaRuntime::load`] always fails, so every oracle
//! consumer — `tests/integration_runtime.rs`, `tdp validate`, the
//! `sparse_factorization` example — takes its artifacts-absent skip path.

use super::Manifest;
use crate::graph::DataflowGraph;
use anyhow::{bail, Result};
use std::path::Path;

const UNAVAILABLE: &str = "tdp was built without the `xla` feature: the PJRT oracle is \
     unavailable (add the xla dependency and rebuild with `--features xla`)";

/// API-compatible placeholder for the PJRT runtime. Never instantiable:
/// [`XlaRuntime::load`] fails before construction.
pub struct XlaRuntime {
    pub manifest: Manifest,
}

impl XlaRuntime {
    pub fn load(_dir: &Path) -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn alu_batch(&self, _a: &[f32], _b: &[f32], _op: &[u32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    pub fn lod_pick(&self, _words: &[u32]) -> Result<u32> {
        bail!(UNAVAILABLE)
    }

    pub fn graph_eval(&self, _g: &DataflowGraph) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_fails_loudly() {
        let err = XlaRuntime::load(Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
