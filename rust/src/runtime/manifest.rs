//! `artifacts/manifest.json` — shapes, file names and the opcode table
//! emitted by `python/compile/aot.py`. A test asserts the python opcode
//! table matches [`crate::graph::Op`], keeping the two layers in sync.

use crate::util::json::{self, Json};
use anyhow::{anyhow, ensure, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, Default)]
pub struct ArtifactInfo {
    pub file: String,
    pub sha256_16: Option<String>,
    pub batch: Option<usize>,
    pub words: Option<usize>,
    pub n: Option<usize>,
    pub lmax: Option<usize>,
}

impl ArtifactInfo {
    fn from_json(j: &Json) -> Result<Self> {
        let file = j
            .get("file")
            .and_then(|f| f.as_str())
            .ok_or_else(|| anyhow!("artifact entry missing 'file'"))?
            .to_string();
        Ok(Self {
            file,
            sha256_16: j.get("sha256_16").and_then(|s| s.as_str()).map(String::from),
            batch: j.get("batch").and_then(|v| v.as_usize()),
            words: j.get("words").and_then(|v| v.as_usize()),
            n: j.get("n").and_then(|v| v.as_usize()),
            lmax: j.get("lmax").and_then(|v| v.as_usize()),
        })
    }
}

#[derive(Debug, Clone)]
pub struct Artifacts {
    pub alu_batch: ArtifactInfo,
    pub lod: ArtifactInfo,
    pub graph_eval: ArtifactInfo,
}

#[derive(Debug, Clone)]
pub struct OpcodeEntry {
    pub name: String,
    pub arity: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: String,
    pub opcodes: BTreeMap<u32, OpcodeEntry>,
    pub artifacts: Artifacts,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let doc = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let format = doc
            .get("format")
            .and_then(|f| f.as_str())
            .ok_or_else(|| anyhow!("manifest missing 'format'"))?
            .to_string();
        ensure!(format == "hlo-text", "unknown artifact format {format}");
        let mut opcodes = BTreeMap::new();
        let ops = doc
            .get("opcodes")
            .and_then(|o| o.as_obj())
            .ok_or_else(|| anyhow!("manifest missing 'opcodes'"))?;
        for (code, entry) in ops {
            let code: u32 = code.parse().map_err(|_| anyhow!("bad opcode key {code}"))?;
            let name = entry
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow!("opcode {code} missing name"))?
                .to_string();
            let arity = entry
                .get("arity")
                .and_then(|a| a.as_usize())
                .ok_or_else(|| anyhow!("opcode {code} missing arity"))?;
            opcodes.insert(code, OpcodeEntry { name, arity });
        }
        let arts = doc
            .get("artifacts")
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let get = |name: &str| -> Result<ArtifactInfo> {
            ArtifactInfo::from_json(
                arts.get(name)
                    .ok_or_else(|| anyhow!("manifest missing artifact '{name}'"))?,
            )
        };
        Ok(Self {
            format,
            opcodes,
            artifacts: Artifacts {
                alu_batch: get("alu_batch")?,
                lod: get("lod")?,
                graph_eval: get("graph_eval")?,
            },
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Assert the python opcode table matches `crate::graph::Op`.
    pub fn check_opcode_table(&self) -> Result<()> {
        use crate::graph::Op;
        for op in Op::ALL {
            let entry = self
                .opcodes
                .get(&op.code())
                .ok_or_else(|| anyhow!("opcode {} missing from manifest", op.code()))?;
            ensure!(
                entry.name == op.name(),
                "opcode {}: manifest says {}, rust says {}",
                op.code(),
                entry.name,
                op.name()
            );
            ensure!(entry.arity == op.arity(), "opcode {} arity mismatch", op.code());
        }
        ensure!(
            self.opcodes.len() == Op::ALL.len(),
            "opcode table size mismatch: manifest {}, rust {}",
            self.opcodes.len(),
            Op::ALL.len()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> String {
        r#"{
          "format": "hlo-text",
          "opcodes": {
            "0": {"name": "ADD", "arity": 2},
            "1": {"name": "MUL", "arity": 2},
            "2": {"name": "SUB", "arity": 2},
            "3": {"name": "DIV", "arity": 2},
            "4": {"name": "MAX", "arity": 2},
            "5": {"name": "MIN", "arity": 2},
            "6": {"name": "NEG", "arity": 1},
            "7": {"name": "COPY", "arity": 1}
          },
          "artifacts": {
            "alu_batch": {"file": "alu_batch.hlo.txt", "batch": 4096},
            "lod": {"file": "lod.hlo.txt", "words": 128},
            "graph_eval": {"file": "graph_eval.hlo.txt", "n": 2048, "lmax": 256}
          }
        }"#
        .to_string()
    }

    #[test]
    fn parse_and_check() {
        let m = Manifest::parse(&sample_json()).unwrap();
        assert_eq!(m.artifacts.alu_batch.batch, Some(4096));
        assert_eq!(m.artifacts.graph_eval.lmax, Some(256));
        m.check_opcode_table().unwrap();
    }

    #[test]
    fn opcode_mismatch_detected() {
        let bad = sample_json().replace("\"ADD\"", "\"XOR\"");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.check_opcode_table().is_err());
    }

    #[test]
    fn missing_artifact_detected() {
        let bad = sample_json().replace("\"lod\":", "\"lodx\":");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // When `make artifacts` has run, validate the real file too.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::load(&path).unwrap();
            m.check_opcode_table().unwrap();
        }
    }
}
