//! PJRT runtime bridge: load the AOT-compiled HLO-text artifacts emitted
//! by `python/compile/aot.py` and execute them from rust. This is the
//! only place the overlay touches XLA; python never runs at request time.
//!
//! Loading pattern (see /opt/xla-example/load_hlo.rs): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Text is the interchange format
//! because xla_extension 0.5.1 rejects jax≥0.5 serialized protos
//! (64-bit instruction ids).

mod manifest;

pub use manifest::{ArtifactInfo, Manifest};

use crate::criticality;
use crate::graph::{DataflowGraph, NodeKind};
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Compiled executables for every artifact in the manifest.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    alu: xla::PjRtLoadedExecutable,
    lod: xla::PjRtLoadedExecutable,
    graph_eval: xla::PjRtLoadedExecutable,
}

impl XlaRuntime {
    /// Load and compile all artifacts from `dir` (usually `artifacts/`).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .context("reading artifacts/manifest.json (run `make artifacts`)")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
        };
        let alu = compile(&manifest.artifacts.alu_batch.file)?;
        let lod = compile(&manifest.artifacts.lod.file)?;
        let graph_eval = compile(&manifest.artifacts.graph_eval.file)?;
        Ok(Self {
            client,
            manifest,
            alu,
            lod,
            graph_eval,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute the L1 Pallas ALU kernel on a batch of node operations.
    /// Inputs shorter than the artifact batch are padded (with op=COPY on
    /// zeroes); the result is truncated back to the input length.
    pub fn alu_batch(&self, a: &[f32], b: &[f32], op: &[u32]) -> Result<Vec<f32>> {
        let batch = self.manifest.artifacts.alu_batch.batch.unwrap_or(0);
        anyhow::ensure!(a.len() == b.len() && a.len() == op.len(), "length mismatch");
        anyhow::ensure!(a.len() <= batch, "batch {} exceeds artifact size {batch}", a.len());
        let mut pa = a.to_vec();
        let mut pb = b.to_vec();
        let mut pop: Vec<i32> = op.iter().map(|&o| o as i32).collect();
        pa.resize(batch, 0.0);
        pb.resize(batch, 0.0);
        pop.resize(batch, 7); // COPY
        let la = xla::Literal::vec1(&pa);
        let lb = xla::Literal::vec1(&pb);
        let lop = xla::Literal::vec1(&pop);
        let out = self
            .alu
            .execute::<xla::Literal>(&[la, lb, lop])
            .map_err(|e| anyhow!("alu execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("alu fetch: {e}"))?;
        let tuple = out.to_tuple1().map_err(|e| anyhow!("alu tuple: {e}"))?;
        let mut v = tuple.to_vec::<f32>().map_err(|e| anyhow!("alu to_vec: {e}"))?;
        v.truncate(a.len());
        Ok(v)
    }

    /// Execute the L1 hierarchical LOD kernel over packed flag words.
    /// Returns the leading node id, or `crate::lod::NO_READY` if none.
    pub fn lod_pick(&self, words: &[u32]) -> Result<u32> {
        let n = self.manifest.artifacts.lod.words.unwrap_or(0);
        anyhow::ensure!(words.len() <= n, "{} words exceeds artifact size {n}", words.len());
        let mut pw: Vec<i32> = words.iter().map(|&w| w as i32).collect();
        pw.resize(n, 0);
        let lw = xla::Literal::vec1(&pw);
        let out = self
            .lod
            .execute::<xla::Literal>(&[lw])
            .map_err(|e| anyhow!("lod execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("lod fetch: {e}"))?;
        let tuple = out.to_tuple1().map_err(|e| anyhow!("lod tuple: {e}"))?;
        let v = tuple.to_vec::<i32>().map_err(|e| anyhow!("lod to_vec: {e}"))?;
        Ok(v[0] as u32)
    }

    /// Evaluate a whole dataflow graph through the L2 `graph_eval`
    /// artifact (levelized gather → Pallas ALU → masked writeback).
    ///
    /// Errors if the graph exceeds the artifact's padded geometry
    /// (`n` slots / `lmax` levels) — callers fall back to
    /// [`DataflowGraph::evaluate`] for larger graphs.
    pub fn graph_eval(&self, g: &DataflowGraph) -> Result<Vec<f32>> {
        let enc = encode_graph(g);
        let n = self.manifest.artifacts.graph_eval.n.unwrap_or(0);
        let lmax = self.manifest.artifacts.graph_eval.lmax.unwrap_or(0) as u32;
        anyhow::ensure!(
            g.len() <= n,
            "graph has {} nodes, artifact padded to {n}",
            g.len()
        );
        anyhow::ensure!(
            enc.depth <= lmax,
            "graph depth {} exceeds artifact lmax {lmax}",
            enc.depth
        );
        let pad = |mut v: Vec<i32>, fill: i32| -> Vec<i32> {
            v.resize(n, fill);
            v
        };
        let mut vals = enc.values0;
        vals.resize(n, 0.0);
        // padding slots: self-gather, COPY, level -1 (never fires)
        let mut src0 = enc.src0;
        let mut src1 = enc.src1;
        for k in g.len()..n {
            src0.push(k as i32);
            src1.push(k as i32);
        }
        let lv = xla::Literal::vec1(&vals);
        let ls0 = xla::Literal::vec1(&src0);
        let ls1 = xla::Literal::vec1(&src1);
        let lop = xla::Literal::vec1(&pad(enc.opcode, 7));
        let llv = xla::Literal::vec1(&pad(enc.level, -1));
        let out = self
            .graph_eval
            .execute::<xla::Literal>(&[lv, ls0, ls1, lop, llv])
            .map_err(|e| anyhow!("graph_eval execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("graph_eval fetch: {e}"))?;
        let tuple = out.to_tuple1().map_err(|e| anyhow!("graph_eval tuple: {e}"))?;
        let mut v = tuple
            .to_vec::<f32>()
            .map_err(|e| anyhow!("graph_eval to_vec: {e}"))?;
        v.truncate(g.len());
        Ok(v)
    }
}

/// The L2 artifact's padded-array graph encoding.
pub struct EncodedGraph {
    pub values0: Vec<f32>,
    pub src0: Vec<i32>,
    pub src1: Vec<i32>,
    pub opcode: Vec<i32>,
    pub level: Vec<i32>,
    pub depth: u32,
}

/// Encode a graph into the levelized arrays `graph_eval` consumes.
pub fn encode_graph(g: &DataflowGraph) -> EncodedGraph {
    let levels = criticality::asap(g);
    let depth = levels.iter().copied().max().unwrap_or(0);
    let n = g.len();
    let mut enc = EncodedGraph {
        values0: vec![0f32; n],
        src0: (0..n as i32).collect(),
        src1: (0..n as i32).collect(),
        opcode: vec![7; n], // COPY
        level: vec![0; n],
        depth,
    };
    for (i, node) in g.nodes().iter().enumerate() {
        match node.kind {
            NodeKind::Input { value } => {
                enc.values0[i] = value;
                enc.level[i] = 0;
            }
            NodeKind::Operation { op, src } => {
                enc.src0[i] = src[0] as i32;
                enc.src1[i] = src[1] as i32;
                enc.opcode[i] = op.code() as i32;
                enc.level[i] = levels[i] as i32;
            }
        }
    }
    enc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;

    #[test]
    fn encode_diamond() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(3.0);
        let b = g.add_input(4.0);
        let s = g.op(Op::Add, &[a, b]);
        let p = g.op(Op::Mul, &[a, b]);
        g.op(Op::Sub, &[s, p]);
        let e = encode_graph(&g);
        assert_eq!(e.values0[..2], [3.0, 4.0]);
        assert_eq!(e.level, vec![0, 0, 1, 1, 2]);
        assert_eq!(e.opcode[2], Op::Add.code() as i32);
        assert_eq!(e.src0[4], 2);
        assert_eq!(e.src1[4], 3);
        assert_eq!(e.depth, 2);
    }

    #[test]
    fn encode_inputs_self_gather() {
        let mut g = DataflowGraph::new();
        g.add_input(1.0);
        g.add_input(2.0);
        let e = encode_graph(&g);
        assert_eq!(e.src0, vec![0, 1]);
        assert_eq!(e.opcode, vec![7, 7]);
        assert_eq!(e.depth, 0);
    }
}
