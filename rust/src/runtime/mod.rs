//! PJRT runtime bridge: load the AOT-compiled HLO-text artifacts emitted
//! by `python/compile/aot.py` and execute them from rust. This is the
//! only place the overlay touches XLA; python never runs at request time.
//!
//! The bridge itself is feature-gated: with `--features xla` (plus the
//! `xla` crate and the xla_extension toolchain, see Cargo.toml) the real
//! [`XlaRuntime`] in `pjrt.rs` is compiled; by default the stub in
//! `pjrt_stub.rs` is, whose `load()` fails so every oracle consumer takes
//! its artifacts-absent skip path. The manifest parsing and the graph
//! encoding below are pure rust and always available.

mod manifest;

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(not(feature = "xla"))]
mod pjrt_stub;

pub use manifest::{ArtifactInfo, Manifest};

#[cfg(feature = "xla")]
pub use pjrt::XlaRuntime;
#[cfg(not(feature = "xla"))]
pub use pjrt_stub::XlaRuntime;

use crate::criticality;
use crate::graph::{DataflowGraph, NodeKind};

/// The L2 artifact's padded-array graph encoding.
pub struct EncodedGraph {
    pub values0: Vec<f32>,
    pub src0: Vec<i32>,
    pub src1: Vec<i32>,
    pub opcode: Vec<i32>,
    pub level: Vec<i32>,
    pub depth: u32,
}

/// Encode a graph into the levelized arrays `graph_eval` consumes.
pub fn encode_graph(g: &DataflowGraph) -> EncodedGraph {
    let levels = criticality::asap(g);
    let depth = levels.iter().copied().max().unwrap_or(0);
    let n = g.len();
    let mut enc = EncodedGraph {
        values0: vec![0f32; n],
        src0: (0..n as i32).collect(),
        src1: (0..n as i32).collect(),
        opcode: vec![7; n], // COPY
        level: vec![0; n],
        depth,
    };
    for (i, node) in g.nodes().iter().enumerate() {
        match node.kind {
            NodeKind::Input { value } => {
                enc.values0[i] = value;
                enc.level[i] = 0;
            }
            NodeKind::Operation { op, src } => {
                enc.src0[i] = src[0] as i32;
                enc.src1[i] = src[1] as i32;
                enc.opcode[i] = op.code() as i32;
                enc.level[i] = levels[i] as i32;
            }
        }
    }
    enc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;

    #[test]
    fn encode_diamond() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(3.0);
        let b = g.add_input(4.0);
        let s = g.op(Op::Add, &[a, b]);
        let p = g.op(Op::Mul, &[a, b]);
        g.op(Op::Sub, &[s, p]);
        let e = encode_graph(&g);
        assert_eq!(e.values0[..2], [3.0, 4.0]);
        assert_eq!(e.level, vec![0, 0, 1, 1, 2]);
        assert_eq!(e.opcode[2], Op::Add.code() as i32);
        assert_eq!(e.src0[4], 2);
        assert_eq!(e.src1[4], 3);
        assert_eq!(e.depth, 2);
    }

    #[test]
    fn encode_inputs_self_gather() {
        let mut g = DataflowGraph::new();
        g.add_input(1.0);
        g.add_input(2.0);
        let e = encode_graph(&g);
        assert_eq!(e.src0, vec![0, 1]);
        assert_eq!(e.opcode, vec![7, 7]);
        assert_eq!(e.depth, 0);
    }
}
