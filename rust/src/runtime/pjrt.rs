//! The real PJRT bridge, compiled only with `--features xla` (requires
//! the `xla` crate — xla-rs over xla_extension 0.5.1 — added under
//! `[dependencies]`, plus the xla_extension toolchain; see Cargo.toml).
//!
//! Loading pattern (see /opt/xla-example/load_hlo.rs): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Text is the interchange format
//! because xla_extension 0.5.1 rejects jax≥0.5 serialized protos
//! (64-bit instruction ids).

use super::{encode_graph, Manifest};
use crate::graph::DataflowGraph;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Compiled executables for every artifact in the manifest.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    alu: xla::PjRtLoadedExecutable,
    lod: xla::PjRtLoadedExecutable,
    graph_eval: xla::PjRtLoadedExecutable,
}

impl XlaRuntime {
    /// Load and compile all artifacts from `dir` (usually `artifacts/`).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .context("reading artifacts/manifest.json (run `make artifacts`)")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
        };
        let alu = compile(&manifest.artifacts.alu_batch.file)?;
        let lod = compile(&manifest.artifacts.lod.file)?;
        let graph_eval = compile(&manifest.artifacts.graph_eval.file)?;
        Ok(Self {
            client,
            manifest,
            alu,
            lod,
            graph_eval,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute the L1 Pallas ALU kernel on a batch of node operations.
    /// Inputs shorter than the artifact batch are padded (with op=COPY on
    /// zeroes); the result is truncated back to the input length.
    pub fn alu_batch(&self, a: &[f32], b: &[f32], op: &[u32]) -> Result<Vec<f32>> {
        let batch = self.manifest.artifacts.alu_batch.batch.unwrap_or(0);
        anyhow::ensure!(a.len() == b.len() && a.len() == op.len(), "length mismatch");
        anyhow::ensure!(a.len() <= batch, "batch {} exceeds artifact size {batch}", a.len());
        let mut pa = a.to_vec();
        let mut pb = b.to_vec();
        let mut pop: Vec<i32> = op.iter().map(|&o| o as i32).collect();
        pa.resize(batch, 0.0);
        pb.resize(batch, 0.0);
        pop.resize(batch, 7); // COPY
        let la = xla::Literal::vec1(&pa);
        let lb = xla::Literal::vec1(&pb);
        let lop = xla::Literal::vec1(&pop);
        let out = self
            .alu
            .execute::<xla::Literal>(&[la, lb, lop])
            .map_err(|e| anyhow!("alu execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("alu fetch: {e}"))?;
        let tuple = out.to_tuple1().map_err(|e| anyhow!("alu tuple: {e}"))?;
        let mut v = tuple.to_vec::<f32>().map_err(|e| anyhow!("alu to_vec: {e}"))?;
        v.truncate(a.len());
        Ok(v)
    }

    /// Execute the L1 hierarchical LOD kernel over packed flag words.
    /// Returns the leading node id, or `crate::lod::NO_READY` if none.
    pub fn lod_pick(&self, words: &[u32]) -> Result<u32> {
        let n = self.manifest.artifacts.lod.words.unwrap_or(0);
        anyhow::ensure!(words.len() <= n, "{} words exceeds artifact size {n}", words.len());
        let mut pw: Vec<i32> = words.iter().map(|&w| w as i32).collect();
        pw.resize(n, 0);
        let lw = xla::Literal::vec1(&pw);
        let out = self
            .lod
            .execute::<xla::Literal>(&[lw])
            .map_err(|e| anyhow!("lod execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("lod fetch: {e}"))?;
        let tuple = out.to_tuple1().map_err(|e| anyhow!("lod tuple: {e}"))?;
        let v = tuple.to_vec::<i32>().map_err(|e| anyhow!("lod to_vec: {e}"))?;
        Ok(v[0] as u32)
    }

    /// Evaluate a whole dataflow graph through the L2 `graph_eval`
    /// artifact (levelized gather → Pallas ALU → masked writeback).
    ///
    /// Errors if the graph exceeds the artifact's padded geometry
    /// (`n` slots / `lmax` levels) — callers fall back to
    /// [`DataflowGraph::evaluate`] for larger graphs.
    pub fn graph_eval(&self, g: &DataflowGraph) -> Result<Vec<f32>> {
        let enc = encode_graph(g);
        let n = self.manifest.artifacts.graph_eval.n.unwrap_or(0);
        let lmax = self.manifest.artifacts.graph_eval.lmax.unwrap_or(0) as u32;
        anyhow::ensure!(
            g.len() <= n,
            "graph has {} nodes, artifact padded to {n}",
            g.len()
        );
        anyhow::ensure!(
            enc.depth <= lmax,
            "graph depth {} exceeds artifact lmax {lmax}",
            enc.depth
        );
        let pad = |mut v: Vec<i32>, fill: i32| -> Vec<i32> {
            v.resize(n, fill);
            v
        };
        let mut vals = enc.values0;
        vals.resize(n, 0.0);
        // padding slots: self-gather, COPY, level -1 (never fires)
        let mut src0 = enc.src0;
        let mut src1 = enc.src1;
        for k in g.len()..n {
            src0.push(k as i32);
            src1.push(k as i32);
        }
        let lv = xla::Literal::vec1(&vals);
        let ls0 = xla::Literal::vec1(&src0);
        let ls1 = xla::Literal::vec1(&src1);
        let lop = xla::Literal::vec1(&pad(enc.opcode, 7));
        let llv = xla::Literal::vec1(&pad(enc.level, -1));
        let out = self
            .graph_eval
            .execute::<xla::Literal>(&[lv, ls0, ls1, lop, llv])
            .map_err(|e| anyhow!("graph_eval execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("graph_eval fetch: {e}"))?;
        let tuple = out.to_tuple1().map_err(|e| anyhow!("graph_eval tuple: {e}"))?;
        let mut v = tuple
            .to_vec::<f32>()
            .map_err(|e| anyhow!("graph_eval to_vec: {e}"))?;
        v.truncate(g.len());
        Ok(v)
    }
}
